"""Core-maintenance benchmarks mirroring the paper's figures/tables.

Paper measured wall-clock vs #workers on a 64-core CPU. This container
has 1 CPU core, so "parallelism" is expressed as the batch width processed
per bulk-synchronous round (the TPU analogue of worker count): width=1
degenerates to sequential-equivalent work; width=B processes the whole
batch in O(rounds) data-parallel sweeps. We report, per paper artifact:

  fig4  — accumulated edit time vs batch width (OurI/OurR = JAX
          Parallel-Order) + sequential baselines OI/OR (Simplified-Order
          oracle) and TI/TR (Traversal oracle).
  tab2  — speedup table (batch JAX vs OI/OR and TI/TR).
  fig5  — |V+| size distribution (locked-set sizes).
  fig6  — scalability: time ratio vs number of edited edges.
  fig7  — stability: variance across disjoint edge batches.
Extra (beyond paper): promotion/drop round counts — the bulk-synchronous
depth of each batch.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.analysis.benchcheck import BENCH_SCHEMA
from repro.core.api import CoreMaintainer
from repro.core.oracle import OrderCoreMaintainer, TraversalCoreMaintainer
from repro.graph.csr import build_csr
from repro.graph.generators import erdos_renyi
from repro.graph.stream import mixed_stream

from .workloads import (
    churn_workload,
    paper_graphs,
    sample_insertions,
    sample_removals,
    temporal_workload,
)

Row = Dict[str, object]


def _fresh_jax(g, cap_mult=4):
    return CoreMaintainer.from_graph(
        g, capacity=max(64, cap_mult * g.edge_array().shape[0])
    )


def _run_jax_batched(m: CoreMaintainer, edges: np.ndarray, width: int,
                     kind: str) -> float:
    t0 = time.perf_counter()
    for i in range(0, len(edges), width):
        chunk = edges[i : i + width]
        if kind == "insert":
            m.insert_edges(chunk)
        else:
            m.remove_edges(chunk)
    # block on device
    m.core.block_until_ready()
    return time.perf_counter() - t0


def _run_oracle(m, edges: np.ndarray, kind: str) -> float:
    t0 = time.perf_counter()
    if kind == "insert":
        m.insert_batch(edges)
    else:
        m.remove_batch(edges)
    return time.perf_counter() - t0


def fig4_runtime(n_edges: int = 512, widths=(1, 32, 512)) -> List[Row]:
    rows: List[Row] = []
    for gname, g in paper_graphs(scale=0.5).items():
        removals = sample_removals(g, n_edges, seed=7)
        insertions = sample_insertions(g, n_edges, seed=7)
        for width in widths:
            mj = _fresh_jax(g)
            # warm the jit caches with a throwaway batch
            mj.insert_edges(sample_insertions(g, min(width, 64), seed=99))
            t_rm = _run_jax_batched(mj, removals, width, "remove")
            t_in = _run_jax_batched(mj, insertions, width, "insert")
            rows.append({"bench": "fig4", "graph": gname, "algo": "OurR",
                         "width": width, "seconds": t_rm})
            rows.append({"bench": "fig4", "graph": gname, "algo": "OurI",
                         "width": width, "seconds": t_in})
        for name, cls in (("O", OrderCoreMaintainer),
                          ("T", TraversalCoreMaintainer)):
            m = cls(g.n, g.edge_array())
            t_rm = _run_oracle(m, removals, "remove")
            t_in = _run_oracle(m, insertions, "insert")
            rows.append({"bench": "fig4", "graph": gname, "algo": f"{name}R",
                         "width": 1, "seconds": t_rm})
            rows.append({"bench": "fig4", "graph": gname, "algo": f"{name}I",
                         "width": 1, "seconds": t_in})
    return rows


def tab2_speedups(fig4_rows: List[Row]) -> List[Row]:
    rows = []
    by = {}
    for r in fig4_rows:
        by[(r["graph"], r["algo"], r["width"])] = r["seconds"]
    for gname in {r["graph"] for r in fig4_rows}:
        wmax = max(r["width"] for r in fig4_rows if r["algo"] == "OurI"
                   and r["graph"] == gname)
        for op in ("I", "R"):
            ours = by[(gname, f"Our{op}", wmax)]
            ours_w1 = by[(gname, f"Our{op}", 1)]
            rows.append({
                "bench": "tab2", "graph": gname, "op": op,
                "batch_vs_width1": ours_w1 / ours,
                "vs_order_seq": by[(gname, f"O{op}", 1)] / ours,
                "vs_traversal_seq": by[(gname, f"T{op}", 1)] / ours,
            })
    return rows


def fig5_vplus(n_edges: int = 400) -> List[Row]:
    rows = []
    for gname, g in paper_graphs(scale=0.25).items():
        m = OrderCoreMaintainer(g.n, g.edge_array())
        ins = sample_insertions(g, n_edges, seed=3)
        sizes_i = []
        for u, v in ins:
            m.insert_edge(int(u), int(v))
            sizes_i.append(m.last_v_plus)
        sizes_r = []
        for u, v in ins[::-1]:
            m.remove_edge(int(u), int(v))
            sizes_r.append(m.last_v_plus)
        for op, sizes in (("insert", sizes_i), ("remove", sizes_r)):
            arr = np.asarray(sizes)
            rows.append({
                "bench": "fig5", "graph": gname, "op": op,
                "frac_le_10": float(np.mean(arr <= 10)),
                "median": float(np.median(arr)),
                "p99": float(np.percentile(arr, 99)),
                "max": int(arr.max()),
            })
    return rows


def fig6_scalability(sizes=(128, 256, 512, 1024)) -> List[Row]:
    rows = []
    for gname, g in paper_graphs(scale=0.5).items():
        base = None
        for k in sizes:
            mj = _fresh_jax(g)
            mj.insert_edges(sample_insertions(g, 64, seed=99))  # warm jit
            ins = sample_insertions(g, k, seed=11)
            t = _run_jax_batched(mj, ins, k, "insert")
            base = t if base is None else base
            rows.append({
                "bench": "fig6", "graph": gname, "edges": k,
                "seconds": t, "ratio_vs_smallest": t / base,
            })
    return rows


def fig7_stability(n_batches: int = 8, batch: int = 128) -> List[Row]:
    rows = []
    for gname, g in paper_graphs(scale=0.25).items():
        mj = _fresh_jax(g, cap_mult=6)
        mj.insert_edges(sample_insertions(g, 64, seed=99))  # warm jit
        times = []
        for i in range(n_batches):
            ins = sample_insertions(g, batch, seed=100 + i)
            t0 = time.perf_counter()
            mj.insert_edges(ins)
            mj.core.block_until_ready()
            times.append(time.perf_counter() - t0)
        arr = np.asarray(times)
        rows.append({
            "bench": "fig7", "graph": gname, "mean_s": float(arr.mean()),
            "std_s": float(arr.std()), "cv": float(arr.std() / arr.mean()),
        })
    return rows


STREAM_ENGINES = ("host", "unified", "sharded", "vertex_sharded",
                  "frontier_sparse", "vertex_halo", "pallas", "weighted")

# engine NAME -> CoreMaintainer kwargs (the bench rows are engine
# configurations, not just engine strings, since PR 4's vertex layouts)
ENGINE_SPECS: Dict[str, Dict[str, object]] = {
    "host": {"engine": "host"},
    "unified": {"engine": "unified"},
    "sharded": {"engine": "sharded"},
    "vertex_sharded": {"engine": "sharded", "vertex_sharding": "range"},
    "frontier_sparse": {"engine": "sharded", "vertex_sharding": "range",
                        "frontier_exchange": "sparse"},
    # the 2-axis halo working set (degenerate (1, d) mesh on the bench
    # host; the mesh_scaling sweep times the proper factorizations)
    "vertex_halo": {"engine": "sharded", "vertex_sharding": "halo"},
    "pallas": {"engine": "unified", "kernel_backend": "pallas"},
    # the weighted h-index engine with every weight 1: weighted coreness
    # degenerates to plain coreness, so this row rides the SAME stream
    # and participates in engines_agree — the cross-check that the
    # weighted fixpoint path computes the same cores the order-based
    # path does, while its timing prices the bisection stat pass
    "weighted": {"engine": "unified", "weighted": True},
}


def round_launch_counts(n: int, cap: int) -> Dict[str, object]:
    """Static per-round kernel-launch histograms, lax vs pallas.

    Traces (never runs) the removal and promotion round bodies with both
    kernel backends and counts launch-class primitives via the jaxpr
    walker — the same counter the committed budget manifests pin
    (``repro.analysis.walker.count_round_launches``). On CPU the timed
    pallas rows run in interpret mode, so wall-clock does NOT show the
    launch win; this section records the claim the fusion actually
    makes: strictly fewer dispatches per fixpoint round on a real
    accelerator backend. ``total`` sums both rounds per backend.
    """
    import jax

    from repro.analysis.programs import (
        EDGE_AXIS,
        trace_promotion_round,
        trace_removal_round,
    )
    from repro.analysis.walker import count_round_launches

    mesh = jax.make_mesh((1,), (EDGE_AXIS,))
    out: Dict[str, object] = {}
    for backend in ("lax", "pallas"):
        rounds: Dict[str, object] = {}
        for rname, tracer in (("removal", trace_removal_round),
                              ("promotion", trace_promotion_round)):
            _, closed = tracer("replicated", n, cap, mesh,
                               kernel_backend=backend)
            rounds[rname] = count_round_launches(closed)
        rounds["total"] = sum(
            c
            for rname in ("removal", "promotion")
            for c in rounds[rname].values()  # type: ignore[union-attr]
        )
        out[backend] = rounds
    return out


TEMPORAL_ENGINES = ("host", "unified", "sharded", "weighted")


def temporal_bench(
    n: int = 1500,
    arrivals: int = 3000,
    horizon: int = 30,
    window: int = 6,
    stride: int = 3,
    engines: Sequence[str] = TEMPORAL_ENGINES,
) -> Dict[str, object]:
    """Sliding-window expiry stream (``workloads.temporal_workload``):
    every engine replays the SAME drained event sequence from an empty
    graph — each step bulk-removes the edges older than ``window`` and
    inserts the new stride's arrivals, so removals are structural
    (expiry by age) rather than sampled. Two replays per engine: an
    untimed one to populate the jit caches (batch widths vary per step,
    but the pow2 lane buckets collapse them to a handful of programs),
    then a timed one on a fresh maintainer. Because the stream drains,
    total insertions == total removals and every engine must end with
    all-zero cores — both recorded for the coherence gate alongside the
    cross-engine finals comparison."""
    n, _, events, max_live = temporal_workload(
        n=n, arrivals=arrivals, horizon=horizon, window=window,
        stride=stride,
    )
    capacity = max(256, 4 * max_live)
    empty = build_csr(n, np.zeros((0, 2), dtype=np.int64))
    total_ins = int(sum(len(ev.edges) for ev in events))
    total_rm = int(sum(len(ev.removals) for ev in events))
    per_engine: Dict[str, Dict[str, float]] = {}
    finals = {}
    for engine in engines:

        def replay():
            mt = CoreMaintainer.from_graph(empty, capacity=capacity,
                                           **ENGINE_SPECS[engine])
            for ev in events:
                if engine == "host":  # seed path: one program per kind
                    mt.remove_edges(ev.removals)
                    mt.insert_edges(ev.edges)
                else:
                    mt.apply_batch(insert_edges=ev.edges,
                                   remove_edges=ev.removals)
            mt.core.block_until_ready()
            return mt

        replay()  # warm replay — the timed pass hits the jit caches
        t0 = time.perf_counter()
        mt = replay()
        dt = time.perf_counter() - t0
        per_engine[engine] = {
            "seconds": dt,
            "batches_per_s": len(events) / dt,
            "edges_per_s": (total_ins + total_rm) / dt,
        }
        finals[engine] = mt.cores()
    agree = all(
        bool((finals[e] == finals[engines[0]]).all()) for e in engines
    )
    zero = all(bool((finals[e] == 0).all()) for e in engines)
    result: Dict[str, object] = {
        "window": window,
        "stride": stride,
        "arrivals": arrivals,
        "horizon": horizon,
        "n_events": len(events),
        "max_live": max_live,
        "capacity": capacity,
        "total_insertions": total_ins,
        "total_removals": total_rm,
        "drained": bool(total_ins == total_rm),
        "engines_agree": agree,
        "final_cores_zero": zero,
    }
    result.update(per_engine)
    return result


def stream_bench(
    n: int = 1500,
    m: int = 6000,
    n_batches: int = 30,
    batch_size: int = 128,
    warmup: int = 3,
    out_json: str = "BENCH_stream.json",
    engines: Sequence[str] = STREAM_ENGINES,
    scaling_device_counts: Sequence[int] = (),
    vertex_scaling_device_counts: Sequence[int] = (),
    frontier_scaling_device_counts: Sequence[int] = (),
    mesh_scaling_shapes: Sequence = (),
    temporal_arrivals: int = 3000,
    temporal_window: int = 6,
    temporal_stride: int = 3,
) -> Dict[str, object]:
    """Mixed insert+remove stream on the SAME events: the unified one-call
    engine (with both the lax and the fused-pallas kernel backends), the
    mesh-sharded engine (replicated AND range-sharded vertex state,
    bitmask AND sparse frontier exchange), the weighted h-index engine
    (unit weights — weighted coreness degenerates to plain coreness, so
    the row joins ``engines_agree`` while its timing prices the
    bisection stat pass) vs the seed two-call path (host-dict dedup +
    separate insert/remove programs). Reports batches/sec per engine, a
    static lax-vs-pallas per-round launch-count section
    (``launches_per_round``), a sliding-window expiry section
    (``temporal`` — see ``temporal_bench``), and writes
    ``out_json``. With
    ``scaling_device_counts`` / ``vertex_scaling_device_counts`` /
    ``frontier_scaling_device_counts`` the sharded / vertex-sharded /
    sparse-frontier engine is re-timed in subprocesses with that many
    forced host devices (the paper's time-vs-workers scaling axis;
    ``sharded_device_scaling``) — recorded as ``sharded_scaling`` /
    ``vertex_scaling`` / ``frontier_scaling`` rows with their
    ``n_devices``.

    Note on jit-cache hygiene: the unified engine's ``active_cap`` is a
    static pow2 bucket of the slot high-water mark. With the defaults
    here (m=6000, ~64 inserts/batch, 33 batches) the whole stream stays
    inside the 8192 bucket, so no recompile lands in the timed region;
    if you change the parameters, keep ``m + n_batches * batch_size/2``
    under the next power of two past ``m`` (or discount the first timed
    batch after a bucket crossing). The sharded engine always runs full
    capacity passes, so it never recompiles mid-stream.
    """
    from repro.core.api import plan_frontier_cap
    from repro.kernels.coremaint import default_interpret

    g = erdos_renyi(n, m, seed=12)
    # one extra untimed batch beyond warmup: see the post-harvest step
    # in the engine loop below
    events = list(
        mixed_stream(g, n_batches + warmup + 1, batch_size, seed=17)
    )
    per_engine: Dict[str, Dict[str, float]] = {}
    finals = {}
    overflow_per_batch: Dict[str, List[int]] = {}
    for engine in engines:
        mt = CoreMaintainer.from_graph(g, capacity=4 * m,
                                       **ENGINE_SPECS[engine])

        def step(ev):
            if engine == "host":  # seed path: one program per edit kind
                rm_st = mt.remove_edges(ev.removals)
                in_st = mt.insert_edges(ev.edges)
                return (rm_st, in_st)
            st = mt.apply_batch(insert_edges=ev.edges,
                                remove_edges=ev.removals)
            return (st,)

        # per-batch stats (device scalars — appending is free; the int()
        # reads happen after the timed region). max_frontier is the datum
        # the sparse frontier_cap planner is tuned from (§4.3), and
        # n_overflow counts the rounds that fell back dense — the warmup
        # batches are kept too, as the planner's blind "before" phase.
        all_stats = []
        for ev in events[:warmup]:  # compile both programs
            all_stats.extend(step(ev))
        mt.core.block_until_ready()
        # one more untimed batch AFTER the sync: the warmup stats are now
        # ready, so the adaptive planners (the sparse frontier cap tuned
        # from observed max_frontier) pick their steady-state bucket here
        # and its compile stays out of the timed region, exactly like the
        # warmup compiles
        all_stats.extend(step(events[warmup]))
        mt.core.block_until_ready()
        t0 = time.perf_counter()
        for ev in events[warmup + 1:]:
            all_stats.extend(step(ev))
        mt.core.block_until_ready()
        dt = time.perf_counter() - t0
        per_engine[engine] = {
            "seconds": dt,
            "batches_per_s": n_batches / dt,
            "edges_per_s": n_batches * batch_size / dt,
            "max_frontier": max(int(s.max_frontier) for s in all_stats),
        }
        # the host path's per-kind stats carry no overflow counter (no
        # halo exchange there) — treat those as zero
        overflow_per_batch[engine] = [
            int(getattr(s, "n_overflow", 0)) for s in all_stats
        ]
        if ENGINE_SPECS[engine].get("kernel_backend") == "pallas":
            # off-TPU the fused kernels run in pallas interpret mode, so
            # this wall-clock row measures the interpreter, not the
            # fusion: stamp it explicitly so the coherence gate can keep
            # the launch-count claim while ignoring the timing
            per_engine[engine]["interpret_mode"] = bool(default_interpret())
        finals[engine] = mt.cores()
    agree = all(
        bool((finals[e] == finals[engines[0]]).all()) for e in engines
    )
    result = {
        # the coherence gate (repro.analysis.benchcheck) refuses
        # artifacts that predate its expected schema stamp
        "schema": BENCH_SCHEMA,
        "graph": {"n": n, "m": m},
        "n_batches": n_batches,
        "batch_size": batch_size,
        "engines_agree": agree,
    }
    result.update(per_engine)
    if "host" in per_engine:
        for engine in engines:
            if engine != "host":
                result[f"speedup_{engine}_vs_host"] = (
                    per_engine["host"]["seconds"]
                    / per_engine[engine]["seconds"]
                )
    # static launch-count roofline term: per-round dispatch histograms
    # for both kernel backends (trace-only — cheap even when the timed
    # sweep above was). The coherence gate requires the pallas rounds to
    # launch strictly fewer kernels than lax.
    result["launches_per_round"] = round_launch_counts(n, 4 * m)
    # sliding-window expiry: structural removals by age over a temporal
    # (u, v, t) stream that drains to an empty graph — the coherence
    # gate requires the drain invariant (insertions == removals,
    # all-zero final cores) on top of the cross-engine agreement
    result["temporal"] = temporal_bench(
        n=n, arrivals=temporal_arrivals, window=temporal_window,
        stride=temporal_stride,
    )
    # the frontier_cap=0 auto-planner before/after: the blind pow2 cap
    # undershoots this stream's removal cascades (max_frontier ~2x the
    # batch multiple), so the early batches pay the dense overflow
    # fallback until the running p95 of the harvested max_frontier
    # grows the cap — the second half of the stream must overflow less
    if "frontier_sparse" in per_engine:
        ovf = overflow_per_batch["frontier_sparse"]
        half = len(ovf) // 2
        observed = per_engine["frontier_sparse"]["max_frontier"]
        result["frontier_autoplan"] = {
            "engine": "frontier_sparse",
            "frontier_cap": 0,  # 0 = auto-planned from observed stats
            "blind_cap": plan_frontier_cap("sparse", 0, batch_size, n),
            "tuned_cap": plan_frontier_cap("sparse", 0, batch_size, n,
                                           observed=observed),
            "overflow_rounds_before": sum(ovf[:half]),
            "overflow_rounds_after": sum(ovf[half:]),
            "overflow_rounds_per_batch": ovf,
        }
    # write the artifact BEFORE the scaling subprocesses and BEFORE
    # asserting: on a divergence or a failed/timed-out scaling run the
    # JSON (with engines_agree and all per-engine timings) survives as
    # the debugging evidence
    def _write():
        if out_json:
            with open(out_json, "w") as fh:
                json.dump(result, fh, indent=2)

    _write()
    if scaling_device_counts:
        result["sharded_scaling"] = sharded_device_scaling(
            scaling_device_counts, n=n, m=m,
            n_batches=min(n_batches, 10), batch_size=batch_size,
        )
        _write()
    if vertex_scaling_device_counts:
        result["vertex_scaling"] = sharded_device_scaling(
            vertex_scaling_device_counts, n=n, m=m,
            n_batches=min(n_batches, 10), batch_size=batch_size,
            vertex_sharding="range",
        )
        _write()
    if frontier_scaling_device_counts:
        result["frontier_scaling"] = sharded_device_scaling(
            frontier_scaling_device_counts, n=n, m=m,
            n_batches=min(n_batches, 10), batch_size=batch_size,
            vertex_sharding="range", frontier_exchange="sparse",
        )
        _write()
    if mesh_scaling_shapes:
        result["mesh_scaling"] = halo_mesh_scaling(
            mesh_scaling_shapes, n=n, m=m,
            n_batches=min(n_batches, 10), batch_size=batch_size,
        )
        _write()
    assert agree, "engines diverged on the same stream"
    tmp = result["temporal"]
    assert tmp["engines_agree"], "engines diverged on the temporal stream"
    assert tmp["drained"] and tmp["final_cores_zero"], (
        "sliding-window stream failed to drain"
    )
    return result


_SCALING_SCRIPT = """
import json, sys, time
import repro
import jax
from repro.core.api import CoreMaintainer
from repro.graph.generators import erdos_renyi
from repro.graph.stream import mixed_stream

n, m, n_batches, batch_size, warmup = map(int, sys.argv[1:6])
vertex_sharding = sys.argv[6]
frontier_exchange = sys.argv[7]
mesh_shape = None
if len(sys.argv) > 8 and sys.argv[8]:
    mesh_shape = tuple(int(t) for t in sys.argv[8].split("x"))
g = erdos_renyi(n, m, seed=12)
events = list(mixed_stream(g, n_batches + warmup, batch_size, seed=17))
kw = {} if mesh_shape is None else {"mesh_shape": mesh_shape}
mt = CoreMaintainer.from_graph(g, capacity=4 * m, engine="sharded",
                               vertex_sharding=vertex_sharding,
                               frontier_exchange=frontier_exchange, **kw)
for ev in events[:warmup]:
    mt.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
mt.core.block_until_ready()
t0 = time.perf_counter()
for ev in events[warmup:]:
    mt.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
mt.core.block_until_ready()
dt = time.perf_counter() - t0
row = {
    "n_devices": len(jax.devices()),
    "vertex_sharding": vertex_sharding,
    "frontier_exchange": frontier_exchange,
    "n_batches": n_batches,
    "seconds": dt,
    "batches_per_s": n_batches / dt,
}
if mesh_shape is not None:
    row["mesh_shape"] = list(mesh_shape)
print(json.dumps(row))
"""


def sharded_device_scaling(
    device_counts: Sequence[int] = (1, 2, 4),
    n: int = 1500,
    m: int = 6000,
    n_batches: int = 10,
    batch_size: int = 128,
    warmup: int = 3,
    vertex_sharding: str = "replicated",
    frontier_exchange: str = "bitmask",
) -> List[Dict[str, float]]:
    """Time the sharded engine (replicated or range-sharded vertex state,
    bitmask or sparse frontier exchange) under forced host device counts
    (one subprocess per count — XLA fixes the device count at init). On
    a single-core CPU container the host devices share one core, so this
    measures collective overhead rather than speedup; on real multi-core
    or multi-chip hardware the same harness reports the paper's
    time-vs-workers curve — the ``vertex_sharding="range"`` sweep is the
    one whose per-round vertex traffic stays O(n + frontier bits * d) as
    d grows (docs/DESIGN.md §4.2), and ``frontier_exchange="sparse"``
    shrinks the frontier term to O(cap * d) words (§4.3)."""
    src_path = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    rows: List[Dict[str, float]] = []
    for ndev in device_counts:
        env = dict(os.environ)
        # append, don't clobber: the child must run under the same XLA
        # settings as the parent's timings, plus the forced device count
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={ndev}"
        ).strip()
        env["PYTHONPATH"] = src_path + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-c", _SCALING_SCRIPT,
             str(n), str(m), str(n_batches), str(batch_size), str(warmup),
             vertex_sharding, frontier_exchange],
            capture_output=True,
            text=True,
            env=env,
            timeout=900,
        )
        if out.returncode != 0:
            raise RuntimeError(
                f"scaling run with {ndev} devices failed:\n"
                f"{out.stdout}\n{out.stderr}"
            )
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def halo_mesh_scaling(
    mesh_shapes: Sequence = ((1, 1), (2, 2), (4, 2), (2, 4)),
    n: int = 1500,
    m: int = 6000,
    n_batches: int = 10,
    batch_size: int = 128,
    warmup: int = 3,
) -> List[Dict[str, float]]:
    """Time the halo engine across 2-axis (edge x vertex) mesh
    factorizations of forced host devices (one subprocess per shape —
    d_e * d_v devices each). The same wall-clock caveat as
    ``sharded_device_scaling`` applies on this 1-core container; what
    the sweep pins everywhere is the SHAPE axis the flat engines don't
    have: at fixed device count, trading edge lanes (d_e) against
    vertex owners (d_v) moves per-device memory O(n/d_v + halo) and the
    halo exchange O(d_v * hcap) in opposite directions
    (docs/DESIGN.md §4.4)."""
    src_path = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    rows: List[Dict[str, float]] = []
    for d_e, d_v in mesh_shapes:
        ndev = d_e * d_v
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={ndev}"
        ).strip()
        env["PYTHONPATH"] = src_path + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-c", _SCALING_SCRIPT,
             str(n), str(m), str(n_batches), str(batch_size), str(warmup),
             "halo", "bitmask", f"{d_e}x{d_v}"],
            capture_output=True,
            text=True,
            env=env,
            timeout=900,
        )
        if out.returncode != 0:
            raise RuntimeError(
                f"mesh scaling run {d_e}x{d_v} failed:\n"
                f"{out.stdout}\n{out.stderr}"
            )
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


CHURN_ENGINES = ("host", "unified", "sharded")


def churn_bench(
    n: int = 1500,
    m: int = 6000,
    n_batches: int = 30,
    batch_size: int = 128,
    warmup: int = 3,
    capacity_mult: float = 1.2,
    out_json: str = "BENCH_stream.json",
    engines: Sequence[str] = CHURN_ENGINES,
) -> Dict[str, object]:
    """Steady-state churn throughput: in-program slot recycling ON (the
    device engines' free-list allocator) vs OFF (the host engine, whose
    tombstones are only reclaimed by host-side ``_compact``) on the SAME
    balanced 50/50 stream over a deliberately tight table
    (``capacity_mult * m``): the host path is forced through periodic
    compaction syncs while the device engines absorb every batch
    in-program. Reports batches/sec, reclaimed slots, defrag counts and
    final capacity per engine, and merges a ``churn`` section into
    ``out_json`` (alongside ``stream_bench``'s sections).
    """
    g, events = churn_workload(n, m, n_batches + warmup, batch_size)
    capacity = int(capacity_mult * g.m) + 64
    per_engine: Dict[str, Dict[str, float]] = {}
    finals = {}
    orig_defrag = CoreMaintainer._defrag_to
    for engine in engines:
        mt = CoreMaintainer.from_graph(g, capacity=capacity,
                                       **ENGINE_SPECS[engine])
        defrags = [0]

        def counting(self, new_cap, _d=defrags):
            _d[0] += 1
            return orig_defrag(self, new_cap)

        stats = []
        try:
            CoreMaintainer._defrag_to = counting
            for ev in events[:warmup]:
                mt.apply_batch(insert_edges=ev.edges,
                               remove_edges=ev.removals)
            mt.core.block_until_ready()
            defrags[0] = 0
            cap0 = mt.capacity
            t0 = time.perf_counter()
            for ev in events[warmup:]:
                # stats are device scalars — collecting them is free; the
                # int() reads happen after the timed region
                stats.append(
                    mt.apply_batch(insert_edges=ev.edges,
                                   remove_edges=ev.removals)
                )
            mt.core.block_until_ready()
            dt = time.perf_counter() - t0
        finally:
            CoreMaintainer._defrag_to = orig_defrag
        per_engine[engine] = {
            "seconds": dt,
            "batches_per_s": n_batches / dt,
            "recycled_slots": int(sum(int(s.n_recycled) for s in stats)),
            "host_defrags": defrags[0],
            "capacity_start": cap0,
            "capacity_final": mt.capacity,
            "high_water_final": int(stats[-1].high_water),
        }
        finals[engine] = mt.cores()
    agree = all(
        bool((finals[e] == finals[engines[0]]).all()) for e in engines
    )
    result: Dict[str, object] = {
        "graph": {"n": n, "m": g.m},
        "n_batches": n_batches,
        "batch_size": batch_size,
        "capacity": capacity,
        "engines_agree": agree,
    }
    result.update(per_engine)
    if "host" in per_engine and "unified" in per_engine:
        result["speedup_unified_vs_host"] = (
            per_engine["host"]["seconds"]
            / per_engine["unified"]["seconds"]
        )
    if out_json:
        blob = {}
        if os.path.exists(out_json):
            with open(out_json) as fh:
                blob = json.load(fh)
        blob["churn"] = result
        with open(out_json, "w") as fh:
            json.dump(blob, fh, indent=2)
    assert agree, "engines diverged on the churn stream"
    return result


def rounds_depth(batch: int = 512) -> List[Row]:
    """Beyond-paper: bulk-synchronous depth (rounds) per batch."""
    rows = []
    for gname, g in paper_graphs(scale=0.5).items():
        mj = _fresh_jax(g)
        ins = sample_insertions(g, batch, seed=5)
        st = mj.insert_edges(ins)
        rows.append({
            "bench": "rounds", "graph": gname, "op": "insert",
            "rounds": int(st.rounds), "v_star": int(st.n_promoted),
            "v_plus": int(st.v_plus),
        })
        st = mj.remove_edges(ins)
        rows.append({
            "bench": "rounds", "graph": gname, "op": "remove",
            "rounds": int(st.rounds), "v_star": int(st.n_dropped),
            "v_plus": int(st.n_dropped),
        })
    return rows
