"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
artifact. Also computes the roofline fraction (useful compute time /
dominant term) used to pick hillclimb targets.

``--launches`` instead renders the per-round kernel-launch roofline of
the maintenance fixpoints (lax vs fused-pallas backends) from the
``launches_per_round`` section of BENCH_stream.json: for the
many-small-kernel lax rounds the dispatch-overhead floor
``launches * LAUNCH_OVERHEAD_S`` dominates the bandwidth terms at these
problem sizes, which is the term the fused kernels attack."""
from __future__ import annotations

import json
import sys


def fmt(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}u"
    if x < 1:
        return f"{x*1e3:.1f}m"
    return f"{x:.2f}s"


def render(path: str = "dryrun_results.json", mesh: str = "16x16"):
    try:
        with open(path) as f:
            cells = json.load(f)
    except FileNotFoundError:
        raise SystemExit(
            f"roofline artifact {path!r} not found — generate it with "
            "`PYTHONPATH=src python -m launch.dryrun` (or pass the path "
            "to an existing dryrun_results.json)"
        )
    cells = [c for c in cells if c["mesh"] == mesh]
    lines = []
    header = (
        "| arch | shape | t_compute | t_memory | t_coll | dominant | "
        "roofline frac | useful ratio | peak GB/dev |"
    )
    lines.append(header)
    lines.append("|" + "---|" * 9)
    rows = []
    for c in cells:
        rf = c["roofline"]
        tc, tm, tx = (
            rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"]
        )
        dom = max(tc, tm, tx)
        frac = (tc / dom) if dom > 0 else 0.0
        ur = c.get("model_vs_hlo")
        rows.append((c["arch"], c["shape"], tc, tm, tx,
                     rf["dominant"].replace("t_", "").replace("_s", ""),
                     frac, ur, c["mem"]["peak_bytes"] / 2**30))
    # sort on the explicit (arch, shape) key: tied rows must not fall
    # through to comparing a possibly-None model_vs_hlo column
    for r in sorted(rows, key=lambda r: (r[0], r[1])):
        lines.append(
            f"| {r[0]} | {r[1]} | {fmt(r[2])} | {fmt(r[3])} | {fmt(r[4])} "
            f"| {r[5]} | {r[6]:.2f} | "
            f"{('%.2f' % r[7]) if r[7] is not None else '-'} | {r[8]:.2f} |"
        )
    return "\n".join(lines)


# Per-launch dispatch overhead for the launch-count roofline term
# (t_launch = launches/round * LAUNCH_OVERHEAD_S). A few microseconds of
# host->accelerator dispatch latency per kernel is the standard planning
# number; it is a latency FLOOR per fixpoint round that pure bandwidth
# modelling misses when a round is a train of tiny gathers/scatters over
# a frontier of a handful of vertices.
LAUNCH_OVERHEAD_S = 5e-6


def render_launches(path: str = "BENCH_stream.json"):
    """Markdown launch-count table, lax vs pallas per fixpoint round."""
    try:
        with open(path) as f:
            blob = json.load(f)
    except FileNotFoundError:
        raise SystemExit(
            f"bench artifact {path!r} not found — generate it with "
            "`PYTHONPATH=src python -m benchmarks.run`"
        )
    lp = blob.get("launches_per_round")
    if not lp:
        raise SystemExit(
            f"{path!r} has no launches_per_round section — regenerate "
            "with a current `PYTHONPATH=src python -m benchmarks.run`"
        )
    lines = [
        "| round | backend | launches | t_launch floor | histogram |",
        "|" + "---|" * 5,
    ]
    for rnd in ("removal", "promotion"):
        for backend in ("lax", "pallas"):
            h = lp[backend][rnd]
            tot = sum(h.values())
            hist = ";".join(f"{k}={v}" for k, v in sorted(h.items()))
            lines.append(
                f"| {rnd} | {backend} | {tot} | "
                f"{fmt(tot * LAUNCH_OVERHEAD_S)} | {hist} |"
            )
        lax_t = sum(lp["lax"][rnd].values())
        pal_t = sum(lp["pallas"][rnd].values())
        lines.append(
            f"| {rnd} | fused saving | -{lax_t - pal_t} | "
            f"-{fmt((lax_t - pal_t) * LAUNCH_OVERHEAD_S)} | "
            f"{lax_t}->{pal_t} per round |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    argv = sys.argv[1:]
    if argv and argv[0] == "--launches":
        print(render_launches(*argv[1:]))
    else:
        print(render(*argv))
