"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
artifact. Also computes the roofline fraction (useful compute time /
dominant term) used to pick hillclimb targets."""
from __future__ import annotations

import json
import sys


def fmt(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}u"
    if x < 1:
        return f"{x*1e3:.1f}m"
    return f"{x:.2f}s"


def render(path: str = "dryrun_results.json", mesh: str = "16x16"):
    cells = [c for c in json.load(open(path)) if c["mesh"] == mesh]
    lines = []
    header = (
        "| arch | shape | t_compute | t_memory | t_coll | dominant | "
        "roofline frac | useful ratio | peak GB/dev |"
    )
    lines.append(header)
    lines.append("|" + "---|" * 9)
    rows = []
    for c in cells:
        rf = c["roofline"]
        tc, tm, tx = (
            rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"]
        )
        dom = max(tc, tm, tx)
        frac = (tc / dom) if dom > 0 else 0.0
        ur = c.get("model_vs_hlo")
        rows.append((c["arch"], c["shape"], tc, tm, tx,
                     rf["dominant"].replace("t_", "").replace("_s", ""),
                     frac, ur, c["mem"]["peak_bytes"] / 2**30))
    for r in sorted(rows):
        lines.append(
            f"| {r[0]} | {r[1]} | {fmt(r[2])} | {fmt(r[3])} | {fmt(r[4])} "
            f"| {r[5]} | {r[6]:.2f} | "
            f"{('%.2f' % r[7]) if r[7] else '-'} | {r[8]:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(render(*sys.argv[1:]))
