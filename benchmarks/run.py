"""Benchmark harness. One section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus a human-readable
summary on stderr). Scaled for this 1-core CPU container; the same
harness drives the real-hardware runs.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--roofline-json F]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--roofline-json", default="dryrun_results.json")
    ap.add_argument("--stream-json", default="BENCH_stream.json")
    args = ap.parse_args()
    if args.quick and args.stream_json == "BENCH_stream.json":
        # --quick skips the device-scaling sweeps; never let it clobber
        # the committed artifact (CI asserts the sweep rows are present)
        args.stream_json = "BENCH_stream.quick.json"

    from . import core_maintenance as cm

    n_edges = 128 if args.quick else 512
    widths = (1, 32, n_edges)

    print("name,us_per_call,derived")

    fig4 = cm.fig4_runtime(n_edges=n_edges, widths=widths)
    for r in fig4:
        _emit(
            f"fig4/{r['graph']}/{r['algo']}/w{r['width']}",
            1e6 * r["seconds"] / n_edges,
            f"total_s={r['seconds']:.4f}",
        )

    for r in cm.tab2_speedups(fig4):
        _emit(
            f"tab2/{r['graph']}/{r['op']}",
            0.0,
            (
                f"batch_vs_w1={r['batch_vs_width1']:.2f}x;"
                f"vs_OI={r['vs_order_seq']:.2f}x;"
                f"vs_TI={r['vs_traversal_seq']:.2f}x"
            ),
        )

    for r in cm.fig5_vplus(n_edges=100 if args.quick else 400):
        _emit(
            f"fig5/{r['graph']}/{r['op']}",
            0.0,
            (
                f"frac|V+|<=10={r['frac_le_10']:.3f};med={r['median']:.0f};"
                f"p99={r['p99']:.0f};max={r['max']}"
            ),
        )

    sizes = (64, 128) if args.quick else (128, 256, 512, 1024)
    for r in cm.fig6_scalability(sizes=sizes):
        _emit(
            f"fig6/{r['graph']}/e{r['edges']}",
            1e6 * r["seconds"] / r["edges"],
            f"ratio={r['ratio_vs_smallest']:.2f}",
        )

    for r in cm.fig7_stability(n_batches=4 if args.quick else 8):
        _emit(
            f"fig7/{r['graph']}",
            1e6 * r["mean_s"],
            f"cv={r['cv']:.3f}",
        )

    for r in cm.rounds_depth(batch=n_edges):
        _emit(
            f"rounds/{r['graph']}/{r['op']}",
            0.0,
            f"rounds={r['rounds']};V*={r['v_star']};V+={r['v_plus']}",
        )

    # mixed-stream engine comparison (writes the BENCH_stream.json artifact)
    sb = cm.stream_bench(
        n_batches=10 if args.quick else 30,
        batch_size=64 if args.quick else 128,
        out_json=args.stream_json,
        scaling_device_counts=() if args.quick else (1, 2, 4),
        vertex_scaling_device_counts=() if args.quick else (1, 2, 4),
        frontier_scaling_device_counts=() if args.quick else (1, 2, 4),
        # 2-axis halo factorizations: degenerate, square, and both
        # proper edge x vertex splits of 8 devices
        mesh_scaling_shapes=(
            () if args.quick else ((1, 1), (2, 2), (4, 2), (2, 4))
        ),
        temporal_arrivals=1000 if args.quick else 3000,
    )
    for eng in cm.STREAM_ENGINES:
        interp = (";interpret_mode=true"
                  if sb[eng].get("interpret_mode") else "")
        _emit(
            f"stream/{eng}",
            1e6 * sb[eng]["seconds"] / sb["n_batches"],
            f"batches_per_s={sb[eng]['batches_per_s']:.2f}{interp}",
        )
    _emit(
        "stream/speedup",
        0.0,
        f"unified_vs_host={sb['speedup_unified_vs_host']:.2f}x;"
        f"sharded_vs_host={sb['speedup_sharded_vs_host']:.2f}x;"
        f"vertex_sharded_vs_host="
        f"{sb['speedup_vertex_sharded_vs_host']:.2f}x;"
        f"frontier_sparse_vs_host="
        f"{sb['speedup_frontier_sparse_vs_host']:.2f}x;"
        f"vertex_halo_vs_host={sb['speedup_vertex_halo_vs_host']:.2f}x;"
        f"weighted_vs_host={sb['speedup_weighted_vs_host']:.2f}x;"
        f"agree={sb['engines_agree']}",
    )
    # sliding-window expiry: structural removals by age, drains to empty
    tb = sb["temporal"]
    for eng in cm.TEMPORAL_ENGINES:
        _emit(
            f"temporal/{eng}",
            1e6 * tb[eng]["seconds"] / tb["n_events"],
            f"batches_per_s={tb[eng]['batches_per_s']:.2f}",
        )
    _emit(
        "temporal/invariants",
        0.0,
        (
            f"window={tb['window']};stride={tb['stride']};"
            f"events={tb['n_events']};"
            f"ins={tb['total_insertions']};rm={tb['total_removals']};"
            f"drained={tb['drained']};zero={tb['final_cores_zero']};"
            f"agree={tb['engines_agree']}"
        ),
    )
    fa = sb.get("frontier_autoplan")
    if fa:
        _emit(
            "stream/frontier_autoplan",
            0.0,
            (
                f"cap={fa['blind_cap']}->{fa['tuned_cap']};"
                f"overflow_rounds={fa['overflow_rounds_before']}->"
                f"{fa['overflow_rounds_after']}"
            ),
        )
    # static per-round kernel-launch counts (the fusion claim the
    # coherence gate enforces: pallas strictly below lax per round)
    lp = sb["launches_per_round"]
    _emit(
        "stream/launches_per_round",
        0.0,
        (
            f"removal={sum(lp['lax']['removal'].values())}->"
            f"{sum(lp['pallas']['removal'].values())};"
            f"promotion={sum(lp['lax']['promotion'].values())}->"
            f"{sum(lp['pallas']['promotion'].values())};"
            f"total={lp['lax']['total']}->{lp['pallas']['total']}"
        ),
    )
    for key in ("sharded_scaling", "vertex_scaling", "frontier_scaling"):
        for row in sb.get(key, ()):
            _emit(
                f"stream/{key}/dev{row['n_devices']}",
                1e6 * row["seconds"] / row["n_batches"],
                f"batches_per_s={row['batches_per_s']:.2f}",
            )
    for row in sb.get("mesh_scaling", ()):
        de, dv = row["mesh_shape"]
        _emit(
            f"stream/mesh_scaling/{de}x{dv}",
            1e6 * row["seconds"] / row["n_batches"],
            f"batches_per_s={row['batches_per_s']:.2f}",
        )

    # steady-state churn on a tight table: in-program slot recycling
    # (device engines) vs host-side _compact reclaim (appends the
    # "churn" section to the BENCH_stream.json artifact)
    cb = cm.churn_bench(
        n_batches=10 if args.quick else 30,
        batch_size=64 if args.quick else 128,
        out_json=args.stream_json,
    )
    for eng in cm.CHURN_ENGINES:
        r = cb[eng]
        _emit(
            f"churn/{eng}",
            1e6 * r["seconds"] / cb["n_batches"],
            (
                f"batches_per_s={r['batches_per_s']:.2f};"
                f"recycled={r['recycled_slots']};"
                f"defrags={r['host_defrags']};"
                f"cap={r['capacity_start']}->{r['capacity_final']}"
            ),
        )
    _emit(
        "churn/speedup",
        0.0,
        f"unified_vs_host={cb['speedup_unified_vs_host']:.2f}x;"
        f"agree={cb['engines_agree']}",
    )

    # roofline table (from the dry-run artifact, if present)
    if os.path.exists(args.roofline_json):
        with open(args.roofline_json) as fh:
            cells = json.load(fh)
        for c in cells:
            if c["mesh"] != "16x16":
                continue
            rf = c["roofline"]
            _emit(
                f"roofline/{c['arch']}/{c['shape']}",
                1e6 * max(rf["t_compute_s"], rf["t_memory_s"],
                          rf["t_collective_s"]),
                (
                    f"dom={rf['dominant']};tc={rf['t_compute_s']:.2e};"
                    f"tm={rf['t_memory_s']:.2e};"
                    f"tx={rf['t_collective_s']:.2e};"
                    f"useful={c.get('model_vs_hlo')}"
                ),
            )
    else:
        print(
            f"# roofline: {args.roofline_json} not found "
            "(run repro.launch.dryrun --all --out first)",
            file=sys.stderr,
        )


if __name__ == "__main__":
    main()
