"""Shared benchmark workloads: graphs + edit batches (paper §5.1/§5.2).

The paper's experiment: sample 100k edges, remove them, then re-insert,
measuring accumulated wall time. CPU-container sizes are scaled down
(graphs ~20-50k vertices, batches 256-4096) but keep the paper's graph
families (ER / BA / RMAT power-law) and its protocol.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.generators import barabasi_albert, erdos_renyi, rmat
from repro.graph.stream import churn_stream, sliding_window_stream


def churn_workload(
    n: int = 1500,
    m: int = 6000,
    n_batches: int = 30,
    batch_size: int = 128,
    seed: int = 23,
):
    """Steady-state churn: balanced 50/50 insert/remove batches with
    heavy just-removed re-insertion (graph/stream.py::churn_stream) —
    the workload where the device engines' in-program slot recycling
    pays and the host engine must fall back to ``_compact``. Live edge
    count is exactly flat, so per-batch work and capacity should be too.

    Returns ``(graph, events)``; every event is a dirty mixed
    ``EdgeEvent`` (duplicates/self-loops/absent removals included, as a
    production stream would carry).
    """
    g = erdos_renyi(n, m, seed=seed)
    events = list(
        churn_stream(g, n_batches, batch_size, p_reinsert=0.6, seed=seed)
    )
    return g, events


def temporal_workload(
    n: int = 1500,
    arrivals: int = 3000,
    horizon: int = 30,
    window: int = 6,
    stride: int = 3,
    seed: int = 31,
):
    """Sliding-window temporal stream: ``arrivals`` random (u, v, t)
    rows with timestamps uniform over ``[0, horizon)``, replayed through
    ``graph/stream.py::sliding_window_stream`` — each step inserts the
    edges arriving in the new stride and bulk-removes the live edges
    older than ``window``. Unlike ``churn_workload`` the removals are
    STRUCTURAL (expiry by age), not sampled, and the stream drains: the
    final live set is empty, so total insertions == total removals and
    the final cores are all zero.

    Returns ``(n, edges_with_time, events, max_live)`` where
    ``max_live`` is the peak live-edge count over the replay (the
    capacity-planning datum) and every event is a mixed ``EdgeEvent``
    whose removals the consumer applies first.
    """
    rng = np.random.default_rng(seed)
    ewt = np.stack(
        [
            rng.integers(0, n, arrivals),
            rng.integers(0, n, arrivals),
            rng.integers(0, horizon, arrivals),
        ],
        axis=1,
    ).astype(np.int64)
    events = list(sliding_window_stream(ewt, window=window, stride=stride))
    live = 0
    max_live = 0
    for ev in events:  # removals-first, matching apply_batch
        live += len(ev.edges) - len(ev.removals)
        max_live = max(max_live, live)
    return n, ewt, events, max_live


def paper_graphs(scale: float = 1.0) -> Dict[str, CSRGraph]:
    n = int(20000 * scale)
    m = int(80000 * scale)
    return {
        "ER": erdos_renyi(n, m, seed=1),
        "BA": barabasi_albert(n, deg=8, seed=1),
        "RMAT": rmat(max(8, int(np.log2(n)) + 1), m, seed=1),
    }


def sample_removals(g: CSRGraph, k: int, seed: int = 0) -> np.ndarray:
    edges = g.edge_array()
    rng = np.random.default_rng(seed)
    idx = rng.choice(edges.shape[0], size=min(k, edges.shape[0]),
                     replace=False)
    return edges[idx]


def sample_insertions(g: CSRGraph, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    out = []
    seen = set()
    while len(out) < k:
        u = int(rng.integers(0, g.n))
        v = int(rng.integers(0, g.n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in seen or g.has_edge(*key):
            continue
        seen.add(key)
        out.append(key)
    return np.asarray(out, dtype=np.int64)


def timed(
    fn: Callable,
    *args,
    warmup: int = 1,
    iters: int = 3,
    sync: Optional[Callable] = None,
) -> float:
    """Median wall seconds of fn(*args).

    JAX dispatch is asynchronous: without blocking on the result the
    timer reads enqueue time, not execution time. ``sync`` is called on
    fn's return value before each timer read; the default blocks on every
    JAX array in the result (a no-op for plain Python/numpy results).
    """
    if sync is None:
        import jax

        sync = jax.block_until_ready
    for _ in range(warmup):
        sync(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        sync(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]
