"""Quickstart: parallel order-based core maintenance in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.api import CoreMaintainer
from repro.core.oracle import bz_from_csr
from repro.graph.csr import add_edges_csr, remove_edges_csr
from repro.graph.generators import erdos_renyi


def main():
    g = erdos_renyi(n=2000, m=8000, seed=0)
    m = CoreMaintainer.from_graph(g)
    print(f"graph: n={g.n} m={g.m}  max core = {m.cores().max()}")

    # insert a batch of 100 random edges — one bulk-synchronous call
    rng = np.random.default_rng(1)
    batch = []
    while len(batch) < 100:
        u, v = rng.integers(0, g.n, size=2)
        if u != v and not g.has_edge(int(u), int(v)):
            batch.append((int(min(u, v)), int(max(u, v))))
    batch = np.asarray(sorted(set(batch)))
    stats = m.insert_edges(batch)
    print(
        f"insert {len(batch)} edges: rounds={int(stats.rounds)} "
        f"|V*|={int(stats.n_promoted)} |V+|={int(stats.v_plus)}"
    )

    # verify against BZ recomputation
    expect = bz_from_csr(add_edges_csr(g, batch))
    assert (m.cores() == expect).all(), "core maintenance mismatch!"
    print("cores match BZ recomputation ✓")

    # remove them again
    stats = m.remove_edges(batch)
    print(f"remove: rounds={int(stats.rounds)} |V*|={int(stats.n_dropped)}")
    expect = bz_from_csr(g)
    assert (m.cores() == expect).all()
    print("cores restored ✓")

    # the maintained k-order is queryable in O(1)
    u, v = 0, 1
    print(f"k-order: vertex 0 {'<' if m.order_lt(0, 1) else '>='} vertex 1")


if __name__ == "__main__":
    main()
