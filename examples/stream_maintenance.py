"""End-to-end driver: a streaming core-maintenance service.

Consumes batches of edge events (the paper's workload: bursts of inserted/
removed edges that must be absorbed on time), maintains core numbers +
k-order, checkpoints atomically, and auto-resumes after a crash.

    PYTHONPATH=src python examples/stream_maintenance.py
    PYTHONPATH=src python examples/stream_maintenance.py --simulate-crash
    PYTHONPATH=src python examples/stream_maintenance.py --weighted --verify
    PYTHONPATH=src python examples/stream_maintenance.py --window 6
"""
import argparse
import os
import time

import numpy as np

from repro.core.api import CoreMaintainer
from repro.core.oracle import bz_from_csr
from repro.core.weighted import weighted_core_oracle
from repro.graph.csr import build_csr
from repro.graph.generators import erdos_renyi
from repro.graph.stream import (mixed_stream, sliding_window_stream,
                                synthetic_stream)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--m", type=int, default=20000)
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_stream_ckpt.npz")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--simulate-crash", action="store_true")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument(
        "--mixed", action="store_true",
        help="mixed insert+remove batches, one compiled call per batch",
    )
    ap.add_argument(
        "--engine", default="unified",
        choices=("unified", "host", "sharded"),
        help="sharded = slot table sharded over all local devices "
             "(XLA_FLAGS=--xla_force_host_platform_device_count=8 to try "
             "multi-device on CPU)",
    )
    ap.add_argument(
        "--vertex-sharding", default="replicated",
        choices=("replicated", "range", "halo"),
        help="where the per-vertex state lives under --engine sharded: "
             "replicated (one psum per statistic), range (each device "
             "owns a vertex range; reduce_scatter stats + bit-packed "
             "frontier masks — docs/DESIGN.md §4.2), or halo (2-axis "
             "mesh, owned range + static halo working set — §4.4)",
    )
    ap.add_argument(
        "--mesh-shape", default=None, metavar="DExDV",
        help="(d_e, d_v) factorization for --vertex-sharding halo, "
             "e.g. 4x2; the product must cover all devices (defaults "
             "to all devices on the edge axis)",
    )
    ap.add_argument(
        "--weighted", action="store_true",
        help="maintain WEIGHTED coreness (weighted h-index, Zhou et al. "
             "WWW'21 — docs/DESIGN.md §4.5): random integer edge "
             "weights, verified against the weighted peeling oracle "
             "under --verify; needs a device engine",
    )
    ap.add_argument(
        "--window", type=int, default=None, metavar="W",
        help="replay a sliding-window TEMPORAL stream instead of the "
             "synthetic one: timestamped arrivals, each edge expiring W "
             "steps after its latest arrival (bulk removals by age), "
             "starting from an empty graph and draining back to empty",
    )
    ap.add_argument(
        "--frontier-exchange", default="bitmask",
        choices=("bitmask", "sparse"),
        help="how changed-vertex masks cross the mesh under "
             "--vertex-sharding range: bitmask (n/8 bytes per shard per "
             "round) or sparse (compacted frontier indices in a static "
             "capacity bucket, falling back to the bitmask per round on "
             "overflow — docs/DESIGN.md §4.3)",
    )
    args = ap.parse_args()
    if args.weighted and args.engine == "host":
        ap.error("--weighted needs a device engine (unified | sharded)")
    if args.window is not None and args.window < 1:
        ap.error("--window must be >= 1")
    if args.vertex_sharding in ("range", "halo") and args.engine != "sharded":
        ap.error(f"--vertex-sharding {args.vertex_sharding} needs "
                 "--engine sharded")
    if (args.frontier_exchange == "sparse"
            and args.vertex_sharding not in ("range", "halo")):
        ap.error("--frontier-exchange sparse needs --vertex-sharding "
                 "range or halo")
    mesh_shape = None
    if args.mesh_shape:
        import re
        mm = re.fullmatch(r"(\d+)x(\d+)", args.mesh_shape)
        if not mm:
            ap.error(f"--mesh-shape must look like 4x2, got "
                     f"{args.mesh_shape!r}")
        mesh_shape = (int(mm.group(1)), int(mm.group(2)))
        if args.vertex_sharding != "halo":
            ap.error("--mesh-shape needs --vertex-sharding halo")

    if args.window is not None:
        # timestamped arrivals over a --batches-step horizon; the window
        # expiry turns them into mixed insert+removal events (removals
        # by AGE — the paper's temporal workload) that start from an
        # empty graph and drain it back to empty
        srng = np.random.default_rng(42)
        arrivals = args.batches * args.batch_size
        ewt = np.stack(
            [srng.integers(0, args.n, arrivals),
             srng.integers(0, args.n, arrivals),
             srng.integers(0, args.batches, arrivals)], axis=1,
        ).astype(np.int64)
        events = list(sliding_window_stream(ewt, window=args.window))
        g = build_csr(args.n, np.zeros((0, 2), np.int64))
    else:
        g = erdos_renyi(args.n, args.m, seed=0)
        stream = mixed_stream if args.mixed else synthetic_stream
        events = list(stream(g, args.batches, args.batch_size, seed=42))
    # the weight stream is regenerated from the same seed on resume, so
    # a restarted run replays identical per-batch insert weights
    wrng = np.random.default_rng(2)
    w0 = (wrng.integers(1, 8, g.m).astype(np.int32)
          if args.weighted else None)
    ins_w = ([wrng.integers(1, 8, len(ev.edges)).astype(np.int32)
              for ev in events] if args.weighted else None)
    state_path = args.ckpt
    meta_path = args.ckpt + ".meta"

    start_batch = 0
    if os.path.exists(state_path) and os.path.exists(meta_path):
        m = CoreMaintainer.load(state_path, engine=args.engine,
                                vertex_sharding=args.vertex_sharding,
                                mesh_shape=mesh_shape,
                                frontier_exchange=args.frontier_exchange,
                                weighted=args.weighted)
        start_batch = int(open(meta_path).read().strip()) + 1
        print(f"[resume] restored checkpoint, continuing at batch "
              f"{start_batch}")
    else:
        m = CoreMaintainer.from_graph(
            g, capacity=8 * args.m, engine=args.engine,
            vertex_sharding=args.vertex_sharding,
            mesh_shape=mesh_shape,
            frontier_exchange=args.frontier_exchange,
            weighted=args.weighted, weights=w0,
        )
    if args.engine == "sharded":
        import jax
        print(f"[mesh] edge slots sharded over {len(jax.devices())} "
              f"device(s), vertex state {args.vertex_sharding}, "
              f"frontier exchange {args.frontier_exchange}")

    t_all = time.perf_counter()
    edges_done = 0
    for i in range(start_batch, len(events)):
        ev = events[i]
        t0 = time.perf_counter()
        if ev.kind == "mixed":
            st = m.apply_batch(
                insert_edges=ev.edges, remove_edges=ev.removals,
                insert_weights=ins_w[i] if args.weighted else None,
            )
            extra = (f"+{int(st.n_inserted)}/-{int(st.n_removed)} "
                     f"|V*|={int(st.n_promoted) + int(st.n_dropped)} "
                     f"rounds={int(st.insert_rounds) + int(st.remove_rounds)} "
                     f"recycled={int(st.n_recycled)} "
                     f"hwm={int(st.high_water)}")
        elif ev.kind == "insert":
            st = m.insert_edges(
                ev.edges, weights=ins_w[i] if args.weighted else None)
            extra = f"|V*|={int(st.n_promoted)} rounds={int(st.rounds)}"
        else:
            st = m.remove_edges(ev.edges)
            extra = f"|V*|={int(st.n_dropped)} rounds={int(st.rounds)}"
        dt = time.perf_counter() - t0
        edges_done += ev.n_edits
        print(f"[batch {i:03d}] {ev.kind:6s} {ev.n_edits} edges "
              f"in {dt*1e3:7.1f} ms  {extra}")
        if i % args.ckpt_every == 0:
            tmp = state_path + ".tmp.npz"
            m.save(tmp)
            os.replace(tmp, state_path)  # atomic commit
            with open(meta_path + ".tmp", "w") as fh:
                fh.write(str(i))
            os.replace(meta_path + ".tmp", meta_path)
        if args.simulate_crash and i == len(events) // 2:
            print("[crash] simulating preemption — restart me to resume")
            raise SystemExit(17)

    total = time.perf_counter() - t_all
    print(f"\nprocessed {edges_done} edge events in {total:.2f}s "
          f"({edges_done/total:.0f} edges/s)")

    if args.verify:
        # rebuild the final graph on the host and compare with the oracle
        items = sorted(m.edge_slot.items())
        live = np.asarray(
            [[a, b] for (a, b), _ in items], dtype=np.int64
        ).reshape(-1, 2)
        if args.weighted:
            wcol = np.asarray(m.w)
            lw = np.asarray([wcol[s] for _, s in items], dtype=np.int64)
            expect = weighted_core_oracle(m.n, live, lw)
            assert (m.cores() == expect).all()
            print("final cores verified against the weighted peeling "
                  "oracle ✓")
        else:
            expect = bz_from_csr(build_csr(m.n, live))
            assert (m.cores() == expect).all()
            print("final cores verified against BZ ✓")
    # clean checkpoint on success
    for p in (state_path, meta_path):
        if os.path.exists(p):
            os.remove(p)


if __name__ == "__main__":
    main()
