"""Train a GNN on a DYNAMIC graph with maintained core-number features.

The paper's technique as a first-class feature: between training steps the
graph receives edge bursts; core numbers are maintained (not recomputed)
and fed to the model as structural node features. Checkpointed + resumable.

    PYTHONPATH=src python examples/train_gnn.py --steps 60
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import CoreMaintainer
from repro.graph.generators import erdos_renyi
from repro.graph.stream import synthetic_stream
from repro.models.gnn import GraphBatch, PNAConfig, pna_forward, pna_init
from repro.train.loop import TrainConfig, run_training


def make_batch(m: CoreMaintainer, feats, edge_cap: int) -> GraphBatch:
    src = np.asarray(m.src)
    dst = np.asarray(m.dst)
    ok = np.asarray(m.valid)
    cores = m.cores().astype(np.float32)
    senders = np.zeros(edge_cap, dtype=np.int32)
    receivers = np.zeros(edge_cap, dtype=np.int32)
    emask = np.zeros(edge_cap, dtype=bool)
    idx = np.nonzero(ok)[0][: edge_cap // 2]
    k = len(idx)
    senders[:k], receivers[:k] = src[idx], dst[idx]
    senders[k:2 * k], receivers[k:2 * k] = dst[idx], src[idx]
    emask[:2 * k] = True
    node_feat = np.concatenate(
        [feats, (cores / (cores.max() + 1e-6))[:, None]], axis=1
    ).astype(np.float32)
    n = feats.shape[0]
    return GraphBatch(
        node_feat=jnp.asarray(node_feat),
        senders=jnp.asarray(senders),
        receivers=jnp.asarray(receivers),
        edge_mask=jnp.asarray(emask),
        node_mask=jnp.ones(n, dtype=bool),
        graph_id=jnp.zeros(n, dtype=jnp.int32),
        n_graphs=1,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    g = erdos_renyi(args.n, 4 * args.n, seed=0)
    m = CoreMaintainer.from_graph(g, capacity=16 * args.n)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(args.n, 8)).astype(np.float32)
    # labels planted from (features + initial core structure) — learnable
    labels = (
        feats[:, 0] + 0.5 * (m.cores() > np.median(m.cores())) > 0.2
    ).astype(np.int32)

    cfg = PNAConfig(n_layers=2, d_hidden=32, d_in=9, n_classes=2)
    params = pna_init(cfg, jax.random.PRNGKey(0))

    stream = synthetic_stream(g, args.steps, 32, seed=7)
    edge_cap = 16 * args.n
    labels_j = jnp.asarray(labels)

    def batches():
        for ev in stream:
            # maintain cores through the burst, then emit a training batch
            if ev.kind == "insert":
                m.insert_edges(ev.edges)
            else:
                m.remove_edges(ev.edges)
            yield make_batch(m, feats, edge_cap), labels_j

    def loss_fn(params, gb, labels):
        logits = pna_forward(cfg, params, gb)  # [N, 2] node logits
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(nll)

    tc = TrainConfig(lr=3e-3, warmup=5, total_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=20)
    params, report = run_training(
        params, loss_fn, batches(), tc,
        on_step=lambda s, mx: print(
            f"step {s:03d} loss={mx['loss']:.4f} "
            f"max_core={m.cores().max()}"
        ) if s % 10 == 0 else None,
    )
    hist = report["history"]
    print(f"\nloss: first={hist[0]['loss']:.4f} last={hist[-1]['loss']:.4f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "training did not improve"
    print("dynamic-graph GNN training improved the loss ✓")


if __name__ == "__main__":
    main()
