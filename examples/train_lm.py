"""Train a small LM end-to-end with the full substrate (data pipeline,
AdamW, cosine schedule, microbatching, checkpoint/auto-resume).

Default config is CPU-sized; ``--preset 100m`` selects a ~100M-param
model for real hardware (same code path).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.data.lm import synthetic_lm_batches
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.train.loop import TrainConfig, run_training


def preset(name: str) -> LMConfig:
    if name == "tiny":
        return LMConfig(
            name="tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            d_head=32, d_ff=384, vocab=512, dtype=jnp.float32,
        )
    if name == "100m":
        return LMConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_head=64, d_ff=2048, vocab=32768,
        )
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = preset(args.preset)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    data = synthetic_lm_batches(cfg.vocab, args.batch, args.seq, seed=0)

    def batches():
        for toks, tgts in data:
            yield jnp.asarray(toks), jnp.asarray(tgts)

    def lf(params, tokens, targets):
        return loss_fn(cfg, params, tokens, targets)

    tc = TrainConfig(
        lr=1e-3, warmup=20, total_steps=args.steps, clip_norm=1.0,
        micro_batches=args.micro_batches,
        ckpt_dir=args.ckpt_dir, ckpt_every=50,
    )
    params, report = run_training(
        params, lf, batches(), tc,
        on_step=lambda s, m: print(
            f"step {s:04d} loss={m['loss']:.4f} lr={m['lr']:.2e}"
        ) if s % 20 == 0 else None,
    )
    hist = report["history"]
    print(f"\nloss: first={hist[0]['loss']:.4f} last={hist[-1]['loss']:.4f} "
          f"(stragglers: {report['stragglers']})")
    assert hist[-1]["loss"] < hist[0]["loss"]
    print("LM training improved the loss ✓")


if __name__ == "__main__":
    main()
