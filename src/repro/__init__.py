"""repro — Parallel Order-Based Core Maintenance as a multi-pod JAX framework.

x64 is enabled globally: the k-order labels are int64 (OM label space).
All neural-model code uses explicit dtypes (bf16/f32/int32) so this does
not change their numerics.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
