"""Static analysis of the engine matrix's jitted programs.

Everything here works on TRACED jaxprs / lowered computations — nothing
executes on device. The package answers, per engine configuration and
before any benchmark runs:

  * what collectives does each fixpoint round issue, at what payload
    (``walker`` + the ``collective_budget`` rule vs the committed
    ``budgets/<engine>.json`` manifests),
  * does the batch program smuggle a host round-trip or an un-donated
    large output (``host_sync``),
  * do the buffers ``apply_batch`` declares donated actually alias in
    the lowered computation (``donation``),
  * can an int64 sentinel (1 << 62) reach an int32 truncation
    (``dtype_policy``),
  * how many jit variants can the (window, frontier-cap) planners ever
    key (``recompile_surface``),
  * what does each program keep live per device — symbolic peak /
    per-round / at-rest / donated byte formulas, plus the
    no-replicated-O(n)-buffer policy for the range layouts
    (``memory_budget``, ``memory.py``),

plus an AST lint of the sync-free planning path (``hostlint``) and the
BENCH_stream.json coherence gate (``benchcheck``). CLI:
``python -m repro.analysis.audit --engine all``; see docs/DESIGN.md §5.
"""
from .audit import (  # noqa: F401
    BUDGET_DIR,
    SCHEMA,
    audit_engines,
    generate_budget,
    load_budget,
    make_check,
    make_report,
    write_budgets,
)
from .benchcheck import check_bench  # noqa: F401
from .hostlint import LintFinding, lint_file  # noqa: F401
from .memory import (  # noqa: F401
    generate_memory_section,
    profile_program,
    program_body,
    replicated_vertex_sites,
)
from .programs import (  # noqa: F401
    ENGINE_CONFIGS,
    AuditParams,
    EngineConfig,
    TracedEngine,
    trace_engine,
    trace_promotion_round,
    trace_removal_round,
)
from .rules import (  # noqa: F401
    RULES,
    Finding,
    cross_check_round,
    eval_formula,
    guess_formula,
    run_rules,
    split_round_collectives,
    tainted_truncations,
)
from .walker import (  # noqa: F401
    COLLECTIVE_PRIMS,
    CollectiveSite,
    Site,
    collectives,
    count_collectives,
    iter_sites,
    primitive_names,
)
