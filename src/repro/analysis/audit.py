"""Audit CLI + report schema — ``python -m repro.analysis.audit``.

Runs every registered rule (repro.analysis.rules) over the traced
programs of each engine configuration and diffs against the committed
budget manifests (``budgets/<engine>.json``), emitting one
machine-readable report (schema ``repro.analysis/report/v1`` — the same
shape ``benchcheck`` uses for the BENCH_stream.json coherence gate, so
CI consumes exactly one report format).

Usage:
    python -m repro.analysis.audit --engine all            # gate
    python -m repro.analysis.audit --engine all --devices 8
    python -m repro.analysis.audit --engine all --memory   # memory only
    python -m repro.analysis.audit --write-budgets --devices 8
    python -m repro.analysis.audit --check-bench BENCH_stream.json

``--devices N`` re-execs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` when the current
process already initialized JAX with a different count (importing this
package imports jax, so the flag cannot be set in-process).

``--write-budgets`` regenerates the manifests from the traced programs.
Run it at ``--devices 8``: payload formulas are matched against the
observed byte counts, and several candidates coincide numerically on 1
device (``n_owned == n``) — a multi-device trace disambiguates them so
the committed formula holds on EVERY device count. The memory section
goes further: each sharded engine is traced a SECOND time on an
explicit 1-device mesh and every buffer dimension is solved against
both size environments at once (see ``memory.generate_memory_section``).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
from typing import Dict, List, Optional, Sequence

SCHEMA = "repro.analysis/report/v1"
BUDGET_SCHEMA = "repro.analysis/budget/v4"
BUDGET_DIR = os.path.join(os.path.dirname(__file__), "budgets")
_CHILD_GUARD = "_REPRO_AUDIT_REEXEC"


def make_check(rule: str, engine: str, findings: Sequence) -> dict:
    """One report entry: a rule applied to one engine config."""
    return {
        "rule": rule,
        "engine": engine,
        "ok": not findings,
        "findings": [
            f.as_dict() if hasattr(f, "as_dict") else dict(f)
            for f in findings
        ],
    }


def make_report(checks: List[dict], **meta) -> dict:
    return {
        "schema": SCHEMA,
        "ok": all(c["ok"] for c in checks),
        "checks": checks,
        **meta,
    }


def budget_path(engine: str, budget_dir: Optional[str] = None) -> str:
    return os.path.join(budget_dir or BUDGET_DIR, f"{engine}.json")


def load_budget(engine: str, budget_dir: Optional[str] = None) -> dict:
    path = budget_path(engine, budget_dir)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no budget manifest for engine {engine!r} at {path} — "
            "generate one with `python -m repro.analysis.audit "
            "--write-budgets --devices 8` and commit it"
        )
    with open(path) as fh:
        budget = json.load(fh)
    got = budget.get("schema")
    if got != BUDGET_SCHEMA:
        raise ValueError(
            f"budget manifest {path} has schema {got!r} but this "
            f"auditor expects {BUDGET_SCHEMA!r} — regenerate with "
            "`python -m repro.analysis.audit --write-budgets "
            "--devices 8` and commit the result"
        )
    return budget


def generate_budget(traced, paired=None) -> dict:
    """Build a budget manifest from a traced engine: exact collective
    histograms, ordered per-round op lists with payload formulas
    (``rules.guess_formula``), the donated-arg sets, the jit-variant
    bound computed at its 1-device maximum (the window lattice is
    largest when one shard holds the whole table), and the symbolic
    per-device memory section (``memory.generate_memory_section``;
    ``paired`` is the same engine traced at a different mesh size).

    The paired trace disambiguates BOTH kinds of formulas: buffer
    dimensions (memory section) and round payload sizes — the round
    jaxprs are structurally identical at every mesh size (ring steps
    are live-masked, not unrolled), so the collective sites zip
    one-to-one and ``guess_formula`` can demand a candidate reproduce
    both environments' byte counts (several candidates coincide at a
    single audit point, e.g. ``hcap * 4 == d_v * cap * 8`` at
    (d_e, d_v) = (4, 2))."""
    from ..core.api import bucket_lattice
    from ..launch.mesh import EDGE_SHARD_AXIS
    from .memory import generate_memory_section
    from .rules import guess_formula, split_round_collectives
    from .walker import count_collectives, count_round_launches

    cfg = traced.config
    env = traced.sizes
    if paired is None:
        paired = []
    elif not isinstance(paired, (list, tuple)):
        paired = [paired]
    # payload formulas pair against the most size-divergent point (the
    # 1-device trace comes first from write_budgets)
    pair0 = paired[0] if paired else None
    rounds = {}
    for rname, (_, closed) in traced.rounds.items():
        sides = dict(zip(
            ("setup", "main", "overflow", "stray"),
            split_round_collectives(closed),
        ))
        if sides["stray"]:
            raise RuntimeError(
                f"{cfg.name}/{rname}: cannot budget unattributable "
                f"collectives {[c.op for c in sides['stray']]}"
            )
        psides: dict = {}
        if pair0 is not None and rname in pair0.rounds:
            pt = dict(zip(
                ("setup", "main", "overflow", "stray"),
                split_round_collectives(pair0.rounds[rname][1]),
            ))
            if not pt["stray"] and all(
                len(pt[k]) == len(sides[k])
                for k in ("setup", "main", "overflow")
            ):
                psides = pt
        rounds[rname] = {
            side: [
                {"op": c.op, "recv_bytes": guess_formula(
                    c.out_bytes, env,
                    psides[side][i].out_bytes if psides else None,
                    pair0.sizes if psides else None,
                )}
                for i, c in enumerate(cols)
            ]
            for side, cols in ((k, sides[k])
                               for k in ("setup", "main", "overflow"))
        }
    if cfg.engine == "host":
        max_variants = max(1, traced.params.lanes).bit_length()
    else:
        # d=1 maximizes the window lattice; committing that bound keeps
        # one manifest valid on every audited device count
        max_variants = len(bucket_lattice(
            traced.params.capacity, traced.params.lanes,
            cfg.frontier_exchange, cfg.frontier_cap, traced.params.n,
        ))
    return {
        "schema": BUDGET_SCHEMA,
        "engine": cfg.name,
        "generated_with": {
            "n": traced.params.n,
            "capacity": traced.params.capacity,
            "lanes": traced.params.lanes,
            "devices": traced.n_devices,
            "mesh_shape": (
                [env["d_e"], env["d_v"]]
                if cfg.vertex_sharding == "halo" else None
            ),
        },
        "program_collectives": {
            p: count_collectives(jx) for p, jx in traced.programs.items()
        },
        "rounds": rounds,
        # launch-class primitives per fixpoint round (a fused pallas_call
        # counts as ONE; rules.check_launch_budget pins these and, for
        # pallas configs, proves the count strictly beats the lax twin)
        "round_launches": {
            rname: count_round_launches(closed)
            for rname, (_, closed) in traced.rounds.items()
        },
        "forbid_round_vertex_psum": cfg.vertex_sharding in ("range", "halo"),
        # pure-edge-axis statistic psums are budgeted traffic, not the
        # forbidden vertex-axis reduction (their payload is the owned
        # slice, n-sized only in the degenerate d_v=1 factorization)
        "round_psum_axes_exempt": (
            [EDGE_SHARD_AXIS] if cfg.vertex_sharding == "halo" else []
        ),
        "donated_args": {
            p: list(traced.donated.get(p, ())) for p in traced.lowered
        },
        "max_callback_primitives": 0,
        "max_tainted_truncations": 0,
        "max_jit_variants": max_variants,
        "large_output_bytes": 1024,
        "require_large_outputs_donated": cfg.engine != "host",
        "memory": generate_memory_section(traced, paired),
    }


def audit_engines(engines: Sequence[str],
                  budget_dir: Optional[str] = None,
                  params=None,
                  rules: Optional[Sequence[str]] = None,
                  mesh_shape=None) -> dict:
    """Pytest-importable entry: trace + audit the given engine configs
    against their committed budgets, returning one report dict.
    ``rules`` restricts the run to a subset of the registry (the CLI's
    ``--memory`` flag passes ``["memory_budget"]``). ``mesh_shape``
    overrides the (d_e, d_v) factorization of halo configs only — CI
    audits ``vertex_halo`` under both 4x2 and 2x4 against the one
    committed manifest; other configs in the same run ignore it."""
    import jax

    from .programs import ENGINE_CONFIGS, AuditParams, trace_engine
    from .rules import run_rules

    params = params or AuditParams()
    checks: List[dict] = []
    for name in engines:
        shape = (mesh_shape
                 if ENGINE_CONFIGS[name].vertex_sharding == "halo"
                 else None)
        traced = trace_engine(name, params, mesh_shape=shape)
        budget = load_budget(name, budget_dir)
        for rname, findings in run_rules(traced, budget, rules).items():
            checks.append(make_check(rname, name, findings))
    return make_report(
        checks,
        n_devices=len(jax.devices()),
        engines=list(engines),
        mesh_shape=list(mesh_shape) if mesh_shape else None,
        params={"n": params.n, "capacity": params.capacity,
                "lanes": params.lanes},
    )


def write_budgets(engines: Sequence[str],
                  budget_dir: Optional[str] = None,
                  params=None) -> List[str]:
    from .programs import ENGINE_CONFIGS, AuditParams, trace_engine

    params = params or AuditParams()
    out_dir = budget_dir or BUDGET_DIR
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name in engines:
        traced = trace_engine(name, params)
        # second trace on an explicit 1-device mesh: shard_map traces
        # one program regardless of mesh size, so the paired point
        # sequences line up and buffer-size formulas get solved against
        # two size environments at once (memory.generate_memory_section)
        paired = []
        if ENGINE_CONFIGS[name].is_sharded and traced.n_devices > 1:
            paired.append(trace_engine(name, params, devices=1))
            # halo configs add every other (d_e, d_v) factorization CI
            # can audit at this device count: the 1-device pair can't
            # separate d_v-only dependences (d_v == 1 collapses them),
            # and the PEAK program point itself moves between
            # factorizations — the committed max() must cover each
            # point that is the peak somewhere
            if ENGINE_CONFIGS[name].vertex_sharding == "halo":
                d = traced.n_devices
                canon = (traced.sizes["d_e"], traced.sizes["d_v"])
                others = [(canon[1], canon[0]), (1, d), (d, 1)]
                for shape in dict.fromkeys(others):
                    if shape != canon and shape[0] * shape[1] == d:
                        paired.append(trace_engine(name, params,
                                                   mesh_shape=shape))
        path = budget_path(name, out_dir)
        with open(path, "w") as fh:
            json.dump(generate_budget(traced, paired), fh, indent=2,
                      sort_keys=True)
            fh.write("\n")
        written.append(path)
    return written


def _reexec_with_devices(n_devices: int, argv: Sequence[str]) -> int:
    """Re-run this CLI in a subprocess with N forced host devices.
    Needed because importing repro.analysis already initialized jax —
    XLA_FLAGS must be set before that import, not after."""
    if os.environ.get(_CHILD_GUARD):
        print(
            f"audit: failed to force {n_devices} host devices via "
            "XLA_FLAGS (still seeing a different count after re-exec)",
            file=sys.stderr,
        )
        return 2
    env = dict(os.environ)
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "",
        env.get("XLA_FLAGS", ""),
    ).strip()
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n_devices}"
    ).strip()
    env[_CHILD_GUARD] = "1"
    src_root = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    env["PYTHONPATH"] = os.pathsep.join(
        [src_root] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    cmd = [sys.executable, "-m", "repro.analysis.audit", *argv]
    return subprocess.call(cmd, env=env)


def _print_summary(report: dict) -> None:
    for c in report["checks"]:
        mark = "ok  " if c["ok"] else "FAIL"
        print(f"[{mark}] {c['engine']:16s} {c['rule']}")
        for f in c["findings"]:
            print(f"       - {f['message']}")
    verdict = "PASS" if report["ok"] else "FAIL"
    extra = (f" on {report['n_devices']} device(s)"
             if "n_devices" in report else "")
    print(f"audit {verdict}{extra}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="Static audit of the engine matrix's traced programs",
    )
    p.add_argument("--engine", default="all",
                   help="comma-separated engine configs, or 'all'")
    p.add_argument("--devices", type=int, default=None,
                   help="force this many host devices (re-execs with "
                        "XLA_FLAGS when needed)")
    p.add_argument("--out", default=None,
                   help="write the JSON report here")
    p.add_argument("--budget-dir", default=None,
                   help="manifest directory (default: the committed "
                        "package budgets/)")
    p.add_argument("--memory", action="store_true",
                   help="run only the memory_budget rule (symbolic "
                        "per-device peak / at-rest / donation audit)")
    p.add_argument("--mesh-shape", default=None, metavar="DExDV",
                   help="re-trace halo configs under this (d_e, d_v) "
                        "factorization, e.g. 2x4 (non-halo configs "
                        "ignore it; product must equal --devices)")
    p.add_argument("--write-budgets", action="store_true",
                   help="regenerate the budget manifests instead of "
                        "checking (run with --devices 8)")
    p.add_argument("--check-bench", default=None, metavar="PATH",
                   help="check a BENCH_stream.json artifact for "
                        "coherence instead of auditing engines")
    args = p.parse_args(argv)

    if args.check_bench:
        from .benchcheck import check_bench

        report = make_report([check_bench(args.check_bench)],
                             artifact=args.check_bench)
        if args.out:
            with open(args.out, "w") as fh:
                json.dump(report, fh, indent=2)
        _print_summary(report)
        return 0 if report["ok"] else 1

    import jax  # after arg parsing: --help must not initialize a backend

    if args.devices is not None and len(jax.devices()) != args.devices:
        child_argv = [a for a in (argv if argv is not None else sys.argv[1:])]
        return _reexec_with_devices(args.devices, child_argv)

    from .programs import ENGINE_CONFIGS

    engines = (sorted(ENGINE_CONFIGS) if args.engine == "all"
               else args.engine.split(","))
    for e in engines:
        if e not in ENGINE_CONFIGS:
            p.error(f"unknown engine {e!r} "
                    f"(expected one of {sorted(ENGINE_CONFIGS)})")

    if args.write_budgets:
        if len(jax.devices()) == 1:
            print(
                "audit: writing budgets from a 1-device trace — size "
                "formulas may not disambiguate (n_owned == n); prefer "
                "--write-budgets --devices 8",
                file=sys.stderr,
            )
        for path in write_budgets(engines, args.budget_dir):
            print(f"wrote {path}")
        return 0

    mesh_shape = None
    if args.mesh_shape:
        m = re.fullmatch(r"(\d+)x(\d+)", args.mesh_shape)
        if not m:
            p.error(f"--mesh-shape must look like 4x2, got "
                    f"{args.mesh_shape!r}")
        mesh_shape = (int(m.group(1)), int(m.group(2)))

    report = audit_engines(
        engines, args.budget_dir,
        rules=["memory_budget"] if args.memory else None,
        mesh_shape=mesh_shape,
    )
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
    _print_summary(report)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
