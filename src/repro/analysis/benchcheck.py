"""Coherence check of the committed BENCH_stream.json artifact.

Replaces the inline heredoc CI used to carry: same assertions, but
emitted as one ``repro.analysis/report/v1`` check (rule
``bench_coherence``) so the bench gate and the static audit share a
report schema. Deliberately dependency-free (no jax import) — CI runs
it before anything heavy.
"""
from __future__ import annotations

import json
from typing import List

# stamped into BENCH_stream.json by benchmarks.core_maintenance; bumped
# whenever the artifact gains fields the audit relies on (v2: per-engine
# max_frontier observability; v3: the fused-pallas kernel-backend row
# plus the static lax-vs-pallas ``launches_per_round`` section; v4: the
# 2-axis ``vertex_halo`` row + ``mesh_scaling`` factorization sweep, the
# explicit ``interpret_mode`` stamp on pallas wall-clock rows, and the
# ``frontier_autoplan`` before/after overflow section; v5: the
# ``weighted`` engine row — unit weights, so it must agree with the
# unweighted engines on the same stream — and the ``temporal``
# sliding-window expiry section with its drain invariant). An artifact
# with an older/missing stamp predates the current manifests and must
# be regenerated, not trusted.
BENCH_SCHEMA = "repro.analysis/bench/v5"

REGEN_HINT = (
    "regenerate with `PYTHONPATH=src python -m benchmarks.run` (no "
    "--quick) and commit the refreshed BENCH_stream.json"
)

# a --quick benchmarks.run skips the device-scaling sweeps (and writes
# BENCH_stream.quick.json instead for that reason) — the committed
# artifact must carry all of these
REQUIRED_KEYS = (
    "vertex_sharded",
    "frontier_sparse",
    "vertex_halo",
    "pallas",
    "weighted",
    "temporal",
    "sharded_scaling",
    "vertex_scaling",
    "frontier_scaling",
    "mesh_scaling",
    "frontier_autoplan",
)

# engines timed inside the ``temporal`` sliding-window section; each
# needs a wall-clock row there
TEMPORAL_ENGINES = ("host", "unified", "sharded", "weighted")

# engine rows whose wall-clock participates in speedup coherence; a row
# stamped ``interpret_mode: true`` (the pallas backend off-TPU) is
# excluded — its timing measures the interpreter, not the kernel — while
# the launch-count coherence below still applies to it unconditionally
SPEEDUP_ENGINES = (
    "unified",
    "sharded",
    "vertex_sharded",
    "frontier_sparse",
    "vertex_halo",
    "pallas",
)


def _finding(message: str) -> dict:
    return {"rule": "bench_coherence", "engine": "bench",
            "program": "", "message": message}


def check_bench(path: str) -> dict:
    """Audit one BENCH_stream.json; returns a report check dict."""
    findings: List[dict] = []
    try:
        with open(path) as fh:
            blob = json.load(fh)
    except FileNotFoundError:
        findings.append(_finding(
            f"no bench artifact at {path} — {REGEN_HINT}"))
        blob = None
    except (OSError, ValueError) as e:
        findings.append(_finding(f"cannot load {path}: {e} — {REGEN_HINT}"))
        blob = None
    if blob is not None and blob.get("schema") != BENCH_SCHEMA:
        findings.append(_finding(
            f"{path} predates the current artifact schema (found "
            f"{blob.get('schema')!r}, expected {BENCH_SCHEMA!r}) — "
            + REGEN_HINT))
        blob = None
    if blob is not None:
        # engines_agree covers EVERY recorded engine row (incl. the
        # frontier_sparse configuration): final cores were compared
        # against the host engine on the same stream when recorded
        if blob.get("engines_agree") is not True:
            findings.append(_finding("stream engines diverged "
                                     "(engines_agree is not true)"))
        if blob.get("churn", {}).get("engines_agree") is not True:
            findings.append(_finding("churn engines diverged "
                                     "(churn.engines_agree is not true)"))
        for key in REQUIRED_KEYS:
            if key not in blob:
                findings.append(_finding(
                    f"BENCH_stream.json lacks {key!r}: regenerate with a "
                    "full (non --quick) benchmarks.run, which records the "
                    "device-scaling sweeps"
                ))
        if "speedup_frontier_sparse_vs_host" not in blob:
            findings.append(_finding(
                "missing speedup_frontier_sparse_vs_host"))
        fs = blob.get("frontier_sparse")
        if isinstance(fs, dict) and not fs.get("batches_per_s", 0) > 0:
            findings.append(_finding(
                "frontier_sparse.batches_per_s is not > 0"))
        pal = blob.get("pallas")
        if isinstance(pal, dict):
            if not pal.get("batches_per_s", 0) > 0:
                findings.append(_finding("pallas.batches_per_s is not > 0"))
            if "interpret_mode" not in pal:
                findings.append(_finding(
                    "pallas row lacks the explicit interpret_mode stamp "
                    "— without it the gate cannot tell a real-hardware "
                    "timing from an interpreter timing; " + REGEN_HINT))
        # speedup coherence: every timed device-engine row must beat the
        # host baseline it was recorded against — EXCEPT rows stamped
        # interpret_mode: true, whose wall-clock is the pallas
        # interpreter's (the launch-count section below still covers the
        # fusion claim for those)
        for eng in SPEEDUP_ENGINES:
            row = blob.get(eng)
            if not isinstance(row, dict):
                continue
            if row.get("interpret_mode") is True:
                continue
            sp = blob.get(f"speedup_{eng}_vs_host")
            if sp is None:
                findings.append(_finding(
                    f"missing speedup_{eng}_vs_host"))
            elif not sp > 1.0:
                findings.append(_finding(
                    f"speedup_{eng}_vs_host is {sp:.2f}x — the "
                    "device engine did not beat the host baseline"))
        hl = blob.get("vertex_halo")
        if isinstance(hl, dict) and not hl.get("batches_per_s", 0) > 0:
            findings.append(_finding(
                "vertex_halo.batches_per_s is not > 0"))
        fa = blob.get("frontier_autoplan")
        if isinstance(fa, dict):
            before = fa.get("overflow_rounds_before")
            after = fa.get("overflow_rounds_after")
            if before is None or after is None:
                findings.append(_finding(
                    "frontier_autoplan lacks overflow_rounds_before/"
                    "after"))
            elif not (after < before or before == 0):
                findings.append(_finding(
                    f"frontier autoplan did not reduce overflow "
                    f"fallbacks ({before} -> {after} rounds)"))
            if (fa.get("tuned_cap") is not None
                    and fa.get("blind_cap") is not None
                    and fa["tuned_cap"] < fa["blind_cap"]):
                findings.append(_finding(
                    "frontier_autoplan tuned_cap shrank below the blind "
                    "cap — the planner must grow monotonically"))
        # the weighted row rides the SAME stream with every weight 1
        # (weighted coreness degenerates to plain coreness), so its
        # correctness claim is the shared engines_agree flag above; here
        # the gate only requires the row to exist and to have actually
        # been timed. It is deliberately NOT in SPEEDUP_ENGINES: the
        # bisection stat pass does strictly more work per round than the
        # order-based path, and the row's purpose is the cross-check +
        # pricing that overhead, not beating the host baseline
        wrow = blob.get("weighted")
        if isinstance(wrow, dict) and not wrow.get("batches_per_s", 0) > 0:
            findings.append(_finding("weighted.batches_per_s is not > 0"))
        # temporal sliding-window section: structural expiry-by-age
        # removals over a drained stream — insertions must balance
        # removals exactly and every engine must end on all-zero cores
        tmp = blob.get("temporal")
        if isinstance(tmp, dict):
            if tmp.get("engines_agree") is not True:
                findings.append(_finding(
                    "temporal engines diverged "
                    "(temporal.engines_agree is not true)"))
            if tmp.get("total_insertions") != tmp.get("total_removals"):
                findings.append(_finding(
                    "temporal stream did not drain: total_insertions="
                    f"{tmp.get('total_insertions')!r} != total_removals="
                    f"{tmp.get('total_removals')!r} — every inserted "
                    "edge must expire out of the sliding window"))
            if tmp.get("final_cores_zero") is not True:
                findings.append(_finding(
                    "temporal.final_cores_zero is not true — a drained "
                    "stream must end on the empty graph"))
            if not (isinstance(tmp.get("window"), int)
                    and isinstance(tmp.get("stride"), int)
                    and tmp["window"] >= 1
                    and 1 <= tmp["stride"] <= tmp["window"]):
                findings.append(_finding(
                    f"temporal window/stride malformed (window="
                    f"{tmp.get('window')!r}, stride={tmp.get('stride')!r}"
                    "); need 1 <= stride <= window for expiry overlap"))
            for eng in TEMPORAL_ENGINES:
                row = tmp.get(eng)
                if not isinstance(row, dict):
                    findings.append(_finding(
                        f"temporal section lacks the {eng!r} engine row"))
                elif not row.get("batches_per_s", 0) > 0:
                    findings.append(_finding(
                        f"temporal.{eng}.batches_per_s is not > 0"))
        # the launch-count section IS the fusion claim: each fixpoint
        # round must dispatch strictly fewer launch-class kernels under
        # the pallas backend than under lax, and the pallas round must
        # actually contain the fused pallas_call (else the backend knob
        # silently fell back to the unfused path)
        lp = blob.get("launches_per_round")
        if not isinstance(lp, dict) or not {"lax", "pallas"} <= set(lp):
            findings.append(_finding(
                "missing launches_per_round lax/pallas section — "
                + REGEN_HINT))
        else:
            for rnd in ("removal", "promotion"):
                lax_h = lp["lax"].get(rnd) or {}
                pal_h = lp["pallas"].get(rnd) or {}
                if not lax_h or not pal_h:
                    findings.append(_finding(
                        f"launches_per_round lacks the {rnd} round — "
                        + REGEN_HINT))
                    continue
                if "pallas_call" not in pal_h:
                    findings.append(_finding(
                        f"pallas {rnd} round traces no pallas_call — the "
                        "fused kernel is absent from the round program"))
                if sum(pal_h.values()) >= sum(lax_h.values()):
                    findings.append(_finding(
                        f"pallas {rnd} round launches "
                        f"{sum(pal_h.values())} kernels, not strictly "
                        f"fewer than lax's {sum(lax_h.values())}"))
        for i, row in enumerate(blob.get("vertex_scaling") or []):
            if "n_devices" not in row:
                findings.append(_finding(
                    f"vertex_scaling[{i}] lacks n_devices"))
        for i, row in enumerate(blob.get("frontier_scaling") or []):
            if "n_devices" not in row:
                findings.append(_finding(
                    f"frontier_scaling[{i}] lacks n_devices"))
            if row.get("frontier_exchange") != "sparse":
                findings.append(_finding(
                    f"frontier_scaling[{i}] is not a sparse-frontier row "
                    f"(frontier_exchange={row.get('frontier_exchange')!r})"))
        for i, row in enumerate(blob.get("mesh_scaling") or []):
            shape = row.get("mesh_shape")
            if (not isinstance(shape, list) or len(shape) != 2
                    or row.get("n_devices") != shape[0] * shape[1]):
                findings.append(_finding(
                    f"mesh_scaling[{i}] lacks a mesh_shape [d_e, d_v] "
                    f"factorizing its n_devices (got shape={shape!r}, "
                    f"n_devices={row.get('n_devices')!r})"))
            if row.get("vertex_sharding") != "halo":
                findings.append(_finding(
                    f"mesh_scaling[{i}] is not a halo row "
                    f"(vertex_sharding={row.get('vertex_sharding')!r})"))
    return {
        "rule": "bench_coherence",
        "engine": "bench",
        "ok": not findings,
        "findings": findings,
    }
