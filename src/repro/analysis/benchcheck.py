"""Coherence check of the committed BENCH_stream.json artifact.

Replaces the inline heredoc CI used to carry: same assertions, but
emitted as one ``repro.analysis/report/v1`` check (rule
``bench_coherence``) so the bench gate and the static audit share a
report schema. Deliberately dependency-free (no jax import) — CI runs
it before anything heavy.
"""
from __future__ import annotations

import json
from typing import List

# stamped into BENCH_stream.json by benchmarks.core_maintenance; bumped
# whenever the artifact gains fields the audit relies on (v2: per-engine
# max_frontier observability; v3: the fused-pallas kernel-backend row
# plus the static lax-vs-pallas ``launches_per_round`` section). An
# artifact with an older/missing stamp predates the current manifests
# and must be regenerated, not trusted.
BENCH_SCHEMA = "repro.analysis/bench/v3"

REGEN_HINT = (
    "regenerate with `PYTHONPATH=src python -m benchmarks.run` (no "
    "--quick) and commit the refreshed BENCH_stream.json"
)

# a --quick benchmarks.run skips the device-scaling sweeps (and writes
# BENCH_stream.quick.json instead for that reason) — the committed
# artifact must carry all of these
REQUIRED_KEYS = (
    "vertex_sharded",
    "frontier_sparse",
    "pallas",
    "sharded_scaling",
    "vertex_scaling",
    "frontier_scaling",
)


def _finding(message: str) -> dict:
    return {"rule": "bench_coherence", "engine": "bench",
            "program": "", "message": message}


def check_bench(path: str) -> dict:
    """Audit one BENCH_stream.json; returns a report check dict."""
    findings: List[dict] = []
    try:
        with open(path) as fh:
            blob = json.load(fh)
    except FileNotFoundError:
        findings.append(_finding(
            f"no bench artifact at {path} — {REGEN_HINT}"))
        blob = None
    except (OSError, ValueError) as e:
        findings.append(_finding(f"cannot load {path}: {e} — {REGEN_HINT}"))
        blob = None
    if blob is not None and blob.get("schema") != BENCH_SCHEMA:
        findings.append(_finding(
            f"{path} predates the current artifact schema (found "
            f"{blob.get('schema')!r}, expected {BENCH_SCHEMA!r}) — "
            + REGEN_HINT))
        blob = None
    if blob is not None:
        # engines_agree covers EVERY recorded engine row (incl. the
        # frontier_sparse configuration): final cores were compared
        # against the host engine on the same stream when recorded
        if blob.get("engines_agree") is not True:
            findings.append(_finding("stream engines diverged "
                                     "(engines_agree is not true)"))
        if blob.get("churn", {}).get("engines_agree") is not True:
            findings.append(_finding("churn engines diverged "
                                     "(churn.engines_agree is not true)"))
        for key in REQUIRED_KEYS:
            if key not in blob:
                findings.append(_finding(
                    f"BENCH_stream.json lacks {key!r}: regenerate with a "
                    "full (non --quick) benchmarks.run, which records the "
                    "device-scaling sweeps"
                ))
        if "speedup_frontier_sparse_vs_host" not in blob:
            findings.append(_finding(
                "missing speedup_frontier_sparse_vs_host"))
        fs = blob.get("frontier_sparse")
        if isinstance(fs, dict) and not fs.get("batches_per_s", 0) > 0:
            findings.append(_finding(
                "frontier_sparse.batches_per_s is not > 0"))
        pal = blob.get("pallas")
        if isinstance(pal, dict) and not pal.get("batches_per_s", 0) > 0:
            findings.append(_finding("pallas.batches_per_s is not > 0"))
        # the launch-count section IS the fusion claim: each fixpoint
        # round must dispatch strictly fewer launch-class kernels under
        # the pallas backend than under lax, and the pallas round must
        # actually contain the fused pallas_call (else the backend knob
        # silently fell back to the unfused path)
        lp = blob.get("launches_per_round")
        if not isinstance(lp, dict) or not {"lax", "pallas"} <= set(lp):
            findings.append(_finding(
                "missing launches_per_round lax/pallas section — "
                + REGEN_HINT))
        else:
            for rnd in ("removal", "promotion"):
                lax_h = lp["lax"].get(rnd) or {}
                pal_h = lp["pallas"].get(rnd) or {}
                if not lax_h or not pal_h:
                    findings.append(_finding(
                        f"launches_per_round lacks the {rnd} round — "
                        + REGEN_HINT))
                    continue
                if "pallas_call" not in pal_h:
                    findings.append(_finding(
                        f"pallas {rnd} round traces no pallas_call — the "
                        "fused kernel is absent from the round program"))
                if sum(pal_h.values()) >= sum(lax_h.values()):
                    findings.append(_finding(
                        f"pallas {rnd} round launches "
                        f"{sum(pal_h.values())} kernels, not strictly "
                        f"fewer than lax's {sum(lax_h.values())}"))
        for i, row in enumerate(blob.get("vertex_scaling") or []):
            if "n_devices" not in row:
                findings.append(_finding(
                    f"vertex_scaling[{i}] lacks n_devices"))
        for i, row in enumerate(blob.get("frontier_scaling") or []):
            if "n_devices" not in row:
                findings.append(_finding(
                    f"frontier_scaling[{i}] lacks n_devices"))
            if row.get("frontier_exchange") != "sparse":
                findings.append(_finding(
                    f"frontier_scaling[{i}] is not a sparse-frontier row "
                    f"(frontier_exchange={row.get('frontier_exchange')!r})"))
    return {
        "rule": "bench_coherence",
        "engine": "bench",
        "ok": not findings,
        "findings": findings,
    }
