"""AST lint: no device->host syncs in the sync-free planning path.

``CoreMaintainer.apply_batch`` promises that the per-batch edit path
never blocks on the device (docs/DESIGN.md §3/§5): planning runs off
monotone host-side bounds (``hwm_ub`` / ``live_ub``) and the only syncs
are the documented amortized ones (``_refresh_bounds``, ``_compact`` /
``_defrag_to``, the lazy ``edge_slot`` mirror) plus the ``engine="host"``
baseline path. That promise is enforced here syntactically, per
function, over the SYNC-FREE set below:

forbidden inside a sync-free function
  * ``<expr>.block_until_ready(...)`` — always a sync
  * ``<expr>.item()`` — always a sync
  * ``int(...)`` / ``float(...)`` / ``bool(...)`` / ``np.asarray(...)``
    / ``np.array(...)`` / ``jax.device_get(...)`` applied to an
    expression that mentions a device-resident field
    (``self.src`` etc. — DEVICE_FIELDS below)

A line carrying a ``# sync: ok`` comment is exempt (use it to mark a
deliberate, reviewed sync — none exist today). ``_refresh_bounds``,
``_insert_edges_host``/``_remove_edges_host``, ``_defrag_to``,
``_maybe_renumber``, ``edge_slot``, ``cores``/``labels`` are NOT in the
sync-free set: they are the documented amortized/host/query sync points.

Beyond api.py, the engine-level builders are linted too (LINT_TARGETS):
``core/engine.py`` (``batch_program`` / ``apply_batch`` /
``batch_dedup`` / ``table_lookup``), ``core/sharded.py``
(``make_sharded_apply`` including its nested shard_map kernel), and the
fixpoint builders in ``core/remove.py`` / ``core/insert.py`` (the Order
removal/promotion fixpoints, the weighted h-index passes, and their
halo twins — all traced round bodies). Those are free functions, so
device state is matched by bare parameter name (DEVICE_PARAMS) rather
than ``self.<field>``.

Run as ``python -m repro.analysis.hostlint`` (CI) or through
tests/test_analysis.py.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import sys
from typing import List, Optional, Sequence

_CORE_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), os.pardir, "core"
))
_LAUNCH_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), os.pardir, "launch"
))
API_PATH = os.path.join(_CORE_DIR, "api.py")
ENGINE_PATH = os.path.join(_CORE_DIR, "engine.py")
SHARDED_PATH = os.path.join(_CORE_DIR, "sharded.py")
REMOVE_PATH = os.path.join(_CORE_DIR, "remove.py")
INSERT_PATH = os.path.join(_CORE_DIR, "insert.py")
VERTEX_LAYOUT_PATH = os.path.join(_CORE_DIR, "vertex_layout.py")
MESH_PATH = os.path.join(_LAUNCH_DIR, "mesh.py")

# the per-batch edit path + every planning helper it calls; a sync in
# any of these lands on the critical path of EVERY batch
SYNC_FREE_FUNCS = frozenset({
    "apply_batch",
    "insert_edges",
    "remove_edges",
    "_validated",
    "_ensure_capacity",
    "_window",
    "_frontier_bucket",
    "_get_sharded_fn",
    "plan_window",
    "plan_frontier_cap",
    "bucket_lattice",
})

# per-file sync-free sets: the engine-level batch builders and the
# shard_map kernel constructor are traced code — ANY host coercion of a
# device-array parameter there is a sync baked into every batch (and
# usually a silent ConcretizationTypeError waiting for jit)
LINT_TARGETS = {
    API_PATH: SYNC_FREE_FUNCS,
    ENGINE_PATH: frozenset({
        "batch_program", "apply_batch", "batch_dedup", "table_lookup",
    }),
    SHARDED_PATH: frozenset({"make_sharded_apply"}),
    # the fixpoint builders themselves: everything here is (or is inlined
    # into) traced round bodies, so a host coercion of a device parameter
    # is a per-round sync — or a ConcretizationTypeError the moment the
    # builder runs under jit. Covers the unweighted Order fixpoints, the
    # weighted h-index passes, and their halo twins.
    REMOVE_PATH: frozenset({
        "removal_fixpoint", "removal_fixpoint_halo",
        "weighted_core_fixpoint_pass", "weighted_core_fixpoint_pass_halo",
        "_weighted_h_index_halo", "remove_batch",
    }),
    INSERT_PATH: frozenset({
        "freelist_alloc", "write_edge_slots",
        "promotion_fixpoint", "promotion_fixpoint_halo",
        "_forward_reach", "_forward_reach_halo",
        "_evict_fixpoint", "_evict_fixpoint_halo",
        "weighted_promotion_fixpoint", "weighted_promotion_fixpoint_halo",
        "insert_batch",
    }),
    # the halo vertex-layout layer: every session method runs INSIDE the
    # per-round shard_map body, so a host coercion there is a sync (or a
    # tracer leak) replayed every fixpoint round
    VERTEX_LAYOUT_PATH: frozenset({
        "bind", "gather_values", "complete", "refresh_mask",
        "refresh_values", "locate", "any_owned", "frontier_peak",
        "add_at", "gather_state", "gather_mask", "own", "make_layout",
    }),
    # mesh constructors run at plan time on the batch critical path —
    # they size axes from static config, never from device scalars
    MESH_PATH: frozenset({
        "make_edge_mesh", "make_edge_vertex_mesh", "make_mesh",
    }),
}

# fields of CoreMaintainer that live on device mid-stream — forcing any
# of them to host blocks until the in-flight batch program finishes
DEVICE_FIELDS = frozenset({
    "src", "dst", "valid", "core", "label", "n_edges",
    "last_batch_stats", "last_insert_stats", "last_remove_stats",
})

# bare parameter names that carry device arrays through the engine-level
# helpers (free functions — no `self.`); matched as plain Names so
# `int(n_edges)` inside batch_program is flagged just like
# `int(self.n_edges)` inside apply_batch
DEVICE_PARAMS = frozenset({
    "src", "dst", "valid", "core", "label", "n_edges", "stats",
    "seed", "slots",
    # vertex-layout session arguments (owned slices, frontier masks,
    # the bound halo id vector) — device-resident inside shard_map
    "owned", "owned_mask", "halo_ids", "core_own", "label_own",
    # fixpoint-builder arguments (core/remove.py / core/insert.py): the
    # halo-gathered working set, the weighted per-slot weight column and
    # replicated total-batch-weight scalar, and the promotion phase's
    # per-lane insert state
    "src_h", "dst_h", "core_h", "label_h",
    "w", "total_w", "ins_w",
    "new_src", "new_dst", "new_ok", "iok", "rok",
    "hi", "dout_same", "u_pos", "v_pos",
})

# aval metadata readable without a device round trip: `x.shape[0]` on a
# device param is static planning input, not a sync
STATIC_META_ATTRS = frozenset({"shape", "dtype", "ndim", "size",
                               "itemsize", "sharding"})

SYNC_BUILTINS = frozenset({"int", "float", "bool"})
SYNC_ATTR_CALLS = frozenset({
    ("np", "asarray"), ("np", "array"),
    ("numpy", "asarray"), ("numpy", "array"),
    ("jax", "device_get"),
})
ALLOW_MARK = "# sync: ok"


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    func: str
    lineno: int
    message: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (f"{self.path}:{self.lineno}: in sync-free "
                f"{self.func}(): {self.message}")


def _touches_device_state(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute):
        if node.attr in STATIC_META_ATTRS:
            return False  # aval metadata: no round trip under the read
        if (isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in DEVICE_FIELDS):
            return True
    if isinstance(node, ast.Name) and node.id in DEVICE_PARAMS:
        return True
    return any(_touches_device_state(c) for c in ast.iter_child_nodes(node))


def _lint_func(fn: ast.AST, lines: Sequence[str],
               path: str) -> List[LintFinding]:
    out: List[LintFinding] = []

    def hit(node: ast.AST, message: str) -> None:
        out.append(LintFinding(path, fn.name, node.lineno, message))

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if ALLOW_MARK in line:
            continue
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "block_until_ready":
                hit(node, "calls .block_until_ready() — an unconditional "
                          "device sync")
            elif f.attr == "item" and not node.args:
                hit(node, "calls .item() — an unconditional device sync")
            elif (isinstance(f.value, ast.Name)
                    and (f.value.id, f.attr) in SYNC_ATTR_CALLS
                    and any(_touches_device_state(a) for a in node.args)):
                hit(node, f"{f.value.id}.{f.attr}(...) forces a "
                          "device-resident field to host")
        elif (isinstance(f, ast.Name) and f.id in SYNC_BUILTINS
                and any(_touches_device_state(a) for a in node.args)):
            hit(node, f"{f.id}(...) forces a device-resident field to "
                      "host (blocks on the in-flight batch)")
    return out


def lint_file(path: Optional[str] = None,
              funcs: Optional[frozenset] = None) -> List[LintFinding]:
    """Lint one source file; returns findings for every forbidden sync
    construct inside the named sync-free functions (default: the file's
    ``LINT_TARGETS`` entry, or the api.py set)."""
    path = path or API_PATH
    if funcs is None:
        funcs = LINT_TARGETS.get(os.path.normpath(path), SYNC_FREE_FUNCS)
    with open(path) as fh:
        src = fh.read()
    tree = ast.parse(src, filename=path)
    lines = src.splitlines()
    findings: List[LintFinding] = []
    for node in ast.walk(tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name in funcs):
            findings.extend(_lint_func(node, lines, path))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    paths = (list(argv if argv is not None else sys.argv[1:])
             or sorted(LINT_TARGETS))
    findings: List[LintFinding] = []
    for p in paths:
        findings.extend(lint_file(p))
    for f in findings:
        print(f)
    if findings:
        print(f"hostlint: {len(findings)} sync violation(s)")
        return 1
    print(f"hostlint: clean ({', '.join(os.path.basename(p) for p in paths)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
