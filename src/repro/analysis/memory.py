"""Symbolic per-device memory auditing — buffer lifetimes over jaxprs.

The ROADMAP's billion-edge direction stands on a MEMORY claim ("O(n/d +
halo) per device after the halo refactor") the way the traffic model
stands on collective counts. This module makes that claim checkable
before the refactor exists:

* ``profile_program`` — a buffer-lifetime pass over the walked jaxpr
  (the same nested pjit/while/cond/shard_map traversal as walker.py):
  every equation is a program point whose live bytes are the deduped sum
  of all buffers still referenced, in this frame and every enclosing
  one. Non-donated program inputs are pinned to the end (the caller
  still owns them); donated inputs die at their last use — which is
  exactly how XLA donation frees them, so the donation credit falls out
  of ordinary liveness instead of being bolted on.
* symbolic formulas — the observed peak / per-round peak / at-rest
  byte counts are re-expressed as closed forms in the audit size names
  (n, d, cap, window, local_cap, …), like the collective budgets'
  ``recv_bytes`` formulas. A single trace cannot disambiguate them, so
  sharded engines are traced at SEVERAL mesh points — the current mesh
  plus an explicit 1-device mesh (``trace_engine(..., devices=1)``),
  and, for the 2-axis halo engine, every other (d_e, d_v)
  factorization of the device count: shard_map traces one program
  regardless of mesh size, so the paired point sequences are identical
  and every buffer dimension is solved against all size environments
  at once (the extra factorizations pin d_v-only dependences and the
  peak program point, both invisible to a d-only pair).
* the sharding-propagation rule — any vertex-sized O(n) buffer live
  REPLICATED inside a shard_map body (a 1-D ``all_gather`` output with
  >= n elements: tiled gathers that materialize full vertex-indexed
  arrays; the 2-D ``[d, ...]`` gathers keep their shard dimension and
  are bounded exchange buffers). The halo refactor deleted the one
  violation this ever flagged — the per-batch entry core/label gather
  of the PR-7 range engine and its one-entry waiver list — so
  every range/halo engine now passes the rule UNWAIVED and the
  manifests carry an empty waiver list that CI keeps empty.

Everything is static: no program executes; all byte counts come from
equation avals, and ``tests/test_memory_audit.py`` cross-checks the
d=1 formulas against real buffer sizes and the compiled program's
``memory_analysis()``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from .rules import Finding, eval_formula, rule
from .walker import ROUND_TAG, iter_sites

# per-program positions of the persistent state arguments, as seen by
# the per-device program body (the at-rest working set of the engine)
STATE_ARGS: Dict[str, Tuple[Tuple[str, int], ...]] = {
    "apply_batch": (("src", 0), ("dst", 1), ("valid", 2),
                    ("core", 3), ("label", 4), ("n_edges", 5)),
    "insert_batch": (("src", 0), ("dst", 1), ("valid", 2),
                     ("core", 3), ("label", 4), ("n_edges", 8)),
    "remove_batch": (("src", 0), ("dst", 1), ("valid", 2),
                     ("core", 3), ("label", 4)),
}

# per-dimension candidate formulas, most-specific first: a dimension is
# committed as the FIRST candidate matching its value in EVERY paired
# size environment, so a d=8/d=1 pair pins e.g. 192 to "n" (not
# "n_owned", which is 24 on the 8-device side). A dimension equal in
# all environments with no matching candidate folds into the literal
# coefficient (constant across mesh points by construction).
DIM_CANDIDATES = (
    "n + 2",
    "cap + 1",
    "local_cap - window",
    "2 * local_cap",
    "d_e * local_cap",
    "local_cap",
    "d_v * hcap",
    "hcap",
    "max(d_v - 1, 1)",
    "window",
    "cap",
    "n",
    "n_owned",
    "lanes",
    "d",
    "d_e",
    "d_v",
    "ceil_div(n_owned, 8)",
    "ceil_div(n, 8)",
    "n_owned * d",
)


def _is_literal(v) -> bool:
    return hasattr(v, "val") and not hasattr(v, "count")


def _aval_bytes(aval) -> int:
    size = 1
    for d in getattr(aval, "shape", ()):
        size *= int(d)
    return size * aval.dtype.itemsize


def _aval_elems(aval) -> int:
    size = 1
    for d in getattr(aval, "shape", ()):
        size *= int(d)
    return size


def _raw(jx):
    """ClosedJaxpr -> Jaxpr (identity on raw jaxprs)."""
    inner = getattr(jx, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return jx


def _body_and_map(closed):
    """The per-device program body plus the outer-arg -> body-invar map.

    Sharded programs are profiled inside the shard_map (where every
    shape is the device-local shard); host/unified programs are the
    pjit-unwrapped top jaxpr. Argument positions are tracked by var
    identity through both unwrappings because shard_map prepends
    hoisted scalar constants to its body's invars — position ``i`` of
    the public program is NOT invar ``i`` of the body."""
    jaxpr = _raw(closed)
    tracked = list(jaxpr.invars)
    while len(jaxpr.eqns) == 1 and jaxpr.eqns[0].primitive.name == "pjit":
        eqn = jaxpr.eqns[0]
        sub = _raw(eqn.params["jaxpr"])
        pos = {id(v): i for i, v in enumerate(eqn.invars)}
        tracked = [
            sub.invars[pos[id(v)]] if id(v) in pos else None
            for v in tracked
        ]
        jaxpr = sub
    sm = [e for e in jaxpr.eqns if e.primitive.name == "shard_map"]
    if len(sm) == 1:
        eqn = sm[0]
        body = _raw(eqn.params["jaxpr"])
        pos = {id(v): i for i, v in enumerate(eqn.invars)}
        tracked = [
            body.invars[pos[id(v)]] if id(v) in pos else None
            for v in tracked
        ]
        jaxpr = body
    by_id = {id(v): i for i, v in enumerate(jaxpr.invars)}
    amap = {
        i: by_id[id(v)]
        for i, v in enumerate(tracked)
        if v is not None and id(v) in by_id
    }
    return jaxpr, amap


def program_body(closed):
    """The per-device program body (see ``_body_and_map``)."""
    return _body_and_map(closed)[0]


def body_arg_map(closed) -> Dict[int, int]:
    """Map public program argument index -> body invar index."""
    return _body_and_map(closed)[1]


class _Buf:
    """One buffer: allocation order is the pairing key across traces."""

    __slots__ = ("uid", "aval", "nbytes")

    def __init__(self, uid: int, aval) -> None:
        self.uid = uid
        self.aval = aval
        self.nbytes = _aval_bytes(aval)


@dataclasses.dataclass
class Profile:
    """Program points of one liveness pass, in deterministic walk order.

    ``point_bytes[i]`` is the deduped live-byte total at point ``i``;
    ``in_round[i]`` marks points inside a ``lax.while_loop`` body (the
    per-round working set). ``captured`` maps a requested point index to
    the live avals there (allocation-ordered — the pairing contract).
    """

    point_bytes: List[int]
    in_round: List[bool]
    captured: Dict[int, Tuple[Any, ...]]

    @property
    def peak(self) -> int:
        return max(self.point_bytes) if self.point_bytes else 0

    @property
    def peak_index(self) -> int:
        return self.point_bytes.index(self.peak)

    def round_peak_index(self) -> Optional[int]:
        best = None
        for i, (b, r) in enumerate(zip(self.point_bytes, self.in_round)):
            if r and (best is None or b > self.point_bytes[best]):
                best = i
        return best

    @property
    def round_peak(self) -> int:
        i = self.round_peak_index()
        return 0 if i is None else self.point_bytes[i]


def _sub_specs(eqn) -> Iterator[Tuple[str, Any, List[Any], bool]]:
    """Yield ``(tag, sub_jaxpr, sub_invar_sources, alias_outs)`` for
    every sub-jaxpr of an equation. ``sub_invar_sources[i]`` is the eqn
    invar (or Literal) feeding sub invar ``i``; ``alias_outs`` marks
    sub-jaxprs whose outvars ARE the equation's outvars (while carries,
    pjit results)."""
    prim = eqn.primitive.name
    if prim == "while":
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        ncar = len(eqn.invars) - cn - bn
        ins = list(eqn.invars)
        yield ("while:cond_jaxpr", _raw(eqn.params["cond_jaxpr"]),
               ins[:cn] + ins[cn + bn:], False)
        yield ("while:body_jaxpr", _raw(eqn.params["body_jaxpr"]),
               ins[cn:], True)
    elif prim == "cond":
        ops = list(eqn.invars[1:])
        for i, br in enumerate(eqn.params["branches"]):
            yield f"cond:branches[{i}]", _raw(br), ops, False
    else:
        for name, val in eqn.params.items():
            vals = val if isinstance(val, (list, tuple)) else [val]
            many = isinstance(val, (list, tuple))
            for i, v in enumerate(vals):
                sub = _raw(v)
                if not hasattr(sub, "eqns"):
                    continue
                tag = f"{prim}:{name}[{i}]" if many else f"{prim}:{name}"
                srcs = (list(eqn.invars)
                        if len(sub.invars) == len(eqn.invars) else
                        [None] * len(sub.invars))
                yield tag, sub, srcs, len(sub.outvars) == len(eqn.outvars)


def profile_program(closed, donated: Sequence[int] = (),
                    capture: Sequence[int] = ()) -> Profile:
    """Run the buffer-lifetime pass over a traced program's body.

    Models exactly the residency XLA enforces: every equation allocates
    its outputs; a buffer stays live until its last reader (across
    nested frames — a sub-jaxpr executes with every enclosing frame's
    live buffers still resident); non-donated program inputs and all
    program outputs are pinned to the end; donated inputs (``donated``,
    in PUBLIC argument positions — remapped to body invars internally)
    are freed at their last use. Aliasing is positional and
    aval-checked: while-loop carries, pjit results, and pass-throughs
    share one buffer instead of double counting.
    """
    body, amap = _body_and_map(closed)
    uid = itertools.count()
    want = frozenset(int(i) for i in capture)
    prof = Profile(point_bytes=[], in_round=[], captured={})

    def walk(jx, in_bufs: List[Optional[_Buf]],
             outer: Dict[int, _Buf], path: Tuple[str, ...],
             pin: Optional[frozenset]) -> List[Optional[_Buf]]:
        env: Dict[Any, _Buf] = {}
        for v, b in zip(jx.invars, in_bufs):
            env[v] = b if b is not None else _Buf(next(uid), v.aval)
        for v in getattr(jx, "constvars", ()):
            env[v] = _Buf(next(uid), v.aval)

        n_eq = len(jx.eqns)
        last: Dict[Any, int] = {}
        for i, eqn in enumerate(jx.eqns):
            for v in eqn.invars:
                if not _is_literal(v):
                    last[v] = i
        for v in jx.outvars:
            if not _is_literal(v):
                last[v] = n_eq
        # pinned (non-donated) top-frame inputs are held for the
        # caller; constvars are compile-time residents either way.
        # Sub-frames pin nothing — their invars alias parent buffers
        # whose lifetime the parent frame already tracks.
        for v in getattr(jx, "constvars", ()):
            last[v] = n_eq
        if pin is not None:
            for pos, v in enumerate(jx.invars):
                if pos in pin:
                    last[v] = n_eq

        # refcounted frame-live set (a buffer may back several vars)
        refs: Dict[int, int] = {}
        bufs: Dict[int, _Buf] = {}
        frame_bytes = 0

        def add(b: _Buf) -> None:
            nonlocal frame_bytes
            refs[b.uid] = refs.get(b.uid, 0) + 1
            if refs[b.uid] == 1:
                bufs[b.uid] = b
                if b.uid not in outer:
                    frame_bytes += b.nbytes

        def drop(b: _Buf) -> None:
            nonlocal frame_bytes
            refs[b.uid] -= 1
            if refs[b.uid] == 0:
                del refs[b.uid], bufs[b.uid]
                if b.uid not in outer:
                    frame_bytes -= b.nbytes

        for v, b in env.items():
            if v in last:
                add(b)
        outer_bytes = sum(b.nbytes for b in outer.values())
        in_round = ROUND_TAG in path

        death: List[List[Any]] = [[] for _ in range(n_eq + 1)]
        for v, i in last.items():
            if i < n_eq and v in env:
                death[i].append(v)

        for i, eqn in enumerate(jx.eqns):
            out_bufs: Optional[List[Optional[_Buf]]] = None
            subs = list(_sub_specs(eqn))
            if subs:
                snapshot = dict(outer)
                snapshot.update(bufs)
                for tag, sub, srcs, alias_outs in subs:
                    sub_in: List[Optional[_Buf]] = []
                    for sv, src in zip(sub.invars, srcs):
                        b = (env.get(src) if src is not None
                             and not _is_literal(src) else None)
                        sub_in.append(
                            b if b is not None and b.aval == sv.aval
                            else None
                        )
                    ret = walk(sub, sub_in, snapshot, path + (tag,),
                               pin=None)
                    if alias_outs and len(ret) == len(eqn.outvars):
                        out_bufs = [
                            b if b is not None and b.aval == ov.aval
                            else None
                            for b, ov in zip(ret, eqn.outvars)
                        ]
            new: List[_Buf] = []
            for k, ov in enumerate(eqn.outvars):
                b = out_bufs[k] if out_bufs is not None else None
                if b is None:
                    b = _Buf(next(uid), ov.aval)
                if ov in last:
                    add(b)
                    new.append(b)
                env[ov] = b
            idx = len(prof.point_bytes)
            prof.point_bytes.append(outer_bytes + frame_bytes)
            prof.in_round.append(in_round or ROUND_TAG in path)
            if idx in want:
                live = dict(outer)
                live.update(bufs)
                prof.captured[idx] = tuple(
                    b.aval for b in sorted(live.values(),
                                           key=lambda b: b.uid)
                )
            for v in death[i]:
                drop(env[v])
        return [None if _is_literal(v) else env.get(v)
                for v in jx.outvars]

    body_donated = {amap[i] for i in donated if i in amap}
    in_bufs = [_Buf(next(uid), v.aval) for v in body.invars]
    walk(body, in_bufs, {}, (), pin=frozenset(
        i for i in range(len(body.invars)) if i not in body_donated))
    return prof


# -- symbolic formulas over paired traces ---------------------------------

def _dim_formula(values: Sequence[int],
                 envs: Sequence[Dict[str, int]]) -> Optional[str]:
    """The first candidate matching the dimension's value in EVERY
    paired environment; None folds an env-constant dimension into the
    coefficient; a device-varying dimension with no candidate raises.

    More environments make the solve stricter, and the 2-axis layouts
    need that: at the canonical (d_e, d_v) = (4, 2) point AND the
    1-device pair, ``max(d_v - 1, 1)`` (the ring scan's step count)
    evaluates to 1 — indistinguishable from a unit dim — so a third
    trace under the transposed factorization is what pins every
    d_v-only dependence."""
    if all(v == 1 for v in values):
        # unit dims (squeezes, keepdims) are structure, not size — a
        # symbolic match ("cap + 1" at cap=0) would claim a dependence
        # the buffer doesn't have
        return None
    for cand in DIM_CANDIDATES:
        try:
            ok = all(eval_formula(cand, e) == v
                     for v, e in zip(values, envs))
        except ValueError:
            continue  # candidate names a size this env does not carry
        if ok:
            return cand
    if len(set(values)) == 1:
        return None
    points = ", ".join(
        f"{v} @ d={e.get('d', '?')} "
        f"({e.get('d_e', '?')}x{e.get('d_v', '?')})"
        for v, e in zip(values, envs)
    )
    raise RuntimeError(
        f"cannot express buffer dimension ({points}) with any "
        "DIM_CANDIDATES entry — add a candidate to "
        "repro.analysis.memory"
    )


def _point_formula(avals_lists: Sequence[Sequence[Any]],
                   envs: Sequence[Dict[str, int]]) -> str:
    """Closed form of one program point's live bytes, from the paired
    live-aval lists (identical allocation order by construction; one
    list per traced environment)."""
    if len({len(a) for a in avals_lists}) != 1:
        raise RuntimeError(
            f"paired traces disagree on the live set: "
            f"{[len(a) for a in avals_lists]} buffers — the program is "
            "not mesh-size-independent"
        )
    terms: Dict[Tuple[str, ...], int] = {}
    for bufs in zip(*avals_lists):
        a0 = bufs[0]
        if any(len(b.shape) != len(a0.shape) or b.dtype != a0.dtype
               for b in bufs[1:]):
            raise RuntimeError(
                "paired live buffers disagree in rank/dtype: "
                + " vs ".join(f"{b.dtype}{list(b.shape)}" for b in bufs)
            )
        coeff = a0.dtype.itemsize
        factors: List[str] = []
        for dims in zip(*(b.shape for b in bufs)):
            f = _dim_formula([int(x) for x in dims], envs)
            if f is None:
                coeff *= int(dims[0])
            else:
                factors.append(f)
        key = tuple(sorted(factors))
        terms[key] = terms.get(key, 0) + coeff
    parts = []
    for key in sorted(terms, key=lambda k: (-len(k), k)):
        factors = [f"({f})" if ("+" in f or "-" in f) else f for f in key]
        parts.append(" * ".join([str(terms[key])] + list(factors)))
    return " + ".join(parts) if parts else "0"


def _verified(formula: str, envs_and_values) -> str:
    for env, value in envs_and_values:
        got = eval_formula(formula, env)
        if got != value:
            raise RuntimeError(
                f"memory formula self-check failed: {formula!r} = {got} "
                f"but the liveness pass observed {value} (env {env})"
            )
    return formula


def _aval_formula(avals, envs) -> str:
    return _verified(
        _point_formula([[a] for a in avals], envs),
        [(e, _aval_bytes(a)) for e, a in zip(envs, avals)],
    )


# -- the replicated-O(n)-buffer rule --------------------------------------

def replicated_vertex_sites(closed, n: int) -> List[Tuple[Any, int]]:
    """Sites materializing a full vertex-indexed array replicated inside
    the (per-device) program body: 1-D ``all_gather`` outputs with
    >= n elements. Tiled state/mask gathers reconstruct O(n) arrays on
    every device; 2-D ``[d, ...]`` gathers keep their shard dimension
    and are bounded exchange buffers, deliberately NOT flagged (the
    sparse frontier payload ``[d, cap+1]`` may exceed n elements while
    staying O(cap * d)). Returns ``(site, n_elems)`` pairs."""
    body = program_body(closed)
    out = []
    for s in iter_sites(body):
        if s.prim != "all_gather":
            continue
        for ov in s.eqn.outvars:
            shape = getattr(ov.aval, "shape", ())
            if len(shape) == 1 and int(shape[0]) >= n:
                out.append((s, int(shape[0])))
    return out


# -- manifest generation --------------------------------------------------

def generate_memory_section(traced, paired=None) -> dict:
    """The budget manifest's ``memory`` section for one traced engine.

    ``paired`` is the same engine traced at one or more OTHER mesh
    points (a single trace or a sequence) — required to disambiguate
    size formulas for sharded engines; without it every dimension is
    solved against one environment only and the committed formula is
    valid only on the generating device count (the audit CLI warns
    about exactly this for ``--write-budgets`` at 1 device). Halo
    engines pair against BOTH the 1-device trace and the transposed
    8-device factorization: the first varies d, the second varies
    (d_e, d_v) at fixed d, and only together do they pin formulas like
    the ring scan's ``max(d_v - 1, 1)`` step count (equal to 1 at both
    the canonical and the 1-device point).
    """
    if paired is None:
        paireds = []
    elif isinstance(paired, (list, tuple)):
        paireds = list(paired)
    else:
        paireds = [paired]
    traces = [traced] + paireds
    envs = [t.sizes for t in traces]
    env_a = traced.sizes
    cfg = traced.config
    programs: Dict[str, dict] = {}
    # every halo-sharded engine ("range" is the edge_axes=() degenerate)
    # must pass the replicated-buffer rule UNWAIVED: the entry state
    # gather this rule was born flagging no longer exists
    forbid = cfg.vertex_sharding in ("range", "halo")

    for prog, closed in traced.programs.items():
        donated = traced.donated.get(prog, ())
        profs = [profile_program(t.programs[prog], donated)
                 for t in traces]
        if len({len(p.point_bytes) for p in profs}) != 1:
            raise RuntimeError(
                f"{cfg.name}/{prog}: paired traces walk "
                f"{[len(p.point_bytes) for p in profs]} "
                "program points — cannot pair buffer dimensions"
            )
        idx = {p.peak_index for p in profs}
        rids = [p.round_peak_index() for p in profs]
        ridx = {i for i in rids if i is not None}
        caps = [profile_program(t.programs[prog], donated,
                                capture=idx | ridx)
                for t in traces]

        def point_form(i: int) -> str:
            return _verified(
                _point_formula([c.captured[i] for c in caps], envs),
                [(e, p.point_bytes[i]) for e, p in zip(envs, profs)],
            )

        def peak_form(indices, peaks) -> str:
            uniq = sorted(set(indices))
            if len(uniq) == 1:
                return point_form(uniq[0])
            forms = [point_form(i) for i in uniq]
            return _verified("max(" + ", ".join(forms) + ")",
                             list(zip(envs, peaks)))

        bodies = [_body_and_map(t.programs[prog]) for t in traces]
        at_rest = [
            [name, _aval_formula(
                [b.invars[m[pos]].aval for b, m in bodies], envs)]
            for name, pos in STATE_ARGS.get(prog, ())
            # seeded test programs reuse engine program names with fewer
            # args — budget only the positions that exist
            if all(pos in m for _, m in bodies)
        ]
        davs = [[b.invars[m[i]].aval for i in donated]
                for b, m in bodies]
        donated_form = (
            "0" if not donated else _verified(
                _point_formula(davs, envs),
                [(e, sum(map(_aval_bytes, dv)))
                 for e, dv in zip(envs, davs)],
            )
        )
        programs[prog] = {
            "at_rest": at_rest,
            "peak": peak_form([p.peak_index for p in profs],
                              [p.peak for p in profs]),
            "round_peak": (
                peak_form(rids, [p.round_peak for p in profs])
                if all(r is not None for r in rids) else "0"
            ),
            "donated": donated_form,
        }
        if forbid:
            offenders = replicated_vertex_sites(closed, env_a["n"])
            if offenders:
                sites = ", ".join(
                    f"{'/'.join(s.path) or '<top>'} ({elems} elems)"
                    for s, elems in offenders
                )
                raise RuntimeError(
                    f"{cfg.name}/{prog}: {len(offenders)} replicated "
                    f"O(n) all_gather site(s) in the shard_map body "
                    f"[{sites}] — the halo refactor deleted the entry "
                    "gather and with it the waiver mechanism; "
                    "vertex-sized state must stay owned slices"
                )
    return {
        "programs": programs,
        "forbid_replicated_vertex_buffers": forbid,
        "require_state_donated": cfg.engine != "host",
        "waivers": [],
    }


# -- the check rule -------------------------------------------------------

@rule("memory_budget")
def check_memory(traced, budget: dict) -> List[Finding]:
    cfg = traced.config
    env = traced.sizes
    findings: List[Finding] = []

    def bad(msg: str, program: str = "") -> None:
        findings.append(Finding("memory_budget", cfg.name, msg, program))

    mem = budget.get("memory")
    if mem is None:
        bad(
            "budget manifest has no memory section — regenerate with "
            "`python -m repro.analysis.audit --write-budgets --devices 8`"
        )
        return findings

    specs = mem.get("programs", {})
    for prog, closed in traced.programs.items():
        spec = specs.get(prog)
        if spec is None:
            bad(f"no memory budget for program {prog!r} — regenerate "
                "with `audit --write-budgets`", prog)
            continue
        donated = traced.donated.get(prog, ())
        prof = profile_program(closed, donated)
        body, amap = _body_and_map(closed)

        for key, observed in (("peak", prof.peak),
                              ("round_peak", prof.round_peak)):
            want = eval_formula(spec.get(key, "0"), env)
            if want != observed:
                bad(
                    f"{key} live bytes drifted: budget formula "
                    f"{spec.get(key)!r} = {want}B but the liveness pass "
                    f"observes {observed}B per device",
                    prog,
                )
        for name, pos in STATE_ARGS.get(prog, ()):
            if pos not in amap:
                continue
            entry = dict(spec.get("at_rest", []) or []).get(name)
            actual = _aval_bytes(body.invars[amap[pos]].aval)
            if entry is None:
                bad(f"at_rest entry for state arg {name!r} missing "
                    "from the memory budget", prog)
            elif eval_formula(entry, env) != actual:
                bad(
                    f"at_rest[{name}]: formula {entry!r} = "
                    f"{eval_formula(entry, env)}B but the state buffer "
                    f"holds {actual}B per device",
                    prog,
                )
        don_actual = sum(_aval_bytes(body.invars[amap[i]].aval)
                         for i in donated)
        if eval_formula(spec.get("donated", "0"), env) != don_actual:
            bad(
                f"donated credit drifted: formula "
                f"{spec.get('donated')!r} = "
                f"{eval_formula(spec.get('donated', '0'), env)}B but "
                f"the donated inputs hold {don_actual}B",
                prog,
            )

        if mem.get("require_state_donated"):
            thresh = env["n_owned"]
            pool = [body.invars[amap[i]].aval for i in donated]
            for k, ov in enumerate(body.outvars):
                aval = getattr(ov, "aval", None)
                if aval is None or _aval_elems(aval) < thresh:
                    continue
                if aval in pool:
                    pool.remove(aval)
                    continue
                bad(
                    f"output {k} ({aval.dtype}{list(aval.shape)}) is "
                    "vertex-sized but aliases no donated input — an "
                    "undonated state-sized output is a hidden per-batch "
                    "copy",
                    prog,
                )

        if mem.get("forbid_replicated_vertex_buffers"):
            waived: Dict[Tuple[str, bool], int] = {}
            for w in mem.get("waivers", []):
                if w.get("program") == prog:
                    key = (w.get("op"), bool(w.get("in_round")))
                    waived[key] = waived.get(key, 0) + int(w["count"])
            found: Dict[Tuple[str, bool], List] = {}
            for s, elems in replicated_vertex_sites(closed, env["n"]):
                found.setdefault((s.prim, s.in_round), []).append(
                    (s, elems))
            for key, sites in found.items():
                allowed = waived.get(key, 0)
                for s, elems in sites[allowed:]:
                    bad(
                        f"O(n)-replicated buffer inside the shard_map "
                        f"body: 1-D {s.prim} output of {elems} elems "
                        f"(>= n={env['n']}) at "
                        f"{'/'.join(s.path) or '<top>'} with no "
                        "committed waiver — vertex-sized state must "
                        "stay owned slices",
                        prog,
                    )
            for key, allowed in waived.items():
                n_found = len(found.get(key, []))
                if n_found < allowed:
                    bad(
                        f"stale waiver: {allowed} {key[0]} site(s) "
                        f"(in_round={key[1]}) waived but only "
                        f"{n_found} traced — delete the waiver "
                        "(regenerate with `audit --write-budgets`)",
                        prog,
                    )
    return findings
