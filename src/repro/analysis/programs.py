"""Trace the engine matrix's programs for static auditing.

The repo's performance claims are STRUCTURAL properties of traced
programs (one reduce_scatter per round, no vertex-sized psum under the
sparse exchange, donated batch buffers, a bounded jit-variant lattice).
This module produces the artifacts the audit rules inspect, without
executing a single batch:

* ``ENGINE_CONFIGS`` — the six bit-identical engine configurations
  (host / unified / sharded / vertex_range / frontier_sparse / pallas),
  exactly the matrix ``tests/test_churn_streams.py`` proves equivalent.
  The ``pallas`` config is the sharded engine with the fused COO stat
  kernels (kernels/coremaint.py): the fusion swaps only LOCAL partials,
  so its collective histogram and memory budgets must EQUAL the lax
  sharded config's — an equality the audit enforces, not assumes;
* ``trace_removal_round`` / ``trace_promotion_round`` — shard_map-trace
  ONE fixpoint under a vertex layout, returning both the trace-time
  traffic log (``record_traffic``) and the closed jaxpr: a
  ``lax.while_loop`` body traces exactly once, so either view IS the
  per-round collective budget (and ``rules.cross_check_round`` verifies
  they agree);
* ``trace_engine`` — the full picture for one config: batch-program
  jaxprs, lowered computations (for donation/aliasing checks), round
  traces, the planned (window, frontier-cap) buckets, and the size
  environment budget formulas evaluate in.

Audit parameters are fixed and small (n=64, capacity=256, 8 batch
lanes): collective COUNTS are device-count independent (shard_map
traces one program regardless of mesh size) and every SIZE is checked
against a closed-form formula in (n, d, cap, ...), so the same
committed manifest gates 1-device and 8-device CI runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.api import plan_frontier_cap, plan_window
from ..core.engine import DONATED_STATE_ARGS, apply_batch
from ..core.insert import insert_batch, promotion_fixpoint
from ..core.remove import remove_batch, removal_fixpoint
from ..core.sharded import make_sharded_apply
from ..core.vertex_layout import Traffic, make_layout, record_traffic

EDGE_AXIS = "data"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One point of the engine matrix, keyed by its audit name."""

    name: str
    engine: str                       # "host" | "unified" | "sharded"
    vertex_sharding: str = "replicated"
    frontier_exchange: str = "bitmask"
    frontier_cap: int = 0             # pinned sparse cap (sparse only)
    freelist: str = "interleaved"
    kernel_backend: str = "lax"       # "lax" | "pallas" stat kernels

    @property
    def is_sharded(self) -> bool:
        return self.engine == "sharded"


ENGINE_CONFIGS: Dict[str, EngineConfig] = {
    c.name: c
    for c in (
        EngineConfig("host", "host"),
        EngineConfig("unified", "unified"),
        EngineConfig("sharded", "sharded"),
        EngineConfig("vertex_range", "sharded", vertex_sharding="range"),
        EngineConfig(
            "frontier_sparse", "sharded", vertex_sharding="range",
            frontier_exchange="sparse", frontier_cap=16,
        ),
        EngineConfig("pallas", "sharded", kernel_backend="pallas"),
    )
}


@dataclasses.dataclass(frozen=True)
class AuditParams:
    """Fixed trace-time sizes. ``n`` and ``capacity`` must be divisible
    by every audited device count (1 and 8 in CI) so the range layout
    pads nothing and the formulas stay exact."""

    n: int = 64
    capacity: int = 256
    lanes: int = 8  # padded batch lanes (both insert and remove lists)

    @property
    def n_levels(self) -> int:
        return self.n + 2


def trace_removal_round(
    vertex_sharding: str, n: int, cap: int, mesh,
    frontier_cap: Optional[int] = None,
    kernel_backend: str = "lax",
) -> Tuple[List[Traffic], Any]:
    """Trace (not run) the removal fixpoint under shard_map.

    Returns ``(log, closed_jaxpr)``: the layout collectives recorded for
    ONE loop round plus the traced program (walk it with
    ``walker.primitive_names`` / ``walker.collectives``). This is the
    one source of truth behind the traffic assertions in
    ``tests/test_vertex_layout.py`` and the audit's round budgets.
    """
    axis = EDGE_AXIS
    n_shards = dict(mesh.shape)[axis]
    layout = (
        make_layout("range", n, axis, n_shards, frontier_cap)
        if vertex_sharding == "range"
        else make_layout("replicated", n, axis)
    )
    stat_spec = P(axis) if vertex_sharding == "range" else P()

    def kernel(src, dst, valid, core, label):
        return removal_fixpoint(src, dst, valid, core, label, n, n + 2,
                                layout=layout,
                                kernel_backend=kernel_backend)

    sm = shard_map(
        kernel, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(), P(), P(), stat_spec, stat_spec, P()),
        check_vma=False,
    )
    src = jnp.zeros(cap, jnp.int32)
    dst = jnp.ones(cap, jnp.int32)
    valid = jnp.zeros(cap, bool)
    core = jnp.zeros(n, jnp.int32)
    label = jnp.zeros(n, jnp.int64)
    with record_traffic() as log:
        jaxpr = jax.make_jaxpr(sm)(src, dst, valid, core, label)
    return log, jaxpr


def trace_promotion_round(
    vertex_sharding: str, n: int, cap: int, mesh,
    frontier_cap: Optional[int] = None, lanes: int = 8,
    kernel_backend: str = "lax",
) -> Tuple[List[Traffic], Any]:
    """Trace the promotion fixpoint under shard_map — the insertion-side
    counterpart of ``trace_removal_round``. Returns ``(log, jaxpr)``;
    records cover one outer round (seed + forward waves + evictions +
    the next-round statistics pass)."""
    axis = EDGE_AXIS
    n_shards = dict(mesh.shape)[axis]
    layout = (
        make_layout("range", n, axis, n_shards, frontier_cap)
        if vertex_sharding == "range"
        else make_layout("replicated", n, axis)
    )
    stat_spec = P(axis) if vertex_sharding == "range" else P()
    n_stat = layout.n_pad if vertex_sharding == "range" else n

    def kernel(src, dst, valid, core, label, nu, nv, nok, hi, dout):
        return promotion_fixpoint(src, dst, valid, core, label,
                                  nu, nv, nok, hi, dout, n, n + 2,
                                  layout=layout,
                                  kernel_backend=kernel_backend)

    sm = shard_map(
        kernel, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(),
                  P(), P(), P(), stat_spec, stat_spec),
        out_specs=(P(), P(), P(), P(), P()),
        check_vma=False,
    )
    src = jnp.zeros(cap, jnp.int32)
    dst = jnp.ones(cap, jnp.int32)
    valid = jnp.zeros(cap, bool)
    core = jnp.zeros(n, jnp.int32)
    label = jnp.zeros(n, jnp.int64)
    nu = jnp.zeros(lanes, jnp.int32)
    nv = jnp.ones(lanes, jnp.int32)
    nok = jnp.zeros(lanes, bool)
    hi = jnp.zeros(n_stat, jnp.int32)
    dout = jnp.zeros(n_stat, jnp.int32)
    with record_traffic() as log:
        jaxpr = jax.make_jaxpr(sm)(src, dst, valid, core, label,
                                   nu, nv, nok, hi, dout)
    return log, jaxpr


@dataclasses.dataclass
class TracedEngine:
    """Everything the audit rules inspect for one engine config."""

    config: EngineConfig
    params: AuditParams
    n_devices: int
    window: int           # planned per-shard active-window bucket
    frontier_cap: int     # planned sparse-cap bucket (0 = exchange off)
    programs: Dict[str, Any]        # name -> ClosedJaxpr (full program)
    lowered: Dict[str, Any]         # name -> jax.stages.Lowered
    donated: Dict[str, Tuple[int, ...]]  # name -> declared donated args
    rounds: Dict[str, Tuple[List[Traffic], Any]]  # name -> (log, jaxpr)
    sizes: Dict[str, int]           # env for budget recv_bytes formulas


def _batch_args(params: AuditParams, n_state: int):
    b = jnp.zeros(params.lanes, jnp.int32)
    ok = jnp.zeros(params.lanes, bool)
    return (
        jnp.zeros(params.capacity, jnp.int32),
        jnp.zeros(params.capacity, jnp.int32),
        jnp.zeros(params.capacity, bool),
        jnp.zeros(n_state, jnp.int32),
        jnp.zeros(n_state, jnp.int64),
        jnp.int32(0),
        b, b, ok, b, b, ok,
    )


def trace_engine(name: str,
                 params: Optional[AuditParams] = None,
                 devices: Optional[int] = None) -> TracedEngine:
    """Trace + lower every auditable program of one engine config on the
    current device count.

    ``devices`` forces a mesh size smaller than the process's device
    count (sharded configs only — host/unified always trace at d=1).
    The memory auditor uses this to trace each sharded program at TWO
    mesh sizes in one process: shard_map traces one program regardless
    of mesh size, so the paired jaxprs are structurally identical and a
    lockstep walk can solve each buffer dimension against two distinct
    size environments (repro.analysis.memory)."""
    if name not in ENGINE_CONFIGS:
        raise ValueError(
            f"unknown engine config {name!r} "
            f"(expected one of {sorted(ENGINE_CONFIGS)})"
        )
    cfg = ENGINE_CONFIGS[name]
    params = params or AuditParams()
    if not cfg.is_sharded:
        d = 1
    elif devices is not None:
        if devices > len(jax.devices()):
            raise ValueError(
                f"devices={devices} exceeds the process's "
                f"{len(jax.devices())} devices"
            )
        d = devices
    else:
        d = len(jax.devices())
    n, cap, lanes = params.n, params.capacity, params.lanes
    if cfg.is_sharded and (n % d or cap % d):
        raise ValueError(
            f"audit sizes n={n}, capacity={cap} must divide the device "
            f"count {d} (pad-free range layout keeps formulas exact)"
        )
    local_cap = cap // d
    n_owned = -(-n // d)
    window = plan_window(0, lanes, local_cap)
    fcap = plan_frontier_cap(cfg.frontier_exchange, cfg.frontier_cap,
                             lanes, n_owned)

    programs: Dict[str, Any] = {}
    lowered: Dict[str, Any] = {}
    donated: Dict[str, Tuple[int, ...]] = {}
    rounds: Dict[str, Tuple[List[Traffic], Any]] = {}

    if cfg.engine == "host":
        # the seed two-program path: one jit per edit kind, no donation
        # (the baseline copies per call — its manifest says so)
        src, dst, valid, core, label, n_edges, iu, iv, iok, ru, rv, rok = (
            _batch_args(params, n)
        )
        ins_args = (src, dst, valid, core, label, iu, iv, iok, n_edges)
        programs["insert_batch"] = jax.make_jaxpr(
            lambda *a: insert_batch(*a, n, params.n_levels)
        )(*ins_args)
        lowered["insert_batch"] = insert_batch.lower(
            *ins_args, n=n, n_levels=params.n_levels
        )
        donated["insert_batch"] = ()
        slots = jnp.full(lanes, -1, jnp.int32)
        rm_args = (src, dst, valid, core, label, slots)
        programs["remove_batch"] = jax.make_jaxpr(
            lambda *a: remove_batch(*a, n, params.n_levels)
        )(*rm_args)
        lowered["remove_batch"] = remove_batch.lower(
            *rm_args, n=n, n_levels=params.n_levels
        )
        donated["remove_batch"] = ()
    elif cfg.engine == "unified":
        args = _batch_args(params, n)
        programs["apply_batch"] = jax.make_jaxpr(
            lambda *a: apply_batch(*a, n, params.n_levels, window)
        )(*args)
        lowered["apply_batch"] = apply_batch.lower(
            *args, n=n, n_levels=params.n_levels, active_cap=window
        )
        donated["apply_batch"] = DONATED_STATE_ARGS
    else:
        mesh = jax.make_mesh((d,), (EDGE_AXIS,))
        fn = make_sharded_apply(
            mesh, n, params.n_levels, axis=EDGE_AXIS,
            local_active=window,
            vertex_sharding=cfg.vertex_sharding,
            freelist=cfg.freelist,
            frontier_exchange=cfg.frontier_exchange,
            frontier_cap=fcap,
            kernel_backend=cfg.kernel_backend,
        )
        n_state = n_owned * d if cfg.vertex_sharding == "range" else n
        args = _batch_args(params, n_state)
        programs["apply_batch"] = jax.make_jaxpr(fn)(*args)
        lowered["apply_batch"] = fn.lower(*args)
        donated["apply_batch"] = DONATED_STATE_ARGS
        round_fcap = fcap if cfg.frontier_exchange == "sparse" else None
        rounds["removal_round"] = trace_removal_round(
            cfg.vertex_sharding, n, cap, mesh, round_fcap,
            kernel_backend=cfg.kernel_backend,
        )
        rounds["promotion_round"] = trace_promotion_round(
            cfg.vertex_sharding, n, cap, mesh, round_fcap, lanes,
            kernel_backend=cfg.kernel_backend,
        )

    sizes = dict(
        n=n, d=d, cap=fcap, n_owned=n_owned, n_pad=n_owned * d,
        lanes=lanes, window=window, local_cap=local_cap,
    )
    return TracedEngine(
        config=cfg, params=params, n_devices=d, window=window,
        frontier_cap=fcap, programs=programs, lowered=lowered,
        donated=donated, rounds=rounds, sizes=sizes,
    )
