"""Trace the engine matrix's programs for static auditing.

The repo's performance claims are STRUCTURAL properties of traced
programs (one reduce_scatter per round, no vertex-sized psum under the
sparse exchange, donated batch buffers, a bounded jit-variant lattice).
This module produces the artifacts the audit rules inspect, without
executing a single batch:

* ``ENGINE_CONFIGS`` — the nine engine configurations
  (host / unified / sharded / vertex_range / frontier_sparse /
  vertex_halo / pallas, all bit-identical on unweighted streams, plus
  the weight-generalized ``weighted`` / ``weighted_sharded`` pair —
  bit-identical to each other and to ``weighted_core_oracle`` on
  weighted streams), exactly the matrix
  ``tests/test_churn_streams.py`` proves equivalent. The ``pallas``
  config is the sharded engine with the fused COO stat kernels
  (kernels/coremaint.py): the fusion swaps only LOCAL partials, so its
  collective histogram and memory budgets must EQUAL the lax sharded
  config's — an equality the audit enforces, not assumes. The
  ``vertex_halo`` config runs the halo working set on a genuine 2-axis
  edge x vertex mesh (``mesh_shape=(d_e, d_v)``,
  ``launch/mesh.py::make_edge_vertex_mesh``) — its manifest carries the
  §4.4 two-axis traffic/memory formulas in d_e/d_v/hcap, and the audit
  re-traces it under BOTH 8-device factorizations (4x2 and 2x4) against
  the one committed manifest;
* ``trace_removal_round`` / ``trace_promotion_round`` — shard_map-trace
  ONE fixpoint under a vertex layout, returning both the trace-time
  traffic log (``record_traffic``) and the closed jaxpr: a
  ``lax.while_loop`` body traces exactly once, so either view IS the
  per-round collective budget (and ``rules.cross_check_round`` verifies
  they agree);
* ``trace_engine`` — the full picture for one config: batch-program
  jaxprs, lowered computations (for donation/aliasing checks), round
  traces, the planned (window, frontier-cap) buckets, and the size
  environment budget formulas evaluate in.

Audit parameters are fixed and small (n=192, capacity=384, 8 batch
lanes): collective COUNTS are device-count independent (shard_map
traces one program regardless of mesh size) and every SIZE is checked
against a closed-form formula in (n, d, d_e, d_v, cap, hcap, ...), so
the same committed manifest gates 1-device and 8-device CI runs in
every mesh factorization. ``n`` is deliberately NOT a power of two:
the static halo capacity is (n=192, window=16, lanes=8 -> hcap=64),
and a pow2 ``n`` could collide with it, letting a halo buffer
dimension masquerade as a vertex-sized one in the solved formulas.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.api import plan_frontier_cap, plan_window
from ..core.engine import (
    DONATED_STATE_ARGS,
    WEIGHTED_DONATED_STATE_ARGS,
    apply_batch,
    apply_batch_weighted,
    build_halo_ids,
    halo_cap_for,
)
from ..core.insert import insert_batch, promotion_fixpoint, \
    promotion_fixpoint_halo
from ..core.remove import remove_batch, removal_fixpoint, \
    removal_fixpoint_halo, weighted_core_fixpoint_pass
from ..core.sharded import make_sharded_apply
from ..core.vertex_layout import Traffic, make_layout, record_traffic
from ..launch.mesh import EDGE_SHARD_AXIS, make_edge_vertex_mesh

EDGE_AXIS = "data"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One point of the engine matrix, keyed by its audit name."""

    name: str
    engine: str                       # "host" | "unified" | "sharded"
    vertex_sharding: str = "replicated"
    frontier_exchange: str = "bitmask"
    frontier_cap: int = 0             # pinned sparse cap (sparse only)
    freelist: str = "interleaved"
    kernel_backend: str = "lax"       # "lax" | "pallas" stat kernels
    weighted: bool = False            # weight-generalized engine (both
    #                                   phases run the weighted h-index
    #                                   bisection fixpoint; the slot
    #                                   table carries a weight column)
    # canonical (d_e, d_v) factorization for vertex_sharding="halo";
    # the audit CLI's --mesh-shape re-traces the same config (and the
    # same committed manifest) under other factorizations
    mesh_shape: Optional[Tuple[int, int]] = None

    @property
    def is_sharded(self) -> bool:
        return self.engine == "sharded"


ENGINE_CONFIGS: Dict[str, EngineConfig] = {
    c.name: c
    for c in (
        EngineConfig("host", "host"),
        EngineConfig("unified", "unified"),
        EngineConfig("sharded", "sharded"),
        EngineConfig("vertex_range", "sharded", vertex_sharding="range"),
        EngineConfig(
            "frontier_sparse", "sharded", vertex_sharding="range",
            frontier_exchange="sparse", frontier_cap=16,
        ),
        EngineConfig(
            "vertex_halo", "sharded", vertex_sharding="halo",
            frontier_exchange="sparse", frontier_cap=16,
            mesh_shape=(4, 2),
        ),
        EngineConfig("pallas", "sharded", kernel_backend="pallas"),
        EngineConfig("weighted", "unified", weighted=True),
        EngineConfig("weighted_sharded", "sharded", weighted=True),
    )
}


@dataclasses.dataclass(frozen=True)
class AuditParams:
    """Fixed trace-time sizes. ``n`` and ``capacity`` must be divisible
    by every audited device count (1 and 8 in CI, in every mesh
    factorization) so the range/halo layouts pad nothing and the
    formulas stay exact. ``n`` is NOT a power of two on purpose — the
    pow2 halo capacity (hcap=64 at these parameters) must never equal
    ``n`` or ``n_owned`` in either paired trace environment, or the
    memory formula solver could mislabel a halo buffer as vertex-sized
    (see the module docstring)."""

    n: int = 192
    capacity: int = 384
    lanes: int = 8  # padded batch lanes (both insert and remove lists)

    @property
    def n_levels(self) -> int:
        return self.n + 2


def resolve_mesh(cfg: EngineConfig, d: int,
                 mesh_shape: Optional[Tuple[int, int]] = None):
    """The mesh one engine config is traced on at ``d`` devices.

    Non-halo sharded configs get the classic 1-D edge mesh. Halo
    configs get the 2-axis ``make_edge_vertex_mesh``: an explicit
    ``mesh_shape`` (the audit CLI's --mesh-shape) wins, else the
    config's canonical factorization, else ``(1, d)``; a 1-device trace
    (the paired memory trace) degenerates to ``(1, 1)``."""
    if cfg.vertex_sharding != "halo":
        if mesh_shape is not None:
            raise ValueError(
                f"mesh_shape={mesh_shape} applies only to "
                "vertex_sharding='halo' configs (the 1-axis engines "
                "trace on the shared edge/owner axis)"
            )
        return jax.make_mesh((d,), (EDGE_AXIS,))
    shape = mesh_shape or cfg.mesh_shape or (1, d)
    if shape[0] * shape[1] != d and mesh_shape is None:
        # the canonical factorization targets the CI device count; any
        # other count (the paired 1-device memory trace, a local run)
        # falls back to a pure owner-axis column of the same size
        shape = (1, d)
    return make_edge_vertex_mesh(d, tuple(shape), axis=EDGE_AXIS,
                                 edge_axis=EDGE_SHARD_AXIS)


def trace_removal_round(
    vertex_sharding: str, n: int, cap: int, mesh,
    frontier_cap: Optional[int] = None,
    window: Optional[int] = None, lanes: int = 8,
    kernel_backend: str = "lax",
) -> Tuple[List[Traffic], Any]:
    """Trace (not run) the removal fixpoint under shard_map.

    Returns ``(log, closed_jaxpr)``: the layout collectives recorded for
    ONE loop round plus the traced program (walk it with
    ``walker.primitive_names`` / ``walker.collectives``). This is the
    one source of truth behind the traffic assertions in
    ``tests/test_vertex_layout.py`` and the audit's round budgets.

    ``window`` mirrors the engine's per-shard active window: the engine
    slices slots to the planned window BEFORE binding the halo session,
    so the traced halo capacity (and with it every halo-sized recv)
    matches the committed budget only if the round trace windows the
    same way. ``None`` keeps the whole local shard (replicated traces,
    standalone use).
    """
    axis = EDGE_AXIS
    all_axes = tuple(mesh.axis_names)
    edge_axes = tuple(a for a in all_axes if a != axis)
    n_shards = dict(mesh.shape)[axis]
    espec = P(all_axes if len(all_axes) > 1 else axis)
    if vertex_sharding in ("range", "halo"):
        layout = make_layout(vertex_sharding, n, axis, n_shards,
                             frontier_cap, edge_axes)
        n_pad = layout.n_pad

        def kernel(src, dst, valid, core, label, ru, rv):
            w = src.shape[0] if window is None else window
            src_w, dst_w, valid_w = src[:w], dst[:w], valid[:w]
            # lane ids fed twice (insert + remove lists) so the traced
            # halo capacity equals the engine's 2*lanes lanes_total
            halo_ids = build_halo_ids(layout, src_w, dst_w,
                                      ru, rv, ru, rv, n)
            session = layout.bind(halo_ids)
            core_h = session.gather_values(core)
            label_h = session.gather_values(label)
            src_h = session.locate(src_w)
            dst_h = session.locate(dst_w)
            return removal_fixpoint_halo(
                src_h, dst_h, valid_w, core, label, core_h, label_h,
                session, n + 2, kernel_backend=kernel_backend,
            )

        sm = shard_map(
            kernel, mesh=mesh,
            in_specs=(espec, espec, espec, P(axis), P(axis), P(), P()),
            out_specs=(P(axis), P(axis), P(), P(), P(),
                       P(axis), P(axis), P(), P()),
            check_vma=False,
        )
        src = jnp.zeros(cap, jnp.int32)
        dst = jnp.ones(cap, jnp.int32)
        valid = jnp.zeros(cap, bool)
        core = jnp.zeros(n_pad, jnp.int32)
        label = jnp.zeros(n_pad, jnp.int64)
        ru = jnp.zeros(lanes, jnp.int32)
        rv = jnp.ones(lanes, jnp.int32)
        with record_traffic() as log:
            jaxpr = jax.make_jaxpr(sm)(src, dst, valid, core, label,
                                       ru, rv)
        return log, jaxpr

    layout = make_layout("replicated", n, axis)

    def kernel(src, dst, valid, core, label):
        return removal_fixpoint(src, dst, valid, core, label, n, n + 2,
                                layout=layout,
                                kernel_backend=kernel_backend)

    sm = shard_map(
        kernel, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P()),
        check_vma=False,
    )
    src = jnp.zeros(cap, jnp.int32)
    dst = jnp.ones(cap, jnp.int32)
    valid = jnp.zeros(cap, bool)
    core = jnp.zeros(n, jnp.int32)
    label = jnp.zeros(n, jnp.int64)
    with record_traffic() as log:
        jaxpr = jax.make_jaxpr(sm)(src, dst, valid, core, label)
    return log, jaxpr


def trace_promotion_round(
    vertex_sharding: str, n: int, cap: int, mesh,
    frontier_cap: Optional[int] = None, lanes: int = 8,
    window: Optional[int] = None,
    kernel_backend: str = "lax",
) -> Tuple[List[Traffic], Any]:
    """Trace the promotion fixpoint under shard_map — the insertion-side
    counterpart of ``trace_removal_round``. Returns ``(log, jaxpr)``;
    records cover one outer round (seed + forward waves + evictions +
    the next-round statistics pass)."""
    axis = EDGE_AXIS
    all_axes = tuple(mesh.axis_names)
    edge_axes = tuple(a for a in all_axes if a != axis)
    n_shards = dict(mesh.shape)[axis]
    espec = P(all_axes if len(all_axes) > 1 else axis)
    if vertex_sharding in ("range", "halo"):
        layout = make_layout(vertex_sharding, n, axis, n_shards,
                             frontier_cap, edge_axes)
        n_pad = layout.n_pad

        def kernel(src, dst, valid, core, label, nu, nv, nok, hi, dout):
            w = src.shape[0] if window is None else window
            src_w, dst_w, valid_w = src[:w], dst[:w], valid[:w]
            halo_ids = build_halo_ids(layout, src_w, dst_w,
                                      nu, nv, nu, nv, n)
            session = layout.bind(halo_ids)
            core_h = session.gather_values(core)
            label_h = session.gather_values(label)
            src_h = session.locate(src_w)
            dst_h = session.locate(dst_w)
            u_pos = session.locate(nu)
            v_pos = session.locate(nv)
            return promotion_fixpoint_halo(
                src_h, dst_h, valid_w, core, label, core_h, label_h,
                nu, nv, u_pos, v_pos, nok, hi, dout, session, n + 2,
                kernel_backend=kernel_backend,
            )

        sm = shard_map(
            kernel, mesh=mesh,
            in_specs=(espec, espec, espec, P(axis), P(axis),
                      P(), P(), P(), P(axis), P(axis)),
            out_specs=(P(axis), P(axis), P(), P(), P(),
                       P(axis), P(), P()),
            check_vma=False,
        )
        src = jnp.zeros(cap, jnp.int32)
        dst = jnp.ones(cap, jnp.int32)
        valid = jnp.zeros(cap, bool)
        core = jnp.zeros(n_pad, jnp.int32)
        label = jnp.zeros(n_pad, jnp.int64)
        nu = jnp.zeros(lanes, jnp.int32)
        nv = jnp.ones(lanes, jnp.int32)
        nok = jnp.zeros(lanes, bool)
        hi = jnp.zeros(n_pad, jnp.int32)
        dout = jnp.zeros(n_pad, jnp.int32)
        with record_traffic() as log:
            jaxpr = jax.make_jaxpr(sm)(src, dst, valid, core, label,
                                       nu, nv, nok, hi, dout)
        return log, jaxpr

    layout = make_layout("replicated", n, axis)

    def kernel(src, dst, valid, core, label, nu, nv, nok, hi, dout):
        return promotion_fixpoint(src, dst, valid, core, label,
                                  nu, nv, nok, hi, dout, n, n + 2,
                                  layout=layout,
                                  kernel_backend=kernel_backend)

    sm = shard_map(
        kernel, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(),
                  P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P()),
        check_vma=False,
    )
    src = jnp.zeros(cap, jnp.int32)
    dst = jnp.ones(cap, jnp.int32)
    valid = jnp.zeros(cap, bool)
    core = jnp.zeros(n, jnp.int32)
    label = jnp.zeros(n, jnp.int64)
    nu = jnp.zeros(lanes, jnp.int32)
    nv = jnp.ones(lanes, jnp.int32)
    nok = jnp.zeros(lanes, bool)
    hi = jnp.zeros(n, jnp.int32)
    dout = jnp.zeros(n, jnp.int32)
    with record_traffic() as log:
        jaxpr = jax.make_jaxpr(sm)(src, dst, valid, core, label,
                                   nu, nv, nok, hi, dout)
    return log, jaxpr


def trace_weighted_round(
    n: int, cap: int, mesh,
    kernel_backend: str = "lax",
) -> Tuple[List[Traffic], Any]:
    """Trace the weighted h-index fixpoint under shard_map — the one
    round shape of BOTH weighted maintenance phases (removal runs it
    from the current cores, promotion from ``core + W``; the traced
    collective structure is identical, so one budget entry covers
    both). The in-round histogram counts one layout completion per
    bisection probe: the inner bisection ``while`` nests inside the
    outer fixpoint ``while``, and both bodies trace exactly once."""
    axis = EDGE_AXIS
    layout = make_layout("replicated", n, axis)

    def kernel(src, dst, valid, ew, core):
        return weighted_core_fixpoint_pass(
            src, dst, valid, ew, core, n, layout=layout,
            kernel_backend=kernel_backend,
        )

    sm = shard_map(
        kernel, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    src = jnp.zeros(cap, jnp.int32)
    dst = jnp.ones(cap, jnp.int32)
    valid = jnp.zeros(cap, bool)
    ew = jnp.ones(cap, jnp.int32)
    core = jnp.zeros(n, jnp.int32)
    with record_traffic() as log:
        jaxpr = jax.make_jaxpr(sm)(src, dst, valid, ew, core)
    return log, jaxpr


@dataclasses.dataclass
class TracedEngine:
    """Everything the audit rules inspect for one engine config."""

    config: EngineConfig
    params: AuditParams
    n_devices: int
    window: int           # planned per-shard active-window bucket
    frontier_cap: int     # planned sparse-cap bucket (0 = exchange off)
    programs: Dict[str, Any]        # name -> ClosedJaxpr (full program)
    lowered: Dict[str, Any]         # name -> jax.stages.Lowered
    donated: Dict[str, Tuple[int, ...]]  # name -> declared donated args
    rounds: Dict[str, Tuple[List[Traffic], Any]]  # name -> (log, jaxpr)
    sizes: Dict[str, int]           # env for budget recv_bytes formulas


def _batch_args(params: AuditParams, n_state: int,
                weighted: bool = False):
    b = jnp.zeros(params.lanes, jnp.int32)
    ok = jnp.zeros(params.lanes, bool)
    state = (
        jnp.zeros(params.capacity, jnp.int32),
        jnp.zeros(params.capacity, jnp.int32),
        jnp.zeros(params.capacity, bool),
    )
    if weighted:
        # the weighted engines add the per-slot weight column to the
        # donated state and a replicated per-lane weight to the batch
        state += (jnp.ones(params.capacity, jnp.int32),)
    state += (
        jnp.zeros(n_state, jnp.int32),
        jnp.zeros(n_state, jnp.int64),
        jnp.int32(0),
    )
    if weighted:
        return state + (b, b, jnp.ones(params.lanes, jnp.int32), ok,
                        b, b, ok)
    return state + (b, b, ok, b, b, ok)


def trace_engine(name: str,
                 params: Optional[AuditParams] = None,
                 devices: Optional[int] = None,
                 mesh_shape: Optional[Tuple[int, int]] = None,
                 ) -> TracedEngine:
    """Trace + lower every auditable program of one engine config on the
    current device count.

    ``devices`` forces a mesh size smaller than the process's device
    count (sharded configs only — host/unified always trace at d=1).
    The memory auditor uses this to trace each sharded program at TWO
    mesh sizes in one process: shard_map traces one program regardless
    of mesh size, so the paired jaxprs are structurally identical and a
    lockstep walk can solve each buffer dimension against two distinct
    size environments (repro.analysis.memory).

    ``mesh_shape`` overrides a halo config's canonical (d_e, d_v)
    factorization — CI re-traces ``vertex_halo`` at 8 devices under
    BOTH 4x2 and 2x4 against the one committed manifest, which is what
    makes the budget formulas genuinely two-axis rather than fitted to
    a single device split."""
    if name not in ENGINE_CONFIGS:
        raise ValueError(
            f"unknown engine config {name!r} "
            f"(expected one of {sorted(ENGINE_CONFIGS)})"
        )
    cfg = ENGINE_CONFIGS[name]
    params = params or AuditParams()
    if not cfg.is_sharded:
        d = 1
    elif devices is not None:
        if devices > len(jax.devices()):
            raise ValueError(
                f"devices={devices} exceeds the process's "
                f"{len(jax.devices())} devices"
            )
        d = devices
    else:
        d = len(jax.devices())
    if mesh_shape is not None and mesh_shape[0] * mesh_shape[1] != d:
        raise ValueError(
            f"mesh_shape {mesh_shape[0]}x{mesh_shape[1]} needs "
            f"{mesh_shape[0] * mesh_shape[1]} devices, tracing {d}"
        )
    if cfg.is_sharded:
        mesh = resolve_mesh(cfg, d, mesh_shape)
        if cfg.vertex_sharding == "halo":
            d_e, d_v = dict(mesh.shape)[EDGE_SHARD_AXIS], \
                dict(mesh.shape)[EDGE_AXIS]
        else:
            d_e, d_v = 1, d
    else:
        mesh = None
        d_e, d_v = 1, 1
    n, cap, lanes = params.n, params.capacity, params.lanes
    if cfg.is_sharded and (n % d_v or cap % d):
        raise ValueError(
            f"audit sizes n={n}, capacity={cap} must divide the device "
            f"counts d={d}, d_v={d_v} (pad-free range/halo layouts keep "
            "formulas exact)"
        )
    local_cap = cap // d
    n_owned = -(-n // d_v)
    window = plan_window(0, lanes, local_cap)
    fcap = plan_frontier_cap(cfg.frontier_exchange, cfg.frontier_cap,
                             lanes, n_owned)

    programs: Dict[str, Any] = {}
    lowered: Dict[str, Any] = {}
    donated: Dict[str, Tuple[int, ...]] = {}
    rounds: Dict[str, Tuple[List[Traffic], Any]] = {}

    if cfg.engine == "host":
        # the seed two-program path: one jit per edit kind, no donation
        # (the baseline copies per call — its manifest says so)
        src, dst, valid, core, label, n_edges, iu, iv, iok, ru, rv, rok = (
            _batch_args(params, n)
        )
        ins_args = (src, dst, valid, core, label, iu, iv, iok, n_edges)
        programs["insert_batch"] = jax.make_jaxpr(
            lambda *a: insert_batch(*a, n, params.n_levels)
        )(*ins_args)
        lowered["insert_batch"] = insert_batch.lower(
            *ins_args, n=n, n_levels=params.n_levels
        )
        donated["insert_batch"] = ()
        slots = jnp.full(lanes, -1, jnp.int32)
        rm_args = (src, dst, valid, core, label, slots)
        programs["remove_batch"] = jax.make_jaxpr(
            lambda *a: remove_batch(*a, n, params.n_levels)
        )(*rm_args)
        lowered["remove_batch"] = remove_batch.lower(
            *rm_args, n=n, n_levels=params.n_levels
        )
        donated["remove_batch"] = ()
    elif cfg.engine == "unified":
        args = _batch_args(params, n, weighted=cfg.weighted)
        if cfg.weighted:
            programs["apply_batch"] = jax.make_jaxpr(
                lambda *a: apply_batch_weighted(*a, n, params.n_levels,
                                                window)
            )(*args)
            lowered["apply_batch"] = apply_batch_weighted.lower(
                *args, n=n, n_levels=params.n_levels, active_cap=window
            )
            donated["apply_batch"] = WEIGHTED_DONATED_STATE_ARGS
        else:
            programs["apply_batch"] = jax.make_jaxpr(
                lambda *a: apply_batch(*a, n, params.n_levels, window)
            )(*args)
            lowered["apply_batch"] = apply_batch.lower(
                *args, n=n, n_levels=params.n_levels, active_cap=window
            )
            donated["apply_batch"] = DONATED_STATE_ARGS
    else:
        fn = make_sharded_apply(
            mesh, n, params.n_levels, axis=EDGE_AXIS,
            local_active=window,
            vertex_sharding=cfg.vertex_sharding,
            freelist=cfg.freelist,
            frontier_exchange=cfg.frontier_exchange,
            frontier_cap=fcap,
            kernel_backend=cfg.kernel_backend,
            weighted=cfg.weighted,
        )
        n_state = (n_owned * d_v
                   if cfg.vertex_sharding in ("range", "halo") else n)
        args = _batch_args(params, n_state, weighted=cfg.weighted)
        programs["apply_batch"] = jax.make_jaxpr(fn)(*args)
        lowered["apply_batch"] = fn.lower(*args)
        donated["apply_batch"] = (WEIGHTED_DONATED_STATE_ARGS
                                  if cfg.weighted else DONATED_STATE_ARGS)
        if cfg.weighted:
            # one round shape serves both weighted phases (the
            # promotion fixpoint is the same program from core + W)
            rounds["weighted_round"] = trace_weighted_round(
                n, cap, mesh, kernel_backend=cfg.kernel_backend,
            )
        else:
            round_fcap = (fcap if cfg.frontier_exchange == "sparse"
                          else None)
            rounds["removal_round"] = trace_removal_round(
                cfg.vertex_sharding, n, cap, mesh, round_fcap,
                window=window, lanes=lanes,
                kernel_backend=cfg.kernel_backend,
            )
            rounds["promotion_round"] = trace_promotion_round(
                cfg.vertex_sharding, n, cap, mesh, round_fcap, lanes,
                window=window,
                kernel_backend=cfg.kernel_backend,
            )

    n_pad = (n_owned * d_v
             if cfg.vertex_sharding in ("range", "halo") else n)
    hcap = (halo_cap_for(window, 2 * lanes, n_pad)
            if cfg.vertex_sharding in ("range", "halo") else 0)
    sizes = dict(
        n=n, d=d, d_e=d_e, d_v=d_v, cap=fcap, n_owned=n_owned,
        n_pad=n_pad, hcap=hcap,
        lanes=lanes, window=window, local_cap=local_cap,
    )
    return TracedEngine(
        config=cfg, params=params, n_devices=d, window=window,
        frontier_cap=fcap, programs=programs, lowered=lowered,
        donated=donated, rounds=rounds, sizes=sizes,
    )
