"""The audit rules — each one pins a structural invariant the engine
matrix's performance claims stand on, against a committed per-engine
budget manifest (``analysis/budgets/<engine>.json``).

Registry (``RULES``, decorated with ``@rule``):

* ``collective_budget`` — exact per-program collective histograms plus
  ordered per-round collective lists with closed-form payload sizes
  (``recv_bytes`` formulas in n/d/cap/…), and the no-vertex-sized-psum
  guarantee of the range layouts; also cross-checks the trace-time
  traffic accounting against the jaxpr (``cross_check_round``) so the
  §4.2/§4.3 traffic model can never silently drift from the program.
* ``host_sync`` — no host-callback primitive in any batch program, and
  every large output aliases a donated input in the lowered computation
  (a non-donated large output is a hidden per-batch copy).
* ``donation`` — the buffers the engines declare donated
  (``engine.DONATED_STATE_ARGS``) really are donated in the lowering
  AND carry a donation marker in the StableHLO (``tf.aliasing_output``
  pins, or ``jax.buffer_donor`` on multi-device lowerings).
* ``dtype_policy`` — int64 sentinel values (the ``1 << 62`` edge-key /
  tombstone sentinel) are never truncated through an int32 convert:
  value-taint analysis from big integer literals, cut at boolean
  outputs and paired through ``sort`` operands (so argsort index
  columns never inherit their keys' taint).
* ``launch_budget`` — the per-round kernel-launch histogram
  (gather/scatter/sort, a fused ``pallas_call`` counting as ONE) stays
  pinned to the manifest; pallas configs are additionally re-traced
  against their lax twin, whose collective schedule must be identical
  (that is what lets them share the lax traffic budgets) and whose
  launch count they must strictly undercut.
* ``recompile_surface`` — the (window, frontier-cap) static bucket
  lattice the planners can reach stays within the manifest's jit
  variant bound (the class of mid-stream recompile that halved unified
  throughput before the pow2 bucketing).

Budget ``recv_bytes`` entries are FORMULA STRINGS (e.g.
``"n_owned * 3 * 4"``, ``"d * (cap + 1) * 4"``) evaluated in the traced
engine's size environment, so one committed manifest gates every device
count.
"""
from __future__ import annotations

import ast
import dataclasses
import operator
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.api import bucket_lattice
from ..core.vertex_layout import SPARSE_COND_BRANCHES, Traffic
from .walker import (
    COLLECTIVE_PRIMS,
    HOST_CALLBACK_PRIMS,
    CollectiveSite,
    collectives,
    count_collectives,
    count_round_launches,
    iter_sites,
)


@dataclasses.dataclass
class Finding:
    """One actionable violation: which rule, which engine config, which
    program/round, and a message naming the offending primitive."""

    rule: str
    engine: str
    message: str
    program: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        where = f" [{self.program}]" if self.program else ""
        return f"{self.rule}/{self.engine}{where}: {self.message}"


RULES: Dict[str, Callable] = {}


def rule(name: str):
    def deco(fn):
        RULES[name] = fn
        return fn
    return deco


def run_rules(traced, budget: dict,
              names: Optional[Sequence[str]] = None) -> Dict[str, List[Finding]]:
    """Run (a subset of) the registry against one traced engine; returns
    ``{rule_name: findings}`` (empty lists mean the rule passed)."""
    out: Dict[str, List[Finding]] = {}
    for name in (names or sorted(RULES)):
        out[name] = RULES[name](traced, budget)
    return out


# -- recv_bytes formula evaluation ----------------------------------------

def _ceil_div(a: int, b: int) -> int:
    return -(-int(a) // int(b))


_FORMULA_FUNCS = {"ceil_div": _ceil_div, "min": min, "max": max}
_BIN_OPS = {
    ast.Add: operator.add, ast.Sub: operator.sub, ast.Mult: operator.mul,
    ast.FloorDiv: operator.floordiv, ast.Mod: operator.mod,
}


def eval_formula(expr, env: Dict[str, int]) -> int:
    """Evaluate a budget size formula — integer arithmetic over the
    traced engine's size names (n, d, cap, n_owned, n_pad, window,
    lanes, local_cap) plus ceil_div/min/max. Anything else is a
    manifest error and raises."""
    if isinstance(expr, (int, np.integer)):
        return int(expr)

    def ev(node):
        if isinstance(node, ast.Expression):
            return ev(node.body)
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return node.value
        if isinstance(node, ast.Name):
            if node.id in env:
                return int(env[node.id])
            raise ValueError(f"unknown size name {node.id!r} in formula")
        if isinstance(node, ast.BinOp) and type(node.op) in _BIN_OPS:
            return _BIN_OPS[type(node.op)](ev(node.left), ev(node.right))
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return -ev(node.operand)
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in _FORMULA_FUNCS and not node.keywords):
            return _FORMULA_FUNCS[node.func.id](*[ev(a) for a in node.args])
        raise ValueError(f"unsupported formula syntax: {ast.dump(node)}")

    return int(ev(ast.parse(str(expr), mode="eval")))


# candidate formulas --write-budgets matches observed payloads against,
# most-specific first; an unmatched payload is committed as its literal
# byte count (still valid, just device-count specific)
FORMULA_CANDIDATES = (
    "4",
    "8",
    "n_owned * 3 * 4",
    "n_owned * 2 * 4",
    "n_owned * 4",
    "n_owned * 8",
    "n * 3 * 4",
    "n * 2 * 4",
    "n * 4",
    "n_pad * 4",
    "n_pad * 8",
    "d_v * hcap * 3 * 4",
    "d_v * hcap * 2 * 4",
    "d_v * hcap * 4",
    "hcap * 4",
    "hcap * 8",
    "d_v * (cap + 1) * 4",
    "d_v * cap * 4",
    "d_v * cap * 8",
    "d * (cap + 1) * 4",
    "d * ceil_div(n_owned, 8)",
    "d * window",
    "d * 4",
)


def guess_formula(nbytes: int, env: Dict[str, int],
                  nbytes_b: Optional[int] = None,
                  env_b: Optional[Dict[str, int]] = None) -> Any:
    """Match an observed payload against the candidate formulas.

    When a paired observation (the same collective site traced in a
    second size environment) is supplied, a candidate must reproduce
    BOTH byte counts. The single-env form is ambiguous on the 2-axis
    audit point — e.g. ``hcap * 4`` and ``d_v * cap * 8`` both evaluate
    to 256 at (d_e, d_v) = (4, 2) — and a wrongly committed formula
    would fail the moment CI re-traces the manifest under the other
    factorization. Pairing against the 1-device trace (where those two
    diverge: 256 vs 128) makes the choice unique."""
    for cand in FORMULA_CANDIDATES:
        try:
            if eval_formula(cand, env) != int(nbytes):
                continue
            if (env_b is not None
                    and eval_formula(cand, env_b) != int(nbytes_b)):
                continue
        except ValueError:
            continue  # candidate names a size this env does not carry
        return cand
    return int(nbytes)


# -- round attribution ----------------------------------------------------

def split_round_collectives(
    closed,
) -> Tuple[List[CollectiveSite], List[CollectiveSite],
           List[CollectiveSite], List[CollectiveSite]]:
    """Partition a round trace's collectives into (setup, main,
    overflow, stray): unconditional collectives BEFORE the fixpoint
    loop (the halo layouts' one-time bind + state gather — paid per
    batch, not per round), unconditional in-round collectives,
    collectives on the sparse exchange's overflow cond arm
    (``branches[1]`` — the tag mapping is
    ``vertex_layout.SPARSE_COND_BRANCHES``), and anything
    unattributable (on a cond arm no budget names)."""
    setup, main, overflow, stray = [], [], [], []
    for c in collectives(closed):
        if not c.in_round:
            (setup if not c.cond_branches else stray).append(c)
        elif not c.cond_branches:
            main.append(c)
        elif (len(c.cond_branches) == 1
              and SPARSE_COND_BRANCHES[c.cond_branches[0]] == "overflow"):
            overflow.append(c)
        else:
            stray.append(c)
    return setup, main, overflow, stray


# trace-time Traffic.op -> the jaxpr primitive it must lower to
TRAFFIC_TO_PRIM = {
    "psum": "psum",
    "psum_scalar": "psum",
    "psum_edge": "psum",
    "pmin_scalar": "pmin",
    "pmax_scalar": "pmax",
    "ppermute": "ppermute",
    "gather_frontier": "all_gather",
    "gather_halo": "all_gather",
    "gather_stats": "all_gather",
    "regather": "reduce_scatter",
}


def cross_check_round(log: List[Traffic], closed) -> List[str]:
    """Verify the trace-time traffic accounting against the jaxpr.

    The §4.2/§4.3/§4.4 traffic model is asserted from
    ``record_traffic`` payload notes; this check proves those notes
    describe the REAL program: collective-by-collective (same order,
    branch attribution via ``SPARSE_COND_BRANCHES``), the noted
    ``recv_bytes`` must equal the lowered collective's output payload
    and the noted op must map to the traced primitive. Unbranched log
    entries split positionally between the setup prefix and the
    in-round remainder — both execute in trace order, so the first
    ``len(setup)`` notes ARE the pre-loop collectives. Returns
    human-readable mismatch strings (empty = the model is honest).
    Either side lying — an unnoted collective, a wrong byte count, a
    mislabeled branch — shows up here.
    """
    mismatches: List[str] = []
    jsetup, jmain, jover, stray = split_round_collectives(closed)
    for c in stray:
        mismatches.append(
            f"jaxpr has an unattributable collective {c.op} "
            f"({c.out_bytes}B) at {'/'.join(c.path) or '<top>'} — "
            "not covered by the traffic accounting"
        )
    plain = [t for t in log if t.branch == ""]
    lsetup, lmain = plain[:len(jsetup)], plain[len(jsetup):]
    lover = [t for t in log if t.branch == "overflow"]
    for tag, lside, jside in (("setup", lsetup, jsetup),
                              ("main", lmain, jmain),
                              ("overflow", lover, jover)):
        if len(lside) != len(jside):
            mismatches.append(
                f"{tag}: traffic log notes {len(lside)} collectives "
                f"({[t.op for t in lside]}) but the jaxpr contains "
                f"{len(jside)} ({[c.op for c in jside]})"
            )
            continue
        for i, (t, c) in enumerate(zip(lside, jside)):
            want_prim = TRAFFIC_TO_PRIM.get(t.op)
            if want_prim is None:
                mismatches.append(
                    f"{tag}[{i}]: unknown traffic op {t.op!r} (no "
                    "primitive mapping)"
                )
            elif c.op != want_prim:
                mismatches.append(
                    f"{tag}[{i}]: traffic notes {t.op} (-> {want_prim}) "
                    f"but the jaxpr primitive is {c.op}"
                )
            if t.recv_bytes != c.out_bytes:
                mismatches.append(
                    f"{tag}[{i}]: traffic notes {t.recv_bytes}B for "
                    f"{t.op} but the {c.op} output carries "
                    f"{c.out_bytes}B"
                )
    return mismatches


# -- rule 1: collective budget --------------------------------------------

@rule("collective_budget")
def check_collective_budget(traced, budget: dict) -> List[Finding]:
    cfg = traced.config
    env = traced.sizes
    findings: List[Finding] = []

    def bad(msg: str, program: str = "") -> None:
        findings.append(Finding("collective_budget", cfg.name, msg, program))

    want_progs = budget.get("program_collectives", {})
    for prog, closed in traced.programs.items():
        want = want_progs.get(prog)
        got = count_collectives(closed)
        if want is None:
            bad(
                f"no program_collectives budget for {prog!r} "
                f"(observed {got or '{}'}) — regenerate with "
                "`audit --write-budgets`",
                prog,
            )
        elif {k: int(v) for k, v in want.items()} != got:
            bad(
                f"collective histogram drifted: budget {want} vs "
                f"traced {got or '{}'}",
                prog,
            )

    want_rounds = budget.get("rounds", {})
    for rname, (log, closed) in traced.rounds.items():
        jsetup, jmain, jover, stray = split_round_collectives(closed)
        for c in stray:
            bad(
                f"unattributable collective {c.op} ({c.out_bytes}B) at "
                f"{'/'.join(c.path) or '<top>'} in {rname}",
                rname,
            )
        rb = want_rounds.get(rname)
        if rb is None:
            bad(
                f"no round budget for {rname!r} (observed setup="
                f"{[c.op for c in jsetup]}, main="
                f"{[c.op for c in jmain]}, overflow="
                f"{[c.op for c in jover]})",
                rname,
            )
        else:
            for key, jside in (("setup", jsetup), ("main", jmain),
                               ("overflow", jover)):
                spec = rb.get(key, [])
                if len(spec) != len(jside):
                    bad(
                        f"{rname}/{key}: budget lists "
                        f"{[s['op'] for s in spec]} but the round "
                        f"contains {[c.op for c in jside]}",
                        rname,
                    )
                    continue
                for i, (s, c) in enumerate(zip(spec, jside)):
                    if s["op"] != c.op:
                        bad(
                            f"{rname}/{key}[{i}]: budget op "
                            f"{s['op']!r} but traced {c.op!r} at "
                            f"{'/'.join(c.path)}",
                            rname,
                        )
                    wb = eval_formula(s["recv_bytes"], env)
                    if wb != c.out_bytes:
                        bad(
                            f"{rname}/{key}[{i}]: {c.op} moves "
                            f"{c.out_bytes}B but the budget formula "
                            f"{s['recv_bytes']!r} = {wb}B",
                            rname,
                        )
        # the traffic model must agree with the program it describes
        for m in cross_check_round(log, closed):
            bad(f"traffic-model cross-check in {rname}: {m}", rname)

    if budget.get("forbid_round_vertex_psum"):
        n = env["n"]
        # pure-edge-axis psums are the 2-axis layouts' statistic
        # completion: their payload is the owned slice, which at
        # d_v = 1 IS n-sized — size alone cannot distinguish it from
        # the forbidden vertex-axis reduction, but the axis set can
        exempt = set(budget.get("round_psum_axes_exempt", ()))
        scopes = [(p, c) for p, c in traced.programs.items()]
        scopes += [(r, jx) for r, (_, jx) in traced.rounds.items()]
        for prog, closed in scopes:
            for c in collectives(closed):
                if c.op == "psum" and c.in_round and c.out_elems >= n:
                    if exempt and c.axes and set(c.axes) <= exempt:
                        continue
                    bad(
                        f"vertex-sized psum inside a fixpoint round: "
                        f"{c.out_elems} elems (>= n={n}) over axes "
                        f"{c.axes} at {'/'.join(c.path)} — the halo "
                        "layouts must move owned slices "
                        "(reduce_scatter) + bounded frontier/halo "
                        "buffers only",
                        prog,
                    )
    return findings


# -- rule 2: host-sync detector -------------------------------------------

def _donation_markers(lowered) -> Tuple[set, int]:
    """Donation evidence read off the StableHLO (both forms survive CPU
    lowering even though the CPU backend copies instead of aliasing at
    run time): ``tf.aliasing_output = K`` pins an input to output K
    (single-device jit), while multi-device shard_map lowerings mark the
    input ``jax.buffer_donor = true`` and leave the output pairing to
    the compiler. Returns (aliased output indices, donor-marked input
    count)."""
    text = lowered.as_text()
    aliased = {int(m)
               for m in re.findall(r"tf\.aliasing_output\s*=\s*(\d+)", text)}
    donors = len(re.findall(r"jax\.buffer_donor\s*=\s*true", text))
    return aliased, donors


def _donated_arg_avals(lowered) -> list:
    import jax

    return [getattr(a, "aval", None) or a._aval
            for a in jax.tree_util.tree_leaves(lowered.args_info)
            if getattr(a, "donated", False)]


@rule("host_sync")
def check_host_sync(traced, budget: dict) -> List[Finding]:
    cfg = traced.config
    findings: List[Finding] = []
    allowed = int(budget.get("max_callback_primitives", 0))
    for prog, closed in traced.programs.items():
        sites = [s for s in iter_sites(closed)
                 if s.prim in HOST_CALLBACK_PRIMS]
        if len(sites) > allowed:
            for s in sites:
                findings.append(Finding(
                    "host_sync", cfg.name,
                    f"host-callback primitive {s.prim!r} at "
                    f"{'/'.join(s.path) or '<top>'} — a device->host "
                    "round-trip on every batch serializes the stream",
                    prog,
                ))
    if budget.get("require_large_outputs_donated"):
        thresh = int(budget.get("large_output_bytes", 1024))
        for prog, lowered in traced.lowered.items():
            aliased, _ = _donation_markers(lowered)
            # donor-marked inputs without a pinned output (the shard_map
            # form): a large output is covered if a donated input of the
            # SAME byte size is still unclaimed
            donor_bytes = [
                int(np.prod(a.shape or (1,))) * a.dtype.itemsize
                for a in _donated_arg_avals(lowered)
            ]
            closed = traced.programs[prog]
            for i, aval in enumerate(closed.out_avals):
                nbytes = int(np.prod(aval.shape or (1,))) * aval.dtype.itemsize
                if nbytes < thresh or i in aliased:
                    continue
                if nbytes in donor_bytes:
                    donor_bytes.remove(nbytes)
                    continue
                findings.append(Finding(
                    "host_sync", cfg.name,
                    f"output {i} ({aval.dtype}{list(aval.shape)}, "
                    f"{nbytes}B >= {thresh}B) does not alias a "
                    "donated input — an undonated large output is a "
                    "hidden per-batch copy",
                    prog,
                ))
    return findings


# -- rule 3: donation verifier --------------------------------------------

@rule("donation")
def check_donation(traced, budget: dict) -> List[Finding]:
    import jax

    cfg = traced.config
    findings: List[Finding] = []
    declared = budget.get("donated_args", {})
    for prog, lowered in traced.lowered.items():
        want = set(declared.get(prog, ()))
        infos = jax.tree_util.tree_leaves(lowered.args_info)
        got = {i for i, a in enumerate(infos) if getattr(a, "donated", False)}
        if got != want:
            findings.append(Finding(
                "donation", cfg.name,
                f"donated-arg set drifted: budget declares "
                f"{sorted(want)} but the lowering donates "
                f"{sorted(got)}",
                prog,
            ))
        aliased, donors = _donation_markers(lowered)
        marked = len(aliased) + donors
        if marked < len(want):
            findings.append(Finding(
                "donation", cfg.name,
                f"only {marked} donation markers (tf.aliasing_output / "
                f"jax.buffer_donor) in the StableHLO but {len(want)} "
                "buffers are declared donated — a declared donation "
                "the lowering drops is a silent copy",
                prog,
            ))
    return findings


# -- rule 4: dtype policy (sentinel taint) --------------------------------

TAINT_THRESHOLD = 1 << 31  # any value needing more than int32


def _value_tainted(val) -> bool:
    try:
        arr = np.asarray(val)
    except Exception:
        return False
    if arr.dtype.kind not in "iu" or arr.size == 0:
        return False
    return int(np.abs(arr.astype(np.int64, copy=False)).max()) >= TAINT_THRESHOLD


def _is_literal(v) -> bool:
    return hasattr(v, "val") and not hasattr(v, "count")


# scalar constant folding for taint SOURCES: the engines build the
# sentinel as ``jnp.int64(1) << 62``, which traces as a shift_left
# equation over small literals — without folding, no big literal ever
# appears in the jaxpr and the rule would pass vacuously
_FOLD_OPS: Dict[str, Callable] = {
    "shift_left": lambda a, b: a << b,
    "add": operator.add,
    "sub": operator.sub,
    "mul": operator.mul,
    "neg": operator.neg,
    "convert_element_type": lambda a: a,
    "broadcast_in_dim": lambda a: a,
}


def tainted_truncations(closed) -> List[str]:
    """Find int64->int32 converts applied to sentinel-tainted values.

    Taint SOURCES are integer literals/consts >= 2**31 (the engines'
    ``1 << 62`` edge-key / tombstone sentinel). Taint propagates through
    every equation's outputs, with two cuts that keep the rule exact on
    the real programs: boolean outputs drop taint (a comparison against
    a sentinel yields an ordinary flag), and ``sort`` pairs operand i
    with output i (so an argsort permutation never inherits its keys'
    taint). Control flow recurses structurally: while loops iterate the
    body to a taint fixpoint over the carry, cond unions its branches,
    scan fixpoints the carry, pjit/shard_map/custom_jvp map inputs
    one-to-one. A flagged site means a >=2**31 value CAN reach an int32
    truncation — exactly the silent corruption ``_require_x64`` guards
    against at the API boundary, caught here inside the programs.
    """
    findings: List[str] = []
    seen = set()

    def sub_closed(v):
        if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
            return v.jaxpr, list(getattr(v, "consts", ()))
        if hasattr(v, "eqns"):
            return v, []
        return None, None

    def run(jx, in_taint: List[bool], const_taint: List[bool],
            path: Tuple[str, ...]) -> List[bool]:
        taint: Dict[Any, bool] = {}
        known: Dict[Any, int] = {}  # folded scalar int constants
        for var, t in zip(jx.invars, in_taint):
            taint[var] = bool(t)
        for var, t in zip(jx.constvars, const_taint):
            taint[var] = bool(t)

        def tin(v) -> bool:
            if _is_literal(v):
                return _value_tainted(v.val)
            return taint.get(v, False)

        def kval(v) -> Optional[int]:
            if _is_literal(v):
                arr = np.asarray(v.val)
                if arr.dtype.kind in "iu" and arr.size == 1:
                    return int(arr)
                return None
            return known.get(v)

        for eqn in jx.eqns:
            prim = eqn.primitive.name
            ins = [tin(v) for v in eqn.invars]
            any_in = any(ins)
            outs = [any_in] * len(eqn.outvars)

            if prim == "convert_element_type" and ins[0]:
                src_dt = eqn.invars[0].aval.dtype
                dst_dt = eqn.outvars[0].aval.dtype
                if (src_dt.kind in "iu" and src_dt.itemsize == 8
                        and dst_dt.kind in "iu" and dst_dt.itemsize < 8):
                    key = (path, id(eqn))
                    if key not in seen:
                        seen.add(key)
                        findings.append(
                            f"tainted {src_dt}->{dst_dt} "
                            "convert_element_type at "
                            f"{'/'.join(path) or '<top>'} — a >=2**31 "
                            "sentinel reaches an int32 truncation"
                        )
            elif prim == "sort":
                # operand i sorts into output i: keys' taint stays on
                # the key column, never on the permutation column
                outs = list(ins)
            elif prim == "while":
                cn = eqn.params["cond_nconsts"]
                bn = eqn.params["body_nconsts"]
                cjx, cconsts = sub_closed(eqn.params["cond_jaxpr"])
                bjx, bconsts = sub_closed(eqn.params["body_jaxpr"])
                cc = ins[:cn]
                bc = ins[cn:cn + bn]
                carry = list(ins[cn + bn:])
                for _ in range(len(carry) + 1):
                    out = run(bjx, bc + carry,
                              [_value_tainted(c) for c in bconsts],
                              path + ("while:body_jaxpr",))
                    new = [a or b for a, b in zip(carry, out)]
                    if new == carry:
                        break
                    carry = new
                run(cjx, cc + carry, [_value_tainted(c) for c in cconsts],
                    path + ("while:cond_jaxpr",))
                outs = carry
            elif prim == "cond":
                ops = list(ins[1:])
                branch_outs = None
                for i, br in enumerate(eqn.params["branches"]):
                    bjx, bconsts = sub_closed(br)
                    out = run(bjx, ops,
                              [_value_tainted(c) for c in bconsts],
                              path + (f"cond:branches[{i}]",))
                    branch_outs = (out if branch_outs is None else
                                   [a or b for a, b in zip(branch_outs, out)])
                outs = branch_outs or []
            elif prim == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                bjx, bconsts = sub_closed(eqn.params["jaxpr"])
                consts_t = ins[:nc]
                carry = list(ins[nc:nc + ncar])
                xs = ins[nc + ncar:]
                ys: List[bool] = []
                for _ in range(len(carry) + 1):
                    out = run(bjx, consts_t + carry + xs,
                              [_value_tainted(c) for c in bconsts],
                              path + ("scan:jaxpr",))
                    new = [a or b for a, b in zip(carry, out[:ncar])]
                    ys = out[ncar:]
                    if new == carry:
                        break
                    carry = new
                outs = carry + ys
            elif any(True for _ in _sub_jaxpr_params(eqn)):
                # one-to-one input mapping covers pjit / shard_map /
                # custom_jvp_call / remat; anything unrecognized falls
                # back to broadcasting the joint input taint (sound,
                # possibly conservative)
                outs = None
                for tag, (sjx, sconsts) in _sub_jaxpr_params(eqn):
                    sub_in = (ins if len(sjx.invars) == len(ins)
                              else [any_in] * len(sjx.invars))
                    out = run(sjx, sub_in,
                              [_value_tainted(c) for c in sconsts],
                              path + (tag,))
                    if len(out) == len(eqn.outvars):
                        outs = (out if outs is None else
                                [a or b for a, b in zip(outs, out)])
                if outs is None:
                    outs = [any_in] * len(eqn.outvars)

            if prim in _FOLD_OPS and len(eqn.outvars) == 1:
                kins = [kval(v) for v in eqn.invars]
                if all(k is not None for k in kins):
                    try:
                        val = int(_FOLD_OPS[prim](*kins))
                    except Exception:
                        val = None
                    if val is not None:
                        known[eqn.outvars[0]] = val
                        if abs(val) >= TAINT_THRESHOLD:
                            outs = [True]  # a computed sentinel: source

            for var, t in zip(eqn.outvars, outs):
                # taint cannot survive a boolean: comparisons against
                # sentinels are ordinary flags
                aval = getattr(var, "aval", None)
                if aval is not None and getattr(aval, "dtype", None) is not None \
                        and aval.dtype.kind == "b":
                    t = False
                taint[var] = bool(t)
        return [tin(v) for v in jx.outvars]

    def _sub_jaxpr_params(eqn):
        prim = eqn.primitive.name
        for pname, val in eqn.params.items():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for i, v in enumerate(vals):
                sjx, sconsts = sub_closed(v)
                if sjx is not None:
                    tag = (f"{prim}:{pname}[{i}]"
                           if isinstance(val, (list, tuple))
                           else f"{prim}:{pname}")
                    yield tag, (sjx, sconsts)

    jaxpr = getattr(closed, "jaxpr", closed)
    consts = list(getattr(closed, "consts", ()))
    run(jaxpr, [False] * len(jaxpr.invars),
        [_value_tainted(c) for c in consts], ())
    return findings


@rule("dtype_policy")
def check_dtype_policy(traced, budget: dict) -> List[Finding]:
    cfg = traced.config
    findings: List[Finding] = []
    allowed = int(budget.get("max_tainted_truncations", 0))
    scopes = list(traced.programs.items())
    scopes += [(r, jx) for r, (_, jx) in traced.rounds.items()]
    total = []
    for prog, closed in scopes:
        for msg in tainted_truncations(closed):
            total.append(Finding("dtype_policy", cfg.name, msg, prog))
    if len(total) > allowed:
        findings.extend(total)
    return findings


# -- rule 5: per-round launch budget --------------------------------------

@rule("launch_budget")
def check_launch_budget(traced, budget: dict) -> List[Finding]:
    """Pin the per-round kernel-launch histogram, and prove the pallas
    backend's fusion claim against its lax twin.

    Part 1 (every engine with round traces): the histogram of
    launch-class primitives per fixpoint round (``LAUNCH_PRIMS``; a
    fused ``pallas_call`` counts as ONE launch) must equal the committed
    ``round_launches`` section — a drifted count is a silently
    re-grown gather/scatter train.

    Part 2 (``kernel_backend="pallas"`` only): re-trace the SAME rounds
    with ``kernel_backend="lax"`` and require (a) the collective
    schedules to be IDENTICAL — op by op, payload by payload, branch by
    branch — which is what entitles the pallas config to share the lax
    collective/traffic budgets rather than assume them, and (b) the
    pallas round's launch total to be STRICTLY lower than the lax
    twin's — the whole point of the fusion, checked structurally so a
    refactor that quietly unfuses the hot path fails the audit, not
    just a benchmark."""
    cfg = traced.config
    findings: List[Finding] = []

    def bad(msg: str, program: str = "") -> None:
        findings.append(Finding("launch_budget", cfg.name, msg, program))

    want_rounds = budget.get("round_launches", {})
    for rname, (_, closed) in traced.rounds.items():
        got = count_round_launches(closed)
        want = want_rounds.get(rname)
        if want is None:
            bad(
                f"no round_launches budget for {rname!r} (observed "
                f"{got or '{}'}) — regenerate with "
                "`audit --write-budgets`",
                rname,
            )
        elif {k: int(v) for k, v in want.items()} != got:
            bad(
                f"launch histogram drifted: budget {want} vs traced "
                f"{got or '{}'}",
                rname,
            )

    if cfg.kernel_backend == "lax" or not traced.rounds:
        return findings

    from .programs import (
        resolve_mesh,
        trace_promotion_round,
        trace_removal_round,
    )

    # rebuild the mesh the audited rounds were traced on — for a halo
    # config that means the SAME (d_e, d_v) factorization, read back
    # from the traced size environment, so the twin comparison never
    # mixes factorizations
    twin_shape = ((traced.sizes["d_e"], traced.sizes["d_v"])
                  if cfg.vertex_sharding == "halo" else None)
    mesh = resolve_mesh(cfg, traced.n_devices, twin_shape)
    n, cap = traced.params.n, traced.params.capacity
    fcap = (traced.frontier_cap
            if cfg.frontier_exchange == "sparse" else None)
    twins = {
        "removal_round": lambda: trace_removal_round(
            cfg.vertex_sharding, n, cap, mesh, fcap,
            window=traced.window, lanes=traced.params.lanes,
            kernel_backend="lax",
        ),
        "promotion_round": lambda: trace_promotion_round(
            cfg.vertex_sharding, n, cap, mesh, fcap,
            traced.params.lanes, window=traced.window,
            kernel_backend="lax",
        ),
    }
    for rname, (_, closed) in traced.rounds.items():
        _, lax_closed = twins[rname]()
        mine = [(c.op, c.out_bytes, c.cond_branches)
                for c in collectives(closed)]
        twin = [(c.op, c.out_bytes, c.cond_branches)
                for c in collectives(lax_closed)]
        if mine != twin:
            bad(
                f"collective schedule diverged from the lax twin: "
                f"pallas {mine} vs lax {twin} — the fused kernels may "
                "only replace LOCAL partials, never a collective",
                rname,
            )
        n_mine = sum(count_round_launches(closed).values())
        n_twin = sum(count_round_launches(lax_closed).values())
        if n_mine >= n_twin:
            bad(
                f"pallas round launches {n_mine} launch-class "
                f"primitives but the lax twin launches {n_twin} — "
                "fusion must STRICTLY reduce the per-round launch "
                "count",
                rname,
            )
    return findings


# -- rule 6: recompile-surface auditor ------------------------------------

@rule("recompile_surface")
def check_recompile_surface(traced, budget: dict) -> List[Finding]:
    cfg = traced.config
    findings: List[Finding] = []
    max_variants = int(budget.get("max_jit_variants", 0))
    if cfg.engine == "host":
        # the host path jits per pow2 batch bucket — no window/cap lattice
        variants = max(1, traced.params.lanes).bit_length()
        if variants > max_variants:
            findings.append(Finding(
                "recompile_surface", cfg.name,
                f"{variants} pow2 batch buckets (lanes <= "
                f"{traced.params.lanes}) exceed max_jit_variants="
                f"{max_variants}",
            ))
        return findings
    lattice = bucket_lattice(
        traced.sizes["local_cap"], traced.params.lanes,
        cfg.frontier_exchange, cfg.frontier_cap,
        traced.sizes["n_owned"],
    )
    if len(lattice) > max_variants:
        findings.append(Finding(
            "recompile_surface", cfg.name,
            f"the planner can reach {len(lattice)} (window, cap) "
            f"buckets {lattice} but max_jit_variants="
            f"{max_variants} — every extra bucket is a mid-stream "
            "recompile",
        ))
    if (traced.window, traced.frontier_cap) not in lattice:
        findings.append(Finding(
            "recompile_surface", cfg.name,
            f"traced bucket (window={traced.window}, "
            f"cap={traced.frontier_cap}) is not in the planner "
            f"lattice {lattice} — the trace used an unplanned variant",
        ))
    return findings


# registers the memory_budget rule (import-cycle-safe: memory imports
# Finding/eval_formula/rule from this module at its top, which is fully
# defined by the time this line runs in either import order)
from . import memory as _memory  # noqa: E402,F401
