"""Recursive jaxpr walking — the substrate every audit rule stands on.

A traced JAX program is a tree of jaxprs: the top-level jaxpr plus the
sub-jaxprs carried in equation params (``pjit``'s ``jaxpr``, ``while``'s
``cond_jaxpr``/``body_jaxpr``, ``cond``'s ``branches``, ``shard_map``'s
``jaxpr``, ``scan``, ``custom_jvp_call``, …). The walker visits every
equation of every nested jaxpr exactly once and records WHERE it sits as
a context path of ``"primitive:param[i]"`` tags, so rules can attribute
a primitive to

* a fixpoint ROUND — any path element ``"while:body_jaxpr"``: a
  ``lax.while_loop`` body traces exactly once, so equations inside it
  ARE the per-round program (the same fact the trace-time traffic
  accounting of ``core/vertex_layout.py`` stands on);
* a ``lax.cond`` ARM — path elements ``"cond:branches[i]"``; for the
  sparse frontier exchange the branch index maps to the
  ``Traffic.branch`` tag through
  ``vertex_layout.SPARSE_COND_BRANCHES`` (branch 1 is the overflow
  fallback), which is how jaxpr-derived budgets line up with the
  trace-time records.

Nothing here executes a program: all facts come from equation params and
the static shapes/dtypes of their ``aval``s.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Set, Tuple

# Collective primitives that move bytes across a mesh axis. ``psum``
# covers pmin/pmax-free reductions (the engines only psum); ``pmax`` is
# listed because slot_high_water completes with one. ``reduce_scatter``
# is what lax.psum_scatter traces to; ``all_gather`` covers both the
# bit-packed mask and the sparse index exchange.
COLLECTIVE_PRIMS = frozenset(
    {
        "psum",
        "pmax",
        "pmin",
        "all_gather",
        "reduce_scatter",
        "ppermute",
        "pshuffle",
        "all_to_all",
    }
)

# Primitives that round-trip through the host (or an arbitrary Python
# callback) at RUN time — none may appear in a batch program: a single
# one serializes the device stream on every batch.
HOST_CALLBACK_PRIMS = frozenset(
    {
        "pure_callback",
        "io_callback",
        "debug_callback",
        "python_callback",
        "callback",
        "infeed",
        "outfeed",
        "host_local_array_to_global_array",
        "global_array_to_host_local_array",
    }
)

ROUND_TAG = "while:body_jaxpr"  # path element marking a fixpoint round

# Launch-class primitives: each one the XLA runtime dispatches as (at
# least) its own kernel on an accelerator backend. The gather/scatter
# family is what ``jax.ops.segment_sum`` and the endpoint-state reads
# lower to; ``pallas_call`` is a single fused launch REGARDLESS of how
# many ops its body contains — which is exactly the reduction the fused
# maintenance kernels (kernels/coremaint.py) claim, and what
# ``count_round_launches`` measures.
LAUNCH_PRIMS = frozenset(
    {
        "gather",
        "scatter",
        "scatter-add",
        "scatter-max",
        "scatter-min",
        "scatter-mul",
        "sort",
        "pallas_call",
    }
)


def _as_jaxpr(v: Any):
    """Unwrap a param value to a raw Jaxpr, or None."""
    inner = getattr(v, "jaxpr", None)  # ClosedJaxpr -> Jaxpr
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(v, "eqns"):
        return v
    return None


def sub_jaxprs(eqn) -> Iterator[Tuple[str, Any]]:
    """Yield ``(tag, jaxpr)`` for every sub-jaxpr an equation carries.

    ``tag`` is ``"primitive:param"`` (plus ``"[i]"`` for params holding a
    sequence of jaxprs, e.g. ``cond``'s ``branches``). Purely generic:
    any param value that quacks like a (Closed)Jaxpr is descended into,
    so new primitives with nested programs are walked without changes
    here.
    """
    prim = eqn.primitive.name
    for name, val in eqn.params.items():
        if isinstance(val, (list, tuple)):
            for i, v in enumerate(val):
                j = _as_jaxpr(v)
                if j is not None:
                    yield f"{prim}:{name}[{i}]", j
        else:
            j = _as_jaxpr(val)
            if j is not None:
                yield f"{prim}:{name}", j


@dataclasses.dataclass(frozen=True)
class Site:
    """One equation plus the context path it was found under."""

    eqn: Any
    path: Tuple[str, ...]

    @property
    def prim(self) -> str:
        return self.eqn.primitive.name

    @property
    def in_round(self) -> bool:
        """True iff the equation sits inside a ``lax.while_loop`` body —
        i.e. it executes once per fixpoint round."""
        return ROUND_TAG in self.path

    @property
    def cond_branches(self) -> Tuple[int, ...]:
        """Branch indices of every enclosing ``lax.cond``, outermost
        first (``lax.cond(pred, true_fn, false_fn)`` traces branches as
        ``(false_fn, true_fn)`` — index 1 is the true arm)."""
        out = []
        for tag in self.path:
            if tag.startswith("cond:branches["):
                out.append(int(tag[len("cond:branches[") : -1]))
        return tuple(out)


def iter_sites(closed) -> Iterator[Site]:
    """Depth-first walk over every equation of a (closed) jaxpr, nested
    sub-jaxprs included."""
    jaxpr = getattr(closed, "jaxpr", closed)

    def walk(jx, path: Tuple[str, ...]) -> Iterator[Site]:
        for eqn in jx.eqns:
            yield Site(eqn, path)
            for tag, sub in sub_jaxprs(eqn):
                yield from walk(sub, path + (tag,))

    yield from walk(jaxpr, ())


def primitive_names(closed) -> Set[str]:
    """All primitive names in a (closed) jaxpr, nested jaxprs included.

    Drop-in replacement for the ad-hoc walkers formerly local to
    ``tests/test_vertex_layout.py``.
    """
    return {s.prim for s in iter_sites(closed)}


def _aval_bytes(aval) -> int:
    try:
        size = 1
        for d in aval.shape:
            size *= int(d)
        return size * aval.dtype.itemsize
    except (AttributeError, TypeError):
        return 0


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective equation with its statically-known payload.

    ``out_bytes`` is the payload each participating device RECEIVES,
    read off the output avals: an ``all_gather`` output is the full
    gathered array, a ``reduce_scatter`` output is the per-device
    scattered slice, a ``psum`` output is the full reduced array — in
    every case exactly the quantity ``vertex_layout.record_traffic``
    notes at trace time, which is what makes the §4.2/§4.3 traffic
    model mechanically cross-checkable against the jaxpr
    (``rules.cross_check_round``).
    """

    op: str
    out_bytes: int
    out_elems: int
    path: Tuple[str, ...]
    in_round: bool
    cond_branches: Tuple[int, ...]
    # mesh axis names the collective completes over (psum's "axes"
    # param / ppermute's "axis_name"), normalized to strings — lets the
    # round-psum rule distinguish a pure-edge-axis partial reduction
    # (bounded by the 2-axis traffic model) from a forbidden
    # vertex-axis one
    axes: Tuple[str, ...] = ()


def _eqn_axes(eqn) -> Tuple[str, ...]:
    raw = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if raw is None:
        return ()
    if isinstance(raw, (str, int)):
        raw = (raw,)
    return tuple(str(a) for a in raw)


def collectives(closed) -> List[CollectiveSite]:
    """Every collective primitive in the program, with payload sizes."""
    out: List[CollectiveSite] = []
    for s in iter_sites(closed):
        if s.prim not in COLLECTIVE_PRIMS:
            continue
        nbytes = 0
        nelems = 0
        for ov in s.eqn.outvars:
            nbytes += _aval_bytes(ov.aval)
            try:
                sz = 1
                for d in ov.aval.shape:
                    sz *= int(d)
                nelems += sz
            except (AttributeError, TypeError):
                pass
        out.append(
            CollectiveSite(
                op=s.prim,
                out_bytes=nbytes,
                out_elems=nelems,
                path=s.path,
                in_round=s.in_round,
                cond_branches=s.cond_branches,
                axes=_eqn_axes(s.eqn),
            )
        )
    return out


def count_round_launches(closed) -> dict:
    """Histogram of launch-class primitives that execute once per
    FIXPOINT ROUND (``Site.in_round`` only).

    Equations nested inside a ``pallas_call``'s body jaxpr are skipped:
    the whole fused kernel is ONE launch, so its internal gathers and
    dots must not count — that skip is precisely what makes the lax
    vs pallas launch comparison meaningful (the pallas round replaces a
    gather/scatter train with a single ``pallas_call`` entry here).
    Counts are per traced round body: a ``lax.while_loop`` body traces
    exactly once, so the histogram IS the per-round launch budget."""
    hist: dict = {}
    for s in iter_sites(closed):
        if not s.in_round or s.prim not in LAUNCH_PRIMS:
            continue
        if any(t.startswith("pallas_call:") for t in s.path):
            continue  # inside a fused kernel: already counted as one
        hist[s.prim] = hist.get(s.prim, 0) + 1
    return hist


def count_collectives(closed, prims: Optional[Sequence[str]] = None) -> dict:
    """Histogram of collective primitive names over the whole program
    (counts are device-count independent: shard_map traces one program
    regardless of the mesh size, only shapes change)."""
    names = COLLECTIVE_PRIMS if prims is None else frozenset(prims)
    hist: dict = {}
    for s in iter_sites(closed):
        if s.prim in names:
            hist[s.prim] = hist.get(s.prim, 0) + 1
    return hist
