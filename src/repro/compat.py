"""Version compatibility shims for the JAX API surface we rely on.

The codebase targets the modern spellings (``jax.shard_map`` with
``check_vma``, ``jax.set_mesh``); older runtimes (e.g. JAX 0.4.x, where
``shard_map`` still lives in ``jax.experimental`` and the kwarg is named
``check_rep``) are bridged here so no call site needs a version check.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

_NEW_API = hasattr(jax, "shard_map")

if not _NEW_API:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def _context_mesh() -> Any:
    """The mesh activated by ``with mesh:`` / ``set_mesh`` (old JAX only)."""
    from jax._src import mesh as mesh_lib

    mesh = mesh_lib.thread_resources.env.physical_mesh
    if mesh.empty:
        raise ValueError(
            "shard_map called without a mesh: pass mesh= explicitly or "
            "activate one with `with mesh:` / repro.compat.set_mesh(mesh)"
        )
    return mesh


def shard_map(
    f,
    mesh: Optional[Any] = None,
    in_specs: Any = None,
    out_specs: Any = None,
    check_vma: Optional[bool] = None,
):
    """``jax.shard_map`` across JAX versions.

    * new JAX: forwards directly (mesh may come from the ambient context);
    * old JAX: resolves ``jax.experimental.shard_map.shard_map``, fills in
      the context mesh when ``mesh`` is omitted, and maps the ``check_vma``
      kwarg onto its old name ``check_rep``.
    """
    if _NEW_API:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        if mesh is not None:
            kw["mesh"] = mesh
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs, **kw)
    kw = {} if check_vma is None else {"check_rep": check_vma}
    if mesh is None:
        mesh = _context_mesh()
    return _legacy_shard_map(
        f, mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )


def set_mesh(mesh) -> Any:
    """``jax.set_mesh`` across versions: on old JAX, enter the mesh context
    globally (the ``with mesh:`` resource env) and return the mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    mesh.__enter__()
    return mesh
