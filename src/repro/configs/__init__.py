"""Architecture registry: --arch <id> -> config module."""
from __future__ import annotations

import importlib
from typing import Dict, List

_ARCHS: Dict[str, str] = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "yi-34b": "yi_34b",
    "qwen3-8b": "qwen3_8b",
    "qwen2-7b": "qwen2_7b",
    "pna": "pna",
    "gin-tu": "gin_tu",
    "dimenet": "dimenet",
    "nequip": "nequip",
    "deepfm": "deepfm",
    "coremaint": "coremaint",
}


def arch_names(include_coremaint: bool = False) -> List[str]:
    names = [n for n in _ARCHS if n != "coremaint"]
    if include_coremaint:
        names.append("coremaint")
    return names


def get_arch(name: str):
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[name]}")
