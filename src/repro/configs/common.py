"""Shared config plumbing: shape cells + the arch registry protocol.

Every ``configs/<arch>.py`` exposes:
  FAMILY       — "lm" | "gnn" | "recsys" | "coremaint"
  full()       — the exact published configuration
  smoke()      — a reduced same-family configuration for CPU smoke tests
  SHAPES       — list[ShapeCell]: the assigned input shapes for this arch
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode | serve | retrieval |
    #                    full_graph | minibatch | molecule
    params: Dict[str, Any]


LM_SHAPES = [
    ShapeCell("train_4k", "train", {"seq": 4096, "batch": 256}),
    ShapeCell("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
    ShapeCell("decode_32k", "decode", {"cache": 32768, "batch": 128}),
    ShapeCell("long_500k", "decode", {"cache": 524288, "batch": 1}),
]

LM_SHAPES_SMOKE = [
    ShapeCell("train_4k", "train", {"seq": 64, "batch": 2}),
    ShapeCell("prefill_32k", "prefill", {"seq": 128, "batch": 1}),
    ShapeCell("decode_32k", "decode", {"cache": 128, "batch": 2}),
    ShapeCell("long_500k", "decode", {"cache": 256, "batch": 1}),
]

GNN_SHAPES = [
    ShapeCell(
        "full_graph_sm", "full_graph",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433},
    ),
    ShapeCell(
        "minibatch_lg", "minibatch",
        {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
         "fanout": (15, 10), "d_feat": 602},
    ),
    ShapeCell(
        "ogb_products", "full_graph",
        {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100},
    ),
    ShapeCell(
        "molecule", "molecule",
        {"n_nodes": 30, "n_edges": 64, "batch": 128},
    ),
]

GNN_SHAPES_SMOKE = [
    ShapeCell("full_graph_sm", "full_graph",
              {"n_nodes": 128, "n_edges": 512, "d_feat": 32}),
    ShapeCell("minibatch_lg", "minibatch",
              {"n_nodes": 1024, "n_edges": 4096, "batch_nodes": 16,
               "fanout": (3, 2), "d_feat": 32}),
    ShapeCell("ogb_products", "full_graph",
              {"n_nodes": 256, "n_edges": 1024, "d_feat": 16}),
    ShapeCell("molecule", "molecule",
              {"n_nodes": 8, "n_edges": 24, "batch": 4}),
]

RECSYS_SHAPES = [
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
]

RECSYS_SHAPES_SMOKE = [
    ShapeCell("train_batch", "train", {"batch": 64}),
    ShapeCell("serve_p99", "serve", {"batch": 16}),
    ShapeCell("serve_bulk", "serve", {"batch": 128}),
    ShapeCell("retrieval_cand", "retrieval",
              {"batch": 1, "n_candidates": 1024}),
]
