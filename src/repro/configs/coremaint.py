"""The paper's own workload: parallel order-based core maintenance over a
dynamic graph (edge batches against livej-scale graphs)."""
import dataclasses

from .common import ShapeCell

FAMILY = "coremaint"


@dataclasses.dataclass(frozen=True)
class CoreMaintConfig:
    name: str = "coremaint"
    n_vertices: int = 4_847_571       # livej scale
    edge_capacity: int = 140_000_000  # 2x livej edges
    batch_edges: int = 100_000        # the paper's batch size


SHAPES = [
    ShapeCell("insert_100k", "coremaint_insert", {"batch_edges": 100_000}),
    ShapeCell("remove_100k", "coremaint_remove", {"batch_edges": 100_000}),
]
SHAPES_SMOKE = [
    ShapeCell("insert_100k", "coremaint_insert", {"batch_edges": 64}),
    ShapeCell("remove_100k", "coremaint_remove", {"batch_edges": 64}),
]


def full() -> CoreMaintConfig:
    return CoreMaintConfig()


def smoke() -> CoreMaintConfig:
    return CoreMaintConfig(name="coremaint-smoke", n_vertices=256,
                           edge_capacity=2048, batch_edges=64)
