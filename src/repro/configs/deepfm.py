"""DeepFM [arXiv:1703.04247; paper]: 39 sparse fields, embed_dim=10,
MLP 400-400-400, FM interaction."""
from ..models.recsys import DeepFMConfig
from .common import RECSYS_SHAPES, RECSYS_SHAPES_SMOKE

FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SHAPES_SMOKE = RECSYS_SHAPES_SMOKE


def full() -> DeepFMConfig:
    return DeepFMConfig(name="deepfm", n_sparse=39, embed_dim=10,
                        mlp_dims=(400, 400, 400), rows_per_field=1_000_000)


def smoke() -> DeepFMConfig:
    return DeepFMConfig(name="deepfm-smoke", n_sparse=8, embed_dim=4,
                        mlp_dims=(32, 32), rows_per_field=1000)
