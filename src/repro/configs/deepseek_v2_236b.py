"""DeepSeek-V2 236B [arXiv:2405.04434; hf]: MLA + DeepSeekMoE.

60L d_model=5120 128 heads, MLA kv_lora=512 (q_lora=1536, rope 64 / nope
128 / v 128), MoE: 2 shared + 160 routed experts top-6, expert d_ff=1536,
vocab 102400.
"""
from ..models.transformer import LMConfig, MLAConfig, MoEConfig
from .common import LM_SHAPES, LM_SHAPES_SMOKE

FAMILY = "lm"
SHAPES = LM_SHAPES
SHAPES_SMOKE = LM_SHAPES_SMOKE


def full() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_head=192,
        d_ff=1536,
        vocab=102400,
        attention="mla",
        mla=MLAConfig(kv_lora=512, q_lora=1536, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_routed=160, n_shared=2, top_k=6, d_expert=1536),
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=24,
        d_ff=96,
        vocab=256,
        attention="mla",
        mla=MLAConfig(kv_lora=16, q_lora=32, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=32),
    )
