"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf]: MLA + DeepSeekMoE.

27L d_model=2048 16 heads, MLA kv_lora=512 (no q_lora), MoE: 2 shared +
64 routed top-6, expert d_ff=1408, vocab 102400.
"""
from ..models.transformer import LMConfig, MLAConfig, MoEConfig
from .common import LM_SHAPES, LM_SHAPES_SMOKE

FAMILY = "lm"
SHAPES = LM_SHAPES
SHAPES_SMOKE = LM_SHAPES_SMOKE


def full() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=192,
        d_ff=1408,
        vocab=102400,
        attention="mla",
        mla=MLAConfig(kv_lora=512, q_lora=0, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408),
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=24,
        d_ff=96,
        vocab=256,
        attention="mla",
        mla=MLAConfig(kv_lora=16, q_lora=0, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=32),
    )
