"""DimeNet [arXiv:2003.03123; unverified]: 6 interaction blocks,
d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6."""
from ..models.gnn import DimeNetConfig
from .common import GNN_SHAPES, GNN_SHAPES_SMOKE

FAMILY = "gnn"
SHAPES = GNN_SHAPES
SHAPES_SMOKE = GNN_SHAPES_SMOKE


def full() -> DimeNetConfig:
    return DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6, cutoff=5.0)


def smoke() -> DimeNetConfig:
    return DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=16,
                         n_bilinear=2, n_spherical=3, n_radial=3, cutoff=5.0)
