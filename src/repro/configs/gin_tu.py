"""GIN [arXiv:1810.00826; paper]: 5L d_hidden=64, sum aggregator,
learnable eps."""
from ..models.gnn import GINConfig
from .common import GNN_SHAPES, GNN_SHAPES_SMOKE

FAMILY = "gnn"
SHAPES = GNN_SHAPES
SHAPES_SMOKE = GNN_SHAPES_SMOKE


def full() -> GINConfig:
    return GINConfig(name="gin-tu", n_layers=5, d_hidden=64, d_in=8,
                     n_classes=2)


def smoke() -> GINConfig:
    return GINConfig(name="gin-tu-smoke", n_layers=2, d_hidden=16, d_in=8,
                     n_classes=2)
