"""NequIP [arXiv:2101.03164; paper]: 5L d_hidden=32, l_max=2, n_rbf=8,
cutoff=5, E(3)-equivariant tensor products."""
from ..models.gnn import NequIPConfig
from .common import GNN_SHAPES, GNN_SHAPES_SMOKE

FAMILY = "gnn"
SHAPES = GNN_SHAPES
SHAPES_SMOKE = GNN_SHAPES_SMOKE


def full() -> NequIPConfig:
    return NequIPConfig(name="nequip", n_layers=5, d_hidden=32, l_max=2,
                        n_rbf=8, cutoff=5.0)


def smoke() -> NequIPConfig:
    return NequIPConfig(name="nequip-smoke", n_layers=2, d_hidden=8, l_max=2,
                        n_rbf=4, cutoff=5.0)
