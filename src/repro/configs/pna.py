"""PNA [arXiv:2004.05718; paper]: 4L d_hidden=75, mean-max-min-std
aggregators x identity-amplification-attenuation scalers."""
from ..models.gnn import PNAConfig
from .common import GNN_SHAPES, GNN_SHAPES_SMOKE

FAMILY = "gnn"
SHAPES = GNN_SHAPES
SHAPES_SMOKE = GNN_SHAPES_SMOKE


def full() -> PNAConfig:
    return PNAConfig(name="pna", n_layers=4, d_hidden=75, d_in=1433,
                     n_classes=7)


def smoke() -> PNAConfig:
    return PNAConfig(name="pna-smoke", n_layers=2, d_hidden=16, d_in=32,
                     n_classes=4)
