"""Qwen2-7B [arXiv:2407.10671; hf]: GQA + QKV bias.

28L d_model=3584 28 heads (GQA kv=4) d_ff=18944 vocab 152064.
"""
from ..models.transformer import LMConfig
from .common import LM_SHAPES, LM_SHAPES_SMOKE

FAMILY = "lm"
SHAPES = LM_SHAPES
SHAPES_SMOKE = LM_SHAPES_SMOKE


def full() -> LMConfig:
    return LMConfig(
        name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_head=128, d_ff=18944, vocab=152064, qkv_bias=True,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen2-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256, qkv_bias=True,
    )
