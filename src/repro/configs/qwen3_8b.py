"""Qwen3-8B [hf:Qwen/Qwen3-8B]: GQA + qk_norm.

36L d_model=4096 32 heads (GQA kv=8) d_ff=12288 vocab 151936.
"""
from ..models.transformer import LMConfig
from .common import LM_SHAPES, LM_SHAPES_SMOKE

FAMILY = "lm"
SHAPES = LM_SHAPES
SHAPES_SMOKE = LM_SHAPES_SMOKE


def full() -> LMConfig:
    return LMConfig(
        name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=12288, vocab=151936, qk_norm=True,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen3-8b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256, qk_norm=True,
    )
