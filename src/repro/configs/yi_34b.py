"""Yi-34B [arXiv:2403.04652; hf]: llama-arch GQA.

60L d_model=7168 56 heads (GQA kv=8) d_ff=20480 vocab 64000.
"""
from ..models.transformer import LMConfig
from .common import LM_SHAPES, LM_SHAPES_SMOKE

FAMILY = "lm"
SHAPES = LM_SHAPES
SHAPES_SMOKE = LM_SHAPES_SMOKE


def full() -> LMConfig:
    return LMConfig(
        name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_head=128, d_ff=20480, vocab=64000,
    )


def smoke() -> LMConfig:
    return LMConfig(
        name="yi-34b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=256,
    )
