"""Parallel Order-Based Core Maintenance — the paper's contribution.

Layers:
* ``oracle``        — sequential Simplified-Order / Traversal / BZ (numpy).
* ``decomposition`` — data-parallel peeling + h-index fixpoint (JAX).
* ``order``         — k-order label maintenance (OM adaptation, JAX).
* ``insert``        — batch-parallel order-based insertion maintenance (JAX).
* ``remove``        — batch-parallel mcd-cascade removal maintenance (JAX).
* ``vertex_layout`` — pluggable vertex-state layouts (replicated / range-
                      sharded) the fixpoints complete statistics through.
* ``api``           — CoreMaintainer public interface (incl. sharded variant).
"""
from .oracle import (  # noqa: F401
    OrderCoreMaintainer,
    TraversalCoreMaintainer,
    bz_core_decomposition,
    bz_from_csr,
)
