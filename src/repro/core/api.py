"""CoreMaintainer — the public interface to parallel order-based core
maintenance.

The default ``unified`` engine runs every batch (mixed insertions +
removals) as ONE jitted device program (`engine.apply_batch`): dedup,
slot lookup/allocation, both fixpoints, and the label-renumber gate all
happen on device with donated buffers — the host stays off the critical
path entirely (see docs/DESIGN.md §3 for the host-sync audit).

The host keeps only
  * a lazily-rebuilt ``edge -> slot`` mirror for queries (invalidated per
    batch, materialized on first access), and
  * two sync-free monotone bounds used for capacity planning:
    ``hwm_ub`` (upper bound on the per-shard slot high-water mark
    reported exactly by ``stats.high_water``) and ``live_ub`` (upper
    bound on the live edge count ``n_edges``). The device program
    recycles tombstoned slots through an in-program free-list
    (``insert.freelist_alloc``), so under balanced churn the high-water
    mark — and with it the active window, the per-batch device work, and
    the capacity — stays flat; the bounds are re-synced from the device
    only when they cross the capacity threshold, and ``_compact`` is a
    rare defrag instead of the only reclaim path.

The seed two-program path (host-dict dedup + `insert.insert_batch` /
`remove.remove_batch`) is preserved under ``engine="host"`` as the
benchmark baseline and fallback.

``engine="sharded"`` runs the SAME one-program-per-batch semantics with
the edge-slot table sharded across the mesh (core/sharded.py,
docs/DESIGN.md §4): per-device work is bounded by the densest shard's
high-water window (not full capacity / n_devices — docs/DESIGN.md
§4.1). ``vertex_sharding`` picks where the per-vertex state lives
(core/vertex_layout.py): ``"replicated"`` (the default — each statistic
costs one psum, O(n) received per device per round), ``"range"``
(core/label owner-sharded over the same single axis: each edge shard
keeps only a bounded HALO of the vertices its active slot window
references — no [n] working copy, no entry state gather; statistics
complete with one bounded halo-stats gather + owner scatter, and only
changed-vertex halo refreshes cross the mesh per round — docs/DESIGN.md
§4.2), or ``"halo"`` (the same halo machinery on a genuine 2-axis
``mesh_shape=(d_e, d_v)`` edge x vertex mesh: edge slots shard over
both axes, vertex ranges over the owner axis only, completed statistics
gain exactly one psum over the pure-edge axis, and per-device vertex
memory drops to O(n / d_v + halo) — docs/DESIGN.md §4.4).
``frontier_exchange="sparse"`` shrinks the per-round refresh traffic
further for the paper's tiny affected sets (its Fig. 5): compacted
frontier INDICES in a static ``frontier_cap`` bucket (planned per batch
like ``active_cap`` — seeded from the running quantile of observed
``stats.max_frontier`` once the stream has produced any — or pinned
explicitly), with an in-program per-round fallback to the dense halo
regather on overflow — bit-identical results in every regime
(docs/DESIGN.md §4.3). ``freelist`` picks the slot-allocator ranking
(``"interleaved"`` | ``"hierarchical"`` — `insert.freelist_alloc`).
``kernel_backend="pallas"`` routes every per-round statistics pass of
the device engines through the fused COO Pallas kernel
(kernels/coremaint.py) — one launch per round instead of a
gather/scatter train, with the removal drop decision + core commit
folded into the same launch wherever the layout completes statistics
locally. Bit-identical to ``"lax"`` (integer adds only), and the mesh
collective schedule is unchanged, so the sharded variants share the
committed collective/memory budgets.
All engine configurations are bit-identical in cores AND k-order labels
on the same streams (tests/test_churn_streams.py).

Batches are padded to power-of-two sizes so the jit cache stays small.

Edge endpoints are validated on every edit path: out-of-range vertices
raise ``ValueError`` by default, or are masked out (dropped) under
``validate=False`` — an invalid edge can never reach the slot table or
the per-vertex stat scatters (which would clamp it onto vertex n-1).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph, build_csr
from .decomposition import peel_decomposition, rank_to_labels
from .engine import BatchStats, apply_batch, apply_batch_weighted
from .graph_ops import KERNEL_BACKENDS
from .insert import InsertStats, insert_batch
from .oracle import bz_core_decomposition
from .order import needs_renumber, renumber
from .remove import (RemoveStats, remove_batch,
                     weighted_core_fixpoint_pass)
from .sharded import make_sharded_apply

EDGE_AXIS = "data"  # mesh axis the sharded engine shards edge slots over

_ENGINES = ("unified", "host", "sharded")


def _pow2_roundup(need: int) -> int:
    """Smallest power of two >= need — the one bucketing idiom behind
    batch padding, the active window, and the frontier cap."""
    p = 1
    while p < need:
        p *= 2
    return p


def plan_window(hwm_ub: int, b_ins: int, local_cap: int) -> int:
    """Pow2 bucket of the per-shard active window covering the high-water
    bound plus a ``b_ins``-insert batch, clamped to the shard size.

    Pure in its arguments — no maintainer state, no device sync — which
    is what lets the recompile-surface audit rule (repro.analysis)
    enumerate every window the planner can ever pick."""
    return min(_pow2_roundup(max(16, hwm_ub + b_ins + 1)), local_cap)


def plan_frontier_cap(frontier_exchange: str, pinned_cap: int,
                      b_pad: int, n_owned: int, observed: int = 0) -> int:
    """Static pow2 capacity of the sparse frontier index buffer for a
    batch padded to ``b_pad`` lanes (0 when the exchange is off,
    ``pinned_cap`` verbatim when the caller pinned one).

    Deterministic in the batch BUCKET — which already keys a trace — so
    a stream with stable batch sizes never recompiles mid-stream for the
    frontier cap, exactly like the active-window bucket planning. The
    blind heuristic covers a few cascade multiples of the batch (the
    paper's Fig. 5: the affected set per edit is tiny, so per-round
    frontiers rarely outrun the batch size). ``observed`` feeds the
    stream back in: the maintainer passes a running quantile of the
    per-batch ``stats.max_frontier`` it has already harvested
    (sync-free — only device values that are ALREADY ready are read),
    and the cap grows monotonically to cover twice that quantile — a
    stream whose cascades genuinely outrun the batch multiple stops
    paying the overflow fallback after the first few batches, at the
    cost of at most log2(n_owned) extra compiles (the caps stay pow2
    buckets, so the recompile lattice stays the enumerable pow2 ladder).
    A miss-sized cap costs only the in-program dense-regather fallback
    round — never correctness — so no sync or exact bound is needed
    here. Clamped to the pow2 roof of the owned range, past which the
    sparse buffer cannot beat the dense exchange anyway (docs/DESIGN.md
    §4.3 crossover)."""
    if frontier_exchange != "sparse":
        return 0
    if pinned_cap > 0:
        return pinned_cap
    cap = _pow2_roundup(max(32, 4 * b_pad, 2 * observed))
    while cap // 2 >= n_owned:
        cap //= 2
    return cap


def bucket_lattice(local_cap: int, max_batch_lanes: int,
                   frontier_exchange: str = "bitmask",
                   pinned_cap: int = 0, n_owned: int = 1) -> list:
    """Every (window, frontier_cap) static bucket pair the planners above
    can reach for batches up to ``max_batch_lanes`` padded lanes.

    Each pair keys exactly one jitted program variant
    (``CoreMaintainer._get_sharded_fn``; the unified engine uses the
    window alone), so the lattice size IS the worst-case compile count
    over an entire stream — the quantity the recompile-surface audit
    rule bounds. Enumerated exhaustively: ``plan_window`` is monotone in
    ``hwm_ub + b_ins`` with image {pow2 p : 16 <= p < local_cap} plus
    the ``local_cap`` clamp, and ``plan_frontier_cap`` depends on the
    pow2 batch bucket plus the pow2 bucket of the observed-frontier
    quantile — whose image is the pow2 ladder from the smallest blind
    cap up to the owned-range roof (every rung reachable when the
    stream's cascades grow past it), so the sparse cap set is that full
    ladder rather than the blind batch-multiple subset."""
    windows = set()
    p = 16
    while p < local_cap:
        windows.add(p)
        p *= 2
    windows.add(min(p, local_cap))
    caps = set()
    if frontier_exchange != "sparse":
        caps.add(0)
    else:
        b = 1
        while b <= max(1, max_batch_lanes):
            caps.add(plan_frontier_cap(frontier_exchange, pinned_cap,
                                       b, n_owned))
            b *= 2
        if pinned_cap <= 0:
            # observed-quantile seeding can push any planned cap up the
            # pow2 ladder as far as the owned-range roof
            c = min(caps)
            roof = plan_frontier_cap(frontier_exchange, pinned_cap, 1,
                                     n_owned, observed=max(1, n_owned))
            while c < roof:
                caps.add(c)
                c *= 2
            caps.add(roof)
    return sorted((w, c) for w in windows for c in caps)


def _pad_pow2(x: np.ndarray, fill: int) -> np.ndarray:
    p = _pow2_roundup(max(1, len(x)))
    out = np.full(p, fill, dtype=np.int32)
    out[: len(x)] = x
    return out


def _as_edge_array(edges) -> np.ndarray:
    if edges is None:
        return np.zeros((0, 2), dtype=np.int64)
    return np.asarray(edges, dtype=np.int64).reshape(-1, 2)


def _require_x64() -> None:
    """The k-order labels are int64 and the engines pack edge keys against
    an int64 sentinel (1 << 62): with x64 disabled both silently truncate
    to int32 and corrupt state. ``import repro`` enables x64; fail loudly
    if a user (or another library) turned it off afterwards."""
    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "CoreMaintainer needs jax_enable_x64 (int64 k-order labels and "
            "1<<62 edge-key sentinels corrupt silently under x32). "
            "Re-enable it with jax.config.update('jax_enable_x64', True) "
            "— `import repro` does this at import time."
        )


def _default_edge_mesh(vertex_sharding: str = "replicated",
                       mesh_shape: Optional[Tuple[int, int]] = None):
    from ..launch.mesh import make_edge_mesh, make_edge_vertex_mesh

    if vertex_sharding == "halo":
        # genuine 2-axis edge x vertex factorization; default (1, d) is
        # the pure owner-axis column of the §4.4 traffic model
        return make_edge_vertex_mesh(
            mesh_shape=mesh_shape or (1, len(jax.devices()))
        )
    if vertex_sharding == "range":
        # same 1-D mesh, named for its double duty: the single axis
        # carries the edge shards AND the vertex ranges
        return make_edge_vertex_mesh(axis=EDGE_AXIS)
    return make_edge_mesh(axis=EDGE_AXIS)


@dataclasses.dataclass
class CoreMaintainer:
    """Dynamic-graph core maintenance with k-order labels (JAX)."""

    n: int
    capacity: int
    src: jax.Array
    dst: jax.Array
    valid: jax.Array
    n_edges: jax.Array
    core: jax.Array
    label: jax.Array
    n_levels: int
    engine: str = "unified"     # "unified" | "host" | "sharded"
    mesh: Optional[Any] = None  # sharded engine only; needs a "data" axis
    vertex_sharding: str = "replicated"  # "replicated" | "range" | "halo"
    mesh_shape: Optional[Tuple[int, int]] = None  # (d_e, d_v) 2-axis
    #                             factorization; vertex_sharding="halo"
    #                             only, builds the default mesh
    freelist: str = "interleaved"        # "interleaved" | "hierarchical"
    frontier_exchange: str = "bitmask"   # "bitmask" (dense halo regather)
    #                                      | "sparse" (range/halo only)
    frontier_cap: int = 0       # sparse index-buffer capacity; 0 = planned
    #                             per batch as a static pow2 bucket
    kernel_backend: str = "lax"  # "lax" | "pallas" per-round stat kernels
    #                              (kernels/coremaint.py; device engines only)
    weighted: bool = False      # weight-generalized engine: the slot table
    #                             carries a per-edge integer weight column
    #                             and both maintenance phases run the
    #                             weighted h-index bisection fixpoint
    #                             (docs/DESIGN.md §4.5); device engines only
    w: Optional[jax.Array] = None  # [capacity] per-slot edge weights
    #                                (weighted=True only; None -> all-ones)
    validate: bool = True       # raise on out-of-range endpoints (else mask)
    last_insert_stats: Optional[InsertStats] = None
    last_remove_stats: Optional[RemoveStats] = None
    last_batch_stats: Optional[BatchStats] = None
    slot_cache: Optional[Dict[Tuple[int, int], int]] = None
    live_ub: int = -1           # upper bound on live edges (-1: from valid)
    hwm_ub: int = -1            # upper bound on the per-shard slot
    #                             high-water mark (-1: compute from valid)
    _last_window: int = dataclasses.field(default=0, repr=False)
    host_renumbered: bool = False  # last host-path call triggered a renumber
    _sharded_fns: Dict[Tuple[int, int], Callable] = dataclasses.field(
        default_factory=dict, repr=False
    )
    # sparse frontier-cap observation feedback (sync-free): device
    # max_frontier scalars awaiting readiness, and the harvested host ints
    _frontier_obs: list = dataclasses.field(default_factory=list,
                                            repr=False)
    _frontier_hist: list = dataclasses.field(default_factory=list,
                                             repr=False)

    def __post_init__(self) -> None:
        # the FULL engine-configuration matrix is validated here, at
        # construction, each message naming the offending field —
        # a bad combination must never survive to surface as an opaque
        # trace-time error inside make_sharded_apply / the layout layer
        if self.engine not in _ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.vertex_sharding not in ("replicated", "range", "halo"):
            raise ValueError(
                f"unknown vertex_sharding {self.vertex_sharding!r}"
            )
        if self.freelist not in ("interleaved", "hierarchical"):
            raise ValueError(f"unknown freelist {self.freelist!r}")
        if self.frontier_exchange not in ("bitmask", "sparse"):
            raise ValueError(
                f"unknown frontier_exchange {self.frontier_exchange!r}"
            )
        if self.mesh is not None and self.engine != "sharded":
            raise ValueError(
                f"mesh= is only consumed by engine='sharded' (got "
                f"engine={self.engine!r}) — a silently ignored mesh "
                "would hide a misconfigured deployment"
            )
        if (self.vertex_sharding in ("range", "halo")
                and self.engine != "sharded"):
            raise ValueError(
                f"vertex_sharding={self.vertex_sharding!r} needs "
                "engine='sharded' (the other engines keep full vertex "
                "state on one device)"
            )
        if self.mesh_shape is not None:
            if self.vertex_sharding != "halo":
                raise ValueError(
                    f"mesh_shape={self.mesh_shape} is only consumed by "
                    "vertex_sharding='halo' (the single-axis layouts "
                    "would silently ignore the factorization)"
                )
            if self.mesh is not None:
                raise ValueError(
                    "pass mesh= OR mesh_shape=, not both — mesh_shape "
                    "builds the default 2-axis mesh; a user mesh carries "
                    "its own factorization"
                )
            de, dv = self.mesh_shape
            if de < 1 or dv < 1:
                raise ValueError(
                    f"mesh_shape must be positive, got {self.mesh_shape}"
                )
        if self.freelist == "hierarchical" and self.engine != "sharded":
            raise ValueError(
                "freelist='hierarchical' needs engine='sharded' — the "
                "ranking only differs across shards (host never uses the "
                "free-list; on one shard it degenerates to interleaved), "
                "so accepting it elsewhere would silently do nothing"
            )
        if (self.frontier_exchange == "sparse"
                and self.vertex_sharding not in ("range", "halo")):
            raise ValueError(
                "frontier_exchange='sparse' needs vertex_sharding="
                "'range' or 'halo' (only the halo layouts exchange "
                "frontier refreshes; the replicated layout would "
                "silently ignore it)"
            )
        if self.frontier_cap < 0:
            raise ValueError(
                f"frontier_cap must be >= 0 (0 = plan automatically), "
                f"got {self.frontier_cap}"
            )
        if self.frontier_cap > 0 and self.frontier_exchange != "sparse":
            raise ValueError(
                f"frontier_cap={self.frontier_cap} is only consumed by "
                "frontier_exchange='sparse' — the bitmask exchange "
                "would silently ignore it"
            )
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r} "
                f"(expected one of {KERNEL_BACKENDS})"
            )
        if self.kernel_backend != "lax" and self.engine == "host":
            raise ValueError(
                "kernel_backend='pallas' needs a device engine "
                "('unified' | 'sharded') — the host path runs the seed "
                "two-program kernels and would silently ignore it"
            )
        if self.weighted:
            if self.engine == "host":
                raise ValueError(
                    "weighted=True needs a device engine ('unified' | "
                    "'sharded') — the seed host path runs the unit-count "
                    "order-maintenance kernels and has no weight column"
                )
            if self.w is None:
                # all-ones weight column: the weighted engine on unit
                # weights computes exactly the classic coreness
                self.w = jnp.ones(self.capacity, dtype=jnp.int32)
            else:
                self.w = jnp.asarray(self.w, dtype=jnp.int32)
                if self.w.shape != (self.capacity,):
                    raise ValueError(
                        f"w has shape {self.w.shape}, expected the slot "
                        f"table shape ({self.capacity},)"
                    )
        elif self.w is not None:
            raise ValueError(
                "w= (per-slot edge weights) needs weighted=True — the "
                "unweighted engines would silently ignore the column"
            )
        _require_x64()
        if self.live_ub < 0 or self.hwm_ub < 0:
            # exact initial bounds from the slot table (construction is
            # the one host-side moment where a sync is free): the global
            # high-water mark upper-bounds every shard's local one
            val = np.asarray(self.valid)
            idx = np.nonzero(val)[0]
            self.live_ub = int(idx.shape[0])
            self.hwm_ub = int(idx[-1]) + 1 if idx.size else 0
        if self.engine == "host":
            # the host path bump-allocates from n_edges: it must cover the
            # high-water mark (device-engine saves store the live count)
            ne = int(self.n_edges)
            if ne < self.hwm_ub:
                self.n_edges = jnp.asarray(self.hwm_ub, dtype=jnp.int32)
        if self.engine == "sharded":
            if self.mesh is None:
                self.mesh = _default_edge_mesh(self.vertex_sharding,
                                               self.mesh_shape)
            if EDGE_AXIS not in dict(self.mesh.shape):
                raise ValueError(
                    f"sharded engine needs a {EDGE_AXIS!r} mesh axis; got "
                    f"axes {tuple(self.mesh.axis_names)}"
                )
            n_axes = len(tuple(self.mesh.axis_names))
            if self.vertex_sharding == "halo" and n_axes < 2:
                raise ValueError(
                    "vertex_sharding='halo' needs a 2-axis (edge x "
                    "vertex) mesh — launch.mesh.make_edge_vertex_mesh("
                    "mesh_shape=(d_e, d_v)) or mesh_shape=; a single "
                    "shared axis is vertex_sharding='range'"
                )
            if self.vertex_sharding != "halo" and n_axes > 1:
                raise ValueError(
                    f"a multi-axis mesh (axes "
                    f"{tuple(self.mesh.axis_names)}) needs "
                    "vertex_sharding='halo' — the single-axis layouts "
                    "would silently drop the pure-edge-axis partials"
                )
            if self._n_shards > 1:
                # one re-layout: pad capacity to an even shard split AND
                # stride the live slots across the shards so the densest
                # shard's high-water mark (the per-shard window bound)
                # starts near live / n_shards; save()d states keep
                # working on any device count
                self._defrag_to(self.capacity)
            else:
                self._place_sharded()

    # -- sharded placement ---------------------------------------------------
    @property
    def _n_vertex_pad(self) -> int:
        """Vertex-state length under the halo layouts: ``n`` rounded up
        to an owner-shard multiple (phantom tail vertices hold zeros and
        are never referenced by an edge or returned by ``cores()``)."""
        nd = self._d_v
        return -(-self.n // nd) * nd

    def _pad_vertex_state(self) -> None:
        core = jnp.asarray(self.core)
        label = jnp.asarray(self.label)
        pad = self._n_vertex_pad - core.shape[0]
        if pad > 0:
            self.core = jnp.concatenate(
                [core, jnp.zeros((pad,), dtype=core.dtype)]
            )
            self.label = jnp.concatenate(
                [label, jnp.zeros((pad,), dtype=label.dtype)]
            )

    def _place_sharded(self) -> None:
        """Commit the slot table sharded over every mesh axis and the
        vertex state replicated — or owner-sharded over the owner
        (``data``) axis only under the halo layouts, edge-axis
        replicated — so the jitted shard_map program never reshards its
        inputs."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        all_axes = tuple(self.mesh.axis_names)
        esh = NamedSharding(
            self.mesh, P(all_axes if len(all_axes) > 1 else EDGE_AXIS)
        )
        rep = NamedSharding(self.mesh, P())
        vsh = rep
        if self.vertex_sharding in ("range", "halo"):
            self._pad_vertex_state()
            vsh = NamedSharding(self.mesh, P(EDGE_AXIS))
        self.src = jax.device_put(jnp.asarray(self.src), esh)
        self.dst = jax.device_put(jnp.asarray(self.dst), esh)
        self.valid = jax.device_put(jnp.asarray(self.valid), esh)
        if self.weighted:
            self.w = jax.device_put(jnp.asarray(self.w), esh)
        self.core = jax.device_put(jnp.asarray(self.core), vsh)
        self.label = jax.device_put(jnp.asarray(self.label), vsh)
        self.n_edges = jax.device_put(
            jnp.asarray(self.n_edges, dtype=jnp.int32), rep
        )

    def _get_sharded_fn(self, local_active: int,
                        frontier_cap: int = 0) -> Callable:
        """Jitted sharded program for one (per-shard window, frontier
        cap) bucket pair. Both are powers of two (one cache entry per
        pair, same jit hygiene as the unified engine's ``active_cap``)."""
        key = (local_active, frontier_cap)
        fn = self._sharded_fns.get(key)
        if fn is None:
            fn = make_sharded_apply(
                self.mesh, self.n, self.n_levels, axis=EDGE_AXIS,
                local_active=local_active,
                vertex_sharding=self.vertex_sharding,
                freelist=self.freelist,
                frontier_exchange=self.frontier_exchange,
                frontier_cap=frontier_cap,
                kernel_backend=self.kernel_backend,
                weighted=self.weighted,
            )
            self._sharded_fns[key] = fn
        return fn

    # -- capacity planning ---------------------------------------------------
    # both buckets delegate to the module-level pure planners above, so
    # the recompile-surface audit (repro.analysis) enumerates the exact
    # lattice the live maintainer draws from
    def _window(self, b_ins: int) -> int:
        return plan_window(self.hwm_ub, b_ins, self._local_cap)

    def _frontier_bucket(self, b_pad: int) -> int:
        return plan_frontier_cap(
            self.frontier_exchange, self.frontier_cap, b_pad,
            -(-self._n_vertex_pad // self._d_v),
            observed=self._observed_frontier(),
        )

    def _observed_frontier(self) -> int:
        """Running quantile (p95) of the harvested per-batch
        ``stats.max_frontier`` observations — the datum the sparse
        frontier-cap planner is seeded from. Sync-free: only device
        scalars whose computation has ALREADY finished are read; the
        rest stay queued for a later batch."""
        if self.frontier_exchange != "sparse" or self.frontier_cap > 0:
            return 0
        pending = []
        for x in self._frontier_obs:
            if hasattr(x, "is_ready") and not x.is_ready():
                pending.append(x)
                continue
            self._frontier_hist.append(int(x))  # sync: ok (value is ready)
        self._frontier_obs = pending
        hist = self._frontier_hist[-256:]
        self._frontier_hist = hist
        if not hist:
            return 0
        return sorted(hist)[int(0.95 * (len(hist) - 1))]

    @property
    def _n_shards(self) -> int:
        """Edge-slot shard count: the FULL mesh size (edge slots shard
        over every axis; on the 2-axis halo mesh that is d_e * d_v)."""
        if self.engine != "sharded":
            return 1
        return int(np.prod([s for _, s in self.mesh.shape.items()]))

    @property
    def _d_v(self) -> int:
        """Vertex owner-shard count: the size of the owner axis alone."""
        if self.engine != "sharded":
            return 1
        return dict(self.mesh.shape)[EDGE_AXIS]

    @property
    def _local_cap(self) -> int:
        """Slots per shard (== capacity off the sharded engine)."""
        return self.capacity // self._n_shards

    # -- construction -------------------------------------------------------
    @classmethod
    def from_graph(
        cls,
        g: CSRGraph,
        capacity: Optional[int] = None,
        init: str = "host-bz",
        engine: str = "unified",
        mesh: Optional[Any] = None,
        vertex_sharding: str = "replicated",
        mesh_shape: Optional[Tuple[int, int]] = None,
        freelist: str = "interleaved",
        frontier_exchange: str = "bitmask",
        frontier_cap: int = 0,
        kernel_backend: str = "lax",
        weighted: bool = False,
        weights=None,
        validate: bool = True,
    ) -> "CoreMaintainer":
        """Build a maintainer from a static graph.

        ``weighted=True`` seeds the weight-generalized engine:
        ``weights`` aligns row-for-row with ``g.edge_array()`` (omitted
        = all ones), and the initial cores are the exact weighted
        coreness — computed on device by the same decrease-only
        weighted h-index fixpoint the engines run, started from the
        weighted-degree upper bound (``init`` is bypassed; the
        unweighted decompositions do not apply). Initial k-order labels
        are the ``(core, vertex id)`` lexicographic ranks — weighted
        maintenance freezes labels through the fixpoints and renumbers
        once per batch, so any deterministic unique assignment agrees
        across every engine configuration."""
        _require_x64()  # before any label math that would truncate quietly
        edges = g.edge_array()
        m = edges.shape[0]
        capacity = capacity or max(16, 2 * m)
        if capacity <= m:
            raise ValueError("capacity must exceed edge count")
        if weights is not None and not weighted:
            raise ValueError("weights= needs weighted=True")
        src = np.zeros(capacity, dtype=np.int32)
        dst = np.zeros(capacity, dtype=np.int32)
        val = np.zeros(capacity, dtype=bool)
        src[:m] = edges[:, 0]
        dst[:m] = edges[:, 1]
        val[:m] = True
        edge_slot = {
            (int(a), int(b)): i for i, (a, b) in enumerate(edges)
        }
        n_levels = g.n + 2
        if weighted:
            if weights is None:
                wv = np.ones(m, dtype=np.int64)
            else:
                wv = np.asarray(weights, dtype=np.int64).reshape(-1)
                if wv.shape[0] != m:
                    raise ValueError(
                        f"weights have length {wv.shape[0]} but the "
                        f"graph has {m} edges"
                    )
                if wv.size and (wv < 1).any():
                    raise ValueError(
                        "edge weights must be positive integers"
                    )
            wcol = np.zeros(capacity, dtype=np.int32)
            wcol[:m] = wv.astype(np.int32)
            # weighted-degree upper bound -> exact weighted cores via
            # the engines' own decrease-only fixpoint (lax; backend
            # choice cannot change the integer result)
            deg_w = np.zeros(g.n, dtype=np.int64)
            np.add.at(deg_w, edges[:, 0], wv)
            np.add.at(deg_w, edges[:, 1], wv)
            core, _, _ = weighted_core_fixpoint_pass(
                jnp.asarray(src), jnp.asarray(dst), jnp.asarray(val),
                jnp.asarray(wcol), jnp.asarray(deg_w.astype(np.int32)),
                g.n,
            )
            core_np = np.asarray(core)
            order = np.lexsort((np.arange(g.n), core_np))
            rank = np.zeros(g.n, dtype=np.int32)
            rank[order] = np.arange(g.n, dtype=np.int32)
            label = rank_to_labels(jnp.asarray(rank))
            return cls(
                n=g.n,
                capacity=capacity,
                src=jnp.asarray(src),
                dst=jnp.asarray(dst),
                valid=jnp.asarray(val),
                n_edges=jnp.asarray(m, dtype=jnp.int32),
                core=core,
                label=label,
                n_levels=n_levels,
                engine=engine,
                mesh=mesh,
                vertex_sharding=vertex_sharding,
                mesh_shape=mesh_shape,
                freelist=freelist,
                frontier_exchange=frontier_exchange,
                frontier_cap=frontier_cap,
                kernel_backend=kernel_backend,
                weighted=True,
                w=jnp.asarray(wcol),
                validate=validate,
                slot_cache=edge_slot,
                live_ub=m,
                hwm_ub=m,
            )
        if init == "host-bz":
            adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
            core_np, order = bz_core_decomposition(g.n, adj)
            rank = np.zeros(g.n, dtype=np.int32)
            rank[np.asarray(order, dtype=np.int64)] = np.arange(
                g.n, dtype=np.int32
            )
            core = jnp.asarray(core_np.astype(np.int32))
            label = rank_to_labels(jnp.asarray(rank))
        elif init == "jax-peel":
            core, rank = peel_decomposition(
                jnp.asarray(src), jnp.asarray(dst), jnp.asarray(val), g.n
            )
            label = rank_to_labels(rank)
        else:
            raise ValueError(init)
        return cls(
            n=g.n,
            capacity=capacity,
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            valid=jnp.asarray(val),
            n_edges=jnp.asarray(m, dtype=jnp.int32),
            core=core,
            label=label,
            n_levels=n_levels,
            engine=engine,
            mesh=mesh,
            vertex_sharding=vertex_sharding,
            mesh_shape=mesh_shape,
            freelist=freelist,
            frontier_exchange=frontier_exchange,
            frontier_cap=frontier_cap,
            kernel_backend=kernel_backend,
            validate=validate,
            slot_cache=edge_slot,
            live_ub=m,
            hwm_ub=m,
        )

    # -- queries -------------------------------------------------------------
    @property
    def edge_slot(self) -> Dict[Tuple[int, int], int]:
        """Host mirror of the live edge -> slot table.

        The unified engine allocates slots on device and only invalidates
        this dict; it is rebuilt here on first access (queries tolerate
        the sync — the per-batch edit path never touches it).
        """
        if self.slot_cache is None:
            src = np.asarray(self.src)
            dst = np.asarray(self.dst)
            live = np.nonzero(np.asarray(self.valid))[0]
            self.slot_cache = {
                (int(min(a, b)), int(max(a, b))): int(i)
                for i, a, b in zip(live, src[live], dst[live])
            }
        return self.slot_cache

    def cores(self) -> np.ndarray:
        # [: n] drops the phantom pad of range-sharded vertex state (a
        # no-op everywhere else)
        return np.asarray(self.core)[: self.n]

    def labels(self) -> np.ndarray:
        return np.asarray(self.label)[: self.n]

    def order_lt(self, u: int, v: int) -> bool:
        cu, cv = int(self.core[u]), int(self.core[v])
        if cu != cv:
            return cu < cv
        return int(self.label[u]) < int(self.label[v])

    @property
    def live_edges(self) -> int:
        return len(self.edge_slot)

    # -- validation ----------------------------------------------------------
    def _validated(self, edges, what: str, weights=None):
        """Normalize an edge batch and enforce endpoint bounds.

        With ``validate`` (the default) an out-of-range endpoint raises;
        otherwise the offending rows are masked out before they can reach
        the slot table or the stat scatters (whose index clamping would
        silently alias them onto vertex n-1). When ``weights`` is given
        it must align row-for-row with ``edges``; weights always
        validate strictly (positive integers) and masked rows drop
        their weight in lockstep. Returns ``edges`` alone, or
        ``(edges, weights)`` when weights were passed."""
        edges = _as_edge_array(edges)
        if weights is not None:
            weights = np.asarray(weights, dtype=np.int64).reshape(-1)
            if weights.shape[0] != edges.shape[0]:
                raise ValueError(
                    f"{what} weights have length {weights.shape[0]} but "
                    f"the edge batch has {edges.shape[0]} rows"
                )
            if weights.size and (weights < 1).any():
                bad_w = weights[weights < 1][0]
                raise ValueError(
                    f"{what} edge weights must be positive integers, "
                    f"got {int(bad_w)}"
                )
        if edges.size:
            bad = ((edges < 0) | (edges >= self.n)).any(axis=1)
            if bad.any():
                if self.validate:
                    row = edges[bad][0]
                    raise ValueError(
                        f"{what} edge {row.tolist()} out of range for "
                        f"n={self.n} (pass validate=False to mask instead)"
                    )
                edges = edges[~bad]
                if weights is not None:
                    weights = weights[~bad]
        if weights is not None:
            return edges, weights
        return edges

    # -- edits ----------------------------------------------------------------
    def apply_batch(
        self,
        insert_edges=None,
        remove_edges=None,
        insert_weights=None,
    ) -> BatchStats:
        """Apply one mixed batch (removals first, then insertions) in a
        single compiled device program — no host dedup, no per-batch
        device->host syncs. Under ``engine="host"`` the batch is served by
        the seed two-call path instead (stats composed from both calls);
        ``engine="sharded"`` runs the same program with the slot table
        sharded across the mesh.

        ``insert_weights`` (weighted maintainers only) aligns
        row-for-row with ``insert_edges``; omitted means weight 1 per
        edge. Duplicate rows keep the FIRST occurrence's weight, and
        inserting an already-live edge is a no-op that keeps the stored
        weight — remove + insert updates a weight."""
        _require_x64()
        if insert_weights is not None and not self.weighted:
            raise ValueError(
                "insert_weights= needs weighted=True — the unweighted "
                "engines would silently drop the weights"
            )
        # validate BOTH lists before any engine touches state, so a
        # rejected batch is rejected atomically (the host path applies
        # removals first and must not commit them before the insert list
        # has passed validation)
        if self.weighted:
            ins_np = _as_edge_array(insert_edges)
            if insert_weights is None:
                insert_weights = np.ones(ins_np.shape[0], dtype=np.int64)
            ins, ins_wts = self._validated(insert_edges, "insert",
                                           weights=insert_weights)
        else:
            ins = self._validated(insert_edges, "insert")
        rm = self._validated(remove_edges, "remove")
        if self.engine == "host":
            n_live0 = self.live_edges
            rm_st = self._remove_edges_host(rm)
            n_live1 = self.live_edges
            renumbered = self.host_renumbered
            in_st = self._insert_edges_host(ins)
            renumbered = renumbered or self.host_renumbered
            stats = BatchStats(
                n_inserted=jnp.int32(self.live_edges - n_live1),
                n_removed=jnp.int32(n_live0 - n_live1),
                insert_rounds=in_st.rounds,
                n_promoted=in_st.n_promoted,
                v_plus=in_st.v_plus,
                remove_rounds=rm_st.rounds,
                n_dropped=rm_st.n_dropped,
                renumbered=jnp.bool_(renumbered),
                n_recycled=jnp.int32(0),  # host path reclaims via _compact
                high_water=self.n_edges,  # == the host bump pointer
                max_frontier=jnp.maximum(in_st.max_frontier,
                                         rm_st.max_frontier),
                n_overflow=jnp.int32(0),  # host path has no halo exchange
            )
            self.last_batch_stats = stats
            return stats
        b_ins = ins.shape[0]
        if b_ins == 0 and rm.shape[0] == 0:
            z = jnp.int32(0)
            stats = BatchStats(z, z, z, z, z, z, z, jnp.bool_(False), z,
                               jnp.int32(self.hwm_ub), z, z)
            self.last_batch_stats = stats
            return stats
        self._ensure_capacity(b_ins)
        iu = _pad_pow2(ins[:, 0], 0)
        iv = _pad_pow2(ins[:, 1], 0)
        iok = np.zeros(len(iu), dtype=bool)
        iok[:b_ins] = True
        ru = _pad_pow2(rm[:, 0], 0)
        rv = _pad_pow2(rm[:, 1], 0)
        rok = np.zeros(len(ru), dtype=bool)
        rok[: rm.shape[0]] = True
        if self.weighted:
            # padded lanes carry weight 1, but iok=False keeps them out
            # of the slot writes and the total-weight promotion bound
            iw = _pad_pow2(ins_wts.astype(np.int32), 1)
            args = (
                self.src,
                self.dst,
                self.valid,
                self.w,
                self.core,
                self.label,
                self.n_edges,
                jnp.asarray(iu),
                jnp.asarray(iv),
                jnp.asarray(iw),
                jnp.asarray(iok),
                jnp.asarray(ru),
                jnp.asarray(rv),
                jnp.asarray(rok),
            )
        else:
            args = (
                self.src,
                self.dst,
                self.valid,
                self.core,
                self.label,
                self.n_edges,
                jnp.asarray(iu),
                jnp.asarray(iv),
                jnp.asarray(iok),
                jnp.asarray(ru),
                jnp.asarray(rv),
                jnp.asarray(rok),
            )
        # static pow2 bound on the per-shard slot high-water mark incl.
        # this batch: every edge pass runs over this per-shard slot
        # prefix only, and (because the free-list allocator fills the
        # lowest holes first) the window always contains >= b_ins free
        # slots per shard — so the in-program recycler can never run dry
        window = self._window(b_ins)
        if 0 < self._last_window < window:
            # the bucket would grow — but hwm_ub is the conservative
            # march, not the truth. Refresh the exact device bounds (one
            # amortized sync) before paying a recompile + wider passes:
            # under balanced churn the true high-water mark is flat and
            # the bucket never actually grows
            self._refresh_bounds()
            window = self._window(b_ins)
        self._last_window = window
        with warnings.catch_warnings():
            # donation is declared for accelerator backends; backends
            # without buffer aliasing (CPU) warn and copy instead
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            if self.engine == "sharded":
                # the per-shard window is sliced INSIDE the shard_map
                # kernel (slicing the sharded buffer here would reshard);
                # the sparse frontier cap is a second static bucket keyed
                # off the padded batch size (0 = exchange off)
                fcap = self._frontier_bucket(max(len(iu), len(ru)))
                out = self._get_sharded_fn(window, fcap)(*args)
            elif self.weighted:
                out = apply_batch_weighted(
                    *args, self.n, self.n_levels, window,
                    kernel_backend=self.kernel_backend)
            else:
                out = apply_batch(*args, self.n, self.n_levels, window,
                                  kernel_backend=self.kernel_backend)
        if self.weighted:
            (
                self.src,
                self.dst,
                self.valid,
                self.w,
                self.core,
                self.label,
                self.n_edges,
                stats,
            ) = out
        else:
            (
                self.src,
                self.dst,
                self.valid,
                self.core,
                self.label,
                self.n_edges,
                stats,
            ) = out
        # monotone sync-free bounds: each insert can raise the densest
        # shard's high-water mark by at most one (holes fill first), and
        # the live count by at most one; removals only help. The exact
        # values (stats.high_water / n_edges) are re-read only when
        # planning crosses the capacity threshold (_refresh_bounds).
        self.hwm_ub = min(self.hwm_ub + b_ins, self._local_cap)
        self.live_ub = min(self.live_ub + b_ins, self.capacity)
        self.slot_cache = None
        self.last_batch_stats = stats
        if self.frontier_exchange == "sparse" and self.frontier_cap == 0:
            # queue the device scalar for the sync-free observed-quantile
            # harvest (_observed_frontier) that seeds future cap buckets
            self._frontier_obs.append(stats.max_frontier)
        return stats

    def insert_edges(self, edges: np.ndarray,
                     weights: Optional[np.ndarray] = None) -> InsertStats:
        if self.engine == "host":
            if weights is not None:
                raise ValueError(
                    "weights= needs weighted=True (a device engine)"
                )
            return self._insert_edges_host(edges)
        st = self.apply_batch(insert_edges=edges, insert_weights=weights)
        self.last_insert_stats = InsertStats(
            rounds=st.insert_rounds,
            n_promoted=st.n_promoted,
            v_plus=st.v_plus,
            max_frontier=st.max_frontier,
        )
        return self.last_insert_stats

    def remove_edges(self, edges: np.ndarray) -> RemoveStats:
        if self.engine == "host":
            return self._remove_edges_host(edges)
        st = self.apply_batch(remove_edges=edges)
        self.last_remove_stats = RemoveStats(
            rounds=st.remove_rounds, n_dropped=st.n_dropped,
            max_frontier=st.max_frontier,
        )
        return self.last_remove_stats

    # -- seed two-program path (benchmark baseline; engine="host") -----------
    def _insert_edges_host(self, edges: np.ndarray) -> InsertStats:
        _require_x64()
        self.host_renumbered = False
        edges = self._validated(edges, "insert")
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        keep, seen = [], set()
        slot_table = self.edge_slot
        for a, b in zip(lo.tolist(), hi.tolist()):
            key = (a, b)
            if a == b or key in seen or key in slot_table:
                continue
            seen.add(key)
            keep.append(key)
        if not keep:
            self.last_insert_stats = None
            return InsertStats(jnp.int32(0), jnp.int32(0), jnp.int32(0),
                               jnp.int32(0))
        arr = np.asarray(keep, dtype=np.int32)
        if int(self.n_edges) + arr.shape[0] + 1 >= self.capacity:
            self._compact()  # replaces slot_cache — re-read below
            if int(self.n_edges) + arr.shape[0] + 1 >= self.capacity:
                self._grow(arr.shape[0])
        base = int(self.n_edges)
        slot_table = self.edge_slot
        for i, key in enumerate(keep):
            slot_table[key] = base + i
        new_src = _pad_pow2(arr[:, 0], 0)
        new_dst = _pad_pow2(arr[:, 1], 0)
        new_ok = np.zeros(len(new_src), dtype=bool)
        new_ok[: arr.shape[0]] = True
        (
            self.src,
            self.dst,
            self.valid,
            self.n_edges,
            self.core,
            self.label,
            stats,
        ) = insert_batch(
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            jnp.asarray(new_src),
            jnp.asarray(new_dst),
            jnp.asarray(new_ok),
            self.n_edges,
            self.n,
            self.n_levels,
        )
        # on the host path n_edges IS the bump pointer (slot high-water)
        self.hwm_ub = int(self.n_edges)
        self.live_ub = self.hwm_ub
        self.host_renumbered = self._maybe_renumber()
        self.last_insert_stats = stats
        return stats

    def _remove_edges_host(self, edges: np.ndarray) -> RemoveStats:
        _require_x64()
        self.host_renumbered = False
        edges = self._validated(edges, "remove")
        slots = []
        slot_table = self.edge_slot
        for a, b in edges:
            key = (int(min(a, b)), int(max(a, b)))
            slot = slot_table.pop(key, None)
            if slot is not None:
                slots.append(slot)
        if not slots:
            self.last_remove_stats = None
            return RemoveStats(jnp.int32(0), jnp.int32(0), jnp.int32(0))
        padded = _pad_pow2(np.asarray(slots, dtype=np.int32), -1)
        self.valid, self.core, self.label, stats = remove_batch(
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            jnp.asarray(padded),
            self.n,
            self.n_levels,
        )
        self.host_renumbered = self._maybe_renumber()
        self.last_remove_stats = stats
        return stats

    # -- maintenance -----------------------------------------------------------
    def _maybe_renumber(self) -> bool:
        if bool(needs_renumber(self.label)):
            self.label = renumber(self.core, self.label)
            return True
        return False

    def _refresh_bounds(self) -> None:
        """Amortized sync point: replace the monotone worst-case planning
        bounds with the exact values the device already computed —
        ``stats.high_water`` (per-shard high-water mark) and ``n_edges``
        (live count). Called only when the conservative bounds cross the
        capacity threshold; the per-batch edit path stays sync-free.
        Under balanced churn the exact high-water mark is flat (the
        free-list recycles every tombstone), so this usually reveals
        plenty of headroom and no defrag or growth happens at all."""
        if self.last_batch_stats is not None:
            self.hwm_ub = int(self.last_batch_stats.high_water)
        self.live_ub = int(self.n_edges)

    def _ensure_capacity(self, b_ins: int) -> None:
        """Make the per-shard window able to hold the live slots plus this
        batch. Escalates: sync-free bound check -> exact-bound refresh
        (one amortized sync) -> defrag, growing in the same re-layout if
        even a perfectly packed table would leave no window headroom —
        so the sharded buffers are placed at most ONCE per call (the old
        compact-then-grow path placed them twice)."""
        if self.hwm_ub + b_ins + 1 < self._local_cap:
            return
        self._refresh_bounds()
        if self.hwm_ub + b_ins + 1 < self._local_cap:
            return
        nd = self._n_shards
        new_cap = self.capacity
        # after a balanced defrag the densest shard holds ceil(live / nd)
        while -(-self.live_ub // nd) + b_ins + 1 >= new_cap // nd:
            new_cap = max(new_cap * 2, new_cap + nd * (2 * b_ins + 16))
        self._defrag_to(new_cap)

    def _defrag_to(self, new_cap: int) -> None:
        """Repack live slots into a balanced layout at ``new_cap`` total
        capacity (compact + grow fused: one buffer re-layout, one sharded
        placement). Live edges are strided across the shards — edge j
        lands on shard ``j % n_shards`` — so every shard's high-water
        mark starts at ~``live / n_shards``. Preserves core/label state.
        Rare: the in-program free-list reclaims tombstones batch-by-batch,
        so this only runs when the exact bounds genuinely leave no window
        headroom (large net growth or a lopsided loaded layout)."""
        nd = self._n_shards
        new_cap += (-new_cap) % nd
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        val = np.asarray(self.valid)
        live = np.nonzero(val)[0]
        m = live.shape[0]
        if new_cap <= m:
            raise ValueError(
                f"defrag target {new_cap} cannot hold {m} live edges"
            )
        local_cap = new_cap // nd
        j = np.arange(m, dtype=np.int64)
        tgt = (j % nd) * local_cap + j // nd
        new_src = np.zeros(new_cap, dtype=np.int32)
        new_dst = np.zeros(new_cap, dtype=np.int32)
        new_val = np.zeros(new_cap, dtype=bool)
        new_src[tgt] = src[live]
        new_dst[tgt] = dst[live]
        new_val[tgt] = True
        self.src = jnp.asarray(new_src)
        self.dst = jnp.asarray(new_dst)
        self.valid = jnp.asarray(new_val)
        if self.weighted:
            wcol = np.asarray(self.w)
            new_w = np.zeros(new_cap, dtype=np.int32)
            new_w[tgt] = wcol[live]
            self.w = jnp.asarray(new_w)
        self.n_edges = jnp.asarray(m, dtype=jnp.int32)
        self.capacity = new_cap
        self.live_ub = m
        self.hwm_ub = -(-m // nd) if m else 0
        self._last_window = 0  # fresh layout: let the next batch re-bucket
        # the mirror is stale either way; let the edge_slot property
        # rebuild it lazily (the unified engine never reads it)
        self.slot_cache = None
        if self.engine == "sharded":
            self._place_sharded()

    def _compact(self) -> None:
        """Drop tombstoned slots (host-path reclaim; a defrag elsewhere).
        The one edit-path step that syncs — amortized over many batches."""
        self._defrag_to(self.capacity)

    def _grow(self, need: int) -> None:
        self._grow_to(max(self.capacity * 2, self.capacity + 2 * need + 16))

    def _grow_to(self, new_cap: int) -> None:
        """Extend the slot table with dead headroom — the host-path
        growth step. The device engines grow through ``_defrag_to``
        (which also re-strides across shards); delegate so a sharded
        caller can never produce an unbalanced un-restrided layout."""
        if self.engine == "sharded":
            self._defrag_to(new_cap)
            return
        pad = new_cap - self.capacity
        if pad <= 0:
            return

        def ext(x, fill):
            x = jnp.asarray(x)
            return jnp.concatenate(
                [x, jnp.full((pad,), fill, dtype=x.dtype)]
            )

        self.src = ext(self.src, 0)
        self.dst = ext(self.dst, 0)
        self.valid = ext(self.valid, False)
        if self.weighted:
            self.w = ext(self.w, 0)
        self.capacity = new_cap

    # -- persistence -------------------------------------------------------------
    def save(self, path: str) -> None:
        """Checkpoint the maintainer. The free-list is implicit — a dead
        slot is exactly a ``valid=False`` entry — so tombstones, the
        recycler's state, and the per-shard high-water marks all
        round-trip through the ``valid`` mask (load() recomputes the
        planning bounds from it, shard-count independent). Range-sharded
        vertex state is saved UNPADDED (``[:n]``), so the checkpoint is
        also vertex-shard-count independent: a state saved range-sharded
        over 8 devices reloads replicated on 1 and vice versa.
        Weighted maintainers add the per-slot weight column ``w``
        (aligned with ``src``/``dst``/``valid``)."""
        payload = dict(
            n=self.n,
            capacity=self.capacity,
            src=np.asarray(self.src),
            dst=np.asarray(self.dst),
            valid=np.asarray(self.valid),
            n_edges=np.asarray(self.n_edges),
            core=self.cores(),
            label=self.labels(),
        )
        if self.weighted:
            payload["w"] = np.asarray(self.w)
        np.savez_compressed(path, **payload)

    @classmethod
    def load(
        cls,
        path: str,
        engine: str = "unified",
        mesh: Optional[Any] = None,
        vertex_sharding: str = "replicated",
        mesh_shape: Optional[Tuple[int, int]] = None,
        freelist: str = "interleaved",
        frontier_exchange: str = "bitmask",
        frontier_cap: int = 0,
        kernel_backend: str = "lax",
        weighted: bool = False,
        validate: bool = True,
    ) -> "CoreMaintainer":
        z = np.load(path)
        w = None
        if weighted:
            # checkpoints from an unweighted maintainer carry no weight
            # column; loading one weighted adopts unit weights (exactly
            # the classic-coreness specialization)
            w = jnp.asarray(z["w"]) if "w" in z.files else None
        return cls(
            n=int(z["n"]),
            capacity=int(z["capacity"]),
            src=jnp.asarray(z["src"]),
            dst=jnp.asarray(z["dst"]),
            valid=jnp.asarray(z["valid"]),
            n_edges=jnp.asarray(z["n_edges"]),
            core=jnp.asarray(z["core"]),
            label=jnp.asarray(z["label"]),
            n_levels=int(z["n"]) + 2,
            engine=engine,
            mesh=mesh,
            vertex_sharding=vertex_sharding,
            mesh_shape=mesh_shape,
            freelist=freelist,
            frontier_exchange=frontier_exchange,
            frontier_cap=frontier_cap,
            kernel_backend=kernel_backend,
            weighted=weighted,
            w=w,
            validate=validate,
            slot_cache=None,  # lazily rebuilt from the live table
            # live_ub / hwm_ub default to -1: __post_init__ recomputes
            # both exactly from the saved valid mask, which makes the
            # high-water bookkeeping portable across device counts (a
            # state saved on 1 device reloads sharded over 8 and vice
            # versa; the sharded path re-strides the layout on entry)
        )


def maintainer_from_edges(n: int, edges: np.ndarray, **kw) -> CoreMaintainer:
    return CoreMaintainer.from_graph(build_csr(n, edges), **kw)
