"""CoreMaintainer — the public interface to parallel order-based core
maintenance.

Host side keeps the edge -> slot dictionary (removals address slots) and
handles capacity compaction; all per-batch work runs as two jitted
fixpoint programs (`insert.insert_batch`, `remove.remove_batch`).

Batches are padded to power-of-two sizes so the jit cache stays small.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph, build_csr
from .decomposition import peel_decomposition, rank_to_labels
from .insert import InsertStats, insert_batch
from .oracle import bz_core_decomposition
from .order import needs_renumber, renumber
from .remove import RemoveStats, remove_batch


def _pad_pow2(x: np.ndarray, fill: int) -> np.ndarray:
    b = max(1, len(x))
    p = 1
    while p < b:
        p *= 2
    out = np.full(p, fill, dtype=np.int32)
    out[: len(x)] = x
    return out


@dataclasses.dataclass
class CoreMaintainer:
    """Dynamic-graph core maintenance with k-order labels (JAX)."""

    n: int
    capacity: int
    src: jax.Array
    dst: jax.Array
    valid: jax.Array
    n_edges: jax.Array
    core: jax.Array
    label: jax.Array
    edge_slot: Dict[Tuple[int, int], int]
    n_levels: int
    last_insert_stats: Optional[InsertStats] = None
    last_remove_stats: Optional[RemoveStats] = None

    # -- construction -------------------------------------------------------
    @classmethod
    def from_graph(
        cls,
        g: CSRGraph,
        capacity: Optional[int] = None,
        init: str = "host-bz",
    ) -> "CoreMaintainer":
        edges = g.edge_array()
        m = edges.shape[0]
        capacity = capacity or max(16, 2 * m)
        if capacity <= m:
            raise ValueError("capacity must exceed edge count")
        src = np.zeros(capacity, dtype=np.int32)
        dst = np.zeros(capacity, dtype=np.int32)
        val = np.zeros(capacity, dtype=bool)
        src[:m] = edges[:, 0]
        dst[:m] = edges[:, 1]
        val[:m] = True
        edge_slot = {
            (int(a), int(b)): i for i, (a, b) in enumerate(edges)
        }
        n_levels = g.n + 2
        if init == "host-bz":
            adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
            core_np, order = bz_core_decomposition(g.n, adj)
            rank = np.zeros(g.n, dtype=np.int32)
            rank[np.asarray(order, dtype=np.int64)] = np.arange(
                g.n, dtype=np.int32
            )
            core = jnp.asarray(core_np.astype(np.int32))
            label = rank_to_labels(jnp.asarray(rank))
        elif init == "jax-peel":
            core, rank = peel_decomposition(
                jnp.asarray(src), jnp.asarray(dst), jnp.asarray(val), g.n
            )
            label = rank_to_labels(rank)
        else:
            raise ValueError(init)
        return cls(
            n=g.n,
            capacity=capacity,
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            valid=jnp.asarray(val),
            n_edges=jnp.asarray(m, dtype=jnp.int32),
            core=core,
            label=label,
            edge_slot=edge_slot,
            n_levels=n_levels,
        )

    # -- queries -------------------------------------------------------------
    def cores(self) -> np.ndarray:
        return np.asarray(self.core)

    def labels(self) -> np.ndarray:
        return np.asarray(self.label)

    def order_lt(self, u: int, v: int) -> bool:
        cu, cv = int(self.core[u]), int(self.core[v])
        if cu != cv:
            return cu < cv
        return int(self.label[u]) < int(self.label[v])

    @property
    def live_edges(self) -> int:
        return len(self.edge_slot)

    # -- edits ----------------------------------------------------------------
    def insert_edges(self, edges: np.ndarray) -> InsertStats:
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        keep, seen = [], set()
        for a, b in zip(lo.tolist(), hi.tolist()):
            key = (a, b)
            if a == b or key in seen or key in self.edge_slot:
                continue
            seen.add(key)
            keep.append(key)
        if not keep:
            self.last_insert_stats = None
            return InsertStats(jnp.int32(0), jnp.int32(0), jnp.int32(0))
        arr = np.asarray(keep, dtype=np.int32)
        if int(self.n_edges) + arr.shape[0] + 1 >= self.capacity:
            self._compact()
            if int(self.n_edges) + arr.shape[0] + 1 >= self.capacity:
                self._grow(arr.shape[0])
        base = int(self.n_edges)
        for i, key in enumerate(keep):
            self.edge_slot[key] = base + i
        new_src = _pad_pow2(arr[:, 0], 0)
        new_dst = _pad_pow2(arr[:, 1], 0)
        new_ok = np.zeros(len(new_src), dtype=bool)
        new_ok[: arr.shape[0]] = True
        (
            self.src,
            self.dst,
            self.valid,
            self.n_edges,
            self.core,
            self.label,
            stats,
        ) = insert_batch(
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            jnp.asarray(new_src),
            jnp.asarray(new_dst),
            jnp.asarray(new_ok),
            self.n_edges,
            self.n,
            self.n_levels,
        )
        self._maybe_renumber()
        self.last_insert_stats = stats
        return stats

    def remove_edges(self, edges: np.ndarray) -> RemoveStats:
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        slots = []
        for a, b in edges:
            key = (int(min(a, b)), int(max(a, b)))
            slot = self.edge_slot.pop(key, None)
            if slot is not None:
                slots.append(slot)
        if not slots:
            self.last_remove_stats = None
            return RemoveStats(jnp.int32(0), jnp.int32(0))
        padded = _pad_pow2(np.asarray(slots, dtype=np.int32), -1)
        self.valid, self.core, self.label, stats = remove_batch(
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            jnp.asarray(padded),
            self.n,
            self.n_levels,
        )
        self._maybe_renumber()
        self.last_remove_stats = stats
        return stats

    # -- maintenance -----------------------------------------------------------
    def _maybe_renumber(self) -> None:
        if bool(needs_renumber(self.label)):
            self.label = renumber(self.core, self.label)

    def _compact(self) -> None:
        """Drop tombstoned slots; preserves core/label state."""
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        val = np.asarray(self.valid)
        live = np.nonzero(val)[0]
        m = live.shape[0]
        new_src = np.zeros(self.capacity, dtype=np.int32)
        new_dst = np.zeros(self.capacity, dtype=np.int32)
        new_val = np.zeros(self.capacity, dtype=bool)
        new_src[:m] = src[live]
        new_dst[:m] = dst[live]
        new_val[:m] = True
        self.src = jnp.asarray(new_src)
        self.dst = jnp.asarray(new_dst)
        self.valid = jnp.asarray(new_val)
        self.n_edges = jnp.asarray(m, dtype=jnp.int32)
        self.edge_slot = {
            (int(min(a, b)), int(max(a, b))): i
            for i, (a, b) in enumerate(zip(new_src[:m], new_dst[:m]))
        }

    def _grow(self, need: int) -> None:
        new_cap = max(self.capacity * 2, self.capacity + 2 * need + 16)
        pad = new_cap - self.capacity

        def ext(x, fill):
            return jnp.concatenate(
                [x, jnp.full((pad,), fill, dtype=x.dtype)]
            )

        self.src = ext(self.src, 0)
        self.dst = ext(self.dst, 0)
        self.valid = ext(self.valid, False)
        self.capacity = new_cap

    # -- persistence -------------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            n=self.n,
            capacity=self.capacity,
            src=np.asarray(self.src),
            dst=np.asarray(self.dst),
            valid=np.asarray(self.valid),
            n_edges=np.asarray(self.n_edges),
            core=np.asarray(self.core),
            label=np.asarray(self.label),
        )

    @classmethod
    def load(cls, path: str) -> "CoreMaintainer":
        z = np.load(path)
        src = np.asarray(z["src"])
        dst = np.asarray(z["dst"])
        val = np.asarray(z["valid"])
        edge_slot = {
            (int(min(a, b)), int(max(a, b))): i
            for i, (a, b, ok) in enumerate(zip(src, dst, val))
            if ok
        }
        return cls(
            n=int(z["n"]),
            capacity=int(z["capacity"]),
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            valid=jnp.asarray(val),
            n_edges=jnp.asarray(z["n_edges"]),
            core=jnp.asarray(z["core"]),
            label=jnp.asarray(z["label"]),
            edge_slot=edge_slot,
            n_levels=int(z["n"]) + 2,
        )


def maintainer_from_edges(n: int, edges: np.ndarray, **kw) -> CoreMaintainer:
    return CoreMaintainer.from_graph(build_csr(n, edges), **kw)
