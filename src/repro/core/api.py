"""CoreMaintainer — the public interface to parallel order-based core
maintenance.

The default ``unified`` engine runs every batch (mixed insertions +
removals) as ONE jitted device program (`engine.apply_batch`): dedup,
slot lookup/allocation, both fixpoints, and the label-renumber gate all
happen on device with donated buffers — the host stays off the critical
path entirely (see docs/DESIGN.md §3 for the host-sync audit).

The host keeps only
  * a lazily-rebuilt ``edge -> slot`` mirror for queries (invalidated per
    batch, materialized on first access), and
  * ``n_edges_ub``, a monotone host-side upper bound on the device slot
    high-water mark, used for capacity compaction/growth planning.

The seed two-program path (host-dict dedup + `insert.insert_batch` /
`remove.remove_batch`) is preserved under ``engine="host"`` as the
benchmark baseline and fallback.

``engine="sharded"`` runs the SAME one-program-per-batch semantics with
the edge-slot table sharded across a mesh's ``data`` axis
(core/sharded.py, docs/DESIGN.md §4): per-device work scales as
capacity / n_devices, vertex state is replicated, and each statistic
costs one psum.

Batches are padded to power-of-two sizes so the jit cache stays small.

Edge endpoints are validated on every edit path: out-of-range vertices
raise ``ValueError`` by default, or are masked out (dropped) under
``validate=False`` — an invalid edge can never reach the slot table or
the per-vertex stat scatters (which would clamp it onto vertex n-1).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph, build_csr
from .decomposition import peel_decomposition, rank_to_labels
from .engine import BatchStats, apply_batch
from .insert import InsertStats, insert_batch
from .oracle import bz_core_decomposition
from .order import needs_renumber, renumber
from .remove import RemoveStats, remove_batch
from .sharded import make_sharded_apply

EDGE_AXIS = "data"  # mesh axis the sharded engine shards edge slots over

_ENGINES = ("unified", "host", "sharded")


def _pad_pow2(x: np.ndarray, fill: int) -> np.ndarray:
    b = max(1, len(x))
    p = 1
    while p < b:
        p *= 2
    out = np.full(p, fill, dtype=np.int32)
    out[: len(x)] = x
    return out


def _as_edge_array(edges) -> np.ndarray:
    if edges is None:
        return np.zeros((0, 2), dtype=np.int64)
    return np.asarray(edges, dtype=np.int64).reshape(-1, 2)


def _require_x64() -> None:
    """The k-order labels are int64 and the engines pack edge keys against
    an int64 sentinel (1 << 62): with x64 disabled both silently truncate
    to int32 and corrupt state. ``import repro`` enables x64; fail loudly
    if a user (or another library) turned it off afterwards."""
    if not jax.config.jax_enable_x64:
        raise RuntimeError(
            "CoreMaintainer needs jax_enable_x64 (int64 k-order labels and "
            "1<<62 edge-key sentinels corrupt silently under x32). "
            "Re-enable it with jax.config.update('jax_enable_x64', True) "
            "— `import repro` does this at import time."
        )


def _default_edge_mesh():
    from ..launch.mesh import make_edge_mesh

    return make_edge_mesh(axis=EDGE_AXIS)


@dataclasses.dataclass
class CoreMaintainer:
    """Dynamic-graph core maintenance with k-order labels (JAX)."""

    n: int
    capacity: int
    src: jax.Array
    dst: jax.Array
    valid: jax.Array
    n_edges: jax.Array
    core: jax.Array
    label: jax.Array
    n_levels: int
    engine: str = "unified"     # "unified" | "host" | "sharded"
    mesh: Optional[Any] = None  # sharded engine only; needs a "data" axis
    validate: bool = True       # raise on out-of-range endpoints (else mask)
    last_insert_stats: Optional[InsertStats] = None
    last_remove_stats: Optional[RemoveStats] = None
    last_batch_stats: Optional[BatchStats] = None
    slot_cache: Optional[Dict[Tuple[int, int], int]] = None
    n_edges_ub: int = 0         # host upper bound on int(n_edges)
    host_renumbered: bool = False  # last host-path call triggered a renumber
    _sharded_fn: Optional[Callable] = dataclasses.field(
        default=None, repr=False
    )

    def __post_init__(self) -> None:
        if self.engine not in _ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}")
        _require_x64()
        if self.engine == "sharded":
            if self.mesh is None:
                self.mesh = _default_edge_mesh()
            if EDGE_AXIS not in dict(self.mesh.shape):
                raise ValueError(
                    f"sharded engine needs a {EDGE_AXIS!r} mesh axis; got "
                    f"axes {tuple(self.mesh.axis_names)}"
                )
            # pad the slot table up to an even shard split (all-invalid
            # headroom); save()d states keep working on any device count.
            # _grow_to places the grown buffers itself, so only place here
            # when no padding was needed
            cap0 = self.capacity
            self._grow_to(self.capacity)
            if self.capacity == cap0:
                self._place_sharded()

    # -- sharded placement ---------------------------------------------------
    def _place_sharded(self) -> None:
        """Commit the slot table sharded over the mesh's data axis and the
        vertex state replicated, so the jitted shard_map program never
        reshards its inputs."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        esh = NamedSharding(self.mesh, P(EDGE_AXIS))
        rep = NamedSharding(self.mesh, P())
        self.src = jax.device_put(jnp.asarray(self.src), esh)
        self.dst = jax.device_put(jnp.asarray(self.dst), esh)
        self.valid = jax.device_put(jnp.asarray(self.valid), esh)
        self.core = jax.device_put(jnp.asarray(self.core), rep)
        self.label = jax.device_put(jnp.asarray(self.label), rep)
        self.n_edges = jax.device_put(
            jnp.asarray(self.n_edges, dtype=jnp.int32), rep
        )

    def _get_sharded_fn(self) -> Callable:
        if self._sharded_fn is None:
            self._sharded_fn = make_sharded_apply(
                self.mesh, self.n, self.n_levels, axis=EDGE_AXIS
            )
        return self._sharded_fn

    # -- construction -------------------------------------------------------
    @classmethod
    def from_graph(
        cls,
        g: CSRGraph,
        capacity: Optional[int] = None,
        init: str = "host-bz",
        engine: str = "unified",
        mesh: Optional[Any] = None,
        validate: bool = True,
    ) -> "CoreMaintainer":
        _require_x64()  # before any label math that would truncate quietly
        edges = g.edge_array()
        m = edges.shape[0]
        capacity = capacity or max(16, 2 * m)
        if capacity <= m:
            raise ValueError("capacity must exceed edge count")
        src = np.zeros(capacity, dtype=np.int32)
        dst = np.zeros(capacity, dtype=np.int32)
        val = np.zeros(capacity, dtype=bool)
        src[:m] = edges[:, 0]
        dst[:m] = edges[:, 1]
        val[:m] = True
        edge_slot = {
            (int(a), int(b)): i for i, (a, b) in enumerate(edges)
        }
        n_levels = g.n + 2
        if init == "host-bz":
            adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
            core_np, order = bz_core_decomposition(g.n, adj)
            rank = np.zeros(g.n, dtype=np.int32)
            rank[np.asarray(order, dtype=np.int64)] = np.arange(
                g.n, dtype=np.int32
            )
            core = jnp.asarray(core_np.astype(np.int32))
            label = rank_to_labels(jnp.asarray(rank))
        elif init == "jax-peel":
            core, rank = peel_decomposition(
                jnp.asarray(src), jnp.asarray(dst), jnp.asarray(val), g.n
            )
            label = rank_to_labels(rank)
        else:
            raise ValueError(init)
        return cls(
            n=g.n,
            capacity=capacity,
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            valid=jnp.asarray(val),
            n_edges=jnp.asarray(m, dtype=jnp.int32),
            core=core,
            label=label,
            n_levels=n_levels,
            engine=engine,
            mesh=mesh,
            validate=validate,
            slot_cache=edge_slot,
            n_edges_ub=m,
        )

    # -- queries -------------------------------------------------------------
    @property
    def edge_slot(self) -> Dict[Tuple[int, int], int]:
        """Host mirror of the live edge -> slot table.

        The unified engine allocates slots on device and only invalidates
        this dict; it is rebuilt here on first access (queries tolerate
        the sync — the per-batch edit path never touches it).
        """
        if self.slot_cache is None:
            src = np.asarray(self.src)
            dst = np.asarray(self.dst)
            live = np.nonzero(np.asarray(self.valid))[0]
            self.slot_cache = {
                (int(min(a, b)), int(max(a, b))): int(i)
                for i, a, b in zip(live, src[live], dst[live])
            }
        return self.slot_cache

    def cores(self) -> np.ndarray:
        return np.asarray(self.core)

    def labels(self) -> np.ndarray:
        return np.asarray(self.label)

    def order_lt(self, u: int, v: int) -> bool:
        cu, cv = int(self.core[u]), int(self.core[v])
        if cu != cv:
            return cu < cv
        return int(self.label[u]) < int(self.label[v])

    @property
    def live_edges(self) -> int:
        return len(self.edge_slot)

    # -- validation ----------------------------------------------------------
    def _validated(self, edges, what: str) -> np.ndarray:
        """Normalize an edge batch and enforce endpoint bounds.

        With ``validate`` (the default) an out-of-range endpoint raises;
        otherwise the offending rows are masked out before they can reach
        the slot table or the stat scatters (whose index clamping would
        silently alias them onto vertex n-1)."""
        edges = _as_edge_array(edges)
        if edges.size:
            bad = ((edges < 0) | (edges >= self.n)).any(axis=1)
            if bad.any():
                if self.validate:
                    row = edges[bad][0]
                    raise ValueError(
                        f"{what} edge {row.tolist()} out of range for "
                        f"n={self.n} (pass validate=False to mask instead)"
                    )
                edges = edges[~bad]
        return edges

    # -- edits ----------------------------------------------------------------
    def apply_batch(
        self,
        insert_edges=None,
        remove_edges=None,
    ) -> BatchStats:
        """Apply one mixed batch (removals first, then insertions) in a
        single compiled device program — no host dedup, no per-batch
        device->host syncs. Under ``engine="host"`` the batch is served by
        the seed two-call path instead (stats composed from both calls);
        ``engine="sharded"`` runs the same program with the slot table
        sharded across the mesh."""
        _require_x64()
        # validate BOTH lists before any engine touches state, so a
        # rejected batch is rejected atomically (the host path applies
        # removals first and must not commit them before the insert list
        # has passed validation)
        ins = self._validated(insert_edges, "insert")
        rm = self._validated(remove_edges, "remove")
        if self.engine == "host":
            n_live0 = self.live_edges
            rm_st = self._remove_edges_host(rm)
            n_live1 = self.live_edges
            renumbered = self.host_renumbered
            in_st = self._insert_edges_host(ins)
            renumbered = renumbered or self.host_renumbered
            stats = BatchStats(
                n_inserted=jnp.int32(self.live_edges - n_live1),
                n_removed=jnp.int32(n_live0 - n_live1),
                insert_rounds=in_st.rounds,
                n_promoted=in_st.n_promoted,
                v_plus=in_st.v_plus,
                remove_rounds=rm_st.rounds,
                n_dropped=rm_st.n_dropped,
                renumbered=jnp.bool_(renumbered),
            )
            self.last_batch_stats = stats
            return stats
        b_ins = ins.shape[0]
        if b_ins == 0 and rm.shape[0] == 0:
            z = jnp.int32(0)
            stats = BatchStats(z, z, z, z, z, z, z, jnp.bool_(False))
            self.last_batch_stats = stats
            return stats
        if self.n_edges_ub + b_ins + 1 >= self.capacity:
            self._compact()
            if self.n_edges_ub + b_ins + 1 >= self.capacity:
                self._grow(b_ins)
        iu = _pad_pow2(ins[:, 0], 0)
        iv = _pad_pow2(ins[:, 1], 0)
        iok = np.zeros(len(iu), dtype=bool)
        iok[:b_ins] = True
        ru = _pad_pow2(rm[:, 0], 0)
        rv = _pad_pow2(rm[:, 1], 0)
        rok = np.zeros(len(ru), dtype=bool)
        rok[: rm.shape[0]] = True
        args = (
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            self.n_edges,
            jnp.asarray(iu),
            jnp.asarray(iv),
            jnp.asarray(iok),
            jnp.asarray(ru),
            jnp.asarray(rv),
            jnp.asarray(rok),
        )
        with warnings.catch_warnings():
            # donation is declared for accelerator backends; backends
            # without buffer aliasing (CPU) warn and copy instead
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            if self.engine == "sharded":
                # every edge pass runs over capacity / n_devices slots per
                # device; no active_cap prefix (slicing would reshard)
                out = self._get_sharded_fn()(*args)
            else:
                # static pow2 bound on the slot high-water mark incl. this
                # batch: every edge pass runs over this slot prefix only
                need = max(16, self.n_edges_ub + b_ins + 1)
                active_cap = 1
                while active_cap < need:
                    active_cap *= 2
                active_cap = min(active_cap, self.capacity)
                out = apply_batch(*args, self.n, self.n_levels, active_cap)
        (
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            self.n_edges,
            stats,
        ) = out
        # monotone host bound: the device allocated at most b_ins new slots
        self.n_edges_ub += b_ins
        self.slot_cache = None
        self.last_batch_stats = stats
        return stats

    def insert_edges(self, edges: np.ndarray) -> InsertStats:
        if self.engine == "host":
            return self._insert_edges_host(edges)
        st = self.apply_batch(insert_edges=edges)
        self.last_insert_stats = InsertStats(
            rounds=st.insert_rounds,
            n_promoted=st.n_promoted,
            v_plus=st.v_plus,
        )
        return self.last_insert_stats

    def remove_edges(self, edges: np.ndarray) -> RemoveStats:
        if self.engine == "host":
            return self._remove_edges_host(edges)
        st = self.apply_batch(remove_edges=edges)
        self.last_remove_stats = RemoveStats(
            rounds=st.remove_rounds, n_dropped=st.n_dropped
        )
        return self.last_remove_stats

    # -- seed two-program path (benchmark baseline; engine="host") -----------
    def _insert_edges_host(self, edges: np.ndarray) -> InsertStats:
        _require_x64()
        self.host_renumbered = False
        edges = self._validated(edges, "insert")
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        keep, seen = [], set()
        slot_table = self.edge_slot
        for a, b in zip(lo.tolist(), hi.tolist()):
            key = (a, b)
            if a == b or key in seen or key in slot_table:
                continue
            seen.add(key)
            keep.append(key)
        if not keep:
            self.last_insert_stats = None
            return InsertStats(jnp.int32(0), jnp.int32(0), jnp.int32(0))
        arr = np.asarray(keep, dtype=np.int32)
        if int(self.n_edges) + arr.shape[0] + 1 >= self.capacity:
            self._compact()  # replaces slot_cache — re-read below
            if int(self.n_edges) + arr.shape[0] + 1 >= self.capacity:
                self._grow(arr.shape[0])
        base = int(self.n_edges)
        slot_table = self.edge_slot
        for i, key in enumerate(keep):
            slot_table[key] = base + i
        new_src = _pad_pow2(arr[:, 0], 0)
        new_dst = _pad_pow2(arr[:, 1], 0)
        new_ok = np.zeros(len(new_src), dtype=bool)
        new_ok[: arr.shape[0]] = True
        (
            self.src,
            self.dst,
            self.valid,
            self.n_edges,
            self.core,
            self.label,
            stats,
        ) = insert_batch(
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            jnp.asarray(new_src),
            jnp.asarray(new_dst),
            jnp.asarray(new_ok),
            self.n_edges,
            self.n,
            self.n_levels,
        )
        self.n_edges_ub = int(self.n_edges)
        self.host_renumbered = self._maybe_renumber()
        self.last_insert_stats = stats
        return stats

    def _remove_edges_host(self, edges: np.ndarray) -> RemoveStats:
        _require_x64()
        self.host_renumbered = False
        edges = self._validated(edges, "remove")
        slots = []
        slot_table = self.edge_slot
        for a, b in edges:
            key = (int(min(a, b)), int(max(a, b)))
            slot = slot_table.pop(key, None)
            if slot is not None:
                slots.append(slot)
        if not slots:
            self.last_remove_stats = None
            return RemoveStats(jnp.int32(0), jnp.int32(0))
        padded = _pad_pow2(np.asarray(slots, dtype=np.int32), -1)
        self.valid, self.core, self.label, stats = remove_batch(
            self.src,
            self.dst,
            self.valid,
            self.core,
            self.label,
            jnp.asarray(padded),
            self.n,
            self.n_levels,
        )
        self.host_renumbered = self._maybe_renumber()
        self.last_remove_stats = stats
        return stats

    # -- maintenance -----------------------------------------------------------
    def _maybe_renumber(self) -> bool:
        if bool(needs_renumber(self.label)):
            self.label = renumber(self.core, self.label)
            return True
        return False

    def _compact(self) -> None:
        """Drop tombstoned slots; preserves core/label state. The one edit
        path step that syncs — amortized over many batches."""
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        val = np.asarray(self.valid)
        live = np.nonzero(val)[0]
        m = live.shape[0]
        new_src = np.zeros(self.capacity, dtype=np.int32)
        new_dst = np.zeros(self.capacity, dtype=np.int32)
        new_val = np.zeros(self.capacity, dtype=bool)
        new_src[:m] = src[live]
        new_dst[:m] = dst[live]
        new_val[:m] = True
        self.src = jnp.asarray(new_src)
        self.dst = jnp.asarray(new_dst)
        self.valid = jnp.asarray(new_val)
        self.n_edges = jnp.asarray(m, dtype=jnp.int32)
        self.n_edges_ub = m
        # the mirror is stale either way; let the edge_slot property
        # rebuild it lazily (the unified engine never reads it)
        self.slot_cache = None
        if self.engine == "sharded":
            self._place_sharded()

    def _grow(self, need: int) -> None:
        self._grow_to(max(self.capacity * 2, self.capacity + 2 * need + 16))

    def _grow_to(self, new_cap: int) -> None:
        if self.engine == "sharded":
            # keep the slot table evenly divisible across the mesh
            ndev = dict(self.mesh.shape)[EDGE_AXIS]
            new_cap += (-new_cap) % ndev
        pad = new_cap - self.capacity
        if pad <= 0:
            return

        def ext(x, fill):
            x = jnp.asarray(x)
            return jnp.concatenate(
                [x, jnp.full((pad,), fill, dtype=x.dtype)]
            )

        self.src = ext(self.src, 0)
        self.dst = ext(self.dst, 0)
        self.valid = ext(self.valid, False)
        self.capacity = new_cap
        if self.engine == "sharded":
            self._place_sharded()

    # -- persistence -------------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            n=self.n,
            capacity=self.capacity,
            src=np.asarray(self.src),
            dst=np.asarray(self.dst),
            valid=np.asarray(self.valid),
            n_edges=np.asarray(self.n_edges),
            core=np.asarray(self.core),
            label=np.asarray(self.label),
        )

    @classmethod
    def load(
        cls,
        path: str,
        engine: str = "unified",
        mesh: Optional[Any] = None,
        validate: bool = True,
    ) -> "CoreMaintainer":
        z = np.load(path)
        return cls(
            n=int(z["n"]),
            capacity=int(z["capacity"]),
            src=jnp.asarray(z["src"]),
            dst=jnp.asarray(z["dst"]),
            valid=jnp.asarray(z["valid"]),
            n_edges=jnp.asarray(z["n_edges"]),
            core=jnp.asarray(z["core"]),
            label=jnp.asarray(z["label"]),
            n_levels=int(z["n"]) + 2,
            engine=engine,
            mesh=mesh,
            validate=validate,
            slot_cache=None,  # lazily rebuilt from the live table
            n_edges_ub=int(z["n_edges"]),
        )


def maintainer_from_edges(n: int, edges: np.ndarray, **kw) -> CoreMaintainer:
    return CoreMaintainer.from_graph(build_csr(n, edges), **kw)
