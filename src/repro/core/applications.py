"""Applications of maintained core numbers inside the framework:
k-core sparsification for full-batch GNN training and
core-ordered neighbor-sampling priorities for minibatch training.

Both consume the LIVE maintained state (no recomputation) — the point of
maintenance is that these stay O(1)-fresh under edge streams.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .api import CoreMaintainer

Array = jax.Array


def kcore_edge_mask(m: CoreMaintainer, k: int) -> Array:
    """Mask of live edges whose BOTH endpoints lie in the k-core.

    The induced subgraph on {v: core(v) >= k} restricted to these edges IS
    the k-core (maximality of the core decomposition)."""
    keep = m.core >= k
    return m.valid & keep[m.src] & keep[m.dst]


def kcore_subgraph(m: CoreMaintainer, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side (nodes, edges) of the k-core — GNN sparsification input."""
    mask = np.asarray(kcore_edge_mask(m, k))
    src = np.asarray(m.src)[mask]
    dst = np.asarray(m.dst)[mask]
    nodes = np.nonzero(np.asarray(m.core) >= k)[0]
    return nodes, np.stack([src, dst], axis=1)


def core_sampling_weights(m: CoreMaintainer, alpha: float = 1.0) -> np.ndarray:
    """Neighbor-sampling priorities proportional to (core+1)^alpha — biases
    GraphSAGE-style fanout sampling toward structurally dense regions
    (the paper's motivating applications: dense-range identification)."""
    c = m.cores().astype(np.float64)
    w = (c + 1.0) ** alpha
    return (w / w.sum()).astype(np.float32)


def densest_region_vertices(m: CoreMaintainer, top_frac: float = 0.01
                            ) -> np.ndarray:
    """Vertices of the max-core shell (paper §1: rapid response targets)."""
    c = m.cores()
    kmax = int(c.max())
    out = np.nonzero(c == kmax)[0]
    want = max(1, int(top_frac * m.n))
    k = kmax
    while out.size < want and k > 0:
        k -= 1
        out = np.nonzero(c >= k)[0]
    return out
