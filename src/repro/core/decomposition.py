"""Data-parallel core decomposition in JAX.

Two algorithms:

* ``peel_decomposition`` — exact level-synchronous peeling (the ParK
  adaptation of BZ, Algorithm 1): every wave removes ALL vertices whose
  current degree is <= k simultaneously. Produces core numbers AND a valid
  k-order (wave-major, vertex-id minor — any intra-wave order satisfies the
  defining certificate ``dout(v) <= core(v)``, see docs/DESIGN.md §2).
* ``h_index_decomposition`` — the decrease-only local fixpoint
  (Lü et al. convergence theorem): starting from any upper bound, iterating
  ``core[v] -= (|{u in N(v): core[u] >= core[v]}| < core[v])`` converges to
  the exact core numbers. Used for bulk refresh and by the removal path.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from . import graph_ops as G

Array = jax.Array
_BIG = jnp.int32(2**30)
LABEL_GAP = jnp.int64(1) << 20


@partial(jax.jit, static_argnames=("n",))
def peel_decomposition(
    src: Array, dst: Array, valid: Array, n: int
) -> Tuple[Array, Array]:
    """Exact core numbers + peel rank for a COO-slot graph.

    Returns ``(core [n] int32, rank [n] int32)`` where ``rank`` is a valid
    k-order position (rank sorts by (core, within-level peel order)).
    """
    deg = G.degree(src, dst, valid, n)
    vid = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        _, alive, *_ = state
        return jnp.any(alive)

    def body(state):
        d, alive, core, rank, pos, k = state
        min_alive = jnp.min(jnp.where(alive, d, _BIG))
        has_frontier = jnp.any(alive & (d <= k))
        k = jnp.where(has_frontier, k, min_alive)
        frontier = alive & (d <= k)
        core = jnp.where(frontier, k, core)
        # intra-wave rank by vertex id (any intra-wave order is a valid
        # BZ-certificate order; see docs/DESIGN.md)
        within = jnp.cumsum(frontier.astype(jnp.int32), dtype=jnp.int32) - 1
        rank = jnp.where(frontier, pos + within, rank)
        pos = pos + jnp.sum(frontier, dtype=jnp.int32)
        alive2 = alive & ~frontier
        dec_src = valid & frontier[dst] & alive2[src]
        dec_dst = valid & frontier[src] & alive2[dst]
        d = (
            d
            - jax.ops.segment_sum(dec_src.astype(jnp.int32), src, num_segments=n)
            - jax.ops.segment_sum(dec_dst.astype(jnp.int32), dst, num_segments=n)
        )
        return (d, alive2, core, rank, pos, k)

    init = (
        deg,
        jnp.ones(n, dtype=bool),
        jnp.zeros(n, dtype=jnp.int32),
        jnp.zeros(n, dtype=jnp.int32),
        jnp.int32(0),
        jnp.int32(0),
    )
    _, _, core, rank, _, _ = jax.lax.while_loop(cond, body, init)
    del vid
    return core, rank


@partial(jax.jit, static_argnames=("n",))
def h_index_decomposition(src: Array, dst: Array, valid: Array, n: int) -> Array:
    """Exact core numbers via the decrease-only mcd fixpoint from the degree
    upper bound. Rounds are bounded by max(deg - core)."""
    deg = G.degree(src, dst, valid, n)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        core, _ = state
        mcd = G.count_ge(src, dst, valid, core, n)
        drop = (mcd < core) & (core > 0)
        return core - drop.astype(jnp.int32), jnp.any(drop)

    core, _ = jax.lax.while_loop(cond, body, (deg, jnp.bool_(True)))
    return core


def rank_to_labels(rank: Array) -> Array:
    """Initial OM labels from peel ranks: int64 with LABEL_GAP spacing."""
    return rank.astype(jnp.int64) * LABEL_GAP
