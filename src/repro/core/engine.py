"""Unified device-resident edit engine: one compiled program per mixed
insert+remove batch.

The seed implementation paid, per batch: a Python-dict dedup loop, an
``int(n_edges)`` sync, a separate jit program per edit kind, a fresh O(m)
statistics pass per phase, a ``bool(needs_renumber)`` sync, and O(capacity)
buffer copies. ``apply_batch`` moves all of it on-device:

  1. REMOVE  — vectorized slot lookup of the removal edges against the
               live ``(src, dst, valid)`` table (no host dict on the
               critical path), tombstoning, then the mcd removal fixpoint
               (remove.removal_fixpoint).
  2. DEDUP   — in-batch duplicate and self-loop masking plus a vectorized
               membership test against the *post-removal* table, so an
               edge removed and re-inserted in the same batch round-trips
               correctly.
  3. INSERT  — batch slot allocation from the in-program free-list
               (``insert.freelist_alloc``: the ``cumsum`` of kept inserts
               draws from dead slots in global slot order, recycling the
               step-1 tombstones without any host reclaim), table writes,
               and the promotion rounds (insert.promotion_fixpoint). The
               removal fixpoint's terminating round already computed (hi,
               dout_same) in its packed scatter; the new edges' O(batch)
               delta is scattered on top, so the promotion phase starts
               with exact statistics without another O(m) pass.
  4. RELABEL — the ``needs_renumber`` gate runs as a ``lax.cond`` inside
               the program (order.maybe_renumber): no dedicated
               device->host sync, and the flag is reported in the stats.

``src``/``dst``/``valid``/``core``/``label``/``n_edges`` are donated, so
each batch updates the edge table in place instead of copying O(capacity)
arrays (donation is a no-op on backends without buffer aliasing, e.g.
CPU; the harmless warning is silenced below).

The host keeps only a lazily-rebuilt edge->slot mirror for queries and an
upper bound on ``n_edges`` for capacity planning — neither touches the
per-batch critical path. See docs/DESIGN.md §3.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import graph_ops as G
from .insert import (
    freelist_alloc,
    promotion_fixpoint,
    promotion_fixpoint_halo,
    weighted_promotion_fixpoint,
    weighted_promotion_fixpoint_halo,
)
from .order import maybe_renumber, maybe_renumber_ring
from .remove import (
    removal_fixpoint,
    removal_fixpoint_halo,
    weighted_core_fixpoint_pass,
    weighted_core_fixpoint_pass_halo,
)
from .vertex_layout import (
    HaloShardedVertices,
    ReplicatedVertices,
    VertexLayout,
    _note,
)

Array = jax.Array

# Positional args of the batch programs holding the persistent state —
# src, dst, valid, core, label, n_edges — donated so each batch updates
# the table in place instead of copying O(capacity) buffers. One
# constant shared by the unified jit below and the sharded jit
# (core/sharded.py), and the ground truth the donation-verifier audit
# rule (repro.analysis) checks the lowered computations against.
DONATED_STATE_ARGS = (0, 1, 2, 3, 4, 5)

# weighted twin: the slot table carries a weight column at position 3
# (src, dst, valid, w, core, label, n_edges), all donated
WEIGHTED_DONATED_STATE_ARGS = (0, 1, 2, 3, 4, 5, 6)


class BatchStats(NamedTuple):
    """Per-batch statistics of the unified engine (all device scalars)."""

    n_inserted: Array      # edges actually added (post dedup/membership)
    n_removed: Array       # live slots tombstoned
    insert_rounds: Array   # promotion rounds executed
    n_promoted: Array      # |V*| of the insertion phase
    v_plus: Array          # |V+| — vertices reached by FORWARD
    remove_rounds: Array   # removal fixpoint rounds executed
    n_dropped: Array       # |V*| of the removal phase
    renumbered: Array      # True if the in-program label renumber fired
    n_recycled: Array      # inserts that reused a tombstoned slot
    high_water: Array      # post-batch max per-shard slot high-water mark
    max_frontier: Array    # max per-shard exchanged-mask count (both phases)
    n_overflow: Array      # sparse exchanges that fell back dense (halo) /
    #                        bitmask (0 outside the sparse regimes) — the
    #                        observed-cap planner's tuning datum (§4.3)


def edge_key(lo: Array, hi: Array, n: int) -> Array:
    """Canonical int64 key of a normalized (lo <= hi) undirected edge."""
    return lo.astype(jnp.int64) * jnp.int64(n) + hi.astype(jnp.int64)


def table_lookup(src: Array, dst: Array, valid: Array, n: int):
    """One sorted int64-key view of a slot table, shared by removal slot
    lookup and insert membership: O(C log C) to build, O(B log C) per
    query batch instead of the naive O(B * C) broadcast compare.

    Returns ``lookup(qkey) -> (found, slot)`` over the given table arrays
    (global slots for the unified engine; shard-local slots when called on
    a shard_map-local shard). Tombstones carry a sentinel key that sorts
    past every real key, so they can never be found.
    """
    capacity = src.shape[0]
    big = jnp.int64(1) << 62  # sentinel: tombstones sort past every real key
    tlo = jnp.minimum(src, dst)
    thi = jnp.maximum(src, dst)
    tkey = jnp.where(valid, edge_key(tlo, thi, n), big)
    torder = jnp.argsort(tkey)
    tsorted = tkey[torder]

    def lookup(qkey):
        pos = jnp.searchsorted(tsorted, qkey)
        pos = jnp.minimum(pos, capacity - 1)
        return tsorted[pos] == qkey, torder[pos]

    return lookup


def batch_dedup(ins_u: Array, ins_v: Array, ins_ok: Array, n: int):
    """Normalize orientation, drop self-loops and in-batch duplicates.

    O(B log B): sort the masked keys and keep one representative per run
    of equals — batch order is irrelevant since the whole batch commits
    simultaneously. Returns ``(ilo, ihi, iok, key)``; the key column is
    reused by the caller's membership test.
    """
    big = jnp.int64(1) << 62
    ilo = jnp.minimum(ins_u, ins_v)
    ihi = jnp.maximum(ins_u, ins_v)
    iok = ins_ok & (ilo != ihi)
    key = edge_key(ilo, ihi, n)
    ikey = jnp.where(iok, key, big)
    iperm = jnp.argsort(ikey)
    isorted = ikey[iperm]
    first = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), isorted[1:] != isorted[:-1]]
    )
    keep = jnp.zeros_like(iok).at[iperm].set(first)
    return ilo, ihi, iok & keep, key


def batch_program(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    n_edges: Array,
    ins_u: Array,
    ins_v: Array,
    ins_ok: Array,
    rm_u: Array,
    rm_v: Array,
    rm_ok: Array,
    n: int,
    n_levels: int,
    axis: str | None = None,
    layout: VertexLayout | None = None,
    freelist: str = "interleaved",
    kernel_backend: str = "lax",
    w: Array | None = None,
    ins_w: Array | None = None,
):
    """The ONE mixed-batch program body, shared verbatim by the unified
    engine (``axis=None``: the table arrays are the global slot table)
    and the sharded engines (``axis`` = mesh axis: the table arrays are
    this device's shard_map-local shard). Sharing the body is what
    guarantees the engines cannot drift.

    ``w`` (the slot table's weight column) and ``ins_w`` (per-lane
    insert weights) switch the program into WEIGHTED mode, statically:
    with ``w=None`` (the default) no weight array exists anywhere in the
    traced program, so the unweighted jaxpr — and with it the committed
    collective/memory/donation manifests — stays byte-identical to the
    pre-weighted engine. With ``w`` both fixpoint phases run the
    decrease-only weighted h-index fixpoint (removal from the current
    cores, promotion from ``core + total batch weight`` —
    remove.weighted_core_fixpoint_pass / docs/DESIGN.md §4.5), labels
    stay frozen through the fixpoints, and ONE forced bucket-free
    renumber per batch re-canonicalizes them whenever any core moved.
    The weighted return is the 8-tuple ``(src, dst, valid, w, core,
    label, n_edges, stats)``.

    The axis parameter changes exactly three things:

    * the free-list allocator ranks dead slots globally from one
      all_gather of the windowed dead masks (O(n_shards * window)
      replicated bytes; ``freelist="hierarchical"`` shrinks that to one
      scalar per shard at the cost of the interleaved shard-balance
      property — `insert.freelist_alloc`), so the batch cumsum still
      assigns globally unique slots and foreign writes drop
      out-of-bounds;
    * reductions over found-flags / removal masks are completed by a
      psum (an edge lives in exactly one shard, so the psum of the local
      verdicts IS the global verdict — no global sort is materialized);
    * every fixpoint statistic is completed by the vertex ``layout``
      (core/vertex_layout.py): psum for replicated vertex state — the
      default, ``layout=None`` builds ``ReplicatedVertices(n, axis)`` —
      or reduce_scatter to owned vertex ranges for
      ``HaloShardedVertices``, with only changed-vertex masks crossing
      the mesh per round: bit-packed (docs/DESIGN.md §4.2) or, when the
      layout carries a ``frontier_cap``, compacted to a fixed index
      bucket with an in-program bitmask fallback on overflow (§4.3).
      The program body never sees which representation moved — it only
      calls ``layout.gather_mask`` — which is why the sparse exchange
      concentrates entirely in the layout layer.

    ``core``/``label`` are full replicated [n] working values either
    way; a range-sharded caller gathers its owned slices before calling
    and re-slices the returned arrays (core/sharded.py).
    """
    capacity = src.shape[0]  # local (windowed) shard length under shard_map
    if layout is None:
        layout = ReplicatedVertices(n, axis)

    def allsum(x):
        return x if axis is None else jax.lax.psum(x, axis)

    # pre-batch LOCAL high-water mark: inserts landing below it reclaimed
    # a tombstone (the n_recycled statistic)
    hwm0 = G.slot_high_water(valid)

    # one sorted view of the (local) table serves BOTH the removal slot
    # lookup and the insert membership test
    lookup = table_lookup(src, dst, valid, n)

    # ---- 1. removals: vectorized slot lookup + tombstoning ---------------
    rlo = jnp.minimum(rm_u, rm_v)
    rhi = jnp.maximum(rm_u, rm_v)
    rm_ok = rm_ok & (rlo != rhi)
    rfound, rslot = lookup(edge_key(rlo, rhi, n))
    found = rfound & rm_ok
    # commutative scatter-max: not-found rows are no-ops; each device
    # tombstones only its own slots
    rm_mask = jnp.zeros(capacity, dtype=bool).at[rslot].max(found)
    valid = valid & ~rm_mask
    n_removed = allsum(jnp.sum(rm_mask, dtype=jnp.int32))

    core_pre_rm = core
    if w is not None:
        core, rm_rounds, rm_fmax = weighted_core_fixpoint_pass(
            src, dst, valid, w, core, n, layout=layout,
            kernel_backend=kernel_backend,
        )
        hi = dout_same = layout.zeros()
    else:
        core, label, rm_rounds, hi, dout_same, rm_fmax = removal_fixpoint(
            src, dst, valid, core, label, n, n_levels, layout=layout,
            kernel_backend=kernel_backend,
        )
    n_dropped = jnp.sum(core != core_pre_rm, dtype=jnp.int32)

    # ---- 2. insert dedup + membership against the post-removal table ----
    ilo, ihi, iok, key = batch_dedup(ins_u, ins_v, ins_ok, n)
    # membership against the POST-removal table: the sorted view predates
    # the tombstoning, so mask out slots removed in step 1 — this is what
    # lets an edge removed and re-inserted in the same batch round-trip
    ifound, islot_hit = lookup(key)
    exists = allsum((ifound & ~rm_mask[islot_hit]).astype(jnp.int32)) > 0
    iok = iok & ~exists

    # ---- 3. batch slot allocation from the free-list: dead slots (the
    # step-1 tombstones included) are ranked lowest-local-index-first,
    # interleaved across shards, and the batch cumsum assigns insert
    # rank r to the r-th free slot; each device writes the ranks landing
    # in its own shard and drops the rest (masked lanes included) via
    # out-of-bounds scatter semantics. The host guarantees enough free
    # slots in the active window (api.py), so the slot table recycles
    # tombstones without ever syncing.
    lpos, iok = freelist_alloc(valid, iok, axis=axis,
                               hierarchical=(freelist == "hierarchical"))
    src = src.at[lpos].set(ilo.astype(src.dtype), mode="drop")
    dst = dst.at[lpos].set(ihi.astype(dst.dtype), mode="drop")
    valid = valid.at[lpos].set(True, mode="drop")
    if w is not None:
        # the weight column rides the same allocation: dedup's stable
        # argsort keeps the FIRST occurrence of an in-batch duplicate,
        # so that lane's weight is the one written; re-inserting a live
        # edge was masked by the membership test above (old weight kept)
        w = w.at[lpos].set(ins_w.astype(w.dtype), mode="drop")
    n_inserted = jnp.sum(iok, dtype=jnp.int32)
    n_recycled = allsum(jnp.sum(lpos < hwm0, dtype=jnp.int32))
    # n_edges is the LIVE edge count (not a bump pointer): removals and
    # insertions both land in it, so it tracks the paper's workload size
    n_edges = n_edges - n_removed + n_inserted

    core_pre_ins = core
    if w is not None:
        # total inserted batch weight: iok is a replicated verdict under
        # sharding (freelist_alloc narrows it from all-gathered counts),
        # so the sum needs no collective
        total_w = jnp.sum(jnp.where(iok, ins_w, 0), dtype=jnp.int32)
        core, ins_rounds, ins_fmax = weighted_promotion_fixpoint(
            src, dst, valid, w, core, total_w, n, layout=layout,
            kernel_backend=kernel_backend,
        )
        v_plus = core != core_pre_ins
    else:
        # O(batch) delta keeps the shared (hi, dout_same) statistics
        # exact for the table with the new edges — same per-edge
        # predicate as the full passes (graph_ops.hi_dout_indicators);
        # the batch is replicated under sharding, so the delta needs no
        # collective (a range-sharded layout scatters each row into its
        # owner's slice and drops the rest OOB)
        hi_u, hi_v, do_u, do_v = G.hi_dout_indicators(
            core, label, ilo, ihi, iok
        )
        hi = layout.add_at(hi, ilo, hi_u.astype(jnp.int32))
        hi = layout.add_at(hi, ihi, hi_v.astype(jnp.int32))
        dout_same = layout.add_at(dout_same, ilo, do_u.astype(jnp.int32))
        dout_same = layout.add_at(dout_same, ihi, do_v.astype(jnp.int32))

        core, label, ins_rounds, v_plus, ins_fmax = promotion_fixpoint(
            src, dst, valid, core, label, ilo, ihi, iok,
            hi, dout_same, n, n_levels, layout=layout,
            kernel_backend=kernel_backend,
        )
    n_promoted = jnp.sum(core != core_pre_ins, dtype=jnp.int32)

    # ---- 4. in-program renumber gate (no host sync) ----------------------
    # weighted mode froze the labels through both fixpoints (no bucketed
    # place_block — weighted levels are unbounded in maxW), so it forces
    # ONE bucket-free relabel whenever any core moved; force=None keeps
    # the unweighted gate byte-identical
    force = ((n_dropped > 0) | (n_promoted > 0)) if w is not None else None
    label, renumbered = maybe_renumber(core, label, force=force)

    stats = BatchStats(
        n_inserted=n_inserted,
        n_removed=n_removed,
        insert_rounds=ins_rounds,
        n_promoted=n_promoted,
        v_plus=jnp.sum(v_plus, dtype=jnp.int32),
        remove_rounds=rm_rounds,
        n_dropped=n_dropped,
        renumbered=renumbered,
        n_recycled=n_recycled,
        # exact post-batch bound the host refreshes its sync-free window
        # planning from (max over shards of the LOCAL high-water mark)
        high_water=G.slot_high_water(valid, axis),
        # observed peak per-shard frontier across both fixpoints — the
        # datum the sparse frontier_cap planner is tuned from (§4.3)
        max_frontier=jnp.maximum(rm_fmax, ins_fmax),
        # the replicated/range paths have no per-round sparse halo
        # refresh; overflow rounds exist only in the halo program below
        n_overflow=jnp.int32(0),
    )
    if w is not None:
        return src, dst, valid, w, core, label, n_edges, stats
    return src, dst, valid, core, label, n_edges, stats


def _pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def halo_cap_for(window: int, lanes_total: int, n_pad: int) -> int:
    """Static halo capacity of one batch program: the pow2 bucket of the
    total endpoint-candidate count — 2 per windowed slot + 2 per batch
    lane (insert and removal) — clamped to ``n_pad``. Deduplication can
    only shrink the candidate set, so overflow is structurally
    impossible: every vertex the batch can reference fits. Derived
    entirely from shapes the jit cache is already keyed on (window and
    lane counts), so the halo adds no recompile surface."""
    return min(_pow2(2 * window + 2 * lanes_total), n_pad)


def build_halo_ids(layout: HaloShardedVertices, src: Array, dst: Array,
                   ins_u: Array, ins_v: Array, rm_u: Array, rm_v: Array,
                   n: int) -> Array:
    """This shard's halo membership: sorted unique global ids referenced
    by its windowed slot prefix or any batch lane, ``n_pad``-sentinel
    padded to the static ``halo_cap_for`` bucket. Tombstoned/garbage
    slot values are still valid vertex ids after the clip — they merely
    widen the halo, never corrupt it (every statistic is gated by the
    edge ``valid`` mask)."""
    cand = jnp.concatenate([src, dst, ins_u, ins_v, rm_u, rm_v]).astype(
        jnp.int32
    )
    cand = jnp.clip(cand, 0, n - 1)
    total = int(cand.shape[0])
    hcap = halo_cap_for(int(src.shape[0]),
                        int(ins_u.shape[0]) + int(rm_u.shape[0]),
                        layout.n_pad)
    sent = jnp.int32(layout.n_pad)
    s = jnp.sort(cand)
    uniq = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), s[1:] != s[:-1]]
    )
    ids = jnp.sort(jnp.where(uniq, s, sent))
    if total >= hcap:
        # hcap == n_pad here (the pow2 bucket was clamped); unique ids
        # number at most n <= n_pad, so truncation only drops sentinels
        return ids[:hcap]
    return jnp.concatenate(
        [ids, jnp.full((hcap - total,), sent, dtype=jnp.int32)]
    )


def batch_program_halo(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    n_edges: Array,
    ins_u: Array,
    ins_v: Array,
    ins_ok: Array,
    rm_u: Array,
    rm_v: Array,
    rm_ok: Array,
    n: int,
    n_levels: int,
    table_axis,
    layout: HaloShardedVertices,
    freelist: str = "interleaved",
    kernel_backend: str = "lax",
    w: Array | None = None,
    ins_w: Array | None = None,
):
    """``batch_program`` for halo-sharded vertex state — the same four
    phases over the same shard-local slot table, with ``core``/``label``
    as OWNED ``[n_owned]`` slices and every edge pass indexing a bounded
    HALO working set instead of a replicated [n] copy (the PR-7 entry
    gather, deleted). ``table_axis`` names ALL mesh axes the edge slots
    are sharded over (a tuple on a 2-axis mesh; its flattened device
    order at degenerate 1 x d / d x 1 shapes equals the 1-axis mesh, so
    slot allocation — hence the whole table history — is bit-identical
    to the shared-axis engines); the vertex ``layout``'s owner axis is
    one of them (1-axis) or a distinct axis (2-axis, ``edge_axes``
    nonempty). Table-membership verdicts complete over ``table_axis``
    (an edge lives in exactly one shard of the full product); vertex
    scalars complete over the owner axis only (owned slices are
    replicated along pure-edge axes). Bit-identical cores, labels, and
    stats to ``batch_program``.
    """
    capacity = src.shape[0]

    def allsum(x):  # table domain: every mesh axis
        return jax.lax.psum(x, table_axis)

    def vsum(x):    # owned-vertex domain: owner axis only
        return jax.lax.psum(x, layout.axis)

    hwm0 = G.slot_high_water(valid)
    lookup = table_lookup(src, dst, valid, n)

    # ---- 1. removals: vectorized slot lookup + tombstoning ---------------
    rlo = jnp.minimum(rm_u, rm_v)
    rhi = jnp.maximum(rm_u, rm_v)
    rm_ok = rm_ok & (rlo != rhi)
    rfound, rslot = lookup(edge_key(rlo, rhi, n))
    found = rfound & rm_ok
    rm_mask = jnp.zeros(capacity, dtype=bool).at[rslot].max(found)
    valid = valid & ~rm_mask
    n_removed = allsum(jnp.sum(rm_mask, dtype=jnp.int32))

    # ---- halo working set: ONE membership gather + ONE bounded value
    # regather per batch replace the deleted O(n) entry state gather
    halo_ids = build_halo_ids(layout, src, dst, ins_u, ins_v, rm_u, rm_v, n)
    session = layout.bind(halo_ids)
    core_h = session.gather_values(core)
    # weighted mode freezes labels through both fixpoints — no edge pass
    # ever reads a halo label, so the label regather is skipped entirely
    label_h = None if w is not None else session.gather_values(label)
    src_h = session.locate(src)
    dst_h = session.locate(dst)

    core_pre_rm = core
    if w is not None:
        core, core_h, rm_rounds, rm_fmax = weighted_core_fixpoint_pass_halo(
            src_h, dst_h, valid, w, core, core_h, session,
            kernel_backend=kernel_backend,
        )
        hi = dout_same = session.zeros()
        rm_ovf = jnp.int32(0)
    else:
        (core, label, core_h, label_h, rm_rounds, hi, dout_same, rm_fmax,
         rm_ovf) = removal_fixpoint_halo(
            src_h, dst_h, valid, core, label, core_h, label_h, session,
            n_levels, kernel_backend=kernel_backend,
        )
    n_dropped = vsum(jnp.sum(core != core_pre_rm, dtype=jnp.int32))

    # ---- 2. insert dedup + membership against the post-removal table ----
    ilo, ihi, iok, key = batch_dedup(ins_u, ins_v, ins_ok, n)
    ifound, islot_hit = lookup(key)
    exists = allsum((ifound & ~rm_mask[islot_hit]).astype(jnp.int32)) > 0
    iok = iok & ~exists

    # ---- 3. slot allocation + table writes (identical to batch_program;
    # the free-list ranks dead slots over the WHOLE mesh product) -------
    lpos, iok = freelist_alloc(valid, iok, axis=table_axis,
                               hierarchical=(freelist == "hierarchical"))
    src = src.at[lpos].set(ilo.astype(src.dtype), mode="drop")
    dst = dst.at[lpos].set(ihi.astype(dst.dtype), mode="drop")
    valid = valid.at[lpos].set(True, mode="drop")
    if w is not None:
        w = w.at[lpos].set(ins_w.astype(w.dtype), mode="drop")
    n_inserted = jnp.sum(iok, dtype=jnp.int32)
    n_recycled = allsum(jnp.sum(lpos < hwm0, dtype=jnp.int32))
    n_edges = n_edges - n_removed + n_inserted

    # the newly written slots reference only lane endpoints — already in
    # the halo by construction — so relocating the window is pure local
    # compute, no new gather
    src_h = session.locate(src)
    dst_h = session.locate(dst)

    core_pre_ins = core
    if w is not None:
        total_w = jnp.sum(jnp.where(iok, ins_w, 0), dtype=jnp.int32)
        (core, core_h, ins_rounds,
         ins_fmax) = weighted_promotion_fixpoint_halo(
            src_h, dst_h, valid, w, core, core_h, total_w, session,
            kernel_backend=kernel_backend,
        )
        v_plus = core != core_pre_ins
        ins_ovf = jnp.int32(0)
    else:
        u_pos = session.locate(ilo)
        v_pos = session.locate(ihi)

        # O(batch) delta on the shared (hi, dout_same): the per-edge
        # predicate reads lane endpoint values from the halo (replicated
        # verdicts), the scatter lands in each owner's slice and drops OOB
        hi_u, hi_v, do_u, do_v = G.hi_dout_indicators(
            core_h, label_h, u_pos, v_pos, iok
        )
        hi = layout.add_at(hi, ilo, hi_u.astype(jnp.int32))
        hi = layout.add_at(hi, ihi, hi_v.astype(jnp.int32))
        dout_same = layout.add_at(dout_same, ilo, do_u.astype(jnp.int32))
        dout_same = layout.add_at(dout_same, ihi, do_v.astype(jnp.int32))

        (core, label, core_h, label_h, ins_rounds, v_plus, ins_fmax,
         ins_ovf) = promotion_fixpoint_halo(
            src_h, dst_h, valid, core, label, core_h, label_h,
            ilo, ihi, u_pos, v_pos, iok, hi, dout_same, session, n_levels,
            kernel_backend=kernel_backend,
        )
    n_promoted = vsum(jnp.sum(core != core_pre_ins, dtype=jnp.int32))

    # ---- 4. in-program renumber gate (ring relabel over owner axis) ------
    force = ((n_dropped > 0) | (n_promoted > 0)) if w is not None else None
    label, renumbered = maybe_renumber_ring(
        core, label, layout.axis, layout.n_shards, note=_note, force=force
    )

    stats = BatchStats(
        n_inserted=n_inserted,
        n_removed=n_removed,
        insert_rounds=ins_rounds,
        n_promoted=n_promoted,
        v_plus=vsum(jnp.sum(v_plus, dtype=jnp.int32)),
        remove_rounds=rm_rounds,
        n_dropped=n_dropped,
        renumbered=renumbered,
        n_recycled=n_recycled,
        high_water=G.slot_high_water(valid, table_axis),
        # per-round peaks were tracked locally; ONE pmax completes them
        max_frontier=session.pmax_scalar(
            jnp.maximum(rm_fmax, ins_fmax)
        ),
        # overflow verdicts are replicated (gathered count columns), so
        # the local sum IS the global round count
        n_overflow=rm_ovf + ins_ovf,
    )
    if w is not None:
        return src, dst, valid, w, core, label, n_edges, stats
    return src, dst, valid, core, label, n_edges, stats


@partial(
    jax.jit,
    static_argnames=("n", "n_levels", "active_cap", "kernel_backend"),
    donate_argnums=DONATED_STATE_ARGS,
)
def apply_batch(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    n_edges: Array,
    ins_u: Array,
    ins_v: Array,
    ins_ok: Array,
    rm_u: Array,
    rm_v: Array,
    rm_ok: Array,
    n: int,
    n_levels: int,
    active_cap: int,
    kernel_backend: str = "lax",
) -> Tuple[Array, Array, Array, Array, Array, Array, BatchStats]:
    """Apply one mixed batch (removals first, then insertions) and restore
    core numbers + k-order labels.

    ``ins_*``/``rm_*`` are padded edge lists masked by their ``_ok``
    flags; orientation is normalized on device. ``active_cap`` is the
    host's (sync-free) power-of-two bound on the slot high-water mark
    incl. this batch: every edge pass in the program body runs over
    ``active_cap`` slots instead of the full over-provisioned capacity,
    so per-batch device work scales with the live graph, not with
    headroom. Because the free-list allocator fills the lowest holes
    first, the window also guarantees the allocator enough dead slots
    (window >= high_water + batch implies free >= batch) and the tail
    past it stays all-invalid. Returns ``(src, dst, valid, core, label,
    n_edges, stats)``.
    """
    full_src, full_dst, full_valid = src, dst, valid
    src, dst, valid, core, label, n_edges, stats = batch_program(
        src[:active_cap], dst[:active_cap], valid[:active_cap],
        core, label, n_edges,
        ins_u, ins_v, ins_ok, rm_u, rm_v, rm_ok,
        n, n_levels, kernel_backend=kernel_backend,
    )
    # splice the active region back into the full-capacity buffers (the
    # inactive tail is untouched: all-invalid headroom)
    src = jnp.concatenate([src, full_src[active_cap:]])
    dst = jnp.concatenate([dst, full_dst[active_cap:]])
    valid = jnp.concatenate([valid, full_valid[active_cap:]])
    return src, dst, valid, core, label, n_edges, stats


@partial(
    jax.jit,
    static_argnames=("n", "n_levels", "active_cap", "kernel_backend"),
    donate_argnums=WEIGHTED_DONATED_STATE_ARGS,
)
def apply_batch_weighted(
    src: Array,
    dst: Array,
    valid: Array,
    w: Array,
    core: Array,
    label: Array,
    n_edges: Array,
    ins_u: Array,
    ins_v: Array,
    ins_w: Array,
    ins_ok: Array,
    rm_u: Array,
    rm_v: Array,
    rm_ok: Array,
    n: int,
    n_levels: int,
    active_cap: int,
    kernel_backend: str = "lax",
):
    """``apply_batch`` with the slot table's weight column: the same
    active-window slice/splice with ``w`` riding alongside the other
    three columns, and the batch's per-lane insert weights threaded to
    the weighted program body. Returns ``(src, dst, valid, w, core,
    label, n_edges, stats)``."""
    full_src, full_dst, full_valid, full_w = src, dst, valid, w
    src, dst, valid, w, core, label, n_edges, stats = batch_program(
        src[:active_cap], dst[:active_cap], valid[:active_cap],
        core, label, n_edges,
        ins_u, ins_v, ins_ok, rm_u, rm_v, rm_ok,
        n, n_levels, kernel_backend=kernel_backend,
        w=w[:active_cap], ins_w=ins_w,
    )
    src = jnp.concatenate([src, full_src[active_cap:]])
    dst = jnp.concatenate([dst, full_dst[active_cap:]])
    valid = jnp.concatenate([valid, full_valid[active_cap:]])
    w = jnp.concatenate([w, full_w[active_cap:]])
    return src, dst, valid, w, core, label, n_edges, stats
