"""Vectorized per-vertex neighborhood statistics over COO edge slots.

These are the message-passing primitives every maintenance round is built
from. ``segment_sum`` tolerates unsorted segment ids, so the dynamic COO
slot layout needs no sorting between edit batches.

Each undirected edge is stored once; each statistic issues two LOCAL
scatter-adds (one per direction) that GSPMD combines into one all-reduce.
Round-level stats are packed into multi-column scatters where profitable
(§Perf iteration C1; a concatenated single-scatter variant measured WORSE
— the concat of two edge-sharded streams forces an all-gather reshard).

Every statistic takes an optional ``layout`` (core/vertex_layout.py):
inside a ``shard_map`` over edge slots the local segment sums are
COMPLETED by the layout — one ``psum`` over the mesh axis for
``ReplicatedVertices`` (exact global statistic on every device), one
``reduce_scatter`` for ``HaloShardedVertices`` (each device receives
only the vertex range it owns; on a 2-axis mesh the owned partials
additionally psum over the pure-edge axes first). With ``layout=None``
(single-device /
GSPMD) completion is the identity and the functions are unchanged. This
is how the sharded engines reuse the exact fixpoint code of remove.py /
insert.py regardless of where the vertex state lives.

This module is also the KERNEL DISPATCH POINT: the round statistics
accept ``backend="lax" | "pallas"``. The lax path (default) is the
bit-exact reference above; the pallas path replaces the per-stat
gather + two-segment-sum launch train with one fused ``pallas_call``
(``kernels/coremaint.py``) producing the SAME local partial sums, then
completes them with the layout exactly as before — so switching the
backend changes kernel launches, never collectives, and the results
stay bit-identical (integer adds in a different order).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import coremaint
from .vertex_layout import ReplicatedVertices, VertexLayout

Array = jax.Array

KERNEL_BACKENDS = ("lax", "pallas")


def completes_locally(layout: Optional[VertexLayout]) -> bool:
    """True when ``layout.complete`` is the identity (single device /
    GSPMD): partial statistics ARE the global statistics, so the fused
    pallas kernels may commit per-vertex threshold decisions in the same
    launch that produced the stat. Under a mesh axis the decision must
    wait for the layout's collective."""
    return layout is None or (
        isinstance(layout, ReplicatedVertices) and layout.axis is None
    )


def _complete(x: Array, layout: Optional[VertexLayout]) -> Array:
    return x if layout is None else layout.complete(x)


def _pmax(x: Array, axis: Optional[str]) -> Array:
    return x if axis is None else jax.lax.pmax(x, axis)


def slot_high_water(valid: Array, axis: Optional[str] = None) -> Array:
    """High-water mark of a slot table: 1 + the largest valid slot index
    (0 when empty). With ``axis`` (shard_map-local shard) the result is
    the max over shards of each shard's LOCAL high-water mark — the
    "densest shard" bound that sizes the per-shard active window of the
    sharded engine (docs/DESIGN.md §4.1)."""
    idx = jnp.arange(valid.shape[0], dtype=jnp.int32)
    local = jnp.max(jnp.where(valid, idx + 1, 0))
    return _pmax(local, axis)


def _seg2(data_to_src: Array, data_to_dst: Array, src: Array, dst: Array,
          n: int, layout: Optional[VertexLayout] = None) -> Array:
    """Two-direction segment sum. Two LOCAL scatter-adds + elementwise add:
    GSPMD then emits a single all-reduce for the combined [n] result.
    (A concatenated single-scatter variant was measured WORSE — the concat
    of two edge-sharded streams forces an all-gather reshard; §Perf C1.)
    Under shard_map the partial result is completed by the vertex layout
    (psum for replicated state, reduce_scatter for range-sharded)."""
    a = jax.ops.segment_sum(data_to_src, src, num_segments=n)
    b = jax.ops.segment_sum(data_to_dst, dst, num_segments=n)
    return _complete(a + b, layout)


def degree(src: Array, dst: Array, valid: Array, n: int,
           layout: Optional[VertexLayout] = None) -> Array:
    one = valid.astype(jnp.int32)
    return _seg2(one, one, src, dst, n, layout)


def count_ge(src: Array, dst: Array, valid: Array, vals: Array, n: int,
             layout: Optional[VertexLayout] = None,
             backend: str = "lax") -> Array:
    """mcd (Def 3.8): per-vertex count of neighbors w with vals[w] >= vals[v]."""
    if backend == "pallas":
        # the "mcd" stat compares core only; the kernel's label input is
        # unused by its predicates but fixed int64 — synthesize one
        out = coremaint.coo_stat(
            src, dst, valid, vals,
            jnp.zeros(vals.shape[0], jnp.int64), n, stat="mcd",
        )
        return _complete(out, layout)[:, 0]
    to_src = (valid & (vals[dst] >= vals[src])).astype(jnp.int32)
    to_dst = (valid & (vals[src] >= vals[dst])).astype(jnp.int32)
    return _seg2(to_src, to_dst, src, dst, n, layout)


def count_gt(src: Array, dst: Array, valid: Array, vals: Array, n: int,
             layout: Optional[VertexLayout] = None) -> Array:
    """Per-vertex count of neighbors w with vals[w] > vals[v]."""
    to_src = (valid & (vals[dst] > vals[src])).astype(jnp.int32)
    to_dst = (valid & (vals[src] > vals[dst])).astype(jnp.int32)
    return _seg2(to_src, to_dst, src, dst, n, layout)


def hi_dout_indicators(
    core: Array, label: Array, u: Array, v: Array, ok: Array
):
    """Per-edge indicator columns of the promotion statistics: for each
    (u, v) edge masked by ``ok``, whether it contributes to hi(u), hi(v),
    dout_same(u), dout_same(v). The single definition shared by the full
    passes below and by the unified engine's O(batch) delta update —
    keeping the statistic's tie-breaking in one place."""
    same = ok & (core[u] == core[v])
    hi_to_u = ok & (core[v] > core[u])
    hi_to_v = ok & (core[u] > core[v])
    dout_to_u = same & (label[v] > label[u])
    dout_to_v = same & (label[u] > label[v])
    return hi_to_u, hi_to_v, dout_to_u, dout_to_v


def hi_and_dout_same(
    src: Array, dst: Array, valid: Array, core: Array, label: Array, n: int,
    layout: Optional[VertexLayout] = None, backend: str = "lax",
):
    """Packed (hi, dout_same) for the insertion round: one [n, 2] result
    (single collective) carries both the higher-core neighbor count and
    the same-level k-order successor count (Defs 3.6/3.7 pieces)."""
    if backend == "pallas":
        out = _complete(
            coremaint.coo_stat(src, dst, valid, core, label, n,
                               stat="hi_dout"),
            layout,
        )
        return out[:, 0], out[:, 1]
    hi_s, hi_d, do_s, do_d = hi_dout_indicators(core, label, src, dst, valid)
    to_src = jnp.stack(
        [hi_s.astype(jnp.int32), do_s.astype(jnp.int32)], axis=-1
    )
    to_dst = jnp.stack(
        [hi_d.astype(jnp.int32), do_d.astype(jnp.int32)], axis=-1
    )
    out = _complete(
        jax.ops.segment_sum(to_src, src, num_segments=n)
        + jax.ops.segment_sum(to_dst, dst, num_segments=n),
        layout,
    )
    return out[:, 0], out[:, 1]


def mcd_hi_dout(
    src: Array, dst: Array, valid: Array, core: Array, label: Array, n: int,
    layout: Optional[VertexLayout] = None, backend: str = "lax",
):
    """Packed (mcd, hi, dout_same) — one [n, 3] scatter carries the removal
    fixpoint's support count (Def 3.8) together with both promotion-seeding
    statistics (Defs 3.6/3.7 pieces). The unified engine runs this once per
    removal round; the terminating round's (hi, dout_same) columns are then
    reused to seed the promotion phase without a fresh O(m) pass."""
    if backend == "pallas":
        out = _complete(
            coremaint.coo_stat(src, dst, valid, core, label, n,
                               stat="mcd_hi_dout"),
            layout,
        )
        return out[:, 0], out[:, 1], out[:, 2]
    hi_s, hi_d, do_s, do_d = hi_dout_indicators(core, label, src, dst, valid)
    to_src = jnp.stack(
        [
            (valid & (core[dst] >= core[src])).astype(jnp.int32),
            hi_s.astype(jnp.int32),
            do_s.astype(jnp.int32),
        ],
        axis=-1,
    )
    to_dst = jnp.stack(
        [
            (valid & (core[src] >= core[dst])).astype(jnp.int32),
            hi_d.astype(jnp.int32),
            do_d.astype(jnp.int32),
        ],
        axis=-1,
    )
    out = _complete(
        jax.ops.segment_sum(to_src, src, num_segments=n)
        + jax.ops.segment_sum(to_dst, dst, num_segments=n),
        layout,
    )
    return out[:, 0], out[:, 1], out[:, 2]


def count_same_level_after(
    src: Array, dst: Array, valid: Array, core: Array, label: Array, n: int,
    layout: Optional[VertexLayout] = None,
) -> Array:
    """dout within level (part of Def 3.7): neighbors with equal core and a
    larger order label (successors in the k-order DAG at the same level)."""
    same = valid & (core[src] == core[dst])
    to_src = (same & (label[dst] > label[src])).astype(jnp.int32)
    to_dst = (same & (label[src] > label[dst])).astype(jnp.int32)
    return _seg2(to_src, to_dst, src, dst, n, layout)


def count_same_level_before_in(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    mask: Array,
    n: int,
    layout: Optional[VertexLayout] = None,
) -> Array:
    """din* (Def 3.6): same-level order-predecessors that are in ``mask``."""
    same = valid & (core[src] == core[dst])
    to_src = (same & (label[dst] < label[src]) & mask[dst]).astype(jnp.int32)
    to_dst = (same & (label[src] < label[dst]) & mask[src]).astype(jnp.int32)
    return _seg2(to_src, to_dst, src, dst, n, layout)


def count_same_level_in(
    src: Array, dst: Array, valid: Array, core: Array, mask: Array, n: int,
    layout: Optional[VertexLayout] = None, backend: str = "lax",
) -> Array:
    """Per-vertex count of same-level neighbors inside ``mask``."""
    if backend == "pallas":
        out = coremaint.coo_stat(
            src, dst, valid, core, jnp.zeros(core.shape[0], jnp.int64), n,
            stat="same_in", aux=mask,
        )
        return _complete(out, layout)[:, 0]
    same = valid & (core[src] == core[dst])
    to_src = (same & mask[dst]).astype(jnp.int32)
    to_dst = (same & mask[src]).astype(jnp.int32)
    return _seg2(to_src, to_dst, src, dst, n, layout)


def din_and_expand(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    rp: Array,
    n: int,
    layout: Optional[VertexLayout] = None,
    backend: str = "lax",
):
    """Fused FORWARD-wave statistics in ONE scatter-add: din counts
    reached-and-passing k-order predecessors, and frontier growth is
    exactly ``din > 0`` (a vertex is newly reachable iff it has an RP
    predecessor) — iteration C1."""
    if backend == "pallas":
        out = coremaint.coo_stat(
            src, dst, valid, core, label, n, stat="din", aux=rp,
        )
        din = _complete(out, layout)[:, 0]
        return din, din > 0
    same = valid & (core[src] == core[dst])
    fwd_to_dst = same & (label[src] < label[dst]) & rp[src]
    fwd_to_src = same & (label[dst] < label[src]) & rp[dst]
    din = _seg2(
        fwd_to_src.astype(jnp.int32), fwd_to_dst.astype(jnp.int32),
        src, dst, n, layout,
    )
    return din, din > 0


def weighted_support(
    src: Array, dst: Array, valid: Array, w: Array, core: Array,
    thresh: Array, n: int, layout: Optional[VertexLayout] = None,
    backend: str = "lax",
) -> Array:
    """Weighted generalization of ``count_ge``: per-vertex SUM of incident
    edge weights to neighbors u with ``core[u] >= thresh[v]`` (the inner
    statistic of the weighted h-index bisection; with unit weights and
    ``thresh == core`` this IS mcd). The weighted column rides the exact
    same two-scatter + layout-completion schedule as the unit stats, so
    the sharded collective budget is unchanged per pass."""
    if backend == "pallas":
        out = coremaint.coo_stat(
            src, dst, valid, core,
            jnp.zeros(core.shape[0], jnp.int64), n, stat="wsum",
            aux=thresh, edge_w=w,
        )
        return _complete(out, layout)[:, 0]
    wi = w.astype(jnp.int32)
    to_src = jnp.where(valid & (core[dst] >= thresh[src]), wi, 0)
    to_dst = jnp.where(valid & (core[src] >= thresh[dst]), wi, 0)
    return _seg2(to_src, to_dst, src, dst, n, layout)


def weighted_h_index(
    src: Array, dst: Array, valid: Array, w: Array, core: Array,
    upper: Array, n: int, layout: Optional[VertexLayout] = None,
    backend: str = "lax",
) -> Array:
    """Per-vertex weighted h-index by lockstep bisection:
    ``H_w(v) = max{h <= upper[v] : sum of weights to nbrs with
    core >= h is >= h}`` (Zhou et al., WWW'21). The feasible set is a
    prefix (the support sum is non-increasing in h), so bisection over
    ``[0, upper]`` needs O(log maxW) masked rounds, each ONE weighted
    support pass over the edge window. The invariant is lo-feasible
    (``lo = 0`` trivially so); converged lanes re-test ``mid == lo``
    and stay fixed, so the while_loop runs until the SLOWEST lane
    converges with every lane stable. Replicated/plain layouts only —
    the halo twin lives in core/remove.py next to its fixpoint."""
    upper = jnp.maximum(upper.astype(jnp.int32), 0)
    lo = jnp.zeros_like(upper)

    def cond(state):
        lo_, hi_ = state
        return jnp.any(lo_ < hi_)

    def body(state):
        lo_, hi_ = state
        mid = (lo_ + hi_ + 1) // 2
        s = weighted_support(src, dst, valid, w, core, mid, n,
                             layout, backend)
        ok = s >= mid
        return jnp.where(ok, mid, lo_), jnp.where(ok, hi_, mid - 1)

    lo, _ = jax.lax.while_loop(cond, body, (lo, upper))
    return lo


def expand_forward(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    frontier: Array,
    n: int,
    layout: Optional[VertexLayout] = None,
) -> Array:
    """One wave of the Forward phase: reach same-level k-order successors of
    ``frontier`` vertices (boolean [n])."""
    same = valid & (core[src] == core[dst])
    hit_dst = same & frontier[src] & (label[src] < label[dst])
    hit_src = same & frontier[dst] & (label[dst] < label[src])
    out = _seg2(
        hit_src.astype(jnp.int32), hit_dst.astype(jnp.int32), src, dst, n,
        layout,
    )
    return out > 0
