"""Batch-parallel edge insertion maintenance (paper Algorithm 5, TPU form).

Round structure (all levels of all inserted edges processed together — the
bulk-synchronous analogue of one-lock-per-vertex worker concurrency):

  1. SEED      — k-order roots of the pending edges (order-min endpoints),
                 plus last round's promoted vertices (cross-round cascades),
                 plus any vertex violating the certificate dout > core
                 (self-healing seeds; see docs/DESIGN.md §2).
  2. FORWARD   — masked wave expansion along same-level k-order-increasing
                 edges, gated by the optimistic candidate test
                 ``hi + dout_same + din_reached > core`` (paper's Forward;
                 the gating is provably reach-complete: every true candidate
                 has a forward path from a seed through passing vertices).
  3. EVICT     — exact candidate fixpoint on the reached set (paper's
                 Backward collapsed into iterative pruning): evict v while
                 ``hi(v) + |same-level candidate nbrs| <= core(v)``.
  4. COMMIT    — survivors' core += 1; moved to the head of O_{K+1} in old
                 label order (required to preserve the k-order certificate).

Rounds repeat until no promotion happens (a batch can raise a core by more
than one; each round applies the paper's +1-per-edge theorem to the whole
batch).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import graph_ops as G
from ..kernels import coremaint
from .order import place_block, place_block_ring
from .remove import (
    weighted_core_fixpoint_pass,
    weighted_core_fixpoint_pass_halo,
)
from .vertex_layout import (
    HaloSession,
    ReplicatedVertices,
    VertexLayout,
    _note,
)

Array = jax.Array


class InsertStats(NamedTuple):
    rounds: Array        # outer promotion rounds
    n_promoted: Array    # |V*| over the whole batch
    v_plus: Array        # |V+| — vertices ever reached by FORWARD
    max_frontier: Array  # max per-shard exchanged-mask count over all rounds


def freelist_alloc(
    valid: Array,
    iok: Array,
    axis: str | None = None,
    hierarchical: bool = False,
) -> Tuple[Array, Array]:
    """Recycling slot allocator: every dead slot IS the free-list.

    Dead slots (``~valid``) are ranked in (local slot, shard) order and
    the batch's kept inserts (``iok``, rank by cumsum) are assigned
    one-to-one to the lowest-ranked free slots. Filling the lowest local
    indices first — interleaved ACROSS shards, not shard-by-shard — does
    two jobs at once: the per-shard slot high-water mark only grows when
    every shard is hole-free below it (so steady-state churn recycles
    tombstones entirely in-program and host-side ``_compact`` becomes a
    rare defrag), and fresh-ground allocation round-robins the shards,
    keeping the densest shard's high-water mark — the quantity that
    sizes the per-shard active window — near ``live / n_shards``.
    Ranking by (shard, slot) instead would funnel every insert into the
    lowest shard's tail before touching the next shard's holes,
    ratcheting that shard up to full local capacity (docs/DESIGN.md
    §4.1). On one shard both orders degenerate to ascending slot id, so
    the unified and 1-device sharded engines still pick identical slots.

    With ``axis`` (shard_map) each device ranks its own dead slots from
    one ``all_gather`` of the [window]-sized dead masks, writes the
    batch ranks that land in its shard, and drops the rest via the
    sentinel position — the same OOB-drop trick as the stat scatters.

    ``hierarchical`` replaces that O(n_shards * window) mask exchange
    with an all_gather of ONE scalar per shard (the per-shard free
    count): each device already knows its local dead ranks, and the
    exclusive prefix sum of the gathered counts offsets them into a
    global ranking. The ranking becomes (shard, local slot) —
    shard-by-shard instead of interleaved — so it gives up the
    §4.1 shard-balance property (fresh ground fills the lowest shard's
    window before touching the next) in exchange for O(n_shards) bytes
    per batch; the LIVE EDGE SET and the maintained core/label state are
    unaffected (core numbers never depend on slot positions), which the
    churn harness pins by running both rankings against each other. On
    one shard both paths are ascending slot id, i.e. identical.

    Returns ``(lpos, iok)``: ``lpos[b]`` is this shard's local slot for
    insert lane ``b`` (``== capacity`` when the lane is masked or owned
    by another shard — out-of-bounds, so ``.at[lpos].set(mode="drop")``
    skips it), and ``iok`` narrowed by the free-exhaustion guard (an
    insert with no free slot anywhere is dropped rather than miscounted;
    the host's capacity planning makes that unreachable).
    """
    capacity = valid.shape[0]
    b = iok.shape[0]
    dead = ~valid
    if axis is None:
        total_free = jnp.sum(dead, dtype=jnp.int32)
        drank = jnp.cumsum(dead.astype(jnp.int32), dtype=jnp.int32) - 1
    elif hierarchical:
        my_free = jnp.sum(dead, dtype=jnp.int32)
        counts = jax.lax.all_gather(my_free, axis)  # [n_shards] scalars
        me = jax.lax.axis_index(axis)
        total_free = jnp.sum(counts, dtype=jnp.int32)
        # my dead slot with local free-rank r has global rank
        # (free slots on shards before me) + r: (shard, slot) order
        base = (jnp.cumsum(counts, dtype=jnp.int32) - counts)[me]
        drank = base + jnp.cumsum(dead.astype(jnp.int32),
                                  dtype=jnp.int32) - 1
    else:
        all_dead = jax.lax.all_gather(dead, axis)  # [n_shards, capacity]
        me = jax.lax.axis_index(axis)
        col = jnp.sum(all_dead, axis=0, dtype=jnp.int32)  # dead per index
        total_free = jnp.sum(col, dtype=jnp.int32)
        # free rank of MY dead slot i = all dead slots at indices < i
        # (any shard) + dead slots at index i on shards before me
        col_before = jnp.cumsum(col, dtype=jnp.int32) - col
        row_before = (
            jnp.cumsum(all_dead.astype(jnp.int32), axis=0) - all_dead
        )[me]
        drank = col_before + row_before
    rank = jnp.cumsum(iok.astype(jnp.int32), dtype=jnp.int32) - 1
    iok = iok & (rank < total_free)
    # ranks past the batch can never be targets (rank < b always), so
    # their dead slots park on the scatter sentinel
    spos = jnp.where(dead & (drank < b), drank, b)
    slot_of_rank = jnp.full((b,), capacity, dtype=jnp.int32).at[spos].set(
        jnp.arange(capacity, dtype=jnp.int32), mode="drop"
    )
    lpos = jnp.where(iok, slot_of_rank[jnp.maximum(rank, 0)], capacity)
    return lpos, iok


def write_edge_slots(
    src: Array,
    dst: Array,
    valid: Array,
    n_edges: Array,
    new_src: Array,
    new_dst: Array,
    new_ok: Array,
) -> Tuple[Array, Array, Array, Array]:
    """Bump slot allocation via ``cumsum`` + masked table writes — the
    seed path behind ``engine="host"``, where ``n_edges`` is the bump
    pointer (slot high-water mark) and tombstones are reclaimed only by
    host-side ``_compact``. The device engines allocate with
    ``freelist_alloc`` instead.

    Padding lanes are parked on the LAST slot (they rewrite its current
    values, a no-op); callers must guarantee that slot is never a real
    allocation target (n_edges + batch + 1 <= table size).
    Returns the updated ``(src, dst, valid, n_edges)``.
    """
    slot = n_edges + jnp.cumsum(new_ok.astype(jnp.int32), dtype=jnp.int32) - 1
    slot = jnp.where(new_ok, slot, src.shape[0] - 1)
    src = src.at[slot].set(jnp.where(new_ok, new_src, src[slot]))
    dst = dst.at[slot].set(jnp.where(new_ok, new_dst, dst[slot]))
    valid = valid.at[slot].set(jnp.where(new_ok, True, valid[slot]))
    return src, dst, valid, n_edges + jnp.sum(new_ok, dtype=jnp.int32)


def promotion_fixpoint(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    new_src: Array,
    new_dst: Array,
    new_ok: Array,
    hi: Array,
    dout_same: Array,
    n: int,
    n_levels: int,
    layout: VertexLayout | None = None,
    kernel_backend: str = "lax",
) -> Tuple[Array, Array, Array, Array, Array]:
    """Promotion rounds for pending edges already written into the table.

    ``hi``/``dout_same`` must describe the CURRENT (core, label, valid)
    state including the pending edges; each round recomputes them after its
    commit, so the caller-provided pair is consumed exactly once. This is
    how the unified engine shares one statistics pass between the removal
    fixpoint and the first promotion round. Under a range-sharded layout
    the pair is OWNED-sized (the caller completed it with the layout).

    With a ``layout`` the table arrays are shard_map-local edge shards and
    all neighborhood statistics are completed by it (psum for replicated
    vertex state, reduce_scatter to owned vertex ranges for
    range-sharded); candidacy/eviction decisions then run on the owned
    slices and come back as all_gathered masks — bit-packed, or sparse
    compacted indices with a per-round overflow fallback when the layout
    carries a ``frontier_cap`` (docs/DESIGN.md §4.3); this code only
    ever sees ``layout.gather_mask``. The pending-edge
    arrays (``new_src``/``new_dst``/``new_ok``) and the working
    core/label stay replicated values, so the seed scatter and the label
    placement need no collective.

    Returns ``(core, label, rounds, v_plus_mask, max_frontier)``;
    ``max_frontier`` is the max per-shard count over every exchanged mask
    (``layout.frontier_peak``) — the observed datum the sparse
    ``frontier_cap`` planner is tuned from (docs/DESIGN.md §4.3).

    ``kernel_backend="pallas"`` runs every wave/evict/terminating
    statistic through the fused COO kernels (kernels/coremaint.py) —
    bit-identical partials, fewer launches; where the layout completes
    locally the terminating violator check folds into the same launch
    as its statistics (``fused_promotion_stats``).
    """
    if layout is None:
        layout = ReplicatedVertices(n)
    fuse_decision = (
        kernel_backend == "pallas" and G.completes_locally(layout)
    )

    def round_cond(state):
        return state[2]

    def round_body(state):
        (core, label, _, promoted_prev, rounds, v_plus, hi, dout_same,
         fmax) = state

        # SEED: roots of pending edges (order-min endpoint at current state)
        e_src_lt = (core[new_src] < core[new_dst]) | (
            (core[new_src] == core[new_dst]) & (label[new_src] < label[new_dst])
        )
        root = jnp.where(e_src_lt, new_src, new_dst)
        seed = (
            jnp.zeros(n, dtype=jnp.int32).at[root].add(new_ok.astype(jnp.int32))
            > 0
        )
        # certificate violators are potential hidden roots (the stats live
        # on their owners; only the violator bitmask crosses the mesh)
        viol = layout.gather_mask((hi + dout_same) > layout.own(core))
        fmax = jnp.maximum(fmax, layout.frontier_peak(viol))
        seed = seed | viol | promoted_prev

        reach, passing, wave_fmax = _forward_reach(
            src, dst, valid, core, label, seed, hi, dout_same, n, layout,
            kernel_backend=kernel_backend,
        )
        cand0 = reach & passing
        cand, evict_round, ev_fmax = _evict_fixpoint(
            src, dst, valid, core, cand0, hi, n, layout,
            kernel_backend=kernel_backend,
        )
        fmax = jnp.maximum(fmax, jnp.maximum(wave_fmax, ev_fmax))

        new_core = core + cand.astype(jnp.int32)
        # promoted -> head of O_{K+1} in old-label order
        label = place_block(new_core, label, cand, at_head=True,
                            n_levels=n_levels)
        # Backward-evicted -> tail of O_K in (eviction round, old label)
        # order; restores the dout <= core certificate (docs/DESIGN.md §2)
        evicted = cand0 & ~cand
        label = place_block(new_core, label, evicted, at_head=False,
                            n_levels=n_levels, round_key=evict_round)
        # fused (hi, dout_same) for the NEXT round — one scatter-add (C1).
        # Continue only while the k-order certificate is violated somewhere:
        # the passing-set fixpoint bootstraps from ``hi + dout_same > core``
        # vertices, so with none of them the next round provably finds no
        # candidates (docs/DESIGN.md §2.3) — this skips the seed
        # implementation's trailing confirm round (a full forward + evict
        # + stats pass) entirely.
        if fuse_decision:
            # ONE pallas_call: stats + the violator threshold mask that
            # decides fixpoint termination
            new_hi, new_dout, viol_next = coremaint.fused_promotion_stats(
                src, dst, valid, new_core, label, n
            )
            changed = jnp.any(viol_next)
        else:
            new_hi, new_dout = G.hi_and_dout_same(
                src, dst, valid, new_core, label, n, layout,
                backend=kernel_backend,
            )
            changed = layout.any_owned(
                (new_hi + new_dout) > layout.own(new_core)
            )
        return (
            new_core,
            label,
            changed,
            cand,
            rounds + 1,
            v_plus | reach,
            new_hi,
            new_dout,
            fmax,
        )

    core, label, _, _, rounds, v_plus, _, _, fmax = jax.lax.while_loop(
        round_cond,
        round_body,
        (core, label, jnp.bool_(True), jnp.zeros(n, dtype=bool),
         jnp.int32(0), jnp.zeros(n, dtype=bool), hi, dout_same,
         jnp.int32(0)),
    )
    return core, label, rounds, v_plus, fmax


def promotion_fixpoint_halo(
    src_h: Array,
    dst_h: Array,
    valid: Array,
    core_own: Array,
    label_own: Array,
    core_h: Array,
    label_h: Array,
    new_src: Array,
    new_dst: Array,
    u_pos: Array,
    v_pos: Array,
    new_ok: Array,
    hi: Array,
    dout_same: Array,
    session: HaloSession,
    n_levels: int,
    kernel_backend: str = "lax",
):
    """The promotion rounds on a halo working set — no [n] buffer.

    The mirror of ``promotion_fixpoint`` with every mask and decision in
    the OWNED domain and every edge-pass input in the HALO domain:
    ``src_h``/``dst_h`` index the halo (``session.locate`` of the
    post-insert window), ``u_pos``/``v_pos`` are the pending lanes' halo
    positions (every lane endpoint is in every device's halo by
    construction, so the root selection replays identically everywhere),
    and ``new_src``/``new_dst`` stay global ids for the owned seed
    scatter. Wave/evict masks cross the owner axis as changed-restricted
    sparse refreshes (dense O(halo_cap) regather on overflow); the
    commits run ``order.place_block_ring``. Bit-identical cores AND
    labels to ``promotion_fixpoint`` on the assembled global state.

    Returns ``(core_own, label_own, core_h, label_h, rounds, v_plus_own,
    max_frontier, n_overflow)`` — ``max_frontier`` is the LOCAL running
    per-round owned frontier count (engine completes with one pmax),
    ``n_overflow`` counts sparse exchanges that fell back dense.
    """
    hcap = session.halo_cap
    d_v = session.layout.n_shards

    def round_cond(state):
        return state[4]

    def round_body(state):
        (core_own, label_own, core_h, label_h, _, promoted_prev, rounds,
         v_plus, hi, dout_same, fmax, n_ovf) = state

        # SEED: roots of pending edges at the current state — the lane
        # endpoints' halo values are identical on every device, so the
        # owned scatter of the replicated root ids needs no collective
        cu, cv = core_h[u_pos], core_h[v_pos]
        e_src_lt = (cu < cv) | (
            (cu == cv) & (label_h[u_pos] < label_h[v_pos])
        )
        root = jnp.where(e_src_lt, new_src, new_dst)
        seed = session.add_at(
            session.zeros(), root, new_ok.astype(jnp.int32)
        ) > 0
        viol = (hi + dout_same) > core_own
        fmax = jnp.maximum(fmax, session.frontier_peak(viol))
        seed = seed | viol | promoted_prev

        reach, passing, wave_fmax, wave_ovf = _forward_reach_halo(
            src_h, dst_h, valid, core_own, core_h, label_h, seed,
            hi, dout_same, session, kernel_backend=kernel_backend,
        )
        cand0 = reach & passing
        cand, evict_round, ev_fmax, ev_ovf = _evict_fixpoint_halo(
            src_h, dst_h, valid, core_own, core_h, cand0, hi, session,
            kernel_backend=kernel_backend,
        )
        fmax = jnp.maximum(fmax, jnp.maximum(wave_fmax, ev_fmax))

        new_core = core_own + cand.astype(jnp.int32)
        # promoted -> head of O_{K+1} in old-label order
        label_own = place_block_ring(
            new_core, label_own, cand, at_head=True, n_levels=n_levels,
            axis=session.axis, n_shards=d_v, note=_note,
        )
        # Backward-evicted -> tail of O_K in (eviction round, old label)
        # order (docs/DESIGN.md §2)
        evicted = cand0 & ~cand
        label_own = place_block_ring(
            new_core, label_own, evicted, at_head=False,
            n_levels=n_levels, axis=session.axis, n_shards=d_v,
            round_key=evict_round, note=_note,
        )
        # cand0 covers every vertex whose core OR label just changed
        # (promoted: both; evicted: label) — the changed-restricted
        # halo refresh the next round's edge pass reads
        core_h, label_h, ovf = session.refresh_values(
            new_core, label_own, cand0, core_h, label_h
        )
        new_hi, new_dout = G.hi_and_dout_same(
            src_h, dst_h, valid, core_h, label_h, hcap, session,
            backend=kernel_backend,
        )
        changed = session.any_owned((new_hi + new_dout) > new_core)
        return (
            new_core, label_own, core_h, label_h, changed, cand,
            rounds + 1, v_plus | reach, new_hi, new_dout, fmax,
            n_ovf + wave_ovf + ev_ovf + ovf.astype(jnp.int32),
        )

    zmask = jnp.zeros(session.n_owned, dtype=bool)
    (core_own, label_own, core_h, label_h, _, _, rounds, v_plus, _, _,
     fmax, n_ovf) = jax.lax.while_loop(
        round_cond, round_body,
        (core_own, label_own, core_h, label_h, jnp.bool_(True), zmask,
         jnp.int32(0), zmask, hi, dout_same, jnp.int32(0), jnp.int32(0)),
    )
    return (core_own, label_own, core_h, label_h, rounds, v_plus, fmax,
            n_ovf)


def _forward_reach_halo(
    src_h: Array,
    dst_h: Array,
    valid: Array,
    core_own: Array,
    core_h: Array,
    label_h: Array,
    seed: Array,
    hi: Array,
    dout_same: Array,
    session: HaloSession,
    kernel_backend: str = "lax",
):
    """``_forward_reach`` with OWNED loop masks and a per-wave halo
    refresh of the reached-and-passing frontier. Returns ``(reach,
    passing, max_frontier, n_overflow)`` — owned masks."""
    hcap = session.halo_cap

    def cond(state):
        return state[2]

    def body(state):
        reach, passing, _, fmax, n_ovf = state
        rp = reach & passing
        rp_h, ovf = session.refresh_mask(rp)
        din, grow = G.din_and_expand(
            src_h, dst_h, valid, core_h, label_h, rp_h, hcap, session,
            backend=kernel_backend,
        )
        new_passing = (hi + dout_same + din) > core_own
        new_reach = reach | grow
        fmax = jnp.maximum(fmax, jnp.maximum(
            session.frontier_peak(new_passing),
            session.frontier_peak(grow),
        ))
        changed = session.any_owned(
            (new_reach != reach) | (new_passing != passing)
        )
        return (new_reach, new_passing, changed, fmax,
                n_ovf + ovf.astype(jnp.int32))

    init_pass = (hi + dout_same) > core_own
    reach, passing, _, fmax, n_ovf = jax.lax.while_loop(
        cond, body,
        (seed, init_pass, jnp.bool_(True),
         session.frontier_peak(init_pass), jnp.int32(0)),
    )
    return reach, passing, fmax, n_ovf


def _evict_fixpoint_halo(
    src_h: Array,
    dst_h: Array,
    valid: Array,
    core_own: Array,
    core_h: Array,
    cand: Array,
    hi: Array,
    session: HaloSession,
    kernel_backend: str = "lax",
):
    """``_evict_fixpoint`` with OWNED candidate masks and a per-round
    halo refresh. Returns ``(cand, evict_round, max_frontier,
    n_overflow)`` — owned arrays."""
    hcap = session.halo_cap

    def cond(state):
        return state[3]

    def body(state):
        cand, evict_round, rnd, _, fmax, n_ovf = state
        cand_h, ovf = session.refresh_mask(cand)
        support = hi + G.count_same_level_in(
            src_h, dst_h, valid, core_h, cand_h, hcap, session,
            backend=kernel_backend,
        )
        keep = support > core_own
        fmax = jnp.maximum(fmax, session.frontier_peak(keep))
        new_cand = cand & keep
        newly_evicted = cand & ~new_cand
        evict_round = jnp.where(newly_evicted, rnd, evict_round)
        changed = session.any_owned(new_cand != cand)
        return (new_cand, evict_round, rnd + 1, changed, fmax,
                n_ovf + ovf.astype(jnp.int32))

    cand, evict_round, _, _, fmax, n_ovf = jax.lax.while_loop(
        cond, body,
        (cand, jnp.zeros(session.n_owned, dtype=jnp.int32),
         jnp.int32(1), jnp.bool_(True), jnp.int32(0), jnp.int32(0)),
    )
    return cand, evict_round, fmax, n_ovf


def _forward_reach(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    seed: Array,
    hi: Array,
    dout_same: Array,
    n: int,
    layout: VertexLayout | None = None,
    kernel_backend: str = "lax",
) -> Tuple[Array, Array, Array]:
    """Monotone fixpoint of gated forward expansion.

    Returns (reach, passing, max_frontier) — boolean masks (full [n],
    replicated) plus the max per-shard count over the exchanged wave
    masks. ``passing`` uses the optimistic test with din counted over
    reached-and-passing predecessors only. Under a range-sharded layout
    each wave moves one reduce_scatter (din, owned) plus the two wave
    bitmasks; the loop state stays full/replicated so the edge pass can
    index it at arbitrary endpoints.
    """
    if layout is None:
        layout = ReplicatedVertices(n)
    core_own = layout.own(core)

    def cond(state):
        _, _, changed, _ = state
        return changed

    def body(state):
        reach, passing, _, fmax = state
        rp = reach & passing
        # one fused scatter per wave: din and frontier growth (C1)
        din, grow = G.din_and_expand(src, dst, valid, core, label, rp, n,
                                     layout, backend=kernel_backend)
        new_passing = layout.gather_mask(
            (hi + dout_same + din) > core_own
        )
        grow_full = layout.gather_mask(grow)
        fmax = jnp.maximum(fmax, jnp.maximum(
            layout.frontier_peak(new_passing), layout.frontier_peak(grow_full)
        ))
        new_reach = reach | grow_full
        changed = jnp.any(new_reach != reach) | jnp.any(new_passing != passing)
        return new_reach, new_passing, changed, fmax

    init_pass = layout.gather_mask((hi + dout_same) > core_own)
    reach, passing, _, fmax = jax.lax.while_loop(
        cond, body,
        (seed, init_pass, jnp.bool_(True), layout.frontier_peak(init_pass)),
    )
    return reach, passing, fmax


def _evict_fixpoint(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    cand: Array,
    hi: Array,
    n: int,
    layout: VertexLayout | None = None,
    kernel_backend: str = "lax",
) -> Tuple[Array, Array, Array]:
    """Greatest fixpoint of the candidate support test (sound + complete
    for any starting superset of V*).

    Returns (surviving candidates, eviction round per vertex,
    max_frontier), masks full [n]. The round numbers order the Backward
    tail placement (never-evicted keep 0); they are maintained
    replicated from the gathered candidate masks, so no integer array
    crosses the mesh.
    """
    if layout is None:
        layout = ReplicatedVertices(n)
    core_own = layout.own(core)

    def cond(state):
        _, _, _, changed, _ = state
        return changed

    def body(state):
        cand, evict_round, rnd, _, fmax = state
        support = hi + G.count_same_level_in(src, dst, valid, core, cand, n,
                                             layout,
                                             backend=kernel_backend)
        keep = layout.gather_mask(support > core_own)
        fmax = jnp.maximum(fmax, layout.frontier_peak(keep))
        new_cand = cand & keep
        newly_evicted = cand & ~new_cand
        evict_round = jnp.where(newly_evicted, rnd, evict_round)
        return (new_cand, evict_round, rnd + 1, jnp.any(new_cand != cand),
                fmax)

    cand, evict_round, _, _, fmax = jax.lax.while_loop(
        cond,
        body,
        (cand, jnp.zeros(n, dtype=jnp.int32), jnp.int32(1), jnp.bool_(True),
         jnp.int32(0)),
    )
    return cand, evict_round, fmax


def weighted_promotion_fixpoint(
    src: Array,
    dst: Array,
    valid: Array,
    w: Array,
    core: Array,
    total_w: Array,
    n: int,
    layout: VertexLayout | None = None,
    kernel_backend: str = "lax",
) -> Tuple[Array, Array, Array]:
    """Weighted promotion phase. The Order machinery's forward/evict
    passes have no weighted analogue of the +1-per-round theorem, so the
    promotion phase is the SAME decrease-only h-index fixpoint as the
    removal phase, started from the sound upper bound ``core +
    total_w``: a batch of total inserted weight W can raise any vertex
    by at most W — including vertices with NO inserted edge incident
    (a new path can close a cycle through them), which is why the
    per-vertex incident-weight bound is unsound (docs/DESIGN.md §4.5).
    Returns ``(core, rounds, max_frontier)``."""
    return weighted_core_fixpoint_pass(
        src, dst, valid, w, core + total_w, n, layout=layout,
        kernel_backend=kernel_backend,
    )


def weighted_promotion_fixpoint_halo(
    src_h: Array,
    dst_h: Array,
    valid: Array,
    w: Array,
    core_own: Array,
    core_h: Array,
    total_w: Array,
    session: HaloSession,
    kernel_backend: str = "lax",
):
    """``weighted_promotion_fixpoint`` on a halo working set: the upper
    bound ``+ total_w`` is replicated, so the halo image stays exact by
    the same local add (sentinel rows drift to ``total_w`` — harmless,
    no valid edge references them). Returns ``(core_own, core_h, rounds,
    max_frontier)``."""
    return weighted_core_fixpoint_pass_halo(
        src_h, dst_h, valid, w, core_own + total_w, core_h + total_w,
        session, kernel_backend=kernel_backend,
    )


@partial(jax.jit, static_argnames=("n", "n_levels"))
def insert_batch(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    new_src: Array,
    new_dst: Array,
    new_ok: Array,
    n_edges: Array,
    n: int,
    n_levels: int,
) -> Tuple[Array, Array, Array, Array, Array, Array, InsertStats]:
    """Insert ``(new_src, new_dst)`` (masked by ``new_ok``) and restore core
    numbers + k-order labels.

    Returns (src, dst, valid, n_edges, core, label, stats).
    """
    src, dst, valid, n_edges = write_edge_slots(
        src, dst, valid, n_edges, new_src, new_dst, new_ok
    )

    core0 = core
    # fused (hi, dout_same) — one scatter-add / one collective (C1)
    hi, dout_same = G.hi_and_dout_same(src, dst, valid, core, label, n)
    core, label, rounds, v_plus, fmax = promotion_fixpoint(
        src, dst, valid, core, label, new_src, new_dst, new_ok,
        hi, dout_same, n, n_levels,
    )
    stats = InsertStats(
        rounds=rounds,
        n_promoted=jnp.sum(core != core0, dtype=jnp.int32),
        v_plus=jnp.sum(v_plus, dtype=jnp.int32),
        max_frontier=fmax,
    )
    return src, dst, valid, n_edges, core, label, stats
