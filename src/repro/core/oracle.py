"""Sequential oracles: BZ decomposition, Simplified-Order (OI/OR) and
Traversal (TI/TR) core maintenance.

These reproduce the paper's sequential baselines faithfully (Algorithms 1,
7-10) and serve as the correctness oracle for the parallel JAX
implementations.  The Order-Maintenance (OM) list is implemented as a
linked list with integer gap labels and amortized per-level renumbering —
the same O(1) ``Order(x, y)`` interface the paper's two-level OM provides
(the two-level/group refinement only changes relabel constants; see
docs/DESIGN.md §6).

All maintainers expose instrumentation: ``last_v_plus`` / ``last_v_star``
(sizes of the searched and changed sets for the most recent edge), which
back the paper's Figure 5 / Table 2 style benchmarks.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

from ..graph.csr import CSRGraph

_GAP = 1 << 20  # label gap for fresh renumbers


# ---------------------------------------------------------------------------
# BZ core decomposition (Algorithm 1), "small degree first" tie-breaking
# ---------------------------------------------------------------------------
def bz_core_decomposition(
    n: int, adj: Sequence[Set[int]]
) -> Tuple[np.ndarray, List[int]]:
    """Return (core numbers, peeling order) for an adjacency-set graph.

    Ties among equal current degree are broken by (original degree, id) —
    the paper's best-performing "small degree first" strategy.
    """
    deg0 = np.array([len(a) for a in adj], dtype=np.int64)
    d = deg0.copy()
    heap = [(int(d[v]), int(deg0[v]), v) for v in range(n)]
    heapq.heapify(heap)
    removed = np.zeros(n, dtype=bool)
    core = np.zeros(n, dtype=np.int64)
    order: List[int] = []
    k = 0
    while heap:
        dv, _, v = heapq.heappop(heap)
        if removed[v] or dv != d[v]:
            continue  # stale heap entry
        removed[v] = True
        k = max(k, int(d[v]))
        core[v] = k
        order.append(v)
        for w in adj[v]:
            if not removed[w] and d[w] > d[v]:
                d[w] -= 1
                heapq.heappush(heap, (int(d[w]), int(deg0[w]), w))
    return core, order


def bz_from_csr(g: CSRGraph) -> np.ndarray:
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    core, _ = bz_core_decomposition(g.n, adj)
    return core


# ---------------------------------------------------------------------------
# Order-maintenance list (per core level)
# ---------------------------------------------------------------------------
class _LevelList:
    """Ordered list of vertices with integer labels; head/tail sentinels are
    label 0 and 2**62. ``Order(x, y)`` is a label comparison."""

    __slots__ = ("nxt", "prv", "label", "ver")

    def __init__(self) -> None:
        self.nxt: Dict[object, object] = {"H": "T"}
        self.prv: Dict[object, object] = {"T": "H"}
        self.label: Dict[object, int] = {"H": 0, "T": 1 << 62}
        self.ver = 0  # bumped on renumber (paper Appendix E version counter)

    def __contains__(self, v: int) -> bool:
        return v in self.label

    def _renumber(self) -> None:
        self.ver += 1
        x = self.nxt["H"]
        i = 1
        while x != "T":
            self.label[x] = i * _GAP
            i += 1
            x = self.nxt[x]

    def insert_after(self, x: object, y: int) -> None:
        nx = self.nxt[x]
        lab = (self.label[x] + self.label[nx]) // 2
        if lab == self.label[x]:  # gap exhausted -> relabel (amortized)
            self._renumber()
            nx = self.nxt[x]
            lab = (self.label[x] + self.label[nx]) // 2
            assert lab != self.label[x]
        self.nxt[x] = y
        self.prv[y] = x
        self.nxt[y] = nx
        self.prv[nx] = y
        self.label[y] = lab

    def append_tail(self, y: int) -> None:
        self.insert_after(self.prv["T"], y)

    def insert_head(self, y: int) -> None:
        self.insert_after("H", y)

    def delete(self, x: int) -> None:
        p, nx = self.prv[x], self.nxt[x]
        self.nxt[p] = nx
        self.prv[nx] = p
        del self.nxt[x], self.prv[x], self.label[x]

    def iter(self):
        x = self.nxt["H"]
        while x != "T":
            yield x
            x = self.nxt[x]


class _KOrder:
    """The global k-order O = O_0 O_1 O_2 ... (one level list per core)."""

    def __init__(self, core: np.ndarray, order: List[int]) -> None:
        self.levels: Dict[int, _LevelList] = {}
        self.core = core
        for v in order:  # peel order within each level
            self.level(int(core[v])).append_tail(v)

    def level(self, k: int) -> _LevelList:
        if k not in self.levels:
            self.levels[k] = _LevelList()
        return self.levels[k]

    def lt(self, u: int, v: int) -> bool:
        """u strictly precedes v in k-order."""
        cu, cv = int(self.core[u]), int(self.core[v])
        if cu != cv:
            return cu < cv
        lab = self.levels[cu].label
        return lab[u] < lab[v]

    def label_of(self, v: int) -> Tuple[int, int]:
        k = int(self.core[v])
        return (k, self.levels[k].label[v])


# ---------------------------------------------------------------------------
# Simplified-Order maintainer (Algorithms 7-10)
# ---------------------------------------------------------------------------
class OrderCoreMaintainer:
    """Sequential Simplified-Order edge insertion (OI) and removal (OR)."""

    def __init__(self, n: int, edges: np.ndarray) -> None:
        self.n = n
        self.adj: List[Set[int]] = [set() for _ in range(n)]
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            if u != v:
                self.adj[int(u)].add(int(v))
                self.adj[int(v)].add(int(u))
        core, order = bz_core_decomposition(n, self.adj)
        self.core = core
        self.O = _KOrder(self.core, order)
        self.last_v_plus = 0
        self.last_v_star = 0

    # -- helpers ----------------------------------------------------------
    def _dout_plus(self, v: int, evicted: Set[int]) -> int:
        """Remaining out-degree (Def 3.7): successors not in V+ \\ V*."""
        return sum(
            1 for w in self.adj[v] if self.O.lt(v, w) and w not in evicted
        )

    def _din_star(self, v: int, v_star: Set[int]) -> int:
        """Candidate in-degree (Def 3.6): predecessors in V*."""
        return sum(1 for w in self.adj[v] if w in v_star and self.O.lt(w, v))

    # -- edge insertion (Algorithm 7 + 8 + 9) ------------------------------
    def insert_edge(self, u: int, v: int) -> List[int]:
        """Insert (u, v); returns the list of vertices whose core rose."""
        if v in self.adj[u]:
            raise ValueError(f"edge ({u},{v}) already present")
        self.adj[u].add(v)
        self.adj[v].add(u)
        if self.O.lt(v, u):
            u, v = v, u  # orient u -> v, u is the k-order root
        K = int(self.core[u])

        evicted: Set[int] = set()
        v_star: Set[int] = set()
        v_star_order: List[int] = []
        dout: Dict[int, int] = {u: self._dout_plus(u, evicted)}
        self.last_v_plus = 0
        self.last_v_star = 0
        if dout[u] <= K:
            return []

        # min-priority queue in k-order; rebuilt when the level renumbers
        # (the sequential analogue of the paper's Appendix E version check).
        in_q: Set[int] = {u}
        q: List[Tuple[int, int]] = [(self.O.label_of(u)[1], u)]
        q_ver = self.O.level(K).ver

        def q_push(w: int) -> None:
            heapq.heappush(q, (self.O.label_of(w)[1], w))
            in_q.add(w)

        while q:
            if self.O.level(K).ver != q_ver:
                q_ver = self.O.level(K).ver
                q = [(self.O.label_of(w)[1], w) for w in in_q]
                heapq.heapify(q)
            _, w = heapq.heappop(q)
            in_q.discard(w)
            if w in v_star or w in evicted:
                continue  # cannot recur (see Appendix C) — defensive
            if w not in dout:
                dout[w] = self._dout_plus(w, evicted)
            din_w = self._din_star(w, v_star)
            self.last_v_plus += 1
            if din_w + dout[w] > K:
                # Forward (Algorithm 8)
                v_star.add(w)
                v_star_order.append(w)
                for x in self.adj[w]:
                    if (
                        int(self.core[x]) == K
                        and self.O.lt(w, x)
                        and x not in in_q
                        and x not in v_star
                        and x not in evicted
                    ):
                        q_push(x)
            elif din_w > 0:
                self._backward(w, din_w, dout, v_star, v_star_order, evicted, K)
            # else: skip — w never joins V+

        # Ending phase (Algorithm 7 lines 9-10)
        lvl_k = self.O.level(K)
        lvl_k1 = self.O.level(K + 1)
        prev: object = "H"
        for w in v_star_order:
            lvl_k.delete(w)
            lvl_k1.insert_after(prev, w)
            prev = w
            self.core[w] = K + 1
        self.last_v_star = len(v_star_order)
        return v_star_order

    def _backward(
        self,
        w: int,
        din_w: int,
        dout: Dict[int, int],
        v_star: Set[int],
        v_star_order: List[int],
        evicted: Set[int],
        K: int,
    ) -> None:
        """Algorithm 9: evict unsupported vertices from V*."""
        evicted.add(w)
        din: Dict[int, int] = {x: self._din_star(x, v_star) for x in v_star}
        r: deque[int] = deque()
        in_r: Set[int] = set()

        def do_pre(x: int) -> None:
            for y in self.adj[x]:
                if y in v_star and self.O.lt(y, x):
                    dout[y] -= 1
                    if din[y] + dout[y] <= K and y not in in_r:
                        r.append(y)
                        in_r.add(y)

        def do_post(x: int) -> None:
            for y in self.adj[x]:
                if y in v_star and self.O.lt(x, y) and din[y] > 0:
                    din[y] -= 1
                    if din[y] + dout[y] <= K and y not in in_r:
                        r.append(y)
                        in_r.add(y)

        do_pre(w)
        dout[w] = dout[w] + din_w  # w's V* predecessors will move above it
        lvl = self.O.level(K)
        pre = w
        while r:
            x = r.popleft()
            in_r.discard(x)
            v_star.discard(x)
            v_star_order.remove(x)
            evicted.add(x)
            do_pre(x)
            do_post(x)
            lvl.delete(x)
            lvl.insert_after(pre, x)
            pre = x
            dout[x] = dout[x] + din[x]
            din[x] = 0

    # -- edge removal (Algorithm 10) ---------------------------------------
    def remove_edge(self, u: int, v: int) -> List[int]:
        """Remove (u, v); returns the list of vertices whose core dropped."""
        if v not in self.adj[u]:
            raise ValueError(f"edge ({u},{v}) not present")
        self.adj[u].discard(v)
        self.adj[v].discard(u)
        K = int(min(self.core[u], self.core[v]))

        mcd: Dict[int, int] = {}
        popped: Set[int] = set()
        in_star: Set[int] = set()
        v_star_order: List[int] = []
        r: deque[int] = deque()

        def mcd_fresh(x: int) -> int:
            # supporters at current cores, minus already-propagated drops
            return sum(
                1
                for y in self.adj[x]
                if self.core[y] >= self.core[x] and y not in popped
            )

        def try_drop(x: int) -> None:
            if mcd[x] < K and x not in in_star:
                in_star.add(x)
                v_star_order.append(x)
                r.append(x)

        for x in (u, v):
            if int(self.core[x]) == K:
                mcd[x] = mcd_fresh(x)
                try_drop(x)

        self.last_v_plus = 0
        while r:
            w = r.popleft()
            popped.add(w)
            self.last_v_plus += 1
            for w2 in self.adj[w]:
                if int(self.core[w2]) == K and w2 not in in_star:
                    if w2 not in mcd:
                        mcd[w2] = mcd_fresh(w2)
                        # w already counted itself out via `popped`
                    else:
                        mcd[w2] -= 1
                    try_drop(w2)

        lvl_k = self.O.level(K)
        lvl_k1 = self.O.level(K - 1)
        for w in v_star_order:
            lvl_k.delete(w)
            lvl_k1.append_tail(w)
            self.core[w] = K - 1
        self.last_v_star = len(v_star_order)
        return v_star_order

    # -- batches ------------------------------------------------------------
    def insert_batch(self, edges: np.ndarray) -> None:
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            self.insert_edge(int(u), int(v))

    def remove_batch(self, edges: np.ndarray) -> None:
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            self.remove_edge(int(u), int(v))

    def check_invariants(self) -> None:
        """k-order must be a valid peel order: within a level, every vertex's
        remaining out-degree (successors) must be <= its core number is NOT
        required; the defining invariant is core correctness (checked against
        BZ by the tests) plus label strict monotonicity per level."""
        for k, lvl in self.O.levels.items():
            labs = [lvl.label[x] for x in lvl.iter()]
            assert labs == sorted(labs)
            for x in lvl.iter():
                assert int(self.core[x]) == k


# ---------------------------------------------------------------------------
# Traversal maintainer (TI/TR baselines, Sariyüce et al.)
# ---------------------------------------------------------------------------
class TraversalCoreMaintainer:
    """Sequential Traversal insertion/removal — the paper's TI/TR baseline.

    Insertion BFS-collects the k-subcore reachable from the root through
    vertices whose optimistic support (cd) exceeds K, then runs the eviction
    fixpoint. Removal is the mcd cascade without order maintenance."""

    def __init__(self, n: int, edges: np.ndarray) -> None:
        self.n = n
        self.adj: List[Set[int]] = [set() for _ in range(n)]
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            if u != v:
                self.adj[int(u)].add(int(v))
                self.adj[int(v)].add(int(u))
        core, _ = bz_core_decomposition(n, self.adj)
        self.core = core
        self.last_v_plus = 0
        self.last_v_star = 0

    def insert_edge(self, u: int, v: int) -> List[int]:
        if v in self.adj[u]:
            raise ValueError(f"edge ({u},{v}) already present")
        self.adj[u].add(v)
        self.adj[v].add(u)
        K = int(min(self.core[u], self.core[v]))
        roots = [x for x in (u, v) if int(self.core[x]) == K]

        # pruned BFS over the K-subcore
        cd: Dict[int, int] = {}
        visited: Set[int] = set()
        stack = []
        for rt in roots:
            if rt not in visited:
                visited.add(rt)
                stack.append(rt)
        while stack:
            w = stack.pop()
            cd[w] = sum(1 for x in self.adj[w] if self.core[x] >= K)
            if cd[w] > K:
                for x in self.adj[w]:
                    if int(self.core[x]) == K and x not in visited:
                        visited.add(x)
                        stack.append(x)
        self.last_v_plus = len(visited)

        # eviction fixpoint on the visited set: a core-K vertex supports a
        # promotion only if it is itself still a live candidate (V* is
        # connected to the root through V*, so candidates outside `visited`
        # cannot exist).
        alive = {w for w in visited if cd[w] > K}
        changed = True
        while changed:
            changed = False
            for w in list(alive):
                support = sum(
                    1
                    for x in self.adj[w]
                    if self.core[x] > K or (self.core[x] == K and x in alive)
                )
                if support <= K:
                    alive.discard(w)
                    changed = True
        for w in alive:
            self.core[w] = K + 1
        self.last_v_star = len(alive)
        return sorted(alive)

    def remove_edge(self, u: int, v: int) -> List[int]:
        if v not in self.adj[u]:
            raise ValueError(f"edge ({u},{v}) not present")
        self.adj[u].discard(v)
        self.adj[v].discard(u)
        K = int(min(self.core[u], self.core[v]))
        mcd: Dict[int, int] = {}
        popped: Set[int] = set()
        in_star: Set[int] = set()
        order: List[int] = []
        r: deque[int] = deque()

        def mcd_fresh(x: int) -> int:
            return sum(
                1
                for y in self.adj[x]
                if self.core[y] >= self.core[x] and y not in popped
            )

        for x in (u, v):
            if int(self.core[x]) == K:
                mcd[x] = mcd_fresh(x)
                if mcd[x] < K and x not in in_star:
                    in_star.add(x)
                    order.append(x)
                    r.append(x)
        self.last_v_plus = 0
        while r:
            w = r.popleft()
            popped.add(w)
            self.last_v_plus += 1
            for w2 in self.adj[w]:
                if int(self.core[w2]) == K and w2 not in in_star:
                    if w2 not in mcd:
                        mcd[w2] = mcd_fresh(w2)
                    else:
                        mcd[w2] -= 1
                    if mcd[w2] < K:
                        in_star.add(w2)
                        order.append(w2)
                        r.append(w2)
        for w in order:
            self.core[w] -= 1
        self.last_v_star = len(order)
        return order

    def insert_batch(self, edges: np.ndarray) -> None:
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            self.insert_edge(int(u), int(v))

    def remove_batch(self, edges: np.ndarray) -> None:
        for u, v in np.asarray(edges, dtype=np.int64).reshape(-1, 2):
            self.remove_edge(int(u), int(v))
