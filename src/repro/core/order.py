"""k-order label maintenance — the TPU adaptation of the parallel OM
data structure (paper §3.2, ref [11]).

Vertices carry ``(core, label)`` pairs; the k-order predicate is the
lexicographic comparison ``(core[u], label[u]) < (core[v], label[v])`` —
an O(1) ``Order(x, y)`` exactly like the OM list's two-label compare.

Batch "Insert at head of O_{K+1}" / "append at tail of O_{K-1}" become
vectorized label assignments below the level minimum / above the level
maximum; the OM rebalance/split relabel collapses into a per-level (or
global) renumber that is a single ``lexsort`` — amortized O(1) per edit
with the LABEL_GAP spacing (2^20 inserts per gap before a renumber).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
LABEL_GAP = jnp.int64(1) << 20
_NEG = jnp.int64(-(1 << 62))
_POS = jnp.int64(1 << 62)


def level_min_labels(core: Array, label: Array, exclude: Array, n_levels: int) -> Array:
    """Min label per level over vertices not in ``exclude``; _POS if empty."""
    vals = jnp.where(exclude, _POS, label)
    return jax.ops.segment_min(vals, core, num_segments=n_levels)


def level_max_labels(core: Array, label: Array, exclude: Array, n_levels: int) -> Array:
    vals = jnp.where(exclude, _NEG, label)
    return jax.ops.segment_max(vals, core, num_segments=n_levels)


def place_block(
    core_new: Array,
    label: Array,
    moving: Array,
    at_head: bool,
    n_levels: int,
    round_key: Array | None = None,
) -> Array:
    """Assign fresh labels to ``moving`` vertices at the head (insertion,
    O_{K+1}) or tail (removal / Backward eviction, O_{K-1} / O_K) of their
    new level.

    Within a level the moving block is ordered by ``(round_key, old label)``
    — old-label order for promotions (required to preserve the k-order
    certificate), eviction-round order for Backward-evicted vertices
    (the batched analogue of the paper's insert-after-traversal-point;
    proof in docs/DESIGN.md §2.2), and any order is valid for removal drops.
    """
    n = core_new.shape[0]
    base_min = level_min_labels(core_new, label, moving, n_levels)
    base_max = level_max_labels(core_new, label, moving, n_levels)
    base_min = jnp.where(base_min == _POS, jnp.int64(0), base_min)
    base_max = jnp.where(base_max == _NEG, jnp.int64(0), base_max)

    # order moving vertices by (new level, round_key, old label)
    sort_level = jnp.where(moving, core_new, jnp.int32(n_levels))
    if round_key is None:
        perm = jnp.lexsort((label, sort_level))
    else:
        perm = jnp.lexsort((label, round_key, sort_level))
    ranks = jnp.zeros(n, dtype=jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    first_rank = jax.ops.segment_min(
        jnp.where(moving, ranks, jnp.int32(2**30)), core_new,
        num_segments=n_levels,
    )
    count = jax.ops.segment_sum(
        moving.astype(jnp.int32), core_new, num_segments=n_levels
    )
    pos = ranks - first_rank[core_new]  # position within the moving block
    if at_head:
        newlab = base_min[core_new] - LABEL_GAP * (
            count[core_new] - pos
        ).astype(jnp.int64)
    else:
        newlab = base_max[core_new] + LABEL_GAP * (pos + 1).astype(jnp.int64)
    return jnp.where(moving, newlab, label)


@partial(jax.jit, static_argnames=())
def renumber(core: Array, label: Array) -> Array:
    """Global relabel: fresh LABEL_GAP-spaced labels in (core, label) order.
    The vectorized analogue of the OM rebalance+split relabel."""
    n = core.shape[0]
    perm = jnp.lexsort((label, core))
    ranks = jnp.zeros(n, dtype=jnp.int64).at[perm].set(
        jnp.arange(n, dtype=jnp.int64)
    )
    return ranks * LABEL_GAP


def needs_renumber(label: Array) -> Array:
    """True when the label space is running out of headroom."""
    lim = jnp.int64(1) << 61
    return (jnp.min(label) < -lim) | (jnp.max(label) > lim)


def maybe_renumber(core: Array, label: Array) -> Tuple[Array, Array]:
    """Device-side renumber gate: relabel iff the label space is out of
    headroom. Returns ``(label, did_renumber)``.

    Folding the gate into the edit program means the per-batch
    ``needs_renumber`` check costs nothing on the host — no dedicated
    device->host sync, and the relabel itself runs in the same compiled
    program when (rarely) triggered."""
    need = needs_renumber(label)
    new_label = jax.lax.cond(
        need, lambda c, l: renumber(c, l), lambda c, l: l, core, label
    )
    return new_label, need
