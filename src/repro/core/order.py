"""k-order label maintenance — the TPU adaptation of the parallel OM
data structure (paper §3.2, ref [11]).

Vertices carry ``(core, label)`` pairs; the k-order predicate is the
lexicographic comparison ``(core[u], label[u]) < (core[v], label[v])`` —
an O(1) ``Order(x, y)`` exactly like the OM list's two-label compare.

Batch "Insert at head of O_{K+1}" / "append at tail of O_{K-1}" become
vectorized label assignments below the level minimum / above the level
maximum; the OM rebalance/split relabel collapses into a per-level (or
global) renumber that is a single ``lexsort`` — amortized O(1) per edit
with the LABEL_GAP spacing (2^20 inserts per gap before a renumber).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
LABEL_GAP = jnp.int64(1) << 20
_NEG = jnp.int64(-(1 << 62))
_POS = jnp.int64(1 << 62)


def level_min_labels(core: Array, label: Array, exclude: Array, n_levels: int) -> Array:
    """Min label per level over vertices not in ``exclude``; _POS if empty."""
    vals = jnp.where(exclude, _POS, label)
    return jax.ops.segment_min(vals, core, num_segments=n_levels)


def level_max_labels(core: Array, label: Array, exclude: Array, n_levels: int) -> Array:
    vals = jnp.where(exclude, _NEG, label)
    return jax.ops.segment_max(vals, core, num_segments=n_levels)


def place_block(
    core_new: Array,
    label: Array,
    moving: Array,
    at_head: bool,
    n_levels: int,
    round_key: Array | None = None,
) -> Array:
    """Assign fresh labels to ``moving`` vertices at the head (insertion,
    O_{K+1}) or tail (removal / Backward eviction, O_{K-1} / O_K) of their
    new level.

    Within a level the moving block is ordered by ``(round_key, old label)``
    — old-label order for promotions (required to preserve the k-order
    certificate), eviction-round order for Backward-evicted vertices
    (the batched analogue of the paper's insert-after-traversal-point;
    proof in docs/DESIGN.md §2.2), and any order is valid for removal drops.
    """
    n = core_new.shape[0]
    base_min = level_min_labels(core_new, label, moving, n_levels)
    base_max = level_max_labels(core_new, label, moving, n_levels)
    base_min = jnp.where(base_min == _POS, jnp.int64(0), base_min)
    base_max = jnp.where(base_max == _NEG, jnp.int64(0), base_max)

    # order moving vertices by (new level, round_key, old label)
    sort_level = jnp.where(moving, core_new, jnp.int32(n_levels))
    if round_key is None:
        perm = jnp.lexsort((label, sort_level))
    else:
        perm = jnp.lexsort((label, round_key, sort_level))
    ranks = jnp.zeros(n, dtype=jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    first_rank = jax.ops.segment_min(
        jnp.where(moving, ranks, jnp.int32(2**30)), core_new,
        num_segments=n_levels,
    )
    count = jax.ops.segment_sum(
        moving.astype(jnp.int32), core_new, num_segments=n_levels
    )
    pos = ranks - first_rank[core_new]  # position within the moving block
    if at_head:
        newlab = base_min[core_new] - LABEL_GAP * (
            count[core_new] - pos
        ).astype(jnp.int64)
    else:
        newlab = base_max[core_new] + LABEL_GAP * (pos + 1).astype(jnp.int64)
    return jnp.where(moving, newlab, label)


def _local_ranks(*keys: Array) -> Array:
    """Rank of each element under the stable lexsort of ``keys`` (last
    key primary). Keys are globally duplicate-free wherever it matters
    (same-level labels are unique — place_block always assigns fresh
    labels strictly beyond the level extremes), so stability only ever
    tie-breaks sentinel rows nobody queries."""
    n = keys[0].shape[0]
    perm = jnp.lexsort(keys)
    return jnp.zeros(n, dtype=jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32)
    )


def _ring_visiting(payload, axis: str, n_shards: int, note=None):
    """One ring rotation of ``payload`` (a tuple of [n_owned] arrays)
    along ``axis``: after ``t`` applications device ``i`` holds device
    ``(i - t) mod n_shards``'s block. ``note`` (op, bytes) is the
    trace-time traffic hook (vertex_layout._note signature)."""
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    out = []
    for arr in payload:
        if note is not None:
            note("ppermute", int(arr.size) * arr.dtype.itemsize)
        out.append(jax.lax.ppermute(arr, axis, perm=perm))
    return tuple(out)


def place_block_ring(
    core_new: Array,
    label: Array,
    moving: Array,
    at_head: bool,
    n_levels: int,
    axis: str,
    n_shards: int,
    round_key: Array | None = None,
    note=None,
) -> Array:
    """``place_block`` on OWNED slices only — bit-identical labels,
    no [n] or [n_levels] buffer on any device.

    Every input is this device's owned range ``[n_owned]`` of the global
    arrays. The global quantities place_block reads off dense per-level
    arrays (block position, block size, level base label) are instead
    accumulated over a ring of ``n_shards - 1`` ``ppermute`` steps: each
    step a visiting block of (level, round_key, label, moving) rows
    answers three ORDER queries per owned moving vertex — visiting
    same-level movers with a smaller (round_key, label) key, visiting
    same-level movers total, and the visiting non-moving label extreme —
    all via single-key ``searchsorted`` over sorted visiting columns
    plus one combined lexsort (cross-device key ties are impossible:
    same-level labels are globally unique). Buffers stay O(n_owned).

    At ``n_shards == 1`` the ring still runs ONE (masked, zero
    contribution) step so the traced program — and the paired memory
    audit's program-point sequence — is mesh-size independent.
    """
    n_owned = core_new.shape[0]
    rkey = jnp.zeros(n_owned, dtype=jnp.int32) if round_key is None \
        else round_key.astype(jnp.int32)
    lvl_sent = jnp.int32(n_levels)
    # moving rows keyed (level, round_key, label); non-moving rows are
    # (n_levels, 0, 0) sentinels that sort past every moving key
    lvl_m = jnp.where(moving, core_new, lvl_sent)
    rk_m = jnp.where(moving, rkey, 0)
    lab_m = jnp.where(moving, label, jnp.int64(0))
    # non-moving rows keyed (level, label) for the base-label extremes
    lvl_nm = jnp.where(moving, lvl_sent, core_new)
    lab_nm = jnp.where(moving, jnp.int64(0), label)

    # local (t = 0) contributions -------------------------------------
    q = _local_ranks(lab_m, rk_m, lvl_m)   # rank among ALL owned rows
    s_lvl_m = jnp.sort(lvl_m)
    below = jnp.searchsorted(s_lvl_m, lvl_m, side="left").astype(jnp.int32)
    pos = q - below                         # rank within my level's movers
    count = (
        jnp.searchsorted(s_lvl_m, lvl_m, side="right").astype(jnp.int32)
        - below
    )

    def _extremes(v_lvl_nm, v_lab_nm):
        """(min, max) non-moving label per owned vertex's level over one
        [n_owned] block; sentinels where the level group is empty."""
        perm = jnp.lexsort((v_lab_nm, v_lvl_nm))
        s_lvl = v_lvl_nm[perm]
        s_lab = v_lab_nm[perm]
        lo = jnp.searchsorted(s_lvl, core_new, side="left")
        hi = jnp.searchsorted(s_lvl, core_new, side="right")
        found = hi > lo
        bmin = jnp.where(found, s_lab[jnp.minimum(lo, n_owned - 1)], _POS)
        bmax = jnp.where(
            found, s_lab[jnp.clip(hi - 1, 0, n_owned - 1)], _NEG
        )
        return bmin, bmax

    bmin, bmax = _extremes(lvl_nm, lab_nm)

    # ring accumulation over the other shards' blocks ------------------
    def step(carry, t):
        pos, count, bmin, bmax, pay = carry
        pay = _ring_visiting(pay, axis, n_shards, note=note)
        v_lvl_m, v_rk_m, v_lab_m, v_lvl_nm, v_lab_nm = pay
        live = (t < n_shards).astype(jnp.int32)  # masks the 1-shard step
        # visiting movers with key strictly below mine, any level: my
        # combined rank minus my local rank (stability keeps my rows in
        # local order; visiting sentinels sort past every moving key)
        p = _local_ranks(
            jnp.concatenate([lab_m, v_lab_m]),
            jnp.concatenate([rk_m, v_rk_m]),
            jnp.concatenate([lvl_m, v_lvl_m]),
        )[:n_owned]
        s_vlvl = jnp.sort(v_lvl_m)
        v_below = jnp.searchsorted(s_vlvl, lvl_m, side="left").astype(
            jnp.int32
        )
        pos = pos + live * ((p - q) - v_below)
        count = count + live * (
            jnp.searchsorted(s_vlvl, lvl_m, side="right").astype(jnp.int32)
            - v_below
        )
        v_bmin, v_bmax = _extremes(v_lvl_nm, v_lab_nm)
        lv = live > 0
        bmin = jnp.minimum(bmin, jnp.where(lv, v_bmin, _POS))
        bmax = jnp.maximum(bmax, jnp.where(lv, v_bmax, _NEG))
        return (pos, count, bmin, bmax, pay), None

    init = (pos, count, bmin, bmax, (lvl_m, rk_m, lab_m, lvl_nm, lab_nm))
    steps = jnp.arange(1, max(n_shards - 1, 1) + 1, dtype=jnp.int32)
    (pos, count, bmin, bmax, _), _ = jax.lax.scan(step, init, steps)

    bmin = jnp.where(bmin == _POS, jnp.int64(0), bmin)
    bmax = jnp.where(bmax == _NEG, jnp.int64(0), bmax)
    if at_head:
        newlab = bmin - LABEL_GAP * (count - pos).astype(jnp.int64)
    else:
        newlab = bmax + LABEL_GAP * (pos + 1).astype(jnp.int64)
    return jnp.where(moving, newlab, label)


def renumber_ring(core: Array, label: Array, axis: str, n_shards: int,
                  note=None) -> Array:
    """``renumber`` on owned slices: global (core, label)-order ranks via
    the same ring merge-count as ``place_block_ring`` (keys are globally
    unique), then fresh LABEL_GAP-spaced labels."""
    n_owned = core.shape[0]
    q = _local_ranks(label, core)
    rank = q.astype(jnp.int64)

    def step(carry, t):
        rank, pay = carry
        pay = _ring_visiting(pay, axis, n_shards, note=note)
        v_core, v_lab = pay
        live = (t < n_shards).astype(jnp.int64)
        p = _local_ranks(
            jnp.concatenate([label, v_lab]),
            jnp.concatenate([core, v_core]),
        )[:n_owned]
        rank = rank + live * (p - q).astype(jnp.int64)
        return (rank, pay), None

    steps = jnp.arange(1, max(n_shards - 1, 1) + 1, dtype=jnp.int32)
    (rank, _), _ = jax.lax.scan(step, (rank, (core, label)), steps)
    return rank * LABEL_GAP


def maybe_renumber_ring(core: Array, label: Array, axis: str,
                        n_shards: int, note=None,
                        force: Array | None = None) -> Tuple[Array, Array]:
    """``maybe_renumber`` over owned slices: the headroom check completes
    with one pmin + one pmax over the owner axis (replicated verdict, so
    every device takes the same cond arm); the relabel itself is the
    ring renumber, traced inside the cond. ``force`` (a replicated bool)
    ORs into the verdict — the weighted engine relabels whenever cores
    moved, since its fixpoints freeze labels instead of placing blocks."""
    lim = jnp.int64(1) << 61
    if note is not None:
        note("pmin_scalar", 8)
        note("pmax_scalar", 8)
    lo = jax.lax.pmin(jnp.min(label), axis)
    hi = jax.lax.pmax(jnp.max(label), axis)
    need = (lo < -lim) | (hi > lim)
    if force is not None:
        need = need | force
    new_label = jax.lax.cond(
        need,
        lambda c, l: renumber_ring(c, l, axis, n_shards, note=note),
        lambda c, l: l,
        core, label,
    )
    return new_label, need


@partial(jax.jit, static_argnames=())
def renumber(core: Array, label: Array) -> Array:
    """Global relabel: fresh LABEL_GAP-spaced labels in (core, label) order.
    The vectorized analogue of the OM rebalance+split relabel."""
    n = core.shape[0]
    perm = jnp.lexsort((label, core))
    ranks = jnp.zeros(n, dtype=jnp.int64).at[perm].set(
        jnp.arange(n, dtype=jnp.int64)
    )
    return ranks * LABEL_GAP


def needs_renumber(label: Array) -> Array:
    """True when the label space is running out of headroom."""
    lim = jnp.int64(1) << 61
    return (jnp.min(label) < -lim) | (jnp.max(label) > lim)


def maybe_renumber(core: Array, label: Array,
                   force: Array | None = None) -> Tuple[Array, Array]:
    """Device-side renumber gate: relabel iff the label space is out of
    headroom. Returns ``(label, did_renumber)``.

    Folding the gate into the edit program means the per-batch
    ``needs_renumber`` check costs nothing on the host — no dedicated
    device->host sync, and the relabel itself runs in the same compiled
    program when (rarely) triggered. ``force`` ORs into the verdict (the
    weighted engine's label-freezing fixpoints relabel whenever any core
    moved); ``force=None`` leaves the traced program byte-identical to
    the pre-weighted gate."""
    need = needs_renumber(label)
    if force is not None:
        need = need | force
    new_label = jax.lax.cond(
        need, lambda c, l: renumber(c, l), lambda c, l: l, core, label
    )
    return new_label, need
