"""Batch-parallel edge removal maintenance (paper Algorithm 6, TPU form).

The lock-based mcd cascade becomes a decrease-only fixpoint over dense
per-vertex state:

    round:  mcd[v] = |{u in N(v) : core[u] >= core[v]}|      (CheckMCD)
            drop   = mcd < core                              (DoMCD)
            core  -= drop                                    (<= 1 per round,
                                                              the paper's
                                                              Theorem bound)

Every round handles ALL affected levels of ALL removed edges at once —
the paper's conditional-lock concurrency collapses into simultaneity:
because all of a round's droppers still count each other in mcd, any
intra-round append order at the new level keeps the k-order certificate
``dout(v) <= core(v)`` valid (proof in docs/DESIGN.md §2.1).

The fixpoint provably converges to the exact core numbers of the edited
graph from any state that upper-bounds them (Lü et al. style argument;
tests/test_jax_core.py property-checks this against the oracle).

``removal_fixpoint`` is the reusable building block: the unified
mixed-batch engine (core/engine.py) runs it back-to-back with the
promotion rounds in one compiled program, reusing the terminating round's
packed (hi, dout_same) statistics to seed the promotion phase.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import graph_ops as G
from ..kernels import coremaint
from .order import place_block, place_block_ring
from .vertex_layout import (
    HaloSession,
    ReplicatedVertices,
    VertexLayout,
    _note,
)

Array = jax.Array


class RemoveStats(NamedTuple):
    rounds: Array        # number of fixpoint rounds executed
    n_dropped: Array     # |V*| — vertices whose core number decreased
    max_frontier: Array  # max per-shard drop-mask count over all rounds


def removal_fixpoint(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    n: int,
    n_levels: int,
    share_stats: bool = True,
    layout: VertexLayout | None = None,
    kernel_backend: str = "lax",
) -> Tuple[Array, Array, Array, Array, Array, Array]:
    """Run the decrease-only mcd fixpoint on an already-tombstoned table.

    Returns ``(core, label, rounds, hi, dout_same, max_frontier)``;
    ``max_frontier`` is the max per-shard drop-mask count observed over
    all rounds (``layout.frontier_peak`` — the datum the sparse
    ``frontier_cap`` planner is tuned from). With ``share_stats``
    the (hi, dout_same) statistics come from the same packed scatter as
    the terminating mcd check, so they describe the FINAL state exactly
    (the last round drops nothing and therefore leaves core/label
    untouched) — the unified engine seeds its promotion phase from them
    for free. Removal-only callers pass ``share_stats=False`` to scatter
    just the 1-column mcd (the returned hi/dout_same stay zero, and are
    OWNED-sized under a range-sharded layout).

    With a ``layout`` the edge arrays are shard_map-local shards of the
    slot table and every statistic is completed by the layout: a psum
    over the mesh axis for replicated vertex state (every device sees
    the full statistic), a reduce_scatter for range-sharded state (each
    device sees only its owned vertex range and decides drops there; the
    drop mask is all_gathered — bit-packed, or as compacted frontier
    indices with an in-program overflow fallback when the layout carries
    a ``frontier_cap`` (docs/DESIGN.md §4.3) — so the commit — core -1
    and the label tail placement — replays identically everywhere).
    Either way the working core/label stay replicated values, so all
    devices run the loop in lockstep.

    ``kernel_backend="pallas"`` routes the statistics pass through the
    fused COO kernel (kernels/coremaint.py): bit-identical partials, one
    launch instead of a gather/scatter train. Where the layout completes
    locally the drop decision + core commit fold into the same launch
    (``fused_removal_round``); under a mesh the decision still runs after
    the layout's collective, so the collective schedule never changes.
    """
    if layout is None:
        layout = ReplicatedVertices(n)
    # decision fusion needs the GLOBAL mcd in-kernel: only where the
    # layout completes statistics locally (single device / GSPMD)
    fuse_decision = (
        kernel_backend == "pallas" and G.completes_locally(layout)
    )

    def cond(state):
        return state[2]

    def body(state):
        core, label, _, rounds, hi, dout_same, fmax = state
        if fuse_decision:
            # ONE pallas_call: packed stats + drop threshold + core commit
            _, k_hi, k_dout, new_core, drop = coremaint.fused_removal_round(
                src, dst, valid, core, label, n
            )
            if share_stats:
                hi, dout_same = k_hi, k_dout
        else:
            if share_stats:
                mcd, hi, dout_same = G.mcd_hi_dout(
                    src, dst, valid, core, label, n, layout,
                    backend=kernel_backend,
                )
            else:
                mcd = G.count_ge(src, dst, valid, core, n, layout,
                                 backend=kernel_backend)
            core_own = layout.own(core)
            drop = layout.gather_mask((mcd < core_own) & (core_own > 0))
            new_core = core - drop.astype(jnp.int32)
        fmax = jnp.maximum(fmax, layout.frontier_peak(drop))
        # place this round's droppers at the tail of their new level
        label = place_block(new_core, label, drop, at_head=False,
                            n_levels=n_levels)
        return (new_core, label, jnp.any(drop), rounds + 1, hi, dout_same,
                fmax)

    z = layout.zeros()
    # rounds counts body executions (the final one observes no drops)
    core, label, _, rounds, hi, dout_same, fmax = jax.lax.while_loop(
        cond, body,
        (core, label, jnp.bool_(True), jnp.int32(0), z, z, jnp.int32(0)),
    )
    return core, label, rounds, hi, dout_same, fmax


def removal_fixpoint_halo(
    src_h: Array,
    dst_h: Array,
    valid: Array,
    core_own: Array,
    label_own: Array,
    core_h: Array,
    label_h: Array,
    session: HaloSession,
    n_levels: int,
    kernel_backend: str = "lax",
):
    """The removal fixpoint on a halo working set — no [n] buffer.

    ``src_h``/``dst_h`` are the windowed edge endpoints as HALO positions
    (``session.locate``); ``core_h``/``label_h`` are the current halo
    values, ``core_own``/``label_own`` the owned slices. Per round: one
    halo-domain stats pass completed into owned by the session (bounded
    all_gather + owner scatter + edge-axis psum), the drop decision on
    the owned slice, the ring ``place_block_ring`` label commit, and ONE
    changed-restricted halo value refresh (sparse indices under a
    ``frontier_cap``, dense O(halo_cap) regather otherwise / on
    overflow) — every step bit-identical to ``removal_fixpoint`` on the
    assembled global state.

    Returns ``(core_own, label_own, core_h, label_h, rounds, hi,
    dout_same, max_frontier, n_overflow)``; ``hi``/``dout_same`` are the
    terminating round's OWNED promotion-seeding stats, ``max_frontier``
    the LOCAL running per-round owned drop count (the engine completes
    it with one pmax at batch end), ``n_overflow`` the number of rounds
    whose sparse refresh fell back to the dense regather.
    """
    hcap = session.halo_cap
    d_v = session.layout.n_shards

    def cond(state):
        return state[4]

    def body(state):
        (core_own, label_own, core_h, label_h, _, rounds, hi, dout_same,
         fmax, n_ovf) = state
        mcd, hi, dout_same = G.mcd_hi_dout(
            src_h, dst_h, valid, core_h, label_h, hcap, session,
            backend=kernel_backend,
        )
        drop = (mcd < core_own) & (core_own > 0)
        fmax = jnp.maximum(fmax, session.frontier_peak(drop))
        new_core = core_own - drop.astype(jnp.int32)
        label_own = place_block_ring(
            new_core, label_own, drop, at_head=False, n_levels=n_levels,
            axis=session.axis, n_shards=d_v, note=_note,
        )
        core_h, label_h, ovf = session.refresh_values(
            new_core, label_own, drop, core_h, label_h
        )
        cont = session.any_owned(drop)
        return (new_core, label_own, core_h, label_h, cont, rounds + 1,
                hi, dout_same, fmax, n_ovf + ovf.astype(jnp.int32))

    z = session.zeros()
    (core_own, label_own, core_h, label_h, _, rounds, hi, dout_same,
     fmax, n_ovf) = jax.lax.while_loop(
        cond, body,
        (core_own, label_own, core_h, label_h, jnp.bool_(True),
         jnp.int32(0), z, z, jnp.int32(0), jnp.int32(0)),
    )
    return (core_own, label_own, core_h, label_h, rounds, hi, dout_same,
            fmax, n_ovf)


def weighted_core_fixpoint_pass(
    src: Array,
    dst: Array,
    valid: Array,
    w: Array,
    core: Array,
    n: int,
    layout: VertexLayout | None = None,
    kernel_backend: str = "lax",
) -> Tuple[Array, Array, Array]:
    """Decrease-only weighted h-index fixpoint (Zhou et al., WWW'21):
    per round ``core <- min(core, H_w(core))`` where ``H_w`` is the
    per-vertex weighted h-index bisection (graph_ops.weighted_h_index),
    until no vertex moves. Converges to the exact weighted cores from
    ANY state upper-bounding them — both engine phases use it: removal
    starts from the current cores, promotion from ``core + W`` (W the
    batch's total inserted weight — docs/DESIGN.md §4.5 derives why the
    per-vertex incident bound is NOT sound).

    Labels are FROZEN throughout: the weighted fixpoint has no per-level
    append order to maintain (levels are unbounded in maxW, so the
    bucketed ``place_block`` does not apply); the engine commits ONE
    bucket-free renumber per batch instead. Returns ``(core, rounds,
    max_frontier)``. Replicated/plain layouts only — the halo twin is
    ``weighted_core_fixpoint_pass_halo``."""
    if layout is None:
        layout = ReplicatedVertices(n)

    def cond(state):
        return state[1]

    def body(state):
        core, _, rounds, fmax = state
        h = G.weighted_h_index(src, dst, valid, w, core, core, n,
                               layout, backend=kernel_backend)
        new_core = jnp.minimum(core, h)
        changed = new_core < core
        fmax = jnp.maximum(fmax, layout.frontier_peak(changed))
        return new_core, jnp.any(changed), rounds + 1, fmax

    core, _, rounds, fmax = jax.lax.while_loop(
        cond, body,
        (core, jnp.bool_(True), jnp.int32(0), jnp.int32(0)),
    )
    return core, rounds, fmax


def _weighted_h_index_halo(src_h, dst_h, valid, w, core_own, core_h,
                           session: HaloSession,
                           kernel_backend: str = "lax"):
    """Lockstep owned+halo weighted h-index bisection. ``(lo, hi)`` live
    in BOTH domains: the owned pair is authoritative, the halo pair is
    its exact image (the per-step ``ok`` verdict crosses the mesh as a
    dense int32 ``gather_values`` — bisection masks flip for ~half the
    vertices per step, so the sparse frontier path would overflow every
    step; dense is the right exchange here). Continuation is carried in
    the loop STATE (one ``any_owned`` psum per step) so the while cond
    stays collective-free and every shard runs the same trip count.
    Returns ``(lo_own, lo_halo)`` — the h-index and its halo image."""
    hcap = session.halo_cap
    lo_o = jnp.zeros_like(core_own)
    hi_o = jnp.maximum(core_own, 0)
    lo_h = jnp.zeros_like(core_h)
    hi_h = jnp.maximum(core_h, 0)

    def cond(state):
        return state[4]

    def body(state):
        lo_o, hi_o, lo_h, hi_h, _ = state
        mid_o = (lo_o + hi_o + 1) // 2
        mid_h = (lo_h + hi_h + 1) // 2
        s = G.weighted_support(src_h, dst_h, valid, w, core_h, mid_h,
                               hcap, session, backend=kernel_backend)
        ok_o = s >= mid_o
        ok_h = session.gather_values(ok_o.astype(jnp.int32)) > 0
        lo_o = jnp.where(ok_o, mid_o, lo_o)
        hi_o = jnp.where(ok_o, hi_o, mid_o - 1)
        lo_h = jnp.where(ok_h, mid_h, lo_h)
        hi_h = jnp.where(ok_h, hi_h, mid_h - 1)
        cont = session.any_owned(lo_o < hi_o)
        return lo_o, hi_o, lo_h, hi_h, cont

    cont0 = session.any_owned(lo_o < hi_o)
    lo_o, _, lo_h, _, _ = jax.lax.while_loop(
        cond, body, (lo_o, hi_o, lo_h, hi_h, cont0)
    )
    return lo_o, lo_h


def weighted_core_fixpoint_pass_halo(
    src_h: Array,
    dst_h: Array,
    valid: Array,
    w: Array,
    core_own: Array,
    core_h: Array,
    session: HaloSession,
    kernel_backend: str = "lax",
):
    """``weighted_core_fixpoint_pass`` on a halo working set. The halo
    core image stays current WITHOUT ``refresh_values``: each round's
    commit is ``min`` against the bisection result, whose halo copy
    (``lo_h``) is already the exact image of the owned one — so the halo
    update is the same local ``min`` (sentinel rows hold 0 and stay 0;
    no valid edge references them). Labels are frozen (see the plain
    twin); the engine runs one ring renumber per batch afterwards.
    Returns ``(core_own, core_h, rounds, max_frontier)`` with
    ``max_frontier`` the LOCAL running per-round owned change count
    (completed by the engine's batch-end pmax)."""

    def cond(state):
        return state[2]

    def body(state):
        core_own, core_h, _, rounds, fmax = state
        lo_o, lo_h = _weighted_h_index_halo(
            src_h, dst_h, valid, w, core_own, core_h, session,
            kernel_backend=kernel_backend,
        )
        new_o = jnp.minimum(core_own, lo_o)
        new_h = jnp.minimum(core_h, lo_h)
        changed = new_o < core_own
        fmax = jnp.maximum(fmax, session.frontier_peak(changed))
        cont = session.any_owned(changed)
        return new_o, new_h, cont, rounds + 1, fmax

    core_own, core_h, _, rounds, fmax = jax.lax.while_loop(
        cond, body,
        (core_own, core_h, jnp.bool_(True), jnp.int32(0), jnp.int32(0)),
    )
    return core_own, core_h, rounds, fmax


@partial(jax.jit, static_argnames=("n", "n_levels"))
def remove_batch(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    slots: Array,
    n: int,
    n_levels: int,
) -> Tuple[Array, Array, Array, RemoveStats]:
    """Remove the edges in ``slots`` (int32, -1 entries are padding) and
    restore core numbers + k-order labels.

    Returns (valid, core, label, stats).
    """
    ok = slots >= 0
    safe = jnp.where(ok, slots, 0)
    # commutative scatter-max: padding entries (ok=False) are no-ops even
    # when they collide with a real removal of slot 0
    rm = jnp.zeros(valid.shape[0], dtype=bool).at[safe].max(ok)
    valid = valid & ~rm

    core0 = core
    core, label, rounds, _, _, fmax = removal_fixpoint(
        src, dst, valid, core, label, n, n_levels, share_stats=False
    )
    stats = RemoveStats(
        rounds=rounds, n_dropped=jnp.sum(core != core0, dtype=jnp.int32),
        max_frontier=fmax,
    )
    return valid, core, label, stats
