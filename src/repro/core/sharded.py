"""Multi-device core maintenance via shard_map (beyond-paper scaling).

The paper targets one shared-memory node; here the edge slots are sharded
across the mesh's ``data`` axis (vertex state is replicated — it is the
small side: n << m for the paper's graphs and batches). Every neighborhood
statistic becomes  local segment_sum over the device's edge shard + one
``psum``. The fixpoint loops are unchanged — bulk-synchronous rounds are
mesh-agnostic, which is exactly why the reformulation scales to pods.

For 1000+-node deployments the vertex state would be range-sharded too
(psum -> reduce_scatter over vertex ranges + all_gather of the frontier
bitmask); that variant is exercised by the dry-run configs in
launch/dryrun.py (arch `coremaint`).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map

Array = jax.Array


def _seg_psum(data: Array, ids: Array, n: int, axis: str) -> Array:
    out = jax.ops.segment_sum(data, ids, num_segments=n)
    return jax.lax.psum(out, axis)


def _count_ge_sharded(src, dst, valid, vals, n, axis):
    to_src = (valid & (vals[dst] >= vals[src])).astype(jnp.int32)
    to_dst = (valid & (vals[src] >= vals[dst])).astype(jnp.int32)
    return _seg_psum(to_src, src, n, axis) + _seg_psum(to_dst, dst, n, axis)


def make_sharded_remove(mesh: Mesh, n: int, axis: str = "data"):
    """Build a jitted sharded removal fixpoint over ``mesh``.

    Edge arrays must be sharded along ``axis``; core is replicated.
    Removal slots are pre-applied by the caller (valid already updated).
    """

    def _kernel(src, dst, valid, core):
        def cond(state):
            return state[1]

        def body(state):
            core, _ = state
            mcd = _count_ge_sharded(src, dst, valid, core, n, axis)
            drop = (mcd < core) & (core > 0)
            return core - drop.astype(jnp.int32), jnp.any(drop)

        core, _ = jax.lax.while_loop(cond, body, (core, jnp.bool_(True)))
        return core

    shardmapped = shard_map(
        _kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    return jax.jit(shardmapped)


def make_sharded_insert_round(mesh: Mesh, n: int, axis: str = "data"):
    """One promotion round (seed -> forward -> evict) as a sharded kernel.

    The caller loops rounds until ``n_promoted == 0`` (host loop keeps the
    per-round HLO small; each round is fully collective-parallel).
    Returns (new_core, promoted_mask).
    """

    def _kernel(src, dst, valid, core, label, seed):
        def count_gt(vals):
            a = (valid & (vals[dst] > vals[src])).astype(jnp.int32)
            b = (valid & (vals[src] > vals[dst])).astype(jnp.int32)
            return _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)

        same = valid & (core[src] == core[dst])
        hi = count_gt(core)
        a = (same & (label[dst] > label[src])).astype(jnp.int32)
        b = (same & (label[src] > label[dst])).astype(jnp.int32)
        dout_same = _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)

        def fwd_cond(state):
            return state[2]

        def fwd_body(state):
            reach, passing, _ = state
            rp = reach & passing
            a = (same & (label[dst] < label[src]) & rp[dst]).astype(jnp.int32)
            b = (same & (label[src] < label[dst]) & rp[src]).astype(jnp.int32)
            din = _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)
            new_passing = (hi + dout_same + din) > core
            gd = (same & rp[src] & (label[src] < label[dst])).astype(jnp.int32)
            gs = (same & rp[dst] & (label[dst] < label[src])).astype(jnp.int32)
            grow = (_seg_psum(gd, dst, n, axis) + _seg_psum(gs, src, n, axis)) > 0
            new_reach = reach | grow
            changed = jnp.any(new_reach != reach) | jnp.any(
                new_passing != passing
            )
            return new_reach, new_passing, changed

        init_pass = (hi + dout_same) > core
        reach, passing, _ = jax.lax.while_loop(
            fwd_cond, fwd_body, (seed, init_pass, jnp.bool_(True))
        )

        def ev_cond(state):
            return state[1]

        def ev_body(state):
            cand, _ = state
            a = (same & cand[dst]).astype(jnp.int32)
            b = (same & cand[src]).astype(jnp.int32)
            sup = hi + _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)
            new_cand = cand & (sup > core)
            return new_cand, jnp.any(new_cand != cand)

        cand, _ = jax.lax.while_loop(
            ev_cond, ev_body, (reach & passing, jnp.bool_(True))
        )
        return core + cand.astype(jnp.int32), cand

    shardmapped = shard_map(
        _kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(shardmapped)


def shard_edges(mesh: Mesh, axis: str, *arrays) -> Tuple[Array, ...]:
    """Place COO slot arrays with the edge dimension sharded on ``axis``."""
    sharding = NamedSharding(mesh, P(axis))
    return tuple(jax.device_put(a, sharding) for a in arrays)
