"""Multi-device core maintenance via shard_map (beyond-paper scaling).

The paper targets one shared-memory node; here the edge slots are sharded
across the mesh's ``data`` axis, and the VERTEX state's home is a
pluggable layout (core/vertex_layout.py): replicated by default (the
small side: n << m for the paper's graphs and batches — every
neighborhood statistic becomes a local segment_sum over the device's
edge shard + one ``psum``) or range-sharded for wide meshes
(``vertex_sharding="range"``: one ``reduce_scatter`` per statistic +
bit-packed frontier masks, docs/DESIGN.md §4.2). The fixpoint loops are
unchanged — bulk-synchronous rounds are mesh-agnostic, which is exactly
why the reformulation scales to pods.

``make_sharded_apply`` is the full order-based maintenance engine behind
``CoreMaintainer(engine="sharded")``: the exact ``engine.apply_batch``
program (dedup, slot lookup, free-list slot recycling, removal fixpoint,
promotion rounds, place_block label assignment, renumber gate) with the
slot table sharded across the mesh and every per-vertex statistic
completed by one psum (docs/DESIGN.md §4). It wraps
``engine.batch_program`` — the unified engine's program body, not a copy
— in a ``shard_map``, with the body's ``axis`` parameter (threaded down
into the remove.py / insert.py fixpoints) supplying the psums, so
unified and sharded engines cannot drift algorithmically. Per-batch
work is bounded by the per-shard high-water window (``local_active``),
sliced locally inside the kernel so the sharded placement never moves.

The older core-only kernels (``make_sharded_remove`` /
``make_sharded_insert_round``) are kept as minimal building blocks for
experiments that maintain core numbers without k-order labels.

For 1000+-node deployments the replicated-vertex assumption breaks; that
is what the halo-sharded layouts are for (core/vertex_layout.py —
``HaloShardedVertices``): the vertex state itself is range-sharded over
the owner axis, each edge shard keeps only a bounded HALO of the
vertices its windowed slot prefix references (no [n] working copy on
any device — per-device memory is O(n / d_v + halo)), every fixpoint
statistic completes with one bounded halo-stats gather + owner scatter
(+ one pure-edge-axis psum on a 2-axis mesh), and only changed-vertex
halo refreshes cross the mesh per round — compacted frontier INDICES in
a fixed ``frontier_cap`` bucket under ``frontier_exchange="sparse"``
(§4.3), with a per-round dense O(halo) regather fallback on overflow.
``vertex_sharding="range"`` is the 1-axis (shared-axis) degenerate;
``vertex_sharding="halo"`` runs on a genuine 2-axis edge x vertex mesh
(``launch/mesh.py::make_edge_vertex_mesh``, docs/DESIGN.md §4.4).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .engine import (DONATED_STATE_ARGS, WEIGHTED_DONATED_STATE_ARGS,
                     batch_program, batch_program_halo)
from .vertex_layout import make_layout

Array = jax.Array


def make_sharded_apply(mesh: Mesh, n: int, n_levels: int,
                       axis: str = "data",
                       local_active: int | None = None,
                       vertex_sharding: str = "replicated",
                       freelist: str = "interleaved",
                       frontier_exchange: str = "bitmask",
                       frontier_cap: int = 0,
                       kernel_backend: str = "lax",
                       weighted: bool = False):
    """Build the jitted sharded mixed-batch engine over ``mesh``.

    The returned function has the same signature and semantics as
    ``engine.apply_batch`` minus the ``n``/``n_levels``/``active_cap``
    statics: ``(src, dst, valid, core, label, n_edges, ins_u, ins_v,
    ins_ok, rm_u, rm_v, rm_ok) -> (src, dst, valid, core, label, n_edges,
    stats)``. ``src``/``dst``/``valid`` must be sharded along ``axis``
    (capacity divisible by the axis size); everything else is replicated —
    except ``core``/``label`` under ``vertex_sharding="range"``, which
    are range-sharded along the same axis (padded to a shard multiple,
    api.py owns the padding).

    ``vertex_sharding`` selects the vertex layout (vertex_layout.py):

    * ``"replicated"`` — every device keeps full [n] vertex state; each
      statistic costs one psum (O(n) received per device per round);
    * ``"range"`` — device ``i`` OWNS vertex range ``i`` on the SHARED
      single mesh axis, and beyond its owned slice keeps only a bounded
      HALO of the vertices its windowed slot prefix references
      (``engine.build_halo_ids`` — no [n] working copy anywhere, no
      entry state gather): statistics complete with one bounded
      halo-stats gather + local owner scatter, decisions run on owned
      slices, labels place via the ring ``order.place_block_ring``, and
      per-round traffic is changed-restricted halo refreshes. Integer
      arithmetic end to end, so the result is BIT-identical to every
      other engine.
    * ``"halo"`` — the same halo machinery on a genuine 2-axis mesh
      (``mesh`` must carry one pure-edge axis plus the owner ``axis``;
      ``launch/mesh.py::make_edge_vertex_mesh(mesh_shape=(d_e, d_v))``):
      edge slots shard over BOTH axes, vertex ranges over the owner
      axis only, and completed statistics gain one psum over the
      pure-edge axis (the d_e term of docs/DESIGN.md §4.4). Per-device
      vertex memory is O(n / d_v + halo).

    ``freelist`` picks the slot-allocator ranking (``"interleaved"`` |
    ``"hierarchical"`` — `insert.freelist_alloc`).

    ``frontier_exchange`` picks how the per-round changed-vertex halo
    refreshes cross the owner axis under range/halo sharding:
    ``"bitmask"`` (historical name — now the DENSE halo regather, one
    O(halo_cap) reduce_scatter per refresh) or ``"sparse"`` (the §4.3
    compacted-index exchange: ``frontier_cap`` global indices per
    shard, count-prefixed and sentinel-padded, O(cap * d_v) words per
    round with a per-round lax.cond falling back to the dense regather
    when any shard's frontier overflows the cap — bit-identical either
    way). ``frontier_cap`` is STATIC: one jitted engine per cap bucket,
    like ``local_active`` (api.py plans the pow2 bucket).

    ``kernel_backend`` picks the per-round statistics implementation
    (``"lax"`` segment_sum scatters or the ``"pallas"`` fused COO kernel,
    kernels/coremaint.py). Inside the shard_map kernel the pallas path
    replaces only the LOCAL partial-statistic computation — the layout
    completion collectives are identical — so the mesh collective
    schedule (and the committed budget manifests) are shared with lax.

    ``weighted`` builds the weight-generalized engine instead: the slot
    table carries a fourth sharded column ``w`` (per-slot edge weight,
    riding the same espec/donation treatment as ``src``/``dst``/
    ``valid``), the batch gains a replicated ``ins_w`` lane array, and
    both maintenance phases run the weighted h-index bisection fixpoint
    (core/remove.py::weighted_core_fixpoint_pass and its halo twin) —
    the weighted partial sums complete through the SAME layout
    collectives as the unit-count statistics, so no new collective
    primitives appear. The returned function's signature becomes
    ``(src, dst, valid, w, core, label, n_edges, ins_u, ins_v, ins_w,
    ins_ok, rm_u, rm_v, rm_ok) -> (src, dst, valid, w, core, label,
    n_edges, stats)``. With ``weighted=False`` (the default) no weight
    array is threaded anywhere, so the traced program — and the
    committed collective/budget manifests — stay byte-identical to the
    pre-weighted engine.

    ``local_active`` is the per-shard high-water window — the sharded
    analogue of the unified engine's ``active_cap``. Slicing a SHARDED
    buffer would force a reshard, so the slice happens INSIDE the
    shard_map kernel on each device's local (already materialized) shard:
    every edge pass runs over ``local_active`` slots per device instead
    of ``capacity / n_devices``, bounding per-batch work by the densest
    shard's live prefix. The host sizes it from the pow2 bucket of
    ``stats.high_water`` (api.py), so live slots — and the free slots the
    allocator needs — always sit inside the window, and the local tail
    past it stays all-invalid. ``None`` runs the full shard (no slicing).

    Division of labor inside the kernel (docs/DESIGN.md §4):

    * slot lookup — each device searches its LOCAL sorted shard; an edge
      lives in exactly one shard, so one psum of the found flags yields
      the global membership/removal verdict without materializing a
      global sort;
    * tombstoning — each device masks only its own slots (no cross-device
      slot indices ever exist);
    * slot allocation — ``insert.freelist_alloc``: dead slots are ranked
      globally (interleaved across shards from one all_gather of the
      windowed dead masks, or shard-by-shard from per-shard scalar free
      counts under ``freelist="hierarchical"``); each device writes the
      batch-cumsum ranks that land in its own shard and drops the rest
      via out-of-bounds scatter semantics;
    * fixpoints — the shared removal/promotion loops with ``layout=…``:
      local scatter-adds completed by the vertex layout each round (one
      psum when replicated; one reduce_scatter + bit-packed mask
      gathers when range-sharded), so every device runs the loop in
      lockstep on identical replicated working core/label values;
    * labels/renumber — pure vertex-state computation on those
      replicated working values — no collective.
    """
    all_axes = tuple(mesh.axis_names)
    if axis not in all_axes:
        raise ValueError(
            f"mesh has axes {all_axes}, no vertex/owner axis {axis!r}"
        )
    edge_axes = tuple(a for a in all_axes if a != axis)
    n_shards = dict(mesh.shape)[axis]
    if frontier_exchange not in ("bitmask", "sparse"):
        raise ValueError(
            f"unknown frontier_exchange {frontier_exchange!r} "
            "(expected 'bitmask' or 'sparse')"
        )
    if frontier_exchange == "sparse" and vertex_sharding not in (
            "range", "halo"):
        raise ValueError(
            "frontier_exchange='sparse' needs vertex_sharding='range' "
            "or 'halo' (the replicated layout exchanges no frontier "
            "masks)"
        )
    if frontier_exchange == "sparse" and frontier_cap < 1:
        raise ValueError(
            f"frontier_exchange='sparse' needs frontier_cap >= 1, got "
            f"{frontier_cap}"
        )
    if frontier_exchange != "sparse" and frontier_cap != 0:
        raise ValueError(
            f"frontier_cap={frontier_cap} is only consumed by "
            "frontier_exchange='sparse' — the dense halo exchange would "
            "silently ignore it"
        )
    if vertex_sharding != "halo" and edge_axes:
        raise ValueError(
            f"a multi-axis mesh (axes {all_axes}) needs "
            "vertex_sharding='halo' — the replicated and shared-axis "
            "range layouts complete statistics over ONE axis and would "
            "silently drop the pure-edge partials"
        )
    # None = replicated: batch_program builds its own ReplicatedVertices
    # over the edge axis, and the kernel skips the owned-state plumbing.
    # Anything else resolves (and validates) through the layout factory.
    layout = (
        None if vertex_sharding == "replicated"
        else make_layout(
            vertex_sharding, n, axis, n_shards,
            frontier_cap if frontier_exchange == "sparse" else None,
            edge_axes,
        )
    )
    # table collectives (lookup/membership psums, free-list ranking,
    # high-water pmax) complete over EVERY axis the slots are sharded on
    table_axis = all_axes if len(all_axes) > 1 else axis

    def _check_window(shard_len):
        if local_active is not None and local_active > shard_len:
            # an oversized window (e.g. sized from the GLOBAL high-water
            # mark instead of the per-shard one) would slice past the
            # shard and silently splice a SHORT table back together —
            # refuse loudly instead of corrupting the slot table
            raise ValueError(
                f"local_active={local_active} exceeds the per-shard "
                f"capacity {shard_len} — the window must be sized "
                "from the PER-SHARD high-water mark (capacity / "
                "n_shards at most), not the global slot count"
            )

    def _kernel(src, dst, valid, core, label, n_edges,
                ins_u, ins_v, ins_ok, rm_u, rm_v, rm_ok):
        # the UNIFIED engine's program body, verbatim, over this device's
        # local shard: its axis parameter turns every table reduction and
        # fixpoint statistic into local-scatter + layout completion
        # (engine.py). The per-shard window is a LOCAL slice (cf.
        # engine.apply_batch's active_cap prefix): the all-invalid tail
        # is spliced back on.
        _check_window(src.shape[0])
        w = src.shape[0] if local_active is None else local_active
        full_src, full_dst, full_valid = src, dst, valid
        if layout is None:
            src, dst, valid, core, label, n_edges, stats = batch_program(
                src[:w], dst[:w], valid[:w], core, label, n_edges,
                ins_u, ins_v, ins_ok, rm_u, rm_v, rm_ok,
                n, n_levels, axis=axis, layout=None, freelist=freelist,
                kernel_backend=kernel_backend,
            )
        else:
            # halo program: core/label stay OWNED [n_owned] slices end
            # to end — the edge passes index a bounded halo working set
            # (engine.build_halo_ids) instead of a gathered [n] copy
            src, dst, valid, core, label, n_edges, stats = (
                batch_program_halo(
                    src[:w], dst[:w], valid[:w], core, label, n_edges,
                    ins_u, ins_v, ins_ok, rm_u, rm_v, rm_ok,
                    n, n_levels, table_axis=table_axis, layout=layout,
                    freelist=freelist, kernel_backend=kernel_backend,
                )
            )
        src = jnp.concatenate([src, full_src[w:]])
        dst = jnp.concatenate([dst, full_dst[w:]])
        valid = jnp.concatenate([valid, full_valid[w:]])
        return src, dst, valid, core, label, n_edges, stats

    def _kernel_weighted(src, dst, valid, ew, core, label, n_edges,
                         ins_u, ins_v, ins_w, ins_ok, rm_u, rm_v, rm_ok):
        # weighted twin: the weight column ``ew`` is sliced/spliced in
        # lockstep with the other slot columns and threaded into the
        # shared program body as its ``w=`` argument
        _check_window(src.shape[0])
        win = src.shape[0] if local_active is None else local_active
        full_src, full_dst, full_valid, full_ew = src, dst, valid, ew
        if layout is None:
            src, dst, valid, ew, core, label, n_edges, stats = (
                batch_program(
                    src[:win], dst[:win], valid[:win], core, label,
                    n_edges, ins_u, ins_v, ins_ok, rm_u, rm_v, rm_ok,
                    n, n_levels, axis=axis, layout=None,
                    freelist=freelist, kernel_backend=kernel_backend,
                    w=ew[:win], ins_w=ins_w,
                )
            )
        else:
            src, dst, valid, ew, core, label, n_edges, stats = (
                batch_program_halo(
                    src[:win], dst[:win], valid[:win], core, label,
                    n_edges, ins_u, ins_v, ins_ok, rm_u, rm_v, rm_ok,
                    n, n_levels, table_axis=table_axis, layout=layout,
                    freelist=freelist, kernel_backend=kernel_backend,
                    w=ew[:win], ins_w=ins_w,
                )
            )
        src = jnp.concatenate([src, full_src[win:]])
        dst = jnp.concatenate([dst, full_dst[win:]])
        valid = jnp.concatenate([valid, full_valid[win:]])
        ew = jnp.concatenate([ew, full_ew[win:]])
        return src, dst, valid, ew, core, label, n_edges, stats

    espec = P(all_axes if len(all_axes) > 1 else axis)
    vspec = P() if layout is None else P(axis)
    if weighted:
        shardmapped = shard_map(
            _kernel_weighted,
            mesh=mesh,
            in_specs=(
                espec, espec, espec, espec,       # src, dst, valid, w
                vspec, vspec, P(),                # core, label, n_edges
                P(), P(), P(), P(), P(), P(), P(),  # batch (replicated)
            ),
            out_specs=(espec, espec, espec, espec, vspec, vspec, P(), P()),
            check_vma=False,
        )
        return jax.jit(shardmapped,
                       donate_argnums=WEIGHTED_DONATED_STATE_ARGS)
    shardmapped = shard_map(
        _kernel,
        mesh=mesh,
        in_specs=(
            espec, espec, espec,                # src, dst, valid
            vspec, vspec, P(),                  # core, label, n_edges
            P(), P(), P(), P(), P(), P(),       # batch (replicated)
        ),
        out_specs=(espec, espec, espec, vspec, vspec, P(), P()),
        check_vma=False,
    )
    return jax.jit(shardmapped, donate_argnums=DONATED_STATE_ARGS)


def _seg_psum(data: Array, ids: Array, n: int, axis: str) -> Array:
    out = jax.ops.segment_sum(data, ids, num_segments=n)
    return jax.lax.psum(out, axis)


def _count_ge_sharded(src, dst, valid, vals, n, axis):
    to_src = (valid & (vals[dst] >= vals[src])).astype(jnp.int32)
    to_dst = (valid & (vals[src] >= vals[dst])).astype(jnp.int32)
    return _seg_psum(to_src, src, n, axis) + _seg_psum(to_dst, dst, n, axis)


def make_sharded_remove(mesh: Mesh, n: int, axis: str = "data"):
    """Build a jitted sharded removal fixpoint over ``mesh``.

    Edge arrays must be sharded along ``axis``; core is replicated.
    Removal slots are pre-applied by the caller (valid already updated).
    """

    def _kernel(src, dst, valid, core):
        def cond(state):
            return state[1]

        def body(state):
            core, _ = state
            mcd = _count_ge_sharded(src, dst, valid, core, n, axis)
            drop = (mcd < core) & (core > 0)
            return core - drop.astype(jnp.int32), jnp.any(drop)

        core, _ = jax.lax.while_loop(cond, body, (core, jnp.bool_(True)))
        return core

    shardmapped = shard_map(
        _kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    return jax.jit(shardmapped)


def make_sharded_insert_round(mesh: Mesh, n: int, axis: str = "data"):
    """One promotion round (seed -> forward -> evict) as a sharded kernel.

    The caller loops rounds until ``n_promoted == 0`` (host loop keeps the
    per-round HLO small; each round is fully collective-parallel).
    Returns (new_core, promoted_mask).
    """

    def _kernel(src, dst, valid, core, label, seed):
        def count_gt(vals):
            a = (valid & (vals[dst] > vals[src])).astype(jnp.int32)
            b = (valid & (vals[src] > vals[dst])).astype(jnp.int32)
            return _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)

        same = valid & (core[src] == core[dst])
        hi = count_gt(core)
        a = (same & (label[dst] > label[src])).astype(jnp.int32)
        b = (same & (label[src] > label[dst])).astype(jnp.int32)
        dout_same = _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)

        def fwd_cond(state):
            return state[2]

        def fwd_body(state):
            reach, passing, _ = state
            rp = reach & passing
            a = (same & (label[dst] < label[src]) & rp[dst]).astype(jnp.int32)
            b = (same & (label[src] < label[dst]) & rp[src]).astype(jnp.int32)
            din = _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)
            new_passing = (hi + dout_same + din) > core
            gd = (same & rp[src] & (label[src] < label[dst])).astype(jnp.int32)
            gs = (same & rp[dst] & (label[dst] < label[src])).astype(jnp.int32)
            grow = (_seg_psum(gd, dst, n, axis) + _seg_psum(gs, src, n, axis)) > 0
            new_reach = reach | grow
            changed = jnp.any(new_reach != reach) | jnp.any(
                new_passing != passing
            )
            return new_reach, new_passing, changed

        init_pass = (hi + dout_same) > core
        reach, passing, _ = jax.lax.while_loop(
            fwd_cond, fwd_body, (seed, init_pass, jnp.bool_(True))
        )

        def ev_cond(state):
            return state[1]

        def ev_body(state):
            cand, _ = state
            a = (same & cand[dst]).astype(jnp.int32)
            b = (same & cand[src]).astype(jnp.int32)
            sup = hi + _seg_psum(a, src, n, axis) + _seg_psum(b, dst, n, axis)
            new_cand = cand & (sup > core)
            return new_cand, jnp.any(new_cand != cand)

        cand, _ = jax.lax.while_loop(
            ev_cond, ev_body, (reach & passing, jnp.bool_(True))
        )
        return core + cand.astype(jnp.int32), cand

    shardmapped = shard_map(
        _kernel,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(shardmapped)


def shard_edges(mesh: Mesh, axis, *arrays) -> Tuple[Array, ...]:
    """Place COO slot arrays with the edge dimension sharded on ``axis``
    (one mesh axis name, or a tuple of axis names on a 2-axis mesh)."""
    sharding = NamedSharding(mesh, P(axis))
    return tuple(jax.device_put(a, sharding) for a in arrays)
