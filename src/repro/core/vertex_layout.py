"""Pluggable vertex-state layout — who holds each per-vertex statistic.

Every maintenance round is "edge pass -> per-vertex decision -> commit".
The edge pass produces PARTIAL per-vertex statistics (each device scatters
only its own edge shard); the layout decides how those partials are
completed and where the per-vertex decision runs:

* ``ReplicatedVertices`` — every device keeps the full ``[n]`` vertex
  state and partial stats complete with one ``psum`` over the edge axis
  (``axis=None`` degenerates to the single-device identity). This is the
  original sharded-engine layout: per-round cross-device vertex traffic
  is O(n * n_devices) words delivered (every device receives every
  completed statistic).

* ``HaloShardedVertices`` — device at owner-axis coordinate ``i`` OWNS
  the contiguous vertex range ``[i * n_owned, (i+1) * n_owned)`` and
  keeps beyond it only a HALO working set: the vertices its local edge
  window and batch lanes actually reference, in a static pow2-capped
  buffer (the paper's Fig. 5 locality — the per-shard referenced set —
  is what bounds it). No device ever materializes an ``[n]`` vertex
  array: per-device memory is O(n / d_v + halo_cap), and the per-batch
  entry state gather of the PR-7 range engine (and the waiver
  that excused it) is gone. Per round the traffic is ONE
  bounded all_gather of halo-domain partial stats (O(d_v * halo_cap)
  words, completed by a local owner scatter-add plus — on a 2-axis
  mesh — one psum over the pure-edge axes), and halo refreshes
  restricted to the round's CHANGED owners: sparse compacted-index
  exchanges of O(frontier_cap * d_v) words (docs/DESIGN.md §4.3) with
  a per-round ``lax.cond`` falling back to a dense halo regather (a
  reduce_scatter of O(halo_cap) words — never a bitmask, never an
  ``[n]`` buffer) whenever any shard's frontier overflows the cap —
  results stay BIT-identical in every regime; the cap is a bandwidth
  knob, never a correctness knob. Decisions run on owned slices;
  labels place via the ring ``order.place_block_ring`` (O(n_owned)
  buffers, same labels). The ``vertex_sharding="range"`` engines are
  the ``edge_axes=()`` degenerate of the same machinery.

All arithmetic is integer, reduce_scatter is an exact sum, and the
refreshed halos are exact images of the owned state — which is why the
halo-sharded engines stay BIT-identical (cores AND k-order labels) to
the replicated ones (``tests/test_churn_streams.py``).

The 2-axis factorization (edge shards x vertex ranges on distinct mesh
axes, ``launch/mesh.py::make_edge_vertex_mesh``) plugs in via
``edge_axes``: stats gain one psum over the pure-edge axes after the
owner scatter (the d_e term of the §4.4 cost model); every other
collective runs over the owner axis only.

Traffic accounting
------------------
``record_traffic()`` captures, at TRACE time, one record per collective
a layout method issues, with the payload each device RECEIVES (computed
from static shapes). ``lax.while_loop`` bodies trace exactly once, so a
recorded fixpoint yields the PER-ROUND collective budget — the object
the acceptance tests assert O(n + frontier-bits * d) on, without running
a single batch. Both arms of the sparse exchange's ``lax.cond`` trace,
so their records carry a ``branch`` tag ("overflow" marks collectives
that only move on the fallback arm); filtering it out yields the
non-overflow round budget the tests pin at O(cap * d) words.
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class Traffic:
    """One collective issued by a layout method (trace-time record)."""

    op: str          # "psum" | "reduce_scatter" | "gather_mask" | ...
    recv_bytes: int  # payload each participating device receives
    branch: str = ""  # "" = unconditional; "overflow" = only moves on
    #                   the sparse exchange's lax.cond fallback arm


# ``Traffic.branch`` value for each arm of the sparse exchange's
# ``lax.cond(overflow, from_bitmask, from_indices, _)``, indexed by the
# traced branch position: JAX stores cond branches as (false, true), so
# branches[0] is the non-overflow index path ("") and branches[1] the
# bitmask fallback ("overflow"). The static auditor (repro.analysis)
# uses this to line jaxpr cond-branch attribution up with the
# trace-time records below.
SPARSE_COND_BRANCHES = ("", "overflow")

_LOG: Optional[List[Traffic]] = None
_OWNER: Optional[int] = None  # thread that opened the active session
# the lock makes session entry/exit and appends atomic, so a second
# session — nested OR from another thread — fails loudly instead of
# silently stealing/corrupting the active log; the owner-thread filter
# in _note keeps a stray trace on another thread out of the session's
# records, and the branch tag is thread-local for the same reason
_LOCK = threading.Lock()
_TLS = threading.local()


@contextmanager
def record_traffic() -> Iterator[List[Traffic]]:
    """Capture the collectives issued while tracing under this context.

    Only one session may be active at a time: a nested (or concurrent)
    entry raises ``RuntimeError`` — a silently-accepted inner context
    would steal the outer one's records (every collective of the inner
    trace would land in the wrong list). Trace one program per context.
    The active context's log survives a rejected entry intact, and only
    the opening thread's traces are recorded into it.
    """
    global _LOG, _OWNER
    with _LOCK:
        if _LOG is not None:
            raise RuntimeError(
                "record_traffic() does not nest (and allows one session "
                "at a time): the second context would steal the active "
                "one's records — trace one program per context"
            )
        _LOG = log = []
        _OWNER = threading.get_ident()
    try:
        yield log
    finally:
        with _LOCK:
            _LOG = None
            _OWNER = None


@contextmanager
def _cond_branch(name: str) -> Iterator[None]:
    """Tag the records noted while tracing one arm of a ``lax.cond``
    (both arms trace exactly once, at cond-construction time).
    Thread-local, so another thread's trace cannot mislabel records."""
    prev = getattr(_TLS, "branch", "")
    _TLS.branch = name
    try:
        yield
    finally:
        _TLS.branch = prev


def _note(op: str, recv_bytes: int) -> None:
    with _LOCK:
        if _LOG is not None and _OWNER == threading.get_ident():
            _LOG.append(
                Traffic(op, int(recv_bytes), getattr(_TLS, "branch", ""))
            )


def _nbytes(x: Array) -> int:
    return int(x.size) * x.dtype.itemsize


@dataclasses.dataclass(frozen=True)
class ReplicatedVertices:
    """Full ``[n]`` vertex state on every device; stats complete by psum
    over the edge axis (identity when ``axis`` is None)."""

    n: int
    axis: Optional[str] = None
    kind: str = dataclasses.field(default="replicated", init=False)

    @property
    def n_owned(self) -> int:
        return self.n

    def complete(self, stats: Array) -> Array:
        """Partial per-vertex stats -> completed stats, full ``[n, ...]``."""
        if self.axis is None:
            return stats
        _note("psum", _nbytes(stats))
        return jax.lax.psum(stats, self.axis)

    def own(self, full: Array) -> Array:
        return full

    def gather_state(self, owned: Array) -> Array:
        return owned

    def gather_mask(self, owned_mask: Array) -> Array:
        return owned_mask

    def any_owned(self, owned_mask: Array) -> Array:
        return jnp.any(owned_mask)

    def frontier_peak(self, full_mask: Array) -> Array:
        """Frontier size of one exchanged mask — with one (replicated)
        shard that is simply the popcount. Local compute, no collective;
        the engines carry the running max through their fixpoints so
        ``stats.max_frontier`` can tune the sparse-cap planner from
        observed data (docs/DESIGN.md §4.3)."""
        return jnp.sum(full_mask, dtype=jnp.int32)

    def zeros(self, dtype=jnp.int32) -> Array:
        return jnp.zeros(self.n, dtype=dtype)

    def add_at(self, owned: Array, idx: Array, vals: Array) -> Array:
        return owned.at[idx].add(vals)


@dataclasses.dataclass(frozen=True)
class HaloShardedVertices:
    """Device at owner-axis coordinate ``i`` owns vertices
    ``[i * n_owned, (i+1) * n_owned)`` and keeps, beyond that owned
    slice, only a HALO: the ``halo_ids`` its local edge window and
    batch lanes actually reference, bucketed into a static pow2 cap
    sized at trace time so overflow is structurally impossible. No
    device ever materializes an [n] vertex buffer — per-device memory
    is O(n / n_shards + halo_cap) (docs/DESIGN.md §4.4); the PR-7 entry
    state gather (and the waiver that excused it) no longer exists.

    ``axis`` is the owner (vertex-range) mesh axis; ``edge_axes`` names
    the PURE-edge mesh axes of a 2-axis factorization
    (``launch/mesh.py::make_edge_vertex_mesh``). With ``edge_axes=()``
    the layout runs on the classic shared single axis — this is what
    ``vertex_sharding="range"`` now builds, so the 1-axis range engines
    share every line of the halo machinery. With ``edge_axes=("edge",)``
    statistics gain one psum over the pure-edge axis (the ``d_e`` term
    of the §4.4 traffic model) after the owner scatter.

    ``n`` pads up to ``n_pad = n_owned * n_shards``; phantom vertices
    past ``n`` hold zeros and are never referenced by an edge or a
    batch lane, so they can never enter a halo or a mask.

    ``frontier_cap`` (static, ``None`` = dense) switches the per-round
    halo refreshes to the sparse compacted-index exchange of
    docs/DESIGN.md §4.3 — O(cap * d_v) words — with a per-round
    ``lax.cond`` falling back to the DENSE halo regather (a
    reduce_scatter of O(halo_cap) words, never a bitmask or an [n]
    buffer) whenever any shard's frontier overflows the cap.
    Bit-identical either way: the cap is a bandwidth knob only.

    The frozen dataclass is the static configuration; ``bind(halo_ids)``
    opens the per-batch :class:`HaloSession` holding the traced halo
    arrays every fixpoint talks to.
    """

    n: int
    axis: str
    n_shards: int
    frontier_cap: Optional[int] = None
    edge_axes: tuple = ()
    kind: str = dataclasses.field(default="halo", init=False)

    @property
    def n_owned(self) -> int:
        return -(-self.n // self.n_shards)

    @property
    def n_pad(self) -> int:
        return self.n_owned * self.n_shards

    def _offset(self) -> Array:
        return jax.lax.axis_index(self.axis) * self.n_owned

    def zeros(self, dtype=jnp.int32) -> Array:
        return jnp.zeros(self.n_owned, dtype=dtype)

    def add_at(self, owned: Array, idx: Array, vals: Array) -> Array:
        """Scatter-add replicated batch contributions into the owned
        slice; rows owned by other devices fall off the end and drop
        (the same OOB trick as the sharded table writes)."""
        loc = idx - self._offset()
        safe = jnp.where((loc >= 0) & (loc < self.n_owned), loc,
                         self.n_owned)
        return owned.at[safe].add(vals, mode="drop")

    def bind(self, halo_ids: Array) -> "HaloSession":
        """Open the per-batch session over ``halo_ids`` (sorted unique
        global ids, ``n_pad``-sentinel padded to the static halo cap).
        ONE all_gather publishes every shard's halo membership for the
        batch — the table the owner-side scatter/regather collectives
        are driven by all rounds long."""
        _note("gather_halo",
              self.n_shards * int(halo_ids.shape[0])
              * halo_ids.dtype.itemsize)
        ids_all = jax.lax.all_gather(halo_ids, self.axis)
        return HaloSession(self, halo_ids, ids_all)


class HaloSession:
    """One batch's halo working set: the traced companion of
    :class:`HaloShardedVertices`.

    ``halo_ids`` is this device's sorted-unique halo membership
    ``[halo_cap]`` (global ids, ``n_pad`` sentinels past the live
    prefix); ``ids_all`` is the ``[n_shards, halo_cap]`` gathered
    membership of the whole owner axis, cached once per batch. Every
    method speaks one of two domains: OWNED ``[n_owned]`` slices (where
    decisions run) and HALO ``[halo_cap]`` arrays (what edge passes
    index). Nothing here is O(n).
    """

    def __init__(self, layout: HaloShardedVertices, halo_ids: Array,
                 ids_all: Array) -> None:
        self.layout = layout
        self.halo_ids = halo_ids
        self.ids_all = ids_all
        self.halo_cap = int(halo_ids.shape[0])

    # -- delegated owned-range geometry --------------------------------
    @property
    def n_owned(self) -> int:
        return self.layout.n_owned

    @property
    def n_pad(self) -> int:
        return self.layout.n_pad

    @property
    def axis(self) -> str:
        return self.layout.axis

    @property
    def frontier_cap(self) -> Optional[int]:
        return self.layout.frontier_cap

    def zeros(self, dtype=jnp.int32) -> Array:
        return self.layout.zeros(dtype)

    def add_at(self, owned: Array, idx: Array, vals: Array) -> Array:
        return self.layout.add_at(owned, idx, vals)

    # -- id <-> halo-position mapping ----------------------------------
    def locate(self, ids: Array) -> Array:
        """Halo position of each global id. Exact for every id the
        batch can reference (window endpoints and batch lanes are in
        the halo by construction); clamped garbage positions for
        anything else, which is safe because every statistic predicate
        is gated by the edge ``valid`` mask."""
        pos = jnp.searchsorted(self.halo_ids, ids.astype(jnp.int32))
        return jnp.clip(pos, 0, self.halo_cap - 1).astype(jnp.int32)

    def _owner_rows(self):
        """(safe_local_row, mine) over ``ids_all``: which gathered halo
        slots fall in MY owned range, and where."""
        loc = self.ids_all - self.layout._offset()
        mine = (loc >= 0) & (loc < self.n_owned)
        return jnp.where(mine, loc, 0), mine

    # -- owner values -> halo (the bounded entry/fallback regather) ----
    def gather_values(self, owned: Array) -> Array:
        """Owned values -> this device's halo values ``[halo_cap]`` via
        ONE reduce_scatter over the owner axis: each shard contributes
        the rows of ``ids_all`` it owns (every id has exactly one
        owner), and the scatter hands each device its own halo row —
        O(halo_cap) received, independent of n. This replaces the
        deleted O(n) entry state gather."""
        safe, mine = self._owner_rows()
        contrib = jnp.where(mine, owned[safe], jnp.zeros((), owned.dtype))
        _note("regather", self.halo_cap * owned.dtype.itemsize)
        return jax.lax.psum_scatter(
            contrib, self.axis, scatter_dimension=0, tiled=False
        )

    # -- halo stat partials -> owned completed stats -------------------
    def complete(self, stats: Array) -> Array:
        """Halo-domain partial stats ``[halo_cap, ...]`` -> exact OWNED
        stats ``[n_owned, ...]``: one all_gather over the owner axis
        (bounded: O(d_v * halo_cap) words), a local owner scatter-add,
        then — on a 2-axis mesh — one psum over the pure-edge axes (the
        ``d_e`` term of the §4.4 cost model)."""
        _note("gather_stats", self.layout.n_shards * _nbytes(stats))
        g = jax.lax.all_gather(stats, self.axis)  # [d_v, halo_cap, ...]
        safe, mine = self._owner_rows()
        tgt = jnp.where(mine, safe, self.n_owned).reshape(-1)
        own = jnp.zeros((self.n_owned,) + stats.shape[1:], stats.dtype)
        own = own.at[tgt].add(
            g.reshape((-1,) + stats.shape[1:]), mode="drop"
        )
        if self.layout.edge_axes:
            _note("psum_edge", _nbytes(own))
            own = jax.lax.psum(own, self.layout.edge_axes)
        return own

    # -- per-round halo refreshes --------------------------------------
    def _sparse_payload(self, owned_mask: Array):
        """Count-prefixed compacted global indices of the owned changed
        set (the §4.3 wire format) plus the compaction positions."""
        cap = self.frontier_cap
        count = jnp.sum(owned_mask, dtype=jnp.int32)
        pos = jnp.cumsum(owned_mask.astype(jnp.int32)) - 1
        gidx = (self.layout._offset()
                + jnp.arange(self.n_owned, dtype=jnp.int32)).astype(
                    jnp.int32)
        safe = jnp.where(owned_mask & (pos < cap), pos, cap)
        buf = jnp.full((cap,), self.n_pad, dtype=jnp.int32)
        buf = buf.at[safe].set(gidx, mode="drop")
        return jnp.concatenate([count[None], buf]), safe

    def _halo_targets(self, flat_gidx: Array) -> Array:
        """Halo positions of gathered global indices; sentinels (and
        ids outside my halo) park one past the end and drop."""
        pos = self.locate(flat_gidx)
        hit = (self.halo_ids[pos] == flat_gidx) & (flat_gidx < self.n_pad)
        return jnp.where(hit, pos, self.halo_cap)

    def refresh_mask(self, owned_mask: Array):
        """Owned bool mask -> (halo mask ``[halo_cap]``, overflow flag).

        Dense (``frontier_cap`` unset): ONE reduce_scatter of the mask
        values over the owner axis — O(halo_cap) received, no [n] or
        bitmask buffer anywhere. Sparse: the §4.3 compacted-index
        all_gather (O(cap * d_v) words) with a per-round ``lax.cond``
        falling back to the dense regather (branch="overflow") when any
        shard's frontier overflows — bit-identical either way. The
        overflow flag is replicated (it comes off the gathered count
        column), feeding the ``BatchStats.n_overflow`` counter the
        observed-cap planner is tuned from."""
        if self.frontier_cap is None:
            return self._mask_dense(owned_mask), jnp.bool_(False)
        payload, _ = self._sparse_payload(owned_mask)
        cap = self.frontier_cap
        _note("gather_frontier", self.layout.n_shards * (cap + 1) * 4)
        g = jax.lax.all_gather(payload, self.axis)  # [d_v, cap + 1]
        overflow = jnp.max(g[:, 0]) > cap

        def from_indices(_):
            tgt = self._halo_targets(g[:, 1:].reshape(-1))
            mask = jnp.zeros(self.halo_cap, dtype=jnp.bool_)
            return mask.at[tgt].max(True, mode="drop")

        def from_dense(_):
            with _cond_branch("overflow"):
                return self._mask_dense(owned_mask)

        return jax.lax.cond(overflow, from_dense, from_indices,
                            None), overflow

    def _mask_dense(self, owned_mask: Array) -> Array:
        return self.gather_values(owned_mask.astype(jnp.int32)) > 0

    def refresh_values(self, core_own: Array, label_own: Array,
                       changed_own: Array, core_h: Array, label_h: Array):
        """Post-commit halo refresh of (core, label) values, restricted
        to the round's changed owners: sparse mode ships compacted
        (index, core, label) columns (three bounded all_gathers), dense
        mode — and the sparse overflow fallback — regathers the full
        halo values with two reduce_scatters (O(halo_cap), exact).
        Returns ``(core_h, label_h, overflow)``."""
        if self.frontier_cap is None:
            return (self.gather_values(core_own),
                    self.gather_values(label_own), jnp.bool_(False))
        payload, safe = self._sparse_payload(changed_own)
        cap = self.frontier_cap
        cbuf = jnp.zeros((cap,), jnp.int32).at[safe].set(
            core_own, mode="drop")
        lbuf = jnp.zeros((cap,), jnp.int64).at[safe].set(
            label_own, mode="drop")
        d_v = self.layout.n_shards
        _note("gather_frontier", d_v * (cap + 1) * 4)
        g_i = jax.lax.all_gather(payload, self.axis)  # [d_v, cap + 1]
        _note("gather_frontier", d_v * cap * 4)
        g_c = jax.lax.all_gather(cbuf, self.axis)     # [d_v, cap]
        _note("gather_frontier", d_v * cap * 8)
        g_l = jax.lax.all_gather(lbuf, self.axis)     # [d_v, cap]
        overflow = jnp.max(g_i[:, 0]) > cap

        def from_indices(args):
            ch, lh = args
            tgt = self._halo_targets(g_i[:, 1:].reshape(-1))
            ch = ch.at[tgt].set(g_c.reshape(-1), mode="drop")
            lh = lh.at[tgt].set(g_l.reshape(-1), mode="drop")
            return ch, lh

        def from_dense(args):
            with _cond_branch("overflow"):
                return (self.gather_values(core_own),
                        self.gather_values(label_own))

        core_h, label_h = jax.lax.cond(
            overflow, from_dense, from_indices, (core_h, label_h)
        )
        return core_h, label_h, overflow

    # -- scalar completions --------------------------------------------
    def any_owned(self, owned_mask: Array) -> Array:
        """Replicated ``any`` over the disjoint owned slices (scalar
        collective over the owner axis; owned values are replicated
        over any pure-edge axes, so the verdict is mesh-global)."""
        _note("psum_scalar", 4)
        return jax.lax.psum(
            jnp.any(owned_mask).astype(jnp.int32), self.axis
        ) > 0

    def frontier_peak(self, owned_mask: Array) -> Array:
        """LOCAL owned popcount of one refreshed mask — no collective;
        the engines carry the running max through their fixpoints and
        complete it with ONE ``pmax_scalar`` at batch end."""
        return jnp.sum(owned_mask, dtype=jnp.int32)

    def pmax_scalar(self, x: Array) -> Array:
        _note("pmax_scalar", 4)
        return jax.lax.pmax(x, self.axis)


VertexLayout = ReplicatedVertices | HaloShardedVertices


def make_layout(kind: str, n: int, axis: Optional[str],
                n_shards: int = 1,
                frontier_cap: Optional[int] = None,
                edge_axes: tuple = ()) -> VertexLayout:
    """Factory keyed by the public ``vertex_sharding`` name.

    ``"range"`` and ``"halo"`` both build :class:`HaloShardedVertices`
    — the 1-axis range engines are the ``edge_axes=()`` degenerate of
    the 2-axis halo engine, so every engine shares one halo code path
    and none materializes an [n] working copy. ``"halo"`` requires the
    pure-edge axes of a 2-axis mesh. Misconfiguration raises HERE, at
    construction — not as an opaque trace-time error three layers
    down."""
    if kind == "replicated":
        if n_shards != 1:
            raise ValueError(
                f"n_shards={n_shards} is meaningless for the replicated "
                "vertex layout (every device keeps the full state; only "
                "kind='range'/'halo' owns per-shard ranges) — pass "
                "n_shards=1 or use a range-sharded kind"
            )
        if frontier_cap is not None:
            raise ValueError(
                f"frontier_cap={frontier_cap} applies only to "
                "kind='range'/'halo' (the replicated layout exchanges "
                "no frontier masks)"
            )
        if edge_axes:
            raise ValueError(
                "edge_axes apply only to kind='halo' (the replicated "
                "layout completes over the one shared axis)"
            )
        return ReplicatedVertices(n, axis)
    if kind in ("range", "halo"):
        if axis is None:
            raise ValueError("range-sharded vertex state needs a mesh axis")
        if frontier_cap is not None and frontier_cap < 1:
            raise ValueError(
                f"frontier_cap must be >= 1 (or None for the dense halo "
                f"regather), got {frontier_cap}"
            )
        if kind == "range" and edge_axes:
            raise ValueError(
                "vertex_sharding='range' is the shared-axis layout; a "
                "2-axis mesh with pure-edge axes needs "
                "vertex_sharding='halo'"
            )
        if kind == "halo" and not edge_axes:
            raise ValueError(
                "vertex_sharding='halo' needs the 2-axis mesh's "
                "pure-edge axes (make_edge_vertex_mesh); for the "
                "shared-axis layout use vertex_sharding='range'"
            )
        return HaloShardedVertices(n, axis, n_shards, frontier_cap,
                                   tuple(edge_axes))
    raise ValueError(f"unknown vertex layout {kind!r}")
