"""Pluggable vertex-state layout — who holds each per-vertex statistic.

Every maintenance round is "edge pass -> per-vertex decision -> commit".
The edge pass produces PARTIAL per-vertex statistics (each device scatters
only its own edge shard); the layout decides how those partials are
completed and where the per-vertex decision runs:

* ``ReplicatedVertices`` — every device keeps the full ``[n]`` vertex
  state and partial stats complete with one ``psum`` over the edge axis
  (``axis=None`` degenerates to the single-device identity). This is the
  original sharded-engine layout: per-round cross-device vertex traffic
  is O(n * n_devices) words delivered (every device receives every
  completed statistic).

* ``RangeShardedVertices`` — device ``i`` OWNS the contiguous vertex
  range ``[i * n_owned, (i+1) * n_owned)``. Partial stats complete with
  ONE ``psum_scatter`` (reduce_scatter): each device receives only its
  owned slice, O(n) words total across the mesh instead of O(n * d).
  The per-vertex decision (drop mask, passing test, eviction test) runs
  on the owned slice, and only the resulting CHANGED-VERTEX mask —
  bit-packed, 1 bit per vertex — is ``all_gather``ed back so every
  device can apply the identical commit. The Order algorithm's commits
  are deterministic functions of ``(core, label, mask)`` (core moves by
  exactly +-1 on the mask; ``order.place_block`` relabels from the mask),
  so the mask IS the frontier delta: no vertex-sized integer array ever
  crosses the mesh inside a round. Per round the traffic is
  O(n) stat words (reduce_scatter) + O(n * d) mask BITS — the quantity
  the layout tests pin via the accounting below (docs/DESIGN.md §4.2).

All arithmetic is integer, reduce_scatter is an exact sum, and the
gathered masks are bitwise identical on every device — which is why the
range-sharded engine stays BIT-identical (cores AND k-order labels) to
the replicated ones (``tests/test_churn_streams.py``).

A 2-axis factorization (edge shards x vertex ranges on distinct mesh
axes) plugs in by psum-ing partials over the pure-edge axes before the
``psum_scatter`` over the vertex axis; the shipped engine reuses ONE
axis for both (``launch/mesh.py::make_edge_vertex_mesh``), which keeps
every collective single-axis.

Traffic accounting
------------------
``record_traffic()`` captures, at TRACE time, one record per collective
a layout method issues, with the payload each device RECEIVES (computed
from static shapes). ``lax.while_loop`` bodies trace exactly once, so a
recorded fixpoint yields the PER-ROUND collective budget — the object
the acceptance tests assert O(n + frontier-bits * d) on, without running
a single batch.
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class Traffic:
    """One collective issued by a layout method (trace-time record)."""

    op: str          # "psum" | "reduce_scatter" | "gather_mask" | ...
    recv_bytes: int  # payload each participating device receives


_LOG: Optional[List[Traffic]] = None


@contextmanager
def record_traffic() -> Iterator[List[Traffic]]:
    """Capture the collectives issued while tracing under this context.

    Nested use is not supported (the inner context would steal the outer
    one's records); the tests trace one program per context.
    """
    global _LOG
    prev, _LOG = _LOG, []
    try:
        yield _LOG
    finally:
        _LOG = prev


def _note(op: str, recv_bytes: int) -> None:
    if _LOG is not None:
        _LOG.append(Traffic(op, int(recv_bytes)))


def _nbytes(x: Array) -> int:
    return int(x.size) * x.dtype.itemsize


@dataclasses.dataclass(frozen=True)
class ReplicatedVertices:
    """Full ``[n]`` vertex state on every device; stats complete by psum
    over the edge axis (identity when ``axis`` is None)."""

    n: int
    axis: Optional[str] = None
    kind: str = dataclasses.field(default="replicated", init=False)

    @property
    def n_owned(self) -> int:
        return self.n

    def complete(self, stats: Array) -> Array:
        """Partial per-vertex stats -> completed stats, full ``[n, ...]``."""
        if self.axis is None:
            return stats
        _note("psum", _nbytes(stats))
        return jax.lax.psum(stats, self.axis)

    def own(self, full: Array) -> Array:
        return full

    def gather_state(self, owned: Array) -> Array:
        return owned

    def gather_mask(self, owned_mask: Array) -> Array:
        return owned_mask

    def any_owned(self, owned_mask: Array) -> Array:
        return jnp.any(owned_mask)

    def zeros(self, dtype=jnp.int32) -> Array:
        return jnp.zeros(self.n, dtype=dtype)

    def add_at(self, owned: Array, idx: Array, vals: Array) -> Array:
        return owned.at[idx].add(vals)


@dataclasses.dataclass(frozen=True)
class RangeShardedVertices:
    """Device ``i`` owns vertices ``[i * n_owned, (i+1) * n_owned)``.

    ``axis`` is the mesh axis that carries both the edge shards and the
    vertex ranges (shared-axis layout, `launch/mesh.py`). ``n`` is padded
    up to ``n_pad = n_owned * n_shards``; phantom vertices past ``n``
    only ever hold zeros (no edge references them, ``own`` pads with
    zeros, completed stats there are 0), so they can never enter a mask
    or a level computation — everything vertex-global (``place_block``,
    ``renumber``) runs on the exact ``[:n]`` prefix.
    """

    n: int
    axis: str
    n_shards: int
    kind: str = dataclasses.field(default="range", init=False)

    @property
    def n_owned(self) -> int:
        return -(-self.n // self.n_shards)

    @property
    def n_pad(self) -> int:
        return self.n_owned * self.n_shards

    def _offset(self) -> Array:
        return jax.lax.axis_index(self.axis) * self.n_owned

    def _pad(self, full: Array) -> Array:
        pad = self.n_pad - full.shape[0]
        if pad == 0:
            return full
        return jnp.concatenate(
            [full, jnp.zeros((pad,) + full.shape[1:], dtype=full.dtype)]
        )

    def complete(self, stats: Array) -> Array:
        """Partial ``[n, ...]`` stats -> exact OWNED slice ``[n_owned, ...]``
        via one reduce_scatter: each device receives O(n / n_shards) words
        — the whole mesh moves O(n), not O(n * n_shards)."""
        padded = self._pad(stats)
        _note("reduce_scatter",
              _nbytes(padded) // self.n_shards)
        return jax.lax.psum_scatter(
            padded, self.axis, scatter_dimension=0, tiled=True
        )

    def own(self, full: Array) -> Array:
        """Slice a replicated full array down to this device's range (no
        collective — the full copy is already local)."""
        return jax.lax.dynamic_slice_in_dim(
            self._pad(full), self._offset(), self.n_owned
        )

    def gather_state(self, owned: Array) -> Array:
        """Owned slices -> full replicated ``[n]`` array. Used ONCE per
        batch (kernel entry) for ``core``/``label`` — never inside a
        round, where only masks cross the mesh."""
        _note("gather_state", self.n_pad * owned.dtype.itemsize)
        return jax.lax.all_gather(owned, self.axis, tiled=True)[: self.n]

    def gather_mask(self, owned_mask: Array) -> Array:
        """Owned bool mask -> full replicated ``[n]`` mask, BIT-packed on
        the wire: each device receives ``n_shards * ceil(n_owned / 8)``
        bytes — the frontier bitmask exchange of docs/DESIGN.md §4.2."""
        packed = jnp.packbits(owned_mask)  # [ceil(n_owned / 8)] uint8
        _note("gather_mask", self.n_shards * int(packed.shape[0]))
        g = jax.lax.all_gather(packed, self.axis)  # [n_shards, bytes]
        bits = jnp.unpackbits(g, axis=1, count=self.n_owned)
        return bits.reshape(-1)[: self.n].astype(jnp.bool_)

    def any_owned(self, owned_mask: Array) -> Array:
        """Replicated ``any`` over the disjoint owned slices (scalar
        collective)."""
        _note("psum_scalar", 4)
        return jax.lax.psum(
            jnp.any(owned_mask).astype(jnp.int32), self.axis
        ) > 0

    def zeros(self, dtype=jnp.int32) -> Array:
        return jnp.zeros(self.n_owned, dtype=dtype)

    def add_at(self, owned: Array, idx: Array, vals: Array) -> Array:
        """Scatter-add replicated batch contributions into the owned
        slice; rows owned by other devices fall off the end and drop
        (the same OOB trick as the sharded table writes)."""
        loc = idx - self._offset()
        safe = jnp.where((loc >= 0) & (loc < self.n_owned), loc,
                         self.n_owned)
        return owned.at[safe].add(vals, mode="drop")


VertexLayout = ReplicatedVertices | RangeShardedVertices


def make_layout(kind: str, n: int, axis: Optional[str],
                n_shards: int = 1) -> VertexLayout:
    """Factory keyed by the public ``vertex_sharding`` name."""
    if kind == "replicated":
        return ReplicatedVertices(n, axis)
    if kind == "range":
        if axis is None:
            raise ValueError("range-sharded vertex state needs a mesh axis")
        return RangeShardedVertices(n, axis, n_shards)
    raise ValueError(f"unknown vertex layout {kind!r}")
