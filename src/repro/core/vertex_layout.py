"""Pluggable vertex-state layout — who holds each per-vertex statistic.

Every maintenance round is "edge pass -> per-vertex decision -> commit".
The edge pass produces PARTIAL per-vertex statistics (each device scatters
only its own edge shard); the layout decides how those partials are
completed and where the per-vertex decision runs:

* ``ReplicatedVertices`` — every device keeps the full ``[n]`` vertex
  state and partial stats complete with one ``psum`` over the edge axis
  (``axis=None`` degenerates to the single-device identity). This is the
  original sharded-engine layout: per-round cross-device vertex traffic
  is O(n * n_devices) words delivered (every device receives every
  completed statistic).

* ``RangeShardedVertices`` — device ``i`` OWNS the contiguous vertex
  range ``[i * n_owned, (i+1) * n_owned)``. Partial stats complete with
  ONE ``psum_scatter`` (reduce_scatter): each device receives only its
  owned slice, O(n) words total across the mesh instead of O(n * d).
  The per-vertex decision (drop mask, passing test, eviction test) runs
  on the owned slice, and only the resulting CHANGED-VERTEX mask —
  bit-packed, 1 bit per vertex — is ``all_gather``ed back so every
  device can apply the identical commit. The Order algorithm's commits
  are deterministic functions of ``(core, label, mask)`` (core moves by
  exactly +-1 on the mask; ``order.place_block`` relabels from the mask),
  so the mask IS the frontier delta: no vertex-sized integer array ever
  crosses the mesh inside a round. Per round the traffic is
  O(n) stat words (reduce_scatter) + O(n * d) mask BITS — the quantity
  the layout tests pin via the accounting below (docs/DESIGN.md §4.2).

  With ``frontier_cap`` set, the mask exchange is SPARSE instead
  (docs/DESIGN.md §4.3): each device compacts its owned changed
  vertices to GLOBAL indices and all_gathers one fixed-capacity
  ``[cap + 1]`` int32 buffer — count-prefixed, sentinel-padded — so a
  round moves O(cap * d) words independent of ``n``; the replicated
  mask is rebuilt by scatter. The paper's Fig. 5 locality (the
  affected set of a batch is tiny) is what makes ``cap`` small. A
  per-round ``lax.cond`` falls back to the bitmask path whenever ANY
  shard's frontier overflows ``cap`` (the gathered count prefix makes
  the verdict replicated), so results stay BIT-identical in every
  regime — the cap is a bandwidth knob, never a correctness knob.

All arithmetic is integer, reduce_scatter is an exact sum, and the
gathered masks are bitwise identical on every device — which is why the
range-sharded engine stays BIT-identical (cores AND k-order labels) to
the replicated ones (``tests/test_churn_streams.py``).

A 2-axis factorization (edge shards x vertex ranges on distinct mesh
axes) plugs in by psum-ing partials over the pure-edge axes before the
``psum_scatter`` over the vertex axis; the shipped engine reuses ONE
axis for both (``launch/mesh.py::make_edge_vertex_mesh``), which keeps
every collective single-axis.

Traffic accounting
------------------
``record_traffic()`` captures, at TRACE time, one record per collective
a layout method issues, with the payload each device RECEIVES (computed
from static shapes). ``lax.while_loop`` bodies trace exactly once, so a
recorded fixpoint yields the PER-ROUND collective budget — the object
the acceptance tests assert O(n + frontier-bits * d) on, without running
a single batch. Both arms of the sparse exchange's ``lax.cond`` trace,
so their records carry a ``branch`` tag ("overflow" marks collectives
that only move on the fallback arm); filtering it out yields the
non-overflow round budget the tests pin at O(cap * d) words.
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class Traffic:
    """One collective issued by a layout method (trace-time record)."""

    op: str          # "psum" | "reduce_scatter" | "gather_mask" | ...
    recv_bytes: int  # payload each participating device receives
    branch: str = ""  # "" = unconditional; "overflow" = only moves on
    #                   the sparse exchange's lax.cond fallback arm


# ``Traffic.branch`` value for each arm of the sparse exchange's
# ``lax.cond(overflow, from_bitmask, from_indices, _)``, indexed by the
# traced branch position: JAX stores cond branches as (false, true), so
# branches[0] is the non-overflow index path ("") and branches[1] the
# bitmask fallback ("overflow"). The static auditor (repro.analysis)
# uses this to line jaxpr cond-branch attribution up with the
# trace-time records below.
SPARSE_COND_BRANCHES = ("", "overflow")

_LOG: Optional[List[Traffic]] = None
_OWNER: Optional[int] = None  # thread that opened the active session
# the lock makes session entry/exit and appends atomic, so a second
# session — nested OR from another thread — fails loudly instead of
# silently stealing/corrupting the active log; the owner-thread filter
# in _note keeps a stray trace on another thread out of the session's
# records, and the branch tag is thread-local for the same reason
_LOCK = threading.Lock()
_TLS = threading.local()


@contextmanager
def record_traffic() -> Iterator[List[Traffic]]:
    """Capture the collectives issued while tracing under this context.

    Only one session may be active at a time: a nested (or concurrent)
    entry raises ``RuntimeError`` — a silently-accepted inner context
    would steal the outer one's records (every collective of the inner
    trace would land in the wrong list). Trace one program per context.
    The active context's log survives a rejected entry intact, and only
    the opening thread's traces are recorded into it.
    """
    global _LOG, _OWNER
    with _LOCK:
        if _LOG is not None:
            raise RuntimeError(
                "record_traffic() does not nest (and allows one session "
                "at a time): the second context would steal the active "
                "one's records — trace one program per context"
            )
        _LOG = log = []
        _OWNER = threading.get_ident()
    try:
        yield log
    finally:
        with _LOCK:
            _LOG = None
            _OWNER = None


@contextmanager
def _cond_branch(name: str) -> Iterator[None]:
    """Tag the records noted while tracing one arm of a ``lax.cond``
    (both arms trace exactly once, at cond-construction time).
    Thread-local, so another thread's trace cannot mislabel records."""
    prev = getattr(_TLS, "branch", "")
    _TLS.branch = name
    try:
        yield
    finally:
        _TLS.branch = prev


def _note(op: str, recv_bytes: int) -> None:
    with _LOCK:
        if _LOG is not None and _OWNER == threading.get_ident():
            _LOG.append(
                Traffic(op, int(recv_bytes), getattr(_TLS, "branch", ""))
            )


def _nbytes(x: Array) -> int:
    return int(x.size) * x.dtype.itemsize


@dataclasses.dataclass(frozen=True)
class ReplicatedVertices:
    """Full ``[n]`` vertex state on every device; stats complete by psum
    over the edge axis (identity when ``axis`` is None)."""

    n: int
    axis: Optional[str] = None
    kind: str = dataclasses.field(default="replicated", init=False)

    @property
    def n_owned(self) -> int:
        return self.n

    def complete(self, stats: Array) -> Array:
        """Partial per-vertex stats -> completed stats, full ``[n, ...]``."""
        if self.axis is None:
            return stats
        _note("psum", _nbytes(stats))
        return jax.lax.psum(stats, self.axis)

    def own(self, full: Array) -> Array:
        return full

    def gather_state(self, owned: Array) -> Array:
        return owned

    def gather_mask(self, owned_mask: Array) -> Array:
        return owned_mask

    def any_owned(self, owned_mask: Array) -> Array:
        return jnp.any(owned_mask)

    def frontier_peak(self, full_mask: Array) -> Array:
        """Frontier size of one exchanged mask — with one (replicated)
        shard that is simply the popcount. Local compute, no collective;
        the engines carry the running max through their fixpoints so
        ``stats.max_frontier`` can tune the sparse-cap planner from
        observed data (docs/DESIGN.md §4.3)."""
        return jnp.sum(full_mask, dtype=jnp.int32)

    def zeros(self, dtype=jnp.int32) -> Array:
        return jnp.zeros(self.n, dtype=dtype)

    def add_at(self, owned: Array, idx: Array, vals: Array) -> Array:
        return owned.at[idx].add(vals)


@dataclasses.dataclass(frozen=True)
class RangeShardedVertices:
    """Device ``i`` owns vertices ``[i * n_owned, (i+1) * n_owned)``.

    ``axis`` is the mesh axis that carries both the edge shards and the
    vertex ranges (shared-axis layout, `launch/mesh.py`). ``n`` is padded
    up to ``n_pad = n_owned * n_shards``; phantom vertices past ``n``
    only ever hold zeros (no edge references them, ``own`` pads with
    zeros, completed stats there are 0), so they can never enter a mask
    or a level computation — everything vertex-global (``place_block``,
    ``renumber``) runs on the exact ``[:n]`` prefix.

    ``frontier_cap`` (static, ``None`` = off) switches ``gather_mask``
    to the sparse compacted-index exchange of docs/DESIGN.md §4.3: the
    wire payload becomes O(frontier_cap * n_shards) words per round
    instead of O(n_pad / 8 * n_shards) bitmask bytes, with a per-round
    ``lax.cond`` falling back to the bitmask whenever any shard's
    frontier overflows the cap — bit-identical results either way.
    """

    n: int
    axis: str
    n_shards: int
    frontier_cap: Optional[int] = None
    kind: str = dataclasses.field(default="range", init=False)

    @property
    def n_owned(self) -> int:
        return -(-self.n // self.n_shards)

    @property
    def n_pad(self) -> int:
        return self.n_owned * self.n_shards

    def _offset(self) -> Array:
        return jax.lax.axis_index(self.axis) * self.n_owned

    def _pad(self, full: Array) -> Array:
        pad = self.n_pad - full.shape[0]
        if pad == 0:
            return full
        return jnp.concatenate(
            [full, jnp.zeros((pad,) + full.shape[1:], dtype=full.dtype)]
        )

    def complete(self, stats: Array) -> Array:
        """Partial ``[n, ...]`` stats -> exact OWNED slice ``[n_owned, ...]``
        via one reduce_scatter: each device receives O(n / n_shards) words
        — the whole mesh moves O(n), not O(n * n_shards)."""
        padded = self._pad(stats)
        _note("reduce_scatter",
              _nbytes(padded) // self.n_shards)
        return jax.lax.psum_scatter(
            padded, self.axis, scatter_dimension=0, tiled=True
        )

    def own(self, full: Array) -> Array:
        """Slice a replicated full array down to this device's range (no
        collective — the full copy is already local)."""
        return jax.lax.dynamic_slice_in_dim(
            self._pad(full), self._offset(), self.n_owned
        )

    def gather_state(self, owned: Array) -> Array:
        """Owned slices -> full replicated ``[n]`` array. Used ONCE per
        batch (kernel entry) for ``core``/``label`` — never inside a
        round, where only masks cross the mesh."""
        _note("gather_state", self.n_pad * owned.dtype.itemsize)
        return jax.lax.all_gather(owned, self.axis, tiled=True)[: self.n]

    def gather_mask(self, owned_mask: Array) -> Array:
        """Owned bool mask -> full replicated ``[n]`` mask.

        With ``frontier_cap`` unset: BIT-packed on the wire — each
        device receives ``n_shards * ceil(n_owned / 8)`` bytes (the
        frontier bitmask exchange of docs/DESIGN.md §4.2). With it set:
        the sparse compacted-index exchange of §4.3, O(cap * n_shards)
        words, falling back to the bitmask per round on overflow."""
        if self.frontier_cap is None:
            return self._gather_mask_bits(owned_mask)
        return self._gather_mask_sparse(owned_mask)

    def _gather_mask_bits(self, owned_mask: Array) -> Array:
        packed = jnp.packbits(owned_mask)  # [ceil(n_owned / 8)] uint8
        _note("gather_mask", self.n_shards * int(packed.shape[0]))
        g = jax.lax.all_gather(packed, self.axis)  # [n_shards, bytes]
        bits = jnp.unpackbits(g, axis=1, count=self.n_owned)
        return bits.reshape(-1)[: self.n].astype(jnp.bool_)

    def _gather_mask_sparse(self, owned_mask: Array) -> Array:
        """Compacted-index frontier exchange (docs/DESIGN.md §4.3).

        Each device compacts its owned changed vertices to GLOBAL
        indices inside one fixed-capacity int32 buffer — element 0 is
        the exact owned count, the remaining ``cap`` slots hold indices
        (``n_pad`` sentinels past the count, dropped out-of-bounds at
        reconstruction) — and ONE all_gather moves ``(cap + 1) * 4``
        bytes per shard instead of the ``ceil(n_owned / 8)`` bitmask
        bytes: O(|frontier| * d) words per round, independent of n.
        The gathered count column is replicated, so every device takes
        the same ``lax.cond`` arm: indices when every shard fit under
        the cap, the bitmask fallback (a SECOND gather, recorded under
        branch="overflow") when any shard overflowed — the compaction
        above dropped indices past the cap, so the sparse buffer is
        unusable and the bitmask restores exactness. Either arm yields
        the identical replicated mask, which is why the cap can be
        planned heuristically (api.py) without any correctness risk."""
        cap = self.frontier_cap
        count = jnp.sum(owned_mask, dtype=jnp.int32)
        pos = jnp.cumsum(owned_mask.astype(jnp.int32)) - 1
        gidx = (self._offset() +
                jnp.arange(self.n_owned, dtype=jnp.int32)).astype(jnp.int32)
        safe = jnp.where(owned_mask & (pos < cap), pos, cap)
        buf = jnp.full((cap,), self.n_pad, dtype=jnp.int32)
        buf = buf.at[safe].set(gidx, mode="drop")
        payload = jnp.concatenate([count[None], buf])  # [cap + 1] int32
        _note("gather_frontier", self.n_shards * (cap + 1) * 4)
        g = jax.lax.all_gather(payload, self.axis)  # [n_shards, cap + 1]
        overflow = jnp.max(g[:, 0]) > cap

        def from_indices(_):
            flat = g[:, 1:].reshape(-1)  # sentinels drop out-of-bounds
            full = jnp.zeros(self.n_pad, dtype=jnp.bool_)
            return full.at[flat].set(True, mode="drop")[: self.n]

        def from_bitmask(_):
            with _cond_branch("overflow"):
                return self._gather_mask_bits(owned_mask)

        return jax.lax.cond(overflow, from_bitmask, from_indices, None)

    def any_owned(self, owned_mask: Array) -> Array:
        """Replicated ``any`` over the disjoint owned slices (scalar
        collective)."""
        _note("psum_scalar", 4)
        return jax.lax.psum(
            jnp.any(owned_mask).astype(jnp.int32), self.axis
        ) > 0

    def frontier_peak(self, full_mask: Array) -> Array:
        """Max per-shard owned count of one exchanged (replicated) full
        mask — the quantity the sparse exchange's ``frontier_cap`` must
        clear for the index path to be taken (docs/DESIGN.md §4.3). The
        mask is already replicated, so the per-range popcounts are local
        compute: no collective is added to the round."""
        owned = self._pad(full_mask).reshape(self.n_shards, self.n_owned)
        return jnp.max(jnp.sum(owned, axis=1, dtype=jnp.int32))

    def zeros(self, dtype=jnp.int32) -> Array:
        return jnp.zeros(self.n_owned, dtype=dtype)

    def add_at(self, owned: Array, idx: Array, vals: Array) -> Array:
        """Scatter-add replicated batch contributions into the owned
        slice; rows owned by other devices fall off the end and drop
        (the same OOB trick as the sharded table writes)."""
        loc = idx - self._offset()
        safe = jnp.where((loc >= 0) & (loc < self.n_owned), loc,
                         self.n_owned)
        return owned.at[safe].add(vals, mode="drop")


VertexLayout = ReplicatedVertices | RangeShardedVertices


def make_layout(kind: str, n: int, axis: Optional[str],
                n_shards: int = 1,
                frontier_cap: Optional[int] = None) -> VertexLayout:
    """Factory keyed by the public ``vertex_sharding`` name.

    Misconfiguration raises HERE, at construction — not as an opaque
    trace-time error three layers down: the replicated layout has no
    shard ranges (``n_shards``) and exchanges no frontier
    (``frontier_cap``), so silently accepting either would hide a
    caller that believes it configured a sharded/sparse layout."""
    if kind == "replicated":
        if n_shards != 1:
            raise ValueError(
                f"n_shards={n_shards} is meaningless for the replicated "
                "vertex layout (every device keeps the full state; only "
                "kind='range' owns per-shard ranges) — pass n_shards=1 "
                "or use kind='range'"
            )
        if frontier_cap is not None:
            raise ValueError(
                f"frontier_cap={frontier_cap} applies only to "
                "kind='range' (the replicated layout exchanges no "
                "frontier masks)"
            )
        return ReplicatedVertices(n, axis)
    if kind == "range":
        if axis is None:
            raise ValueError("range-sharded vertex state needs a mesh axis")
        if frontier_cap is not None and frontier_cap < 1:
            raise ValueError(
                f"frontier_cap must be >= 1 (or None for the bitmask "
                f"exchange), got {frontier_cap}"
            )
        return RangeShardedVertices(n, axis, n_shards, frontier_cap)
    raise ValueError(f"unknown vertex layout {kind!r}")
