"""Weighted-coreness ORACLE and reference kernels (paper §6 future work).

The PRODUCTION weighted engine lives in the engine matrix now:
``CoreMaintainer(weighted=True)`` threads a per-slot weight column
through ``core/engine.py::batch_program`` (and its halo twin), runs
both maintenance phases through the shared decrease-only weighted
h-index fixpoint (``core/remove.py::weighted_core_fixpoint_pass`` /
``core/insert.py::weighted_promotion_fixpoint``, statistics via
``core/graph_ops.py::weighted_support`` on either kernel backend), and
is audited by the committed ``weighted`` / ``weighted_sharded`` budget
manifests. This module is what that engine is PINNED against: the
numpy peeling oracle (``weighted_core_oracle``), a standalone
single-device fixpoint (``weighted_core_fixpoint``), and the small
``WeightedCoreMaintainer`` reference harness
(tests/test_weighted_core.py, tests/test_churn_streams.py).

Weighted coreness (Zhou et al., WWW'21): the weighted degree of v is the
sum of incident edge weights; the weighted k-core is the maximal subgraph
with weighted degree >= k inside it; integer weights give integer cores.

The decrease-only fixpoint generalizes from mcd to the *weighted
h-index*:

    H_w(v) = max{ h : sum of w(u,v) over neighbors with core(u) >= h  >= h }

Iterating ``c <- min(c, H_w(c))`` from ANY upper bound converges to the
exact weighted core numbers (same monotone argument as the unweighted
mcd fixpoint — the fixpoint set {v: c(v) >= k} induces a subgraph of
weighted degree >= k, and values at the true core never drop). Upper
bounds: the weighted degree (decomposition), the current cores
(removals), current cores + TOTAL batch inserted weight (insertions —
docs/DESIGN.md §4.5 derives why the per-vertex incident bound is not
sound).

H_w is computed data-parallel with a per-vertex bisection: O(log maxW)
masked segment-sums per round — every edge and every vertex of every
level in parallel, the paper's parallelism claim carried to the weighted
setting.
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# numpy oracle: generalized peeling
# ---------------------------------------------------------------------------
def weighted_core_oracle(n: int, edges: np.ndarray,
                         weights: np.ndarray) -> np.ndarray:
    """Exact weighted cores by min-weighted-degree peeling (BZ analogue)."""
    import heapq

    adj: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for (u, v), w in zip(edges, weights):
        adj[int(u)].append((int(v), int(w)))
        adj[int(v)].append((int(u), int(w)))
    wdeg = np.array([sum(w for _, w in a) for a in adj], dtype=np.int64)
    heap = [(int(wdeg[v]), v) for v in range(n)]
    heapq.heapify(heap)
    removed = np.zeros(n, dtype=bool)
    core = np.zeros(n, dtype=np.int64)
    k = 0
    while heap:
        d, v = heapq.heappop(heap)
        if removed[v] or d != wdeg[v]:
            continue
        removed[v] = True
        k = max(k, int(wdeg[v]))
        core[v] = k
        for u, w in adj[v]:
            if not removed[u]:
                wdeg[u] -= w
                heapq.heappush(heap, (int(wdeg[u]), u))
    return core


# ---------------------------------------------------------------------------
# JAX weighted h-index fixpoint
# ---------------------------------------------------------------------------
def _weighted_h_index(src, dst, w, valid, c, n):
    """Per-vertex H_w via simultaneous bisection (all vertices at once)."""
    lo = jnp.zeros(n, jnp.int32)
    hi = c  # H_w(v) <= c(v) suffices for a decrease-only iteration

    def cond(state):
        lo, hi = state
        return jnp.any(lo < hi)

    def body(state):
        lo, hi = state
        mid = (lo + hi + 1) // 2
        to_src = jnp.where(valid & (c[dst] >= mid[src]), w, 0)
        to_dst = jnp.where(valid & (c[src] >= mid[dst]), w, 0)
        s = (
            jax.ops.segment_sum(to_src, src, num_segments=n)
            + jax.ops.segment_sum(to_dst, dst, num_segments=n)
        )
        ok = s >= mid
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, _ = jax.lax.while_loop(cond, body, (lo, hi))
    return lo


@partial(jax.jit, static_argnames=("n",))
def weighted_core_fixpoint(src: Array, dst: Array, w: Array, valid: Array,
                           upper: Array, n: int) -> Array:
    """Exact weighted cores from any per-vertex upper bound."""

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        c, _ = state
        h = _weighted_h_index(src, dst, w, valid, c, n)
        new_c = jnp.minimum(c, h)
        return new_c, jnp.any(new_c != c)

    c, _ = jax.lax.while_loop(cond, body, (upper, jnp.bool_(True)))
    return c


class WeightedCoreMaintainer:
    """Dynamic weighted-core maintenance over COO slots (host wrapper)."""

    def __init__(self, n: int, edges: np.ndarray, weights: np.ndarray,
                 capacity: int | None = None):
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        weights = np.asarray(weights, dtype=np.int32)
        m = edges.shape[0]
        capacity = capacity or max(16, 2 * m)
        self.n = n
        self.capacity = capacity
        src = np.zeros(capacity, np.int32)
        dst = np.zeros(capacity, np.int32)
        wgt = np.zeros(capacity, np.int32)
        val = np.zeros(capacity, bool)
        src[:m], dst[:m], wgt[:m], val[:m] = (
            edges[:, 0], edges[:, 1], weights, True
        )
        self.src = jnp.asarray(src)
        self.dst = jnp.asarray(dst)
        self.w = jnp.asarray(wgt)
        self.valid = jnp.asarray(val)
        self.n_edges = m
        self.edge_slot = {
            (int(min(a, b)), int(max(a, b))): i
            for i, (a, b) in enumerate(edges)
        }
        wdeg = (
            jax.ops.segment_sum(self.w * self.valid, self.src,
                                num_segments=n)
            + jax.ops.segment_sum(self.w * self.valid, self.dst,
                                  num_segments=n)
        ).astype(jnp.int32)
        self.core = weighted_core_fixpoint(
            self.src, self.dst, self.w, self.valid, wdeg, n
        )

    def cores(self) -> np.ndarray:
        return np.asarray(self.core)

    def insert_edges(self, edges: np.ndarray, weights: np.ndarray) -> None:
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        weights = np.asarray(weights, dtype=np.int32)
        base = self.n_edges
        assert base + len(edges) < self.capacity, "grow not implemented"
        src = np.asarray(self.src).copy()
        dst = np.asarray(self.dst).copy()
        wgt = np.asarray(self.w).copy()
        val = np.asarray(self.valid).copy()
        for i, ((a, b), ww) in enumerate(zip(edges, weights)):
            key = (int(min(a, b)), int(max(a, b)))
            self.edge_slot[key] = base + i
            src[base + i], dst[base + i] = key
            wgt[base + i], val[base + i] = ww, True
        self.n_edges = base + len(edges)
        self.src, self.dst = jnp.asarray(src), jnp.asarray(dst)
        self.w, self.valid = jnp.asarray(wgt), jnp.asarray(val)
        # upper bound: ANY vertex's weighted core can rise by at most the
        # total inserted weight (the weighted analogue of "+1 per inserted
        # edge", which applies to every vertex of V*, not just endpoints)
        upper = (self.core + jnp.int32(int(weights.sum()))).astype(jnp.int32)
        self.core = weighted_core_fixpoint(
            self.src, self.dst, self.w, self.valid, upper, self.n
        )

    def remove_edges(self, edges: np.ndarray) -> None:
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        val = np.asarray(self.valid).copy()
        for a, b in edges:
            key = (int(min(a, b)), int(max(a, b)))
            slot = self.edge_slot.pop(key, None)
            if slot is not None:
                val[slot] = False
        self.valid = jnp.asarray(val)
        # current cores upper-bound the post-removal cores
        self.core = weighted_core_fixpoint(
            self.src, self.dst, self.w, self.valid, self.core, self.n
        )
