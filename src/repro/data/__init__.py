from .lm import synthetic_lm_batches  # noqa: F401
from .recsys import synthetic_ctr_batches  # noqa: F401
from .graphs import load_cora_like, random_molecule_batch  # noqa: F401
