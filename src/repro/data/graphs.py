"""Graph datasets: synthetic Cora-like full-batch data and random molecule
batches (positions + species) for DimeNet/NequIP."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..graph.csr import CSRGraph
from ..graph.generators import erdos_renyi
from ..models.gnn import GraphBatch

import jax.numpy as jnp


def load_cora_like(
    n: int = 2708, m: int = 5278, d_feat: int = 1433, n_classes: int = 7,
    seed: int = 0,
) -> Tuple[CSRGraph, GraphBatch, np.ndarray]:
    """Synthetic citation-graph stand-in with community-correlated features
    and labels (full_graph_sm shape: 2708 nodes / 10556 directed edges)."""
    rng = np.random.default_rng(seed)
    g = erdos_renyi(n, m, seed=seed)
    labels = rng.integers(0, n_classes, size=n)
    centers = rng.normal(size=(n_classes, d_feat)) * 0.5
    feats = (centers[labels] + rng.normal(size=(n, d_feat))).astype(
        np.float32
    )
    edges = g.edge_array()
    senders = np.concatenate([edges[:, 0], edges[:, 1]]).astype(np.int32)
    receivers = np.concatenate([edges[:, 1], edges[:, 0]]).astype(np.int32)
    batch = GraphBatch(
        node_feat=jnp.asarray(feats),
        senders=jnp.asarray(senders),
        receivers=jnp.asarray(receivers),
        edge_mask=jnp.ones(len(senders), dtype=bool),
        node_mask=jnp.ones(n, dtype=bool),
        graph_id=jnp.zeros(n, dtype=jnp.int32),
        n_graphs=1,
    )
    return g, batch, labels


def random_molecule_batch(
    n_mols: int = 4, n_atoms: int = 30, n_edges: int = 64,
    n_species: int = 8, seed: int = 0,
) -> GraphBatch:
    """Batched random molecules: radius-graph edges over random coordinates."""
    rng = np.random.default_rng(seed)
    N = n_mols * n_atoms
    pos = rng.normal(size=(n_mols, n_atoms, 3)) * 2.0
    senders, receivers = [], []
    for mi in range(n_mols):
        d = np.linalg.norm(
            pos[mi][:, None, :] - pos[mi][None, :, :], axis=-1
        )
        src, dst = np.nonzero((d < 3.0) & (d > 1e-6))
        order = rng.permutation(len(src))[: n_edges]
        senders.append(src[order] + mi * n_atoms)
        receivers.append(dst[order] + mi * n_atoms)
    s = np.concatenate(senders).astype(np.int32)
    r = np.concatenate(receivers).astype(np.int32)
    e_cap = n_mols * n_edges
    es = np.zeros(e_cap, dtype=np.int32)
    er = np.zeros(e_cap, dtype=np.int32)
    em = np.zeros(e_cap, dtype=bool)
    es[: len(s)], er[: len(r)], em[: len(s)] = s, r, True
    return GraphBatch(
        node_feat=jnp.zeros((N, 1), jnp.float32),
        senders=jnp.asarray(es),
        receivers=jnp.asarray(er),
        edge_mask=jnp.asarray(em),
        node_mask=jnp.ones(N, dtype=bool),
        graph_id=jnp.asarray(np.repeat(np.arange(n_mols), n_atoms),
                             dtype=jnp.int32),
        n_graphs=n_mols,
        positions=jnp.asarray(pos.reshape(N, 3), jnp.float32),
        species=jnp.asarray(rng.integers(0, n_species, size=N),
                            dtype=jnp.int32),
    )
