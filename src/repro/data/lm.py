"""Deterministic synthetic LM token pipeline.

Produces (tokens, targets) batches from a seeded Zipfian sampler with
Markov structure (so the loss is learnable — a pure-uniform stream cannot
show training progress). Sharded loading: each host materializes only its
slice of the global batch.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def synthetic_lm_batches(
    vocab: int,
    batch: int,
    seq: int,
    seed: int = 0,
    host_id: int = 0,
    n_hosts: int = 1,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    assert batch % n_hosts == 0
    local = batch // n_hosts
    rng = np.random.default_rng(seed * 1000 + host_id)
    # Zipf unigram + a sticky bigram kernel
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    step = 0
    while True:
        base = rng.choice(vocab, size=(local, seq + 1), p=probs)
        # Markov stickiness: with p=0.5 copy previous token + 1 (mod vocab)
        sticky = rng.random((local, seq + 1)) < 0.5
        for t in range(1, seq + 1):
            base[:, t] = np.where(
                sticky[:, t], (base[:, t - 1] + 1) % vocab, base[:, t]
            )
        yield base[:, :-1].astype(np.int32), base[:, 1:].astype(np.int32)
        step += 1
