"""Synthetic CTR batches (Criteo-like): hashed categorical ids + a planted
logistic ground truth so AUC/loss are meaningful."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def synthetic_ctr_batches(
    n_fields: int,
    rows_per_field: int,
    batch: int,
    seed: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    # planted per-field weights on a small latent id space
    latent = 1024
    w = rng.normal(size=(n_fields, latent)) * 0.5
    while True:
        ids_latent = rng.integers(0, latent, size=(batch, n_fields))
        logit = w[np.arange(n_fields)[None, :], ids_latent].sum(axis=1)
        label = (rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(
            np.float32
        )
        # expand latent ids into the big hashed space (stable hash)
        ids = (ids_latent * 2654435761 % rows_per_field).astype(np.int32)
        yield ids, label
