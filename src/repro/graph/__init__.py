from .csr import (  # noqa: F401
    COOEdges,
    CSRGraph,
    ELLGraph,
    add_edges_csr,
    build_csr,
    coo_from_csr,
    ell_from_csr,
    remove_edges_csr,
)
