"""Graph containers.

Three layouts are used across the framework:

* ``CSRGraph`` — static numpy CSR for oracles, generators and CSR rebuilds.
* ``COOEdges`` — device-resident dynamic edge slots (capacity + validity
  mask); the layout all JAX maintenance rounds operate on.  ``segment_sum``
  does not require sorted ids, so insertion/removal is O(batch) slot writes.
* ``ELLGraph`` — padded neighbor matrix (row-major ``[n, max_deg]``) used by
  the Pallas kernels and the GNN aggregation paths.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

try:  # jax is always present in this repo, but keep numpy paths importable
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None


# ---------------------------------------------------------------------------
# numpy CSR (host side)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CSRGraph:
    """Undirected graph in CSR form. Each undirected edge appears twice."""

    n: int
    indptr: np.ndarray  # [n + 1] int64
    indices: np.ndarray  # [2m] int32

    @property
    def m(self) -> int:
        return int(self.indices.shape[0] // 2)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]: self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        return bool(np.any(self.neighbors(u) == v))

    def edge_array(self) -> np.ndarray:
        """Unique undirected edges as an [m, 2] array with src < dst."""
        src = np.repeat(np.arange(self.n), np.diff(self.indptr))
        dst = self.indices
        keep = src < dst
        return np.stack([src[keep], dst[keep]], axis=1).astype(np.int64)


def build_csr(n: int, edges: np.ndarray) -> CSRGraph:
    """Build a CSR graph from an [m, 2] array of undirected edges.

    Self loops and duplicate edges are removed (paper §5.1 preprocessing).
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.size:
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        keep = lo != hi
        lo, hi = lo[keep], hi[keep]
        key = lo * n + hi
        _, first = np.unique(key, return_index=True)
        lo, hi = lo[first], hi[first]
    else:
        lo = hi = np.zeros((0,), dtype=np.int64)
    src = np.concatenate([lo, hi])
    dst = np.concatenate([hi, lo])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRGraph(n=n, indptr=indptr, indices=dst.astype(np.int32))


def remove_edges_csr(g: CSRGraph, edges: np.ndarray) -> CSRGraph:
    """Return a new CSR graph with the given undirected edges removed."""
    cur = g.edge_array()
    n = g.n
    cur_key = cur[:, 0] * n + cur[:, 1]
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    rm_key = lo * n + hi
    keep = ~np.isin(cur_key, rm_key)
    return build_csr(n, cur[keep])


def add_edges_csr(g: CSRGraph, edges: np.ndarray) -> CSRGraph:
    cur = g.edge_array()
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    return build_csr(g.n, np.concatenate([cur, edges], axis=0))


# ---------------------------------------------------------------------------
# COO dynamic edge slots (device side)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class COOEdges:
    """Fixed-capacity undirected edge slots.

    Attributes
    ----------
    n:        number of vertices (static).
    src, dst: int32 [capacity]; meaningful where ``valid``.
    valid:    bool [capacity].
    n_edges:  int32 scalar — number of slots ever written (free slots are
              ``[n_edges:]``; removed slots are tombstoned, compaction is a
              host-side maintenance action).
    """

    n: int
    src: "jnp.ndarray"
    dst: "jnp.ndarray"
    valid: "jnp.ndarray"
    n_edges: "jnp.ndarray"

    @property
    def capacity(self) -> int:
        return int(self.src.shape[0])

    def tree_flatten(self):
        return (self.src, self.dst, self.valid, self.n_edges), (self.n,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, valid, n_edges = children
        return cls(n=aux[0], src=src, dst=dst, valid=valid, n_edges=n_edges)


if jax is not None:
    jax.tree_util.register_pytree_node(
        COOEdges, COOEdges.tree_flatten, COOEdges.tree_unflatten
    )


def coo_from_csr(g: CSRGraph, capacity: Optional[int] = None) -> COOEdges:
    edges = g.edge_array()
    m = edges.shape[0]
    capacity = capacity or max(1, int(m * 2))
    if capacity < m:
        raise ValueError(f"capacity {capacity} < m {m}")
    src = np.zeros(capacity, dtype=np.int32)
    dst = np.zeros(capacity, dtype=np.int32)
    valid = np.zeros(capacity, dtype=bool)
    src[:m] = edges[:, 0]
    dst[:m] = edges[:, 1]
    valid[:m] = True
    return COOEdges(
        n=g.n,
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        valid=jnp.asarray(valid),
        n_edges=jnp.asarray(m, dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# ELL padded neighbor matrix
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ELLGraph:
    """Padded neighbor lists: ``nbrs[v, i]`` is the i-th neighbor of v.

    Padding entries hold ``n`` (one-past-last vertex id) so gathers can index
    a sentinel row appended to per-vertex value arrays.
    """

    n: int
    max_deg: int
    nbrs: np.ndarray  # [n, max_deg] int32
    deg: np.ndarray  # [n] int32


def ell_from_csr(g: CSRGraph, max_deg: Optional[int] = None) -> ELLGraph:
    deg = g.degrees().astype(np.int32)
    md = int(deg.max()) if deg.size else 0
    max_deg = max_deg or max(md, 1)
    if md > max_deg:
        raise ValueError(f"max_deg {max_deg} < graph max degree {md}")
    nbrs = np.full((g.n, max_deg), g.n, dtype=np.int32)
    for v in range(g.n):
        nb = g.neighbors(v)
        nbrs[v, : nb.shape[0]] = nb
    return ELLGraph(n=g.n, max_deg=max_deg, nbrs=nbrs, deg=deg)
