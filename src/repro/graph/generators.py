"""Synthetic graph generators used in the paper's experiments (§5.1).

ER (Erdős–Rényi), BA (Barabási–Albert) and RMAT, mirroring the SNAP
generators the paper uses (average degree fixed by (n, m)). All are
deterministic given a seed.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, build_csr


def erdos_renyi(n: int, m: int, seed: int = 0) -> CSRGraph:
    """G(n, m): m undirected edges sampled uniformly without self loops."""
    rng = np.random.default_rng(seed)
    # oversample to survive dedup/self-loop removal
    k = int(m * 1.3) + 16
    src = rng.integers(0, n, size=k, dtype=np.int64)
    dst = rng.integers(0, n, size=k, dtype=np.int64)
    edges = np.stack([src, dst], axis=1)
    edges = edges[src != dst][:m]
    return build_csr(n, edges)


def barabasi_albert(n: int, deg: int = 8, seed: int = 0) -> CSRGraph:
    """BA preferential attachment, ``deg//2`` edges per arriving vertex.

    Vectorized approximation of preferential attachment: targets are drawn
    from the current edge endpoint multiset (degree-proportional).
    """
    rng = np.random.default_rng(seed)
    k = max(1, deg // 2)
    targets = list(range(k))
    src_list = []
    dst_list = []
    endpoint_pool: list[int] = list(range(k))
    for v in range(k, n):
        pool = np.asarray(endpoint_pool, dtype=np.int64)
        picks = pool[rng.integers(0, pool.shape[0], size=k)]
        for t in np.unique(picks):
            src_list.append(v)
            dst_list.append(int(t))
            endpoint_pool.append(int(t))
            endpoint_pool.append(v)
    edges = np.stack(
        [np.asarray(src_list, dtype=np.int64), np.asarray(dst_list, dtype=np.int64)],
        axis=1,
    )
    return build_csr(n, edges)


def rmat(n_log2: int, m: int, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> CSRGraph:
    """R-MAT recursive matrix graph (power-law, SNAP defaults)."""
    rng = np.random.default_rng(seed)
    n = 1 << n_log2
    k = int(m * 1.4) + 16
    src = np.zeros(k, dtype=np.int64)
    dst = np.zeros(k, dtype=np.int64)
    for level in range(n_log2):
        r = rng.random(k)
        in_a = r < a
        in_b = (r >= a) & (r < a + b)
        in_c = (r >= a + b) & (r < a + b + c)
        # quadrant -> (row bit, col bit)
        row_bit = (~in_a & ~in_b).astype(np.int64)  # c or d -> bottom half
        row_bit = (in_c | (~in_a & ~in_b & ~in_c)).astype(np.int64)
        col_bit = (in_b | (~in_a & ~in_b & ~in_c)).astype(np.int64)
        src = src * 2 + row_bit
        dst = dst * 2 + col_bit
    edges = np.stack([src, dst], axis=1)
    edges = edges[src != dst][:m]
    return build_csr(n, edges)


def random_edge_batch(g: CSRGraph, n_edges: int, seed: int = 0,
                      existing: bool = False) -> np.ndarray:
    """Sample a batch of edges for insertion (non-existing) or removal
    (existing). Mirrors the paper's 100k random-edge experiment setup."""
    rng = np.random.default_rng(seed)
    if existing:
        all_edges = g.edge_array()
        idx = rng.choice(all_edges.shape[0], size=min(n_edges, all_edges.shape[0]),
                         replace=False)
        return all_edges[idx]
    out = []
    seen = set()
    while len(out) < n_edges:
        u = int(rng.integers(0, g.n))
        v = int(rng.integers(0, g.n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in seen or g.has_edge(u, v):
            continue
        seen.add(key)
        out.append(key)
    return np.asarray(out, dtype=np.int64)
