"""Fanout neighbor sampler (GraphSAGE-style) for the minibatch_lg shape.

Host-side numpy sampling producing fixed-shape padded blocks (the device
program is shape-static). Samples L-hop neighborhoods with per-hop fanouts
and relabels to a compact local id space.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .csr import CSRGraph


@dataclasses.dataclass
class SampledBlock:
    """A sampled computation block: local subgraph + seed positions."""

    node_ids: np.ndarray     # [N_cap] global ids (padded with -1)
    senders: np.ndarray      # [E_cap] local ids
    receivers: np.ndarray    # [E_cap] local ids
    edge_mask: np.ndarray    # [E_cap]
    node_mask: np.ndarray    # [N_cap]
    seed_mask: np.ndarray    # [N_cap] — the batch nodes (loss positions)


class NeighborSampler:
    def __init__(self, g: CSRGraph, fanouts: Tuple[int, ...] = (15, 10),
                 seed: int = 0):
        self.g = g
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)
        # capacity: batch * prod(fanout+1) edges upper bound
        self._node_cap_mult = 1
        for f in fanouts:
            self._node_cap_mult *= f + 1

    def sample(self, batch_nodes: np.ndarray) -> SampledBlock:
        b = len(batch_nodes)
        node_cap = b * self._node_cap_mult
        edge_cap = node_cap * 2
        nodes: List[int] = list(dict.fromkeys(int(v) for v in batch_nodes))
        local = {v: i for i, v in enumerate(nodes)}
        edges: List[Tuple[int, int]] = []
        frontier = list(nodes)
        for f in self.fanouts:
            nxt: List[int] = []
            for v in frontier:
                nbrs = self.g.neighbors(v)
                if len(nbrs) > f:
                    nbrs = self.rng.choice(nbrs, size=f, replace=False)
                for w in nbrs:
                    w = int(w)
                    if w not in local:
                        if len(nodes) >= node_cap:
                            continue
                        local[w] = len(nodes)
                        nodes.append(w)
                        nxt.append(w)
                    if len(edges) < edge_cap:
                        edges.append((local[w], local[v]))  # msg w -> v
            frontier = nxt
        node_ids = np.full(node_cap, -1, dtype=np.int64)
        node_ids[: len(nodes)] = nodes
        senders = np.zeros(edge_cap, dtype=np.int32)
        receivers = np.zeros(edge_cap, dtype=np.int32)
        emask = np.zeros(edge_cap, dtype=bool)
        for i, (s, r) in enumerate(edges):
            senders[i], receivers[i], emask[i] = s, r, True
        nmask = np.zeros(node_cap, dtype=bool)
        nmask[: len(nodes)] = True
        smask = np.zeros(node_cap, dtype=bool)
        for v in batch_nodes:
            smask[local[int(v)]] = True
        return SampledBlock(
            node_ids=node_ids, senders=senders, receivers=receivers,
            edge_mask=emask, node_mask=nmask, seed_mask=smask,
        )
