"""Temporal edge streams — the paper's dynamic-graph workload.

Generates (or replays) timestamped edge events and yields fixed-size
batches of insertions/removals, the input format of the streaming core
maintenance service (examples/stream_maintenance.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from .csr import CSRGraph


@dataclasses.dataclass
class EdgeEvent:
    edges: np.ndarray   # [b, 2] — insertions ("insert"/"mixed"), removals ("remove")
    kind: str           # "insert" | "remove" | "mixed"
    t: int
    removals: Optional[np.ndarray] = None  # [b', 2], only for kind="mixed"

    @property
    def n_edits(self) -> int:
        return len(self.edges) + (
            len(self.removals) if self.removals is not None else 0
        )


def synthetic_stream(
    g: CSRGraph,
    n_batches: int,
    batch_size: int,
    p_insert: float = 0.5,
    seed: int = 0,
) -> Iterator[EdgeEvent]:
    """Random insert/remove batches against a live edge set (paper §5.2:
    edges are first removed then inserted; here interleaved)."""
    rng = np.random.default_rng(seed)
    live = {tuple(e) for e in g.edge_array().tolist()}
    n = g.n
    for t in range(n_batches):
        if rng.random() < p_insert or len(live) < batch_size:
            batch = []
            while len(batch) < batch_size:
                u, v = rng.integers(0, n, size=2)
                key = (int(min(u, v)), int(max(u, v)))
                if u == v or key in live or key in batch:
                    continue
                batch.append(key)
            live.update(batch)
            yield EdgeEvent(np.asarray(batch, dtype=np.int64), "insert", t)
        else:
            lst = sorted(live)
            take = rng.choice(len(lst), size=batch_size, replace=False)
            batch = [lst[i] for i in take]
            live.difference_update(batch)
            yield EdgeEvent(np.asarray(batch, dtype=np.int64), "remove", t)


def mixed_stream(
    g: CSRGraph,
    n_batches: int,
    batch_size: int,
    p_insert: float = 0.5,
    seed: int = 0,
) -> Iterator[EdgeEvent]:
    """Mixed insert+remove batches — the paper's burst workload in the
    format the unified engine consumes in ONE compiled call per batch.

    Each event carries ~``p_insert * batch_size`` fresh insertions in
    ``edges`` and the rest as removals of currently-live edges in
    ``removals``. Removed edges return to the candidate pool, so an edge
    removed at t may be re-inserted at a later t (the re-insertion path
    the engine tests pin down)."""
    rng = np.random.default_rng(seed)
    live = {tuple(e) for e in g.edge_array().tolist()}
    n = g.n
    max_edges = n * (n - 1) // 2
    for t in range(n_batches):
        n_ins = int(round(batch_size * p_insert))
        # clamp to what the graph can absorb: never sample more fresh
        # edges than are absent (dense/small graphs would spin forever)
        n_ins = min(n_ins, max_edges - len(live))
        n_rm = min(batch_size - n_ins, len(live))
        inserts: list = []
        picked = set()
        while len(inserts) < n_ins:
            u, v = rng.integers(0, n, size=2)
            key = (int(min(u, v)), int(max(u, v)))
            if u == v or key in live or key in picked:
                continue
            picked.add(key)
            inserts.append(key)
        lst = sorted(live)
        take = rng.choice(len(lst), size=n_rm, replace=False)
        removals = [lst[i] for i in take]
        live.difference_update(removals)
        live.update(inserts)
        yield EdgeEvent(
            np.asarray(inserts, dtype=np.int64).reshape(-1, 2),
            "mixed",
            t,
            removals=np.asarray(removals, dtype=np.int64).reshape(-1, 2),
        )


def temporal_replay(
    edges_with_time: np.ndarray, batch_size: int
) -> Iterator[EdgeEvent]:
    """Replay a [m, 3] (u, v, t) temporal edge list in timestamp order as
    insertion batches (KONECT-style temporal graphs)."""
    order = np.argsort(edges_with_time[:, 2], kind="stable")
    ordered = edges_with_time[order]
    for i in range(0, len(ordered), batch_size):
        chunk = ordered[i : i + batch_size]
        yield EdgeEvent(chunk[:, :2].astype(np.int64), "insert",
                        int(chunk[-1, 2]))
