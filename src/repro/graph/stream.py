"""Temporal edge streams — the paper's dynamic-graph workload.

Generates (or replays) timestamped edge events and yields fixed-size
batches of insertions/removals, the input format of the streaming core
maintenance service (examples/stream_maintenance.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from .csr import CSRGraph


@dataclasses.dataclass
class EdgeEvent:
    edges: np.ndarray   # [b, 2]
    kind: str           # "insert" | "remove"
    t: int


def synthetic_stream(
    g: CSRGraph,
    n_batches: int,
    batch_size: int,
    p_insert: float = 0.5,
    seed: int = 0,
) -> Iterator[EdgeEvent]:
    """Random insert/remove batches against a live edge set (paper §5.2:
    edges are first removed then inserted; here interleaved)."""
    rng = np.random.default_rng(seed)
    live = {tuple(e) for e in g.edge_array().tolist()}
    n = g.n
    for t in range(n_batches):
        if rng.random() < p_insert or len(live) < batch_size:
            batch = []
            while len(batch) < batch_size:
                u, v = rng.integers(0, n, size=2)
                key = (int(min(u, v)), int(max(u, v)))
                if u == v or key in live or key in batch:
                    continue
                batch.append(key)
            live.update(batch)
            yield EdgeEvent(np.asarray(batch, dtype=np.int64), "insert", t)
        else:
            lst = sorted(live)
            take = rng.choice(len(lst), size=batch_size, replace=False)
            batch = [lst[i] for i in take]
            live.difference_update(batch)
            yield EdgeEvent(np.asarray(batch, dtype=np.int64), "remove", t)


def temporal_replay(
    edges_with_time: np.ndarray, batch_size: int
) -> Iterator[EdgeEvent]:
    """Replay a [m, 3] (u, v, t) temporal edge list in timestamp order as
    insertion batches (KONECT-style temporal graphs)."""
    order = np.argsort(edges_with_time[:, 2], kind="stable")
    ordered = edges_with_time[order]
    for i in range(0, len(ordered), batch_size):
        chunk = ordered[i : i + batch_size]
        yield EdgeEvent(chunk[:, :2].astype(np.int64), "insert",
                        int(chunk[-1, 2]))
