"""Temporal edge streams — the paper's dynamic-graph workload.

Generates (or replays) timestamped edge events and yields fixed-size
batches of insertions/removals, the input format of the streaming core
maintenance service (examples/stream_maintenance.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from .csr import CSRGraph


@dataclasses.dataclass
class EdgeEvent:
    edges: np.ndarray   # [b, 2] — insertions ("insert"/"mixed"), removals ("remove")
    kind: str           # "insert" | "remove" | "mixed"
    t: int
    removals: Optional[np.ndarray] = None  # [b', 2], only for kind="mixed"

    @property
    def n_edits(self) -> int:
        return len(self.edges) + (
            len(self.removals) if self.removals is not None else 0
        )


def synthetic_stream(
    g: CSRGraph,
    n_batches: int,
    batch_size: int,
    p_insert: float = 0.5,
    seed: int = 0,
) -> Iterator[EdgeEvent]:
    """Random insert/remove batches against a live edge set (paper §5.2:
    edges are first removed then inserted; here interleaved)."""
    rng = np.random.default_rng(seed)
    live = {tuple(e) for e in g.edge_array().tolist()}
    n = g.n
    for t in range(n_batches):
        if rng.random() < p_insert or len(live) < batch_size:
            batch = []
            while len(batch) < batch_size:
                u, v = rng.integers(0, n, size=2)
                key = (int(min(u, v)), int(max(u, v)))
                if u == v or key in live or key in batch:
                    continue
                batch.append(key)
            live.update(batch)
            yield EdgeEvent(np.asarray(batch, dtype=np.int64), "insert", t)
        else:
            lst = sorted(live)
            take = rng.choice(len(lst), size=batch_size, replace=False)
            batch = [lst[i] for i in take]
            live.difference_update(batch)
            yield EdgeEvent(np.asarray(batch, dtype=np.int64), "remove", t)


def mixed_stream(
    g: CSRGraph,
    n_batches: int,
    batch_size: int,
    p_insert: float = 0.5,
    seed: int = 0,
) -> Iterator[EdgeEvent]:
    """Mixed insert+remove batches — the paper's burst workload in the
    format the unified engine consumes in ONE compiled call per batch.

    Each event carries ~``p_insert * batch_size`` fresh insertions in
    ``edges`` and the rest as removals of currently-live edges in
    ``removals``. Removed edges return to the candidate pool, so an edge
    removed at t may be re-inserted at a later t (the re-insertion path
    the engine tests pin down)."""
    rng = np.random.default_rng(seed)
    live = {tuple(e) for e in g.edge_array().tolist()}
    n = g.n
    max_edges = n * (n - 1) // 2
    for t in range(n_batches):
        n_ins = int(round(batch_size * p_insert))
        # clamp to what the graph can absorb: never sample more fresh
        # edges than are absent (dense/small graphs would spin forever)
        n_ins = min(n_ins, max_edges - len(live))
        n_rm = min(batch_size - n_ins, len(live))
        inserts: list = []
        picked = set()
        while len(inserts) < n_ins:
            u, v = rng.integers(0, n, size=2)
            key = (int(min(u, v)), int(max(u, v)))
            if u == v or key in live or key in picked:
                continue
            picked.add(key)
            inserts.append(key)
        lst = sorted(live)
        take = rng.choice(len(lst), size=n_rm, replace=False)
        removals = [lst[i] for i in take]
        live.difference_update(removals)
        live.update(inserts)
        yield EdgeEvent(
            np.asarray(inserts, dtype=np.int64).reshape(-1, 2),
            "mixed",
            t,
            removals=np.asarray(removals, dtype=np.int64).reshape(-1, 2),
        )


def churn_stream(
    g: CSRGraph,
    n_batches: int,
    batch_size: int,
    p_reinsert: float = 0.6,
    same_batch_roundtrip: bool = True,
    dirty: bool = True,
    seed: int = 0,
) -> Iterator[EdgeEvent]:
    """Balanced 50/50 insert/remove churn with adversarial recycling
    pressure — the steady-state workload the in-program free-list
    allocator (core/engine.py) exists for.

    Per batch: ``batch_size // 2`` removals of live edges, then the same
    number of insertions of which ~``p_reinsert`` re-insert RECENTLY
    removed edges (landing on slots the recycler just reclaimed; the
    rest are fresh absent edges). With ``same_batch_roundtrip`` one of
    the batch's own removals is re-inserted in the SAME event (the slot
    is freed and refilled inside one compiled program). With ``dirty``
    each event also carries rows every engine must mask on device: a
    self-loop, an in-batch duplicate, a duplicate of a live edge, and a
    removal of an absent edge. Live edge count is exactly flat across
    every event — the capacity/high-water invariant tests key on this.

    Consumers tracking the live set must apply removals first, then
    deduped insertions (``CoreMaintainer.apply_batch`` order).
    """
    rng = np.random.default_rng(seed)
    live = {tuple(e) for e in g.edge_array().tolist()}
    pool: list = []  # recently removed candidates for re-insertion
    n = g.n
    max_edges = n * (n - 1) // 2
    for t in range(n_batches):
        k = min(batch_size // 2, len(live))
        lst = sorted(live)
        take = rng.choice(len(lst), size=k, replace=False)
        removals = [lst[i] for i in take]
        live.difference_update(removals)
        inserts: list = []
        if same_batch_roundtrip and removals:
            inserts.append(removals[0])  # removed and re-inserted at t
        while pool and len(inserts) < int(round(k * p_reinsert)):
            e = pool.pop(int(rng.integers(0, len(pool))))
            if e not in live and e not in inserts:
                inserts.append(e)
        # clamp to the absent pairs actually available so the rejection
        # loop terminates on (near-)complete graphs; the removals above
        # guarantee at least k absent pairs, so live stays exactly flat
        # on any graph that is not literally full
        k_ins = min(k, max_edges - len(live))
        while len(inserts) < k_ins:
            u, v = rng.integers(0, n, size=2)
            key = (int(min(u, v)), int(max(u, v)))
            if u == v or key in live or key in inserts:
                continue
            inserts.append(key)
        pool.extend(e for e in removals if e not in inserts)
        live.update(inserts)
        ins = np.asarray(inserts, dtype=np.int64).reshape(-1, 2)
        rm = np.asarray(removals, dtype=np.int64).reshape(-1, 2)
        if dirty:
            garnish = [[3 % n, 3 % n]]  # self-loop
            if inserts:
                garnish.append(list(inserts[-1]))  # in-batch duplicate
            if live:
                garnish.append(list(next(iter(live))))  # dup of live edge
            ins = np.concatenate(
                [ins, np.asarray(garnish, dtype=np.int64)]
            )
            absent_rm = None  # removal of an absent edge is a no-op
            for _ in range(20):
                u, v = rng.integers(0, n, size=2)
                key = (int(min(u, v)), int(max(u, v)))
                if u != v and key not in live:
                    absent_rm = key
                    break
            if absent_rm is not None:
                rm = np.concatenate(
                    [rm, np.asarray([absent_rm], dtype=np.int64)]
                )
        yield EdgeEvent(ins, "mixed", t, removals=rm)


def _validated_temporal(edges_with_time) -> np.ndarray:
    """Normalize a temporal edge list to an int64 [m, 3] array, failing
    loudly on the malformed inputs that used to slip through (a [m, 2]
    list silently replayed vertex ids as timestamps; float timestamps
    truncated)."""
    arr = np.asarray(edges_with_time)
    if arr.ndim != 2 or arr.shape[1] != 3:
        raise ValueError(
            f"temporal edge list must have shape [m, 3] (u, v, t), got "
            f"{arr.shape}"
        )
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"temporal edge list must have an integer dtype (u, v, t), "
            f"got {arr.dtype} — cast timestamps explicitly rather than "
            "letting them truncate silently"
        )
    return arr.astype(np.int64)


def temporal_replay(
    edges_with_time: np.ndarray, batch_size: int
) -> Iterator[EdgeEvent]:
    """Replay a [m, 3] (u, v, t) temporal edge list in timestamp order as
    insertion batches (KONECT-style temporal graphs).

    Ordering guarantee: the sort is STABLE, so rows sharing a timestamp
    replay in input order — a given edge list always produces the same
    batches. That guarantee cuts both ways: when the input is NOT
    already time-sorted and a run of equal timestamps straddles a batch
    boundary, which of the tied edges land in the earlier batch is an
    artifact of input file order rather than of time, so this replay
    refuses (``ValueError``) instead of silently committing one of the
    m! equally-valid batchings. Pre-sort the list (any tie order you
    pick is then YOUR deterministic choice) or use a ``batch_size``
    that keeps ties together."""
    arr = _validated_temporal(edges_with_time)
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    t = arr[:, 2]
    presorted = bool(np.all(t[:-1] <= t[1:]))
    order = np.argsort(t, kind="stable")
    ordered = arr[order]
    ts = ordered[:, 2]
    if not presorted and len(ordered) > batch_size:
        bounds = np.arange(batch_size, len(ordered), batch_size)
        cross = bounds[ts[bounds - 1] == ts[bounds]]
        if cross.size:
            raise ValueError(
                "temporal_replay: unsorted input has equal-timestamp "
                f"ties (t={int(ts[cross[0]])}) crossing a batch "
                "boundary — the stable sort keeps INPUT order within a "
                "timestamp, so the batch split would be an artifact of "
                "file order, not time; pre-sort the edge list or pick a "
                "batch_size that keeps ties in one batch"
            )
    for i in range(0, len(ordered), batch_size):
        chunk = ordered[i : i + batch_size]
        yield EdgeEvent(chunk[:, :2].astype(np.int64), "insert",
                        int(chunk[-1, 2]))


def sliding_window_stream(
    edges_with_time: np.ndarray,
    window: int,
    stride: Optional[int] = None,
) -> Iterator[EdgeEvent]:
    """Sliding-window expiry over a [m, 3] (u, v, t) temporal edge list
    — the workload where REMOVALS are structural, not sampled: each
    step advances time by ``stride`` and yields one mixed event whose
    insertions are the edges arriving in the new stride and whose
    removals are the live edges older than ``window`` (bulk expiry by
    age, the Li et al. dynamic-graph evaluation pattern).

    Semantics (matching ``CoreMaintainer.apply_batch``'s
    removals-first order):

    * the live set is keyed on the undirected pair; a re-arrival of a
      live edge REFRESHES its age (the event does not re-insert it —
      the engine would no-op the duplicate anyway) and a re-arrival of
      an edge expiring in the same step round-trips through one event
      (removal + insertion, the same-batch recycling path);
    * self-loops are dropped; in-step duplicate pairs insert once and
      age by their LATEST arrival;
    * events with neither arrivals nor expiries are elided; the stream
      drains until every edge has expired, so the final live set is
      empty and Σ removals == Σ insertions.

    Timestamps only gate WHICH step an edge joins, so unlike
    ``temporal_replay`` the equal-timestamp tie order never changes the
    output — the input needs no pre-sorting (the stable sort plus
    per-step set semantics make the events input-order independent up
    to in-step insertion order)."""
    arr = _validated_temporal(edges_with_time)
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if stride is None:
        stride = window
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    order = np.argsort(arr[:, 2], kind="stable")
    ordered = arr[order]
    m = len(ordered)
    if m == 0:
        return
    live: dict = {}  # (u, v) -> latest arrival time
    i = 0
    hi = int(ordered[0, 2]) + stride  # step covers arrivals with t < hi
    while i < m or live:
        cutoff = hi - window
        removals = [e for e, ta in live.items() if ta <= cutoff]
        for e in removals:
            del live[e]
        inserts: list = []
        while i < m and int(ordered[i, 2]) < hi:
            u, v, t = (int(x) for x in ordered[i])
            i += 1
            if u == v:
                continue
            key = (min(u, v), max(u, v))
            if key not in live and key not in inserts:
                inserts.append(key)
            live[key] = max(live.get(key, t), t)
        if inserts or removals:
            yield EdgeEvent(
                np.asarray(inserts, dtype=np.int64).reshape(-1, 2),
                "mixed",
                hi,
                removals=np.asarray(
                    removals, dtype=np.int64).reshape(-1, 2),
            )
        hi += stride
