"""Fused Pallas kernels for the core-maintenance round hot path.

The lax reference path (``core/graph_ops.py``) realizes every per-round
neighborhood statistic as gather -> per-edge indicators -> two
``segment_sum`` scatters (per direction), which XLA emits as separate
scatter/gather kernels: one ``mcd_hi_dout`` pass alone launches 6+
kernels, and the 1-core BENCH_stream.json rows show that *dispatch*
overhead — not compute — dominates each round. These kernels collapse a
whole statistics pass over the active-prefix COO slot table into ONE
``pallas_call``:

  HBM:  src/dst/valid [E]  (the active slot window), core [n] int32,
        label [n] int64, aux [n] (rp / candidate mask, stat-dependent)
  VMEM: edge block [BE] + the full per-vertex vectors
  out:  [n, C] packed statistic columns

Grid is ``(n/BN, E/BE)``; the edge axis accumulates into the revisited
output row-block (the block/accumulator idiom of ``segment_ell.py``).
Inside a cell the two directional scatter-adds become two one-hot
matmuls — ``onehot[BN, BE] @ indicators[BE, C]`` — integer adds in a
different order than ``segment_sum``, hence BIT-identical results (the
churn differential harness pins this across every engine config).

Decision fusion: when the caller's vertex layout completes statistics
locally (single device / GSPMD — ``layout.complete`` is the identity),
the per-vertex threshold decision and its commit fold into the same
``pallas_call`` on the last edge block: ``fused_removal_round`` emits
``(mcd, hi, dout_same, new_core, drop)`` and ``fused_promotion_stats``
emits ``(hi, dout_same, viol)`` in one launch. Under a mesh the partial
statistics still need the layout's collective first, so sharded callers
use the stats-only ``coo_stat`` and keep the decision in lax after
``layout.complete`` — which is exactly why the pallas backend changes
LAUNCHES but not COLLECTIVES (the static auditor pins the pallas
config's collective budget equal to the lax one's).

All arithmetic is int32/int64 compares and adds — no floating point —
so ``kernel_backend="pallas"`` is bit-exact against the lax reference,
not merely allclose. ``interpret=True`` (the default off-TPU) lowers to
plain JAX ops, which is how CPU CI runs these under ``shard_map``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# stat name -> number of packed output columns; predicates mirror
# graph_ops.hi_dout_indicators / din_and_expand / count_same_level_in
# verbatim so the two backends cannot drift on tie-breaking
_STAT_COLS = {
    "mcd_hi_dout": 3,
    "hi_dout": 2,
    "mcd": 1,
    "din": 1,
    "same_in": 1,
    "wsum": 1,
}


def default_interpret() -> bool:
    """Interpret mode off-TPU: the kernels lower to plain JAX ops (and so
    compose with shard_map on forced host devices); on TPU they compile."""
    return jax.default_backend() != "tpu"


def _edge_columns(stat, valid, cs, cd, ls, ld, auxs, auxd):
    """Per-edge indicator columns (to_src, to_dst) of one packed stat —
    the in-kernel twin of ``graph_ops``' per-edge predicates."""
    same = valid & (cs == cd)
    if stat == "mcd_hi_dout":
        to_src = (valid & (cd >= cs), valid & (cd > cs), same & (ld > ls))
        to_dst = (valid & (cs >= cd), valid & (cs > cd), same & (ls > ld))
    elif stat == "hi_dout":
        to_src = (valid & (cd > cs), same & (ld > ls))
        to_dst = (valid & (cs > cd), same & (ls > ld))
    elif stat == "mcd":
        to_src = (valid & (cd >= cs),)
        to_dst = (valid & (cs >= cd),)
    elif stat == "din":
        # din_and_expand: reached-and-passing k-order predecessors
        to_src = (same & (ld < ls) & auxd,)
        to_dst = (same & (ls < ld) & auxs,)
    elif stat == "same_in":
        # count_same_level_in: same-level neighbors inside the aux mask
        to_src = (same & auxd,)
        to_dst = (same & auxs,)
    else:
        raise ValueError(f"stat {stat!r} not in {tuple(_STAT_COLS)}")
    pack = lambda cols: jnp.stack(
        [c.astype(jnp.int32) for c in cols], axis=-1
    )
    return pack(to_src), pack(to_dst)


def _accumulate(src, dst, to_src, to_dst, row0, block_n):
    """Scatter one edge block's columns into the [BN, C] row block via two
    one-hot matmuls (the MXU-friendly form of a segment_sum)."""
    be = src.shape[0]
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_n, be), 0)
    onehot_s = (rows == src[None, :]).astype(jnp.int32)
    onehot_d = (rows == dst[None, :]).astype(jnp.int32)
    return (
        jnp.dot(onehot_s, to_src, preferred_element_type=jnp.int32)
        + jnp.dot(onehot_d, to_dst, preferred_element_type=jnp.int32)
    ).astype(jnp.int32)


def _gather_endpoint_state(src, dst, core, label, aux):
    cs = jnp.take(core, src, axis=0, fill_value=0)
    cd = jnp.take(core, dst, axis=0, fill_value=0)
    ls = jnp.take(label, src, axis=0, fill_value=0)
    ld = jnp.take(label, dst, axis=0, fill_value=0)
    auxs = jnp.take(aux, src, axis=0, fill_value=0) != 0
    auxd = jnp.take(aux, dst, axis=0, fill_value=0) != 0
    return cs, cd, ls, ld, auxs, auxd


def _stat_kernel(src_ref, dst_ref, valid_ref, core_ref, label_ref, aux_ref,
                 out_ref, *, stat: str, block_n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...] != 0
    cs, cd, ls, ld, auxs, auxd = _gather_endpoint_state(
        src, dst, core_ref[...], label_ref[...], aux_ref[...]
    )
    to_src, to_dst = _edge_columns(stat, valid, cs, cd, ls, ld, auxs, auxd)
    partial = _accumulate(src, dst, to_src, to_dst, i * block_n, block_n)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


def _wsum_kernel(src_ref, dst_ref, valid_ref, w_ref, core_ref, thresh_ref,
                 out_ref, *, block_n: int):
    """Weighted support sum — the "wsum" stat. Unlike ``_stat_kernel``
    the aux vector carries INTEGER per-vertex thresholds (the bisection
    mids), not a boolean mask, and each edge contributes its weight
    instead of a unit count — hence the dedicated kernel body (the
    shared gather helper folds aux to bool)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...] != 0
    w = w_ref[...]
    core = core_ref[...]
    thresh = thresh_ref[...]
    cs = jnp.take(core, src, axis=0, fill_value=0)
    cd = jnp.take(core, dst, axis=0, fill_value=0)
    ts = jnp.take(thresh, src, axis=0, fill_value=0)
    td = jnp.take(thresh, dst, axis=0, fill_value=0)
    to_src = jnp.where(valid & (cd >= ts), w, 0)[:, None].astype(jnp.int32)
    to_dst = jnp.where(valid & (cs >= td), w, 0)[:, None].astype(jnp.int32)
    partial = _accumulate(src, dst, to_src, to_dst, i * block_n, block_n)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial


def _pad_inputs(src, dst, valid, aux, n, block_e):
    e = src.shape[0]
    e_pad = -e % block_e
    src_p = jnp.pad(src, (0, e_pad))
    dst_p = jnp.pad(dst, (0, e_pad))
    valid_p = jnp.pad(valid.astype(jnp.int32), (0, e_pad))
    aux_p = (
        jnp.zeros((n,), jnp.int32) if aux is None else aux.astype(jnp.int32)
    )
    return src_p, dst_p, valid_p, aux_p


def coo_stat(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    n: int,
    stat: str = "mcd_hi_dout",
    aux: Optional[Array] = None,
    edge_w: Optional[Array] = None,
    block_n: int = 256,
    block_e: int = 256,
    interpret: Optional[bool] = None,
) -> Array:
    """LOCAL packed per-vertex statistics over a COO edge-slot window.

    Returns ``[n, C]`` int32 partial sums — exactly what the lax path's
    local ``segment_sum`` pair produces *before* ``layout.complete``, so
    sharded callers psum / reduce_scatter the result unchanged and the
    collective schedule is identical to the lax backend's.

    ``aux`` is the stat-dependent per-vertex input: a mask (``rp`` for
    "din", the candidate mask for "same_in") or the integer per-vertex
    thresholds for "wsum"; ignored by the other stats. ``edge_w`` is the
    per-slot weight column, consumed only by "wsum" (each edge scatters
    its weight where the endpoint core clears the other endpoint's
    threshold — the weighted h-index bisection's inner statistic).
    """
    ncols = _STAT_COLS[stat]  # raises KeyError loudly on an unknown stat
    if label.dtype != jnp.int64:
        raise TypeError(
            f"label must be int64 (k-order labels), got {label.dtype} — "
            "is jax_enable_x64 off?"
        )
    if src.shape[0] == 0 or n == 0:
        # zero grid = kernel never runs = uninitialized output
        return jnp.zeros((n, ncols), jnp.int32)
    if interpret is None:
        interpret = default_interpret()
    if stat == "wsum":
        if edge_w is None or aux is None:
            raise ValueError(
                "stat='wsum' needs edge_w (per-slot weights) and aux "
                "(per-vertex integer thresholds)"
            )
        e_pad = -src.shape[0] % block_e
        src_p = jnp.pad(src, (0, e_pad))
        dst_p = jnp.pad(dst, (0, e_pad))
        valid_p = jnp.pad(valid.astype(jnp.int32), (0, e_pad))
        w_p = jnp.pad(edge_w.astype(jnp.int32), (0, e_pad))
        np_ = n + (-n % block_n)
        grid = (np_ // block_n, src_p.shape[0] // block_e)
        out = pl.pallas_call(
            functools.partial(_wsum_kernel, block_n=block_n),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_e,), lambda i, j: (j,)),
                pl.BlockSpec((block_e,), lambda i, j: (j,)),
                pl.BlockSpec((block_e,), lambda i, j: (j,)),
                pl.BlockSpec((block_e,), lambda i, j: (j,)),
                pl.BlockSpec((n,), lambda i, j: (0,)),
                pl.BlockSpec((n,), lambda i, j: (0,)),
            ],
            out_specs=pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.int32),
            interpret=interpret,
        )(src_p, dst_p, valid_p, w_p, core, aux.astype(jnp.int32))
        return out[:n]
    src_p, dst_p, valid_p, aux_p = _pad_inputs(
        src, dst, valid, aux, n, block_e
    )
    np_ = n + (-n % block_n)
    grid = (np_ // block_n, src_p.shape[0] // block_e)
    out = pl.pallas_call(
        functools.partial(_stat_kernel, stat=stat, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, ncols), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, ncols), jnp.int32),
        interpret=interpret,
    )(src_p, dst_p, valid_p, core, label, aux_p)
    return out[:n]


def _removal_kernel(src_ref, dst_ref, valid_ref, core_ref, label_ref,
                    aux_ref, coreblk_ref, out_ref, newcore_ref, drop_ref,
                    *, block_n: int, n_eblocks: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...] != 0
    cs, cd, ls, ld, auxs, auxd = _gather_endpoint_state(
        src, dst, core_ref[...], label_ref[...], aux_ref[...]
    )
    to_src, to_dst = _edge_columns(
        "mcd_hi_dout", valid, cs, cd, ls, ld, auxs, auxd
    )
    partial = _accumulate(src, dst, to_src, to_dst, i * block_n, block_n)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial

    # the row block's mcd column is complete once the LAST edge block has
    # accumulated (the grid's second axis is innermost): fold the removal
    # round's threshold decision + core commit into the same launch
    @pl.when(j == n_eblocks - 1)
    def _decide():
        mcd = out_ref[..., 0]
        core_blk = coreblk_ref[...]
        drop = (mcd < core_blk) & (core_blk > 0)
        drop_ref[...] = drop.astype(jnp.int32)
        newcore_ref[...] = core_blk - drop.astype(jnp.int32)


def fused_removal_round(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    n: int,
    block_n: int = 256,
    block_e: int = 256,
    interpret: Optional[bool] = None,
):
    """One removal round — stat + drop decision + core commit — in ONE
    ``pallas_call``. Valid only where the vertex layout completes
    statistics locally (``graph_ops.completes_locally``): the drop test
    needs the GLOBAL mcd. Returns ``(mcd, hi, dout_same, new_core,
    drop)`` with ``drop`` boolean [n]; the label tail placement stays
    outside (``order.place_block`` is a global sort-free relabel over
    the committed mask).
    """
    if label.dtype != jnp.int64:
        raise TypeError(
            f"label must be int64 (k-order labels), got {label.dtype}"
        )
    if src.shape[0] == 0 or n == 0:
        z = jnp.zeros((n,), jnp.int32)
        return z, z, z, core, jnp.zeros((n,), bool)
    if interpret is None:
        interpret = default_interpret()
    src_p, dst_p, valid_p, aux_p = _pad_inputs(
        src, dst, valid, None, n, block_e
    )
    np_ = n + (-n % block_n)
    core_p = jnp.pad(core, (0, np_ - n))
    grid = (np_ // block_n, src_p.shape[0] // block_e)
    stats, new_core, drop = pl.pallas_call(
        functools.partial(
            _removal_kernel, block_n=block_n, n_eblocks=grid[1]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, 3), jnp.int32),
            jax.ShapeDtypeStruct((np_,), core.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(src_p, dst_p, valid_p, core, label, aux_p, core_p)
    return (
        stats[:n, 0],
        stats[:n, 1],
        stats[:n, 2],
        new_core[:n],
        drop[:n] != 0,
    )


def _promotion_kernel(src_ref, dst_ref, valid_ref, core_ref, label_ref,
                      aux_ref, coreblk_ref, out_ref, viol_ref,
                      *, block_n: int, n_eblocks: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    src = src_ref[...]
    dst = dst_ref[...]
    valid = valid_ref[...] != 0
    cs, cd, ls, ld, auxs, auxd = _gather_endpoint_state(
        src, dst, core_ref[...], label_ref[...], aux_ref[...]
    )
    to_src, to_dst = _edge_columns(
        "hi_dout", valid, cs, cd, ls, ld, auxs, auxd
    )
    partial = _accumulate(src, dst, to_src, to_dst, i * block_n, block_n)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial

    # certificate check fused onto the completed row block: a violator has
    # hi + dout_same > core (docs/DESIGN.md §2.3) — the mask that seeds
    # the next promotion round and decides fixpoint termination
    @pl.when(j == n_eblocks - 1)
    def _decide():
        s = out_ref[...]
        viol = (s[..., 0] + s[..., 1]) > coreblk_ref[...]
        viol_ref[...] = viol.astype(jnp.int32)


def fused_promotion_stats(
    src: Array,
    dst: Array,
    valid: Array,
    core: Array,
    label: Array,
    n: int,
    block_n: int = 256,
    block_e: int = 256,
    interpret: Optional[bool] = None,
):
    """Promotion-round terminating statistics — (hi, dout_same) + the
    certificate-violator mask — in ONE ``pallas_call``. Local-completion
    layouts only (the threshold needs global statistics). Returns
    ``(hi, dout_same, viol)`` with ``viol`` boolean [n]."""
    if label.dtype != jnp.int64:
        raise TypeError(
            f"label must be int64 (k-order labels), got {label.dtype}"
        )
    if src.shape[0] == 0 or n == 0:
        z = jnp.zeros((n,), jnp.int32)
        return z, z, jnp.zeros((n,), bool)
    if interpret is None:
        interpret = default_interpret()
    src_p, dst_p, valid_p, aux_p = _pad_inputs(
        src, dst, valid, None, n, block_e
    )
    np_ = n + (-n % block_n)
    core_p = jnp.pad(core, (0, np_ - n))
    grid = (np_ // block_n, src_p.shape[0] // block_e)
    stats, viol = pl.pallas_call(
        functools.partial(
            _promotion_kernel, block_n=block_n, n_eblocks=grid[1]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, 2), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(src_p, dst_p, valid_p, core, label, aux_p, core_p)
    return stats[:n, 0], stats[:n, 1], viol[:n] != 0
