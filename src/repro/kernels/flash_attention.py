"""Pallas TPU kernel: blockwise (flash) attention forward, GQA-aware.

Grid: (batch, q_heads, Sq/BQ); the KV loop runs inside the kernel with
running max / normalizer (the standard streaming-softmax recurrence), so
the [Sq, Sk] score matrix never materializes — VMEM holds
BQ x D (q), BK x D (k, v) and BQ x BK (scores) tiles only.

GQA: the kv head index is derived from the q head index in the BlockSpec
index map (h // group) — no KV repetition in HBM.

Block defaults 512x512 keep the score tile at 1 MB fp32 and both matmul
operands MXU-aligned (D is 64/128 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
                  causal: bool, scale: float, block_q: int):
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [BQ, D]
    bq, d = q.shape
    qi = pl.program_id(2)
    n_kv = seq_k // block_k

    acc = jnp.zeros((bq, d), jnp.float32)
    m = jnp.full((bq, 1), _NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)

    def body(kv_i, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.ds(kv_i * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(kv_i * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T  # [BQ, BK]
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 0
            )
            k_pos = kv_i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = alpha * acc + p @ v
        return acc_new, m_new, l_new

    if causal:
        # only kv blocks with start <= q block end participate
        upper = jnp.minimum(n_kv, (qi + 1) * block_q // block_k + 1)
    else:
        upper = n_kv
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc, m, l))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q [B, H, Sq, D]; k, v [B, Hkv, Sk, D] with H % Hkv == 0."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    scale = scale if scale is not None else float(1.0 / (d ** 0.5))
    grid = (b, h, sq // block_q)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_k=block_k,
            seq_k=sk,
            causal=causal,
            scale=scale,
            block_q=block_q,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda b_, h_, i: (b_, h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda b_, h_, i: (b_, h_ // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h_, i: (b_, h_, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
