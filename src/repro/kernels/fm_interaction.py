"""Pallas TPU kernel: DeepFM second-order interaction.

out[b] = 0.5 * sum_d ((sum_f emb[b,f,d])^2 - sum_f emb[b,f,d]^2)

One pass over the embedding block; fuses what XLA would otherwise emit as
two reductions + elementwise into a single VMEM-resident tile. Tiled on
batch; fields x dim for the assigned deepfm config is 39 x 10 — a single
tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fm_kernel(emb_ref, out_ref):
    e = emb_ref[...].astype(jnp.float32)  # [BB, F, D]
    s = jnp.sum(e, axis=1)
    s2 = jnp.sum(e * e, axis=1)
    out_ref[...] = (0.5 * jnp.sum(s * s - s2, axis=-1)).astype(out_ref.dtype)


def fm_interaction(
    emb: jax.Array, block_b: int = 1024, interpret: bool = False
) -> jax.Array:
    """emb [B, F, D] -> [B] second-order FM logit."""
    b, f, d = emb.shape
    block_b = min(block_b, b)
    pad = -b % block_b
    emb_p = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    bp = emb_p.shape[0]
    out = pl.pallas_call(
        functools.partial(_fm_kernel),
        grid=(bp // block_b,),
        in_specs=[pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), emb.dtype),
        interpret=interpret,
    )(emb_p)
    return out[:b]
