"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU,
so the same call sites work in both environments. Models take a
``use_pallas`` config flag; the default XLA paths remain the reference.
"""
from __future__ import annotations

from functools import partial

import jax

from .fm_interaction import fm_interaction
from .flash_attention import flash_attention
from .segment_ell import ell_aggregate, ell_stat


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("op", "interpret"))
def ell_stat_op(nbrs, vals, self_vals, op="count_ge", interpret=None):
    interpret = _on_cpu() if interpret is None else interpret
    return ell_stat(nbrs, vals, self_vals, op=op, interpret=interpret)


@partial(jax.jit, static_argnames=("op", "interpret"))
def ell_aggregate_op(nbrs, feats, op="sum", interpret=None):
    interpret = _on_cpu() if interpret is None else interpret
    return ell_aggregate(nbrs, feats, op=op, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "interpret", "block_q", "block_k"))
def flash_attention_op(
    q, k, v, causal=True, block_q=512, block_k=512, interpret=None
):
    interpret = _on_cpu() if interpret is None else interpret
    return flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


@partial(jax.jit, static_argnames=("interpret",))
def fm_interaction_op(emb, interpret=None):
    interpret = _on_cpu() if interpret is None else interpret
    return fm_interaction(emb, interpret=interpret)
