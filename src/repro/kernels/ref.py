"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# -- segment_ell ------------------------------------------------------------
def ell_stat_ref(nbrs, vals, self_vals, op="count_ge"):
    n = nbrs.shape[0]
    if n == 0 or nbrs.shape[1] == 0:
        return jnp.zeros((n,), vals.dtype)
    vals_ext = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
    gathered = vals_ext[nbrs]  # [n, D]
    mask = nbrs < n
    if op == "count_ge":
        return jnp.sum(
            (mask & (gathered >= self_vals[:, None])).astype(vals.dtype), axis=1
        )
    if op == "count_gt":
        return jnp.sum(
            (mask & (gathered > self_vals[:, None])).astype(vals.dtype), axis=1
        )
    if op == "sum":
        return jnp.sum(jnp.where(mask, gathered, 0), axis=1)
    if op == "max":
        # empty-neighborhood identity is 0 (matches the kernel's post-
        # reduce sentinel mask); rows with neighbors take the true max
        neg = jnp.asarray(-(2**30), vals.dtype)
        raw = jnp.max(jnp.where(mask, gathered, neg), axis=1)
        return jnp.where(jnp.any(mask, axis=1), raw, 0)
    raise ValueError(op)


def ell_aggregate_ref(nbrs, feats, op="sum"):
    n = nbrs.shape[0]
    if n == 0 or nbrs.shape[1] == 0:
        return jnp.zeros((n, feats.shape[1]), feats.dtype)
    feats_ext = jnp.concatenate(
        [feats, jnp.zeros((1, feats.shape[1]), feats.dtype)], axis=0
    )
    gathered = feats_ext[nbrs]  # [n, D, F]
    mask = (nbrs < n)[..., None]
    if op == "sum":
        return jnp.sum(jnp.where(mask, gathered, 0.0), axis=1)
    if op == "max":
        raw = jnp.max(jnp.where(mask, gathered, -1e30), axis=1)
        return jnp.where(jnp.any(mask, axis=1), raw, 0.0)
    raise ValueError(op)


# -- flash attention ----------------------------------------------------------
def mha_ref(q, k, v, causal=True, scale=None):
    """q [B,H,S,D], k/v [B,Hkv,S,D]; GQA via head broadcast."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32)).astype(
        q.dtype
    )


# -- FM interaction -------------------------------------------------------------
def fm_interaction_ref(emb):
    """DeepFM 2nd-order term: emb [B, F, D] -> [B].
    0.5 * sum_d ((sum_f v)^2 - sum_f v^2)."""
    s = jnp.sum(emb, axis=1)  # [B, D]
    s2 = jnp.sum(emb * emb, axis=1)  # [B, D]
    return 0.5 * jnp.sum(s * s - s2, axis=-1)
