"""Pallas TPU kernel: ELL-tiled neighborhood statistics.

The hot loop of every maintenance round (and of GNN aggregation) is
"for each vertex, reduce a function of its neighbors' values". On TPU we
lay neighbor lists out as a padded ELL matrix ``nbrs [n, max_deg]``
(pad = n) so the reduction becomes a dense, perfectly-tiled
gather -> compare/combine -> row-reduce:

  HBM:  nbrs [n, D]  (int32), vals [n+1]   (value per vertex + sentinel)
  VMEM: row-block [BN, BD] of nbrs + the full vals vector
  out:  [n] per-vertex statistic

Grid is (n/BN, D/BD); the BD axis accumulates into the output block
(revisited across the second grid dimension), which keeps the VMEM
working set at BN*BD + (n+1) elements. Block sizes default to the
MXU/VPU-aligned 256x128.

This is the paper's hardware adaptation: the lock-protected per-vertex
loops become one dense tiled pass (docs/DESIGN.md §1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_OPS = ("count_ge", "count_gt", "count_eq_gt_label", "sum", "max")


def _kernel(nbrs_ref, vals_ref, self_ref, out_ref, *, op: str, n: int):
    j = pl.program_id(1)
    idx = nbrs_ref[...]  # [BN, BD] int32 neighbor ids (pad = n)
    vals = vals_ref[...]  # [n + 1]
    mask = idx < n
    gathered = jnp.take(vals, idx, axis=0, fill_value=0)  # [BN, BD]
    mine = self_ref[...]  # [BN]
    if op == "count_ge":
        contrib = (mask & (gathered >= mine[:, None])).astype(jnp.int32)
        partial = jnp.sum(contrib, axis=1)
    elif op == "count_gt":
        contrib = (mask & (gathered > mine[:, None])).astype(jnp.int32)
        partial = jnp.sum(contrib, axis=1)
    elif op == "sum":
        contrib = jnp.where(mask, gathered, 0)
        partial = jnp.sum(contrib, axis=1)
    elif op == "max":
        neg = jnp.asarray(-(2**30), dtype=out_ref.dtype)
        contrib = jnp.where(mask, gathered, neg)
        partial = jnp.max(contrib, axis=1)
    else:
        raise ValueError(op)
    # under x64, integer reductions accumulate in int64 while out_ref keeps
    # the input dtype — cast back before the swap
    partial = partial.astype(out_ref.dtype)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        if op == "max":
            out_ref[...] = jnp.maximum(out_ref[...], partial)
        else:
            out_ref[...] = out_ref[...] + partial


def ell_stat(
    nbrs: jax.Array,
    vals: jax.Array,
    self_vals: jax.Array,
    op: str = "count_ge",
    block_n: int = 256,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-vertex neighbor statistic.

    nbrs:      [n, max_deg] int32, pad entries = n
    vals:      [n] per-vertex value (int32); a sentinel row is appended
    self_vals: [n] the per-vertex comparison value (usually == vals)
    op:        count_ge (mcd) | count_gt (hi) | sum | max
    """
    if op not in _OPS:
        raise ValueError(f"op {op} not in {_OPS}")
    n, max_deg = nbrs.shape
    n_pad = -n % block_n
    d_pad = -max_deg % block_d
    nbrs_p = jnp.pad(nbrs, ((0, n_pad), (0, d_pad)), constant_values=n)
    self_p = jnp.pad(self_vals, (0, n_pad))
    vals_p = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
    np_, dp_ = nbrs_p.shape
    grid = (np_ // block_n, dp_ // block_d)
    out = pl.pallas_call(
        functools.partial(_kernel, op=op, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((n + 1,), lambda i, j: (0,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), vals.dtype),
        interpret=interpret,
    )(nbrs_p, vals_p, self_p)
    return out[:n]


def _agg_kernel(nbrs_ref, feat_ref, out_ref, *, op: str, n: int):
    j = pl.program_id(1)
    idx = nbrs_ref[...]  # [BN, BD]
    feats = feat_ref[...]  # [n + 1, F]
    mask = (idx < n)[..., None]  # [BN, BD, 1]
    gathered = jnp.take(feats, idx, axis=0, fill_value=0.0)  # [BN, BD, F]
    if op == "sum":
        partial = jnp.sum(jnp.where(mask, gathered, 0.0), axis=1)
    elif op == "max":
        neg = jnp.asarray(-1e30, feats.dtype)
        partial = jnp.max(jnp.where(mask, gathered, neg), axis=1)
    else:
        raise ValueError(op)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        if op == "max":
            out_ref[...] = jnp.maximum(out_ref[...], partial)
        else:
            out_ref[...] = out_ref[...] + partial


def ell_aggregate(
    nbrs: jax.Array,
    feats: jax.Array,
    op: str = "sum",
    block_n: int = 128,
    block_d: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """GNN neighbor aggregation over an ELL layout.

    nbrs:  [n, max_deg] int32 (pad = n)
    feats: [n, F] float
    Returns [n, F] aggregated features (sum or max).
    """
    n, max_deg = nbrs.shape
    f = feats.shape[1]
    n_pad = -n % block_n
    d_pad = -max_deg % block_d
    nbrs_p = jnp.pad(nbrs, ((0, n_pad), (0, d_pad)), constant_values=n)
    feats_p = jnp.concatenate(
        [feats, jnp.zeros((1, f), feats.dtype)], axis=0
    )
    np_, dp_ = nbrs_p.shape
    grid = (np_ // block_n, dp_ // block_d)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, op=op, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((n + 1, f), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, f), feats.dtype),
        interpret=interpret,
    )(nbrs_p, feats_p)
    return out[:n]
