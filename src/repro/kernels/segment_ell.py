"""Pallas TPU kernel: ELL-tiled neighborhood statistics.

The hot loop of every maintenance round (and of GNN aggregation) is
"for each vertex, reduce a function of its neighbors' values". On TPU we
lay neighbor lists out as a padded ELL matrix ``nbrs [n, max_deg]``
(pad = n) so the reduction becomes a dense, perfectly-tiled
gather -> compare/combine -> row-reduce:

  HBM:  nbrs [n, D]  (int32), vals [n+1]   (value per vertex + sentinel)
  VMEM: row-block [BN, BD] of nbrs + the full vals vector
  out:  [n] per-vertex statistic

Grid is (n/BN, D/BD); the BD axis accumulates into the output block
(revisited across the second grid dimension), which keeps the VMEM
working set at BN*BD + (n+1) elements. Block sizes default to the
MXU/VPU-aligned 256x128.

This is the paper's hardware adaptation: the lock-protected per-vertex
loops become one dense tiled pass (docs/DESIGN.md §1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_OPS = ("count_ge", "count_gt", "count_eq_gt_label", "sum", "max")


def _kernel(nbrs_ref, vals_ref, self_ref, out_ref, cnt_ref, *, op: str,
            n: int, n_dblocks: int):
    j = pl.program_id(1)
    idx = nbrs_ref[...]  # [BN, BD] int32 neighbor ids (pad = n)
    vals = vals_ref[...]  # [n + 1]
    mask = idx < n
    gathered = jnp.take(vals, idx, axis=0, fill_value=0)  # [BN, BD]
    mine = self_ref[...]  # [BN]
    if op == "count_ge":
        contrib = (mask & (gathered >= mine[:, None])).astype(jnp.int32)
        partial = jnp.sum(contrib, axis=1)
    elif op == "count_gt":
        contrib = (mask & (gathered > mine[:, None])).astype(jnp.int32)
        partial = jnp.sum(contrib, axis=1)
    elif op == "sum":
        contrib = jnp.where(mask, gathered, 0)
        partial = jnp.sum(contrib, axis=1)
    elif op == "max":
        neg = jnp.asarray(-(2**30), dtype=out_ref.dtype)
        contrib = jnp.where(mask, gathered, neg)
        partial = jnp.max(contrib, axis=1)
    else:
        raise ValueError(op)
    # under x64, integer reductions accumulate in int64 while out_ref keeps
    # the input dtype — cast back before the swap
    partial = partial.astype(out_ref.dtype)
    ncnt = jnp.sum(mask.astype(jnp.int32), axis=1).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial
        cnt_ref[...] = ncnt

    @pl.when(j != 0)
    def _acc():
        if op == "max":
            out_ref[...] = jnp.maximum(out_ref[...], partial)
        else:
            out_ref[...] = out_ref[...] + partial
        cnt_ref[...] = cnt_ref[...] + ncnt

    if op == "max":
        # the running max of an all-pad row is still the init sentinel;
        # replace it with the defined empty-neighborhood identity (0) once
        # the row's last degree block has been accumulated
        @pl.when(j == n_dblocks - 1)
        def _mask_empty():
            out_ref[...] = jnp.where(
                cnt_ref[...] == 0, jnp.zeros_like(out_ref[...]), out_ref[...]
            )


def ell_stat(
    nbrs: jax.Array,
    vals: jax.Array,
    self_vals: jax.Array,
    op: str = "count_ge",
    block_n: int = 256,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-vertex neighbor statistic.

    nbrs:      [n, max_deg] int32, pad entries = n
    vals:      [n] per-vertex value (int32); a sentinel row is appended
    self_vals: [n] the per-vertex comparison value (usually == vals)
    op:        count_ge (mcd) | count_gt (hi) | sum | max

    Rows with no valid neighbors (all-pad, including the ``max_deg == 0``
    degenerate layout) return 0 for every op — ``max`` uses 0 as its
    defined empty-neighborhood identity rather than leaking the internal
    init sentinel.
    """
    if op not in _OPS:
        raise ValueError(f"op {op} not in {_OPS}")
    n, max_deg = nbrs.shape
    if n == 0 or max_deg == 0:
        # a zero grid dimension would skip every kernel invocation and
        # return an UNINITIALIZED buffer — short-circuit to the correct
        # empty-neighborhood result instead
        return jnp.zeros((n,), vals.dtype)
    n_pad = -n % block_n
    d_pad = -max_deg % block_d
    nbrs_p = jnp.pad(nbrs, ((0, n_pad), (0, d_pad)), constant_values=n)
    self_p = jnp.pad(self_vals, (0, n_pad))
    vals_p = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
    np_, dp_ = nbrs_p.shape
    grid = (np_ // block_n, dp_ // block_d)
    out, _ = pl.pallas_call(
        functools.partial(_kernel, op=op, n=n, n_dblocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((n + 1,), lambda i, j: (0,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), vals.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(nbrs_p, vals_p, self_p)
    return out[:n]


def _agg_kernel(nbrs_ref, feat_ref, out_ref, cnt_ref, *, op: str, n: int,
                n_dblocks: int):
    j = pl.program_id(1)
    idx = nbrs_ref[...]  # [BN, BD]
    feats = feat_ref[...]  # [n + 1, F]
    mask = (idx < n)[..., None]  # [BN, BD, 1]
    gathered = jnp.take(feats, idx, axis=0, fill_value=0.0)  # [BN, BD, F]
    if op == "sum":
        partial = jnp.sum(jnp.where(mask, gathered, 0.0), axis=1)
    elif op == "max":
        neg = jnp.asarray(-1e30, feats.dtype)
        partial = jnp.max(jnp.where(mask, gathered, neg), axis=1)
    else:
        raise ValueError(op)
    ncnt = jnp.sum((idx < n).astype(jnp.int32), axis=1).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial
        cnt_ref[...] = ncnt

    @pl.when(j != 0)
    def _acc():
        if op == "max":
            out_ref[...] = jnp.maximum(out_ref[...], partial)
        else:
            out_ref[...] = out_ref[...] + partial
        cnt_ref[...] = cnt_ref[...] + ncnt

    if op == "max":
        # isolated-vertex rows would otherwise return the -1e30 init
        # sentinel; commit the defined empty-neighborhood identity (0)
        @pl.when(j == n_dblocks - 1)
        def _mask_empty():
            out_ref[...] = jnp.where(
                (cnt_ref[...] == 0)[:, None],
                jnp.zeros_like(out_ref[...]),
                out_ref[...],
            )


def ell_aggregate(
    nbrs: jax.Array,
    feats: jax.Array,
    op: str = "sum",
    block_n: int = 128,
    block_d: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """GNN neighbor aggregation over an ELL layout.

    nbrs:  [n, max_deg] int32 (pad = n)
    feats: [n, F] float
    Returns [n, F] aggregated features (sum or max); rows with no valid
    neighbors return 0 for both ops (the ``max`` identity is pinned to 0,
    not the internal -1e30 init sentinel).
    """
    n, max_deg = nbrs.shape
    f = feats.shape[1]
    if n == 0 or max_deg == 0:
        # zero grid dimension = kernel never runs = uninitialized output;
        # short-circuit to the empty-neighborhood aggregate
        return jnp.zeros((n, f), feats.dtype)
    n_pad = -n % block_n
    d_pad = -max_deg % block_d
    nbrs_p = jnp.pad(nbrs, ((0, n_pad), (0, d_pad)), constant_values=n)
    feats_p = jnp.concatenate(
        [feats, jnp.zeros((1, f), feats.dtype)], axis=0
    )
    np_, dp_ = nbrs_p.shape
    grid = (np_ // block_n, dp_ // block_d)
    out, _ = pl.pallas_call(
        functools.partial(_agg_kernel, op=op, n=n, n_dblocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((n + 1, f), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, f), feats.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.int32),
        ],
        interpret=interpret,
    )(nbrs_p, feats_p)
    return out[:n]
