import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, print memory/cost analysis, collect roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-check]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json

The single-pod (16x16) compile feeds the roofline table; the multi-pod
(2x16x16) compile proves the "pod" axis shards.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.compat import set_mesh  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell, cell_names  # noqa: E402
from repro.configs import arch_names, get_arch  # noqa: E402

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*"
)
_SHAPE_RE = re.compile(r"\b((?:f|bf|s|u|pred)\d*)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}


_OP_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)


_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str, default: int = 16) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str) -> dict:
    """Per-device WIRE bytes of every collective, ring-algorithm model:

      all-gather        result * (p-1)/p
      reduce-scatter    result * (p-1)        (result is the shard)
      all-reduce        2 * result * (p-1)/p  (RS + AG phases)
      all-to-all        result * (p-1)/p
      collective-permute result

    p is parsed from replica_groups on each op line.
    """
    out = {
        "all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group("result")):
            nbytes = _DTYPE_BYTES.get(dt, 4)
            size = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        size *= int(d)
            total += size * nbytes
        kind = m.group("kind")
        p = _group_size(line)
        if kind == "all-reduce":
            wire = 2.0 * total * (p - 1) / p
        elif kind == "reduce-scatter":
            wire = 1.0 * total * (p - 1)
        elif kind == "collective-permute":
            wire = float(total)
        else:  # all-gather, all-to-all
            wire = 1.0 * total * (p - 1) / p
        out[kind] += wire
        counts[kind] += 1
    out["op_counts"] = counts
    return out


def _layer_probe(arch: str, shape: str, mesh, multi_pod: bool):
    """Per-layer HLO cost probe for LM cells.

    XLA's cost_analysis counts a rolled ``scan`` body ONCE (calibrated in
    EXPERIMENTS.md §Dry-run), so the full-program numbers undercount the
    layer stack by (L-1)x. This probe lowers ONE layer with the same
    shardings; run_cell reports corrected = rolled + (L-1) * probe.
    """
    import functools

    from jax.sharding import PartitionSpec as P
    from repro.models import transformer as tf_mod
    from repro.parallel import sharding as shard_rules

    import dataclasses as _dc

    mod = get_arch(arch)
    if mod.FAMILY != "lm":
        return None
    cfg = _dc.replace(
        mod.full(),
        batch_axes=("pod", "data") if multi_pod else "data",
        tp_axis="model",
        attn_chunk=2048,
    )
    cell = next(c for c in mod.SHAPES if c.name == shape)
    L = cfg.n_layers
    p_abs = jax.eval_shape(
        functools.partial(tf_mod.init_params, cfg), jax.random.PRNGKey(0)
    )
    lay_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
        p_abs["layers"],
    )
    lay_spec = jax.tree.map(
        lambda s: jax.sharding.PartitionSpec(*s[1:]),
        shard_rules.lm_param_specs(cfg)["layers"],
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    batch_ax = ("pod", "data") if multi_pod else "data"
    pods = 2 if multi_pod else 1

    if cell.kind in ("train", "prefill"):
        b, s = cell.params["batch"], cell.params["seq"]
        x_abs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)
        x_spec = P(batch_ax, None, None)
        positions = None

        if cell.kind == "train":
            def probe(lp, x):
                pos = jnp.arange(x.shape[1])[None, :]

                def f(args):
                    lp_, x_ = args
                    y, aux = tf_mod._layer_fwd(cfg, lp_, x_, pos)
                    return jnp.sum(y.astype(jnp.float32)) + aux

                g = jax.grad(f)((lp, x))
                return g
        else:
            def probe(lp, x):
                pos = jnp.arange(x.shape[1])[None, :]
                y, _ = tf_mod._layer_fwd(cfg, lp, x, pos)
                return y

        abstract = (lay_abs, x_abs)
        specs = (lay_spec, x_spec)
    else:  # decode
        b, t = cell.params["batch"], cell.params["cache"]
        cache_abs = jax.eval_shape(
            functools.partial(tf_mod.init_cache, cfg, b, t)
        )
        lc_abs = {
            k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
            for k, v in cache_abs.items() if k != "length"
        }
        full_cache_spec = shard_rules.lm_cache_specs(
            cfg, multi_pod, batch=b
        )
        lc_spec = {
            k: jax.sharding.PartitionSpec(*v[1:])
            for k, v in full_cache_spec.items() if k != "length"
        }
        x_abs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.dtype)
        x_spec = (
            P(batch_ax, None, None)
            if b % (16 * pods) == 0 else P(None, None, None)
        )

        def probe(lp, lc, x):
            y, _ = tf_mod._decode_layer(cfg, lp, x, lc, jnp.int32(t // 2))
            return y

        abstract = (lay_abs, lc_abs, x_abs)
        specs = (lay_spec, lc_spec, x_spec)

    in_sh = jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    with mesh:
        compiled = jax.jit(probe, in_shardings=in_sh).lower(
            *abstract
        ).compile()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "n_layers": L,
        "flops": float(ca.get("flops", 0.0)),
        "dot_flops": dot_flops(hlo),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
    }


def model_flops(arch: str, shape: str) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (N_active for MoE), 2*N*D for
    prefill, 2*N_active per decoded token + attention KV term."""
    mod = get_arch(arch)
    cell = next(c for c in mod.SHAPES if c.name == shape)
    if mod.FAMILY == "lm":
        cfg = mod.full()
        n_act = cfg.n_active_params
        if cell.kind == "train":
            d = cell.params["batch"] * cell.params["seq"]
            return 6.0 * n_act * d
        if cell.kind == "prefill":
            d = cell.params["batch"] * cell.params["seq"]
            return 2.0 * n_act * d
        # decode: matmul flops + attention against the cache
        b, t = cell.params["batch"], cell.params["cache"]
        if cfg.attention == "mla":
            m = cfg.mla
            attn = 2.0 * b * t * cfg.n_heads * (
                m.kv_lora + m.rope_head_dim + m.kv_lora
            )
        else:
            attn = 4.0 * b * t * cfg.n_heads * cfg.d_head
        return 2.0 * n_act * b + attn * cfg.n_layers
    if mod.FAMILY == "recsys":
        cfg = mod.full()
        b = cell.params.get("batch", 1)
        d_in = cfg.n_sparse * cfg.embed_dim
        dims = (d_in,) + tuple(cfg.mlp_dims) + (1,)
        mlp = sum(2 * a * c for a, c in zip(dims[:-1], dims[1:]))
        per_ex = mlp + 4 * cfg.n_sparse * cfg.embed_dim
        mult = 3.0 if cell.kind == "train" else 1.0
        if cell.kind == "retrieval":
            return 2.0 * cell.params["n_candidates"] * cfg.embed_dim
        return mult * per_ex * b
    return 0.0  # GNN: reported via HLO only (no closed form in 6ND terms)


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_DOT_LINE_RE = re.compile(
    r"=\s*[a-z0-9]+\[(?P<res>[\d,]*)\][^=]*?\bdot\("
    r"\s*%?(?P<a>[\w.\-]+)\s*,\s*%?(?P<b>[\w.\-]+)\s*\)"
    r".*?lhs_contracting_dims=\{(?P<lc>[\d,]*)\}"
)


def dot_flops(hlo_text: str) -> float:
    """Matmul flops counted directly from optimized HLO dot ops
    (per-device): 2 * prod(result dims) * prod(lhs contracting sizes).
    Operand shapes come from a module-wide symbol table (HLO text omits
    operand types on the op line). Transparent alternative to XLA's
    aggregate 'flops', which also counts elementwise/convert traffic."""
    defs = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, _, dims = m.groups()
            defs[name] = [int(d) for d in dims.split(",") if d]
    total = 0.0
    for line in hlo_text.splitlines():
        m = _DOT_LINE_RE.search(line)
        if not m:
            continue
        res = [int(d) for d in m.group("res").split(",") if d]
        lhs = defs.get(m.group("a"), [])
        lc = [int(d) for d in m.group("lc").split(",") if d]
        k = 1
        for dim in lc:
            if dim < len(lhs):
                k *= lhs[dim]
        r = 1
        for d in res:
            r *= d
        total += 2.0 * r * k
    return total


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             probe_layers: bool = True, unroll: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    prog = build_cell(arch, shape, smoke=False, multi_pod=multi_pod,
                      unroll=unroll)
    if unroll:
        probe_layers = False  # exact: every layer present in the HLO
    in_shardings = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        prog.in_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    out_shardings = None
    if prog.out_specs is not None:
        out_shardings = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec),
            prog.out_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
    t0 = time.time()
    set_mesh(mesh)
    with mesh:
        jitted = jax.jit(
            prog.fn, in_shardings=in_shardings, out_shardings=out_shardings,
            donate_argnums=prog.donate,
        )
        lowered = jitted.lower(*prog.abstract_inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    dflops = dot_flops(hlo)

    # scan-body correction: cost_analysis counts the layer scan body once;
    # add (L-1) x the per-layer probe costs (LM cells only).
    probe = (
        _layer_probe(arch, shape, mesh, multi_pod) if probe_layers else None
    )
    if probe:
        k = probe["n_layers"] - 1
        cost = dict(cost or {})
        dflops = dflops + k * probe["dot_flops"]
        cost["flops"] = float(cost.get("flops", 0.0)) + k * probe["flops"]
        cost["bytes accessed"] = (
            float(cost.get("bytes accessed", 0.0))
            + k * probe["bytes_accessed"]
        )
        for key in coll:
            if key == "op_counts":
                continue
            coll[key] += k * probe["collective_bytes"].get(key, 0)

    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "dot_flops": dflops,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0))
        if cost else 0.0,
        "collective_bytes": coll,
        "mem": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(
                mem, "peak_memory_in_bytes",
                getattr(mem, "temp_size_in_bytes", 0),
            ),
        },
    }
    # roofline terms (single-pod table; see EXPERIMENTS.md §Roofline).
    # cost_analysis flops/bytes are PER-DEVICE program costs (calibrated);
    # collective result-bytes are per-device wire bytes.
    coll_total = sum(v for k, v in coll.items() if k != "op_counts")
    # memory term: argument+output bytes are the schedule-independent HBM
    # traffic floor (weights/opt-state/cache each touched once); XLA's
    # fusion-blind "bytes accessed" is reported as the pessimistic bound.
    mem_floor = (
        result["mem"]["argument_bytes"] + result["mem"]["output_bytes"]
    )
    result["roofline"] = {
        "t_compute_s": result["dot_flops"] / HW["peak_flops_bf16"],
        "t_memory_s": mem_floor / HW["hbm_bw"],
        "t_collective_s": coll_total
        / (HW["ici_bw_per_link"] * HW["ici_links"]),
        "t_memory_xla_upper_s": result["bytes_accessed"] / HW["hbm_bw"],
    }
    terms = {
        k: v for k, v in result["roofline"].items()
        if k in ("t_compute_s", "t_memory_s", "t_collective_s")
    }
    dom = max(terms, key=terms.get)
    result["roofline"]["dominant"] = dom
    mf = model_flops(arch, shape)
    result["model_flops_global"] = mf
    dot_global = result["dot_flops"] * n_dev
    result["model_vs_hlo"] = (mf / dot_global) if dot_global else None
    if verbose:
        print(f"== {arch} x {shape} on {result['mesh']} "
              f"({n_dev} devices) ==")
        print(f"   lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"   memory_analysis: {result['mem']}")
        print(f"   cost_analysis: xla_flops={result['flops']:.3e} "
              f"dot_flops={result['dot_flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e}")
        print(f"   model_flops={mf:.3e} useful-ratio={result['model_vs_hlo']}")
        print(f"   collectives: {coll}")
        print(f"   roofline: {terms}")
        sys.stdout.flush()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--include-coremaint", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan for exact HLO accounting")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        f"expected 512 virtual devices, got {len(jax.devices())}"
    )
    cells = []
    if args.all:
        for a in arch_names(include_coremaint=args.include_coremaint):
            for s in cell_names(a):
                cells.append((a, s))
    else:
        archs = [args.arch] if args.arch else arch_names()
        for a in archs:
            shapes = [args.shape] if args.shape else cell_names(a)
            for s in shapes:
                cells.append((a, s))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results, failures = [], []
    for a, s in cells:
        for mp in meshes:
            try:
                results.append(run_cell(a, s, multi_pod=mp,
                                        unroll=args.unroll))
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((a, s, mp, repr(e)))
    print(f"\n{len(results)} cells compiled OK, {len(failures)} failed")
    for f in failures:
        print("FAILED:", f)
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(results, fh, indent=1)
        print(f"wrote {args.out}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
