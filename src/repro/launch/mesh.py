"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_edge_mesh(n_devices=None, axis: str = "data"):
    """1-D mesh over the local devices for edge-slot sharding — the mesh
    shape ``CoreMaintainer(engine="sharded")`` consumes by default. On a
    production slice this is the flattened ``data`` axis of
    ``make_production_mesh``."""
    ndev = n_devices or len(jax.devices())
    return jax.make_mesh((ndev,), (axis,))


# The axis that carries the vertex OWNER sharding of the halo layouts
# (``CoreMaintainer(engine="sharded", vertex_sharding="range" | "halo")``).
# Vertex range i lives on owner-axis coordinate i, so every statistic
# completes with owner-axis collectives (core/vertex_layout.py).
VERTEX_AXIS = "data"

# The pure-edge axis of the 2-axis factorization: edge slots shard over
# (EDGE_SHARD_AXIS, VERTEX_AXIS) flattened, vertex state only over
# VERTEX_AXIS, and completed statistics gain exactly one psum over this
# axis (docs/DESIGN.md §4.4).
EDGE_SHARD_AXIS = "edge"


def make_edge_vertex_mesh(n_devices=None, mesh_shape=None,
                          axis: str = VERTEX_AXIS,
                          edge_axis: str = EDGE_SHARD_AXIS):
    """Mesh for the halo-sharded vertex layouts.

    ``mesh_shape=(d_e, d_v)`` builds the genuine 2-axis factorization:
    ``d_e`` pure-edge shards x ``d_v`` vertex-owner ranges, axes
    ``(edge_axis, axis)``. Edge slots shard over BOTH axes (the flattened
    device order matches the 1-D mesh, so the degenerate ``(1, d)`` and
    ``(d, 1)`` shapes are bit-identical — slot allocation included — to
    the single-axis engines); vertex state shards over ``axis`` only and
    is replicated across ``edge_axis``, which is what drops per-device
    vertex memory to O(n / d_v + halo).

    ``mesh_shape=None`` keeps the historical single shared axis: device i
    owns edge shard i AND vertex range i (``vertex_sharding="range"``),
    every collective single-axis — exactly the ``(1, d_v)`` column of the
    §4.4 traffic model."""
    if mesh_shape is None:
        return make_edge_mesh(n_devices, axis)
    d_e, d_v = (int(mesh_shape[0]), int(mesh_shape[1]))
    if d_e < 1 or d_v < 1:
        raise ValueError(f"mesh_shape must be positive, got {mesh_shape}")
    ndev = n_devices or len(jax.devices())
    if d_e * d_v != ndev:
        raise ValueError(
            f"mesh_shape {d_e}x{d_v} needs {d_e * d_v} devices, have "
            f"{ndev}"
        )
    return jax.make_mesh((d_e, d_v), (edge_axis, axis))


HW = {
    "name": "TPU v5e",
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw_per_link": 50e9,       # bytes/s per link (~)
    "ici_links": 4,                # 2D torus: 4 links per chip
    "hbm_bytes": 16 * 2**30,
}
