"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_edge_mesh(n_devices=None, axis: str = "data"):
    """1-D mesh over the local devices for edge-slot sharding — the mesh
    shape ``CoreMaintainer(engine="sharded")`` consumes by default. On a
    production slice this is the flattened ``data`` axis of
    ``make_production_mesh``."""
    ndev = n_devices or len(jax.devices())
    return jax.make_mesh((ndev,), (axis,))


# The axis that carries the vertex RANGE sharding of
# ``CoreMaintainer(engine="sharded", vertex_sharding="range")``. It is
# the edge axis: vertex range i lives with edge shard i, so every
# statistic completes with a single-axis reduce_scatter and the frontier
# bitmasks with a single-axis all_gather (core/vertex_layout.py).
VERTEX_AXIS = "data"


def make_edge_vertex_mesh(n_devices=None, axis: str = VERTEX_AXIS):
    """Mesh for the range-sharded vertex layout: one axis shared by the
    edge-slot sharding AND the vertex range sharding.

    Sharing the axis is deliberate — device i owns edge shard i and
    vertex range i, so ``RangeShardedVertices.complete`` is one
    ``psum_scatter`` over this axis and no cross-axis collective exists.
    A genuine 2-axis factorization (edge shards x vertex ranges, e.g.
    re-using ``make_production_mesh``'s ``data`` x ``model``) plugs in
    by psum-ing partial stats over the pure-edge axes before the
    scatter; the shipped engine does not need it and keeps every
    collective single-axis."""
    return make_edge_mesh(n_devices, axis)


HW = {
    "name": "TPU v5e",
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw_per_link": 50e9,       # bytes/s per link (~)
    "ici_links": 4,                # 2D torus: 4 links per chip
    "hbm_bytes": 16 * 2**30,
}
