"""Serving launcher: prefill + batched decode with the KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-lite-16b \
      --smoke --batch 2 --prompt-len 32 --gen 16

Demonstrates the serving substrate on CPU with a reduced config; the full
configs are exercised via the dry-run (prefill_32k / decode_32k /
long_500k cells).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as tf_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit("launch.serve drives LM archs")
    import dataclasses
    cfg = mod.smoke() if args.smoke else mod.full()
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen

    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    t0 = time.perf_counter()
    logits, cache = tf_mod.prefill(cfg, params, prompt)
    # widen the cache to generation capacity
    for k in cache:
        if k == "length":
            continue
        pad = max_seq - cache[k].shape[2]
        widths = [(0, 0)] * cache[k].ndim
        widths[2] = (0, pad)
        cache[k] = jnp.pad(cache[k], widths)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(
        lambda p, c, t: tf_mod.decode_step(cfg, p, c, t)
    )
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.stack(out, axis=1)
    print(f"[serve] {cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill*1e3:.1f} ms; "
          f"decoded {args.gen-1} steps in {t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("[serve] sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
