"""Cell programs: (arch x shape) -> step function + inputs + shardings.

Used by BOTH the per-arch smoke tests (concrete small inputs, 1 device)
and the multi-pod dry-run (ShapeDtypeStruct inputs + PartitionSpecs,
512 devices). One code path builds the function; only the input source
differs — which is what makes the dry-run meaningful.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs import get_arch
from ..configs.common import ShapeCell
from ..models import gnn as gnn_mod
from ..models import recsys as rec_mod
from ..models import transformer as tf_mod
from ..models.gnn import GraphBatch
from ..optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from ..parallel import sharding as shard_rules

Array = jax.Array
SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellProgram:
    """Everything needed to lower or run one (arch x shape) cell."""

    name: str
    fn: Callable[..., Any]
    abstract_inputs: Tuple[Any, ...]
    in_specs: Optional[Tuple[Any, ...]]      # PartitionSpecs (dry-run)
    out_specs: Optional[Any]
    concrete_inputs: Optional[Callable[[jax.Array], Tuple[Any, ...]]] = None
    donate: Tuple[int, ...] = ()


def _tree_sds(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def _abstract_params(init_fn, key_shape=()):
    """Shape-evaluate an init function without allocating."""
    return jax.eval_shape(init_fn, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_train_step(cfg):
    def loss(params, tokens, targets):
        return tf_mod.loss_fn(cfg, params, tokens, targets)

    def step(params, opt_state, tokens, targets):
        l, grads = jax.value_and_grad(loss)(params, tokens, targets)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(
            params, grads, opt_state, jnp.float32(1e-4)
        )
        return params, opt_state, {"loss": l, "grad_norm": gn}

    return step


def _lm_cell(arch_name: str, cfg, cell: ShapeCell, multi_pod: bool,
             for_smoke: bool) -> CellProgram:
    if not for_smoke and not os.environ.get("REPRO_NO_PIN"):
        cfg = dataclasses.replace(
            cfg,
            batch_axes=("pod", "data") if multi_pod else "data",
            tp_axis="model",
            attn_chunk=2048,  # streaming-softmax KV chunking (D2)
        )
    init = functools.partial(tf_mod.init_params, cfg)
    p_abs = _abstract_params(init)
    p_spec = shard_rules.lm_param_specs(cfg, None)
    batch_spec = shard_rules.lm_batch_spec(multi_pod)
    opt_spec = {
        "m": p_spec, "v": p_spec, "count": P(),
    }
    if cell.kind == "train":
        b, s = cell.params["batch"], cell.params["seq"]
        fn = _lm_train_step(cfg)
        opt_abs = jax.eval_shape(adamw_init, p_abs)
        abstract = (
            p_abs, opt_abs,
            SDS((b, s), jnp.int32), SDS((b, s), jnp.int32),
        )
        in_specs = (p_spec, opt_spec, batch_spec, batch_spec)
        out_specs = (p_spec, opt_spec, {"loss": P(), "grad_norm": P()})
        donate = (0, 1)

        def concrete(key):
            params = init(key)
            toks = jax.random.randint(key, (b, s), 0, cfg.vocab, jnp.int32)
            return params, adamw_init(params), toks, toks

    elif cell.kind == "prefill":
        b, s = cell.params["batch"], cell.params["seq"]

        def fn(params, tokens):
            return tf_mod.prefill(cfg, params, tokens)

        abstract = (p_abs, SDS((b, s), jnp.int32))
        in_specs = (p_spec, batch_spec)
        cache_spec = shard_rules.lm_cache_specs(cfg, multi_pod, batch=b)
        out_specs = (P(batch_spec[0], "model"), cache_spec)
        donate = ()

        def concrete(key):
            return init(key), jax.random.randint(
                key, (b, s), 0, cfg.vocab, jnp.int32
            )

    elif cell.kind == "decode":
        b, t = cell.params["batch"], cell.params["cache"]
        cache_abs = jax.eval_shape(
            functools.partial(tf_mod.init_cache, cfg, b, t)
        )

        def fn(params, cache, token):
            return tf_mod.decode_step(cfg, params, cache, token)

        pods = 2 if multi_pod else 1
        tok_spec = (
            P(("pod", "data") if multi_pod else "data")
            if b % (16 * pods) == 0 else P(None)
        )
        cache_spec = shard_rules.lm_cache_specs(cfg, multi_pod, batch=b)
        abstract = (p_abs, cache_abs, SDS((b,), jnp.int32))
        in_specs = (p_spec, cache_spec, tok_spec)
        out_specs = (P(tok_spec[0], "model"), cache_spec)
        donate = (1,)

        def concrete(key):
            params = init(key)
            cache = tf_mod.init_cache(cfg, b, t)
            cache["length"] = jnp.asarray(t // 2, jnp.int32)
            tok = jax.random.randint(key, (b,), 0, cfg.vocab, jnp.int32)
            return params, cache, tok

    else:
        raise ValueError(cell.kind)
    return CellProgram(
        name=f"{arch_name}:{cell.name}", fn=fn, abstract_inputs=abstract,
        in_specs=in_specs, out_specs=out_specs,
        concrete_inputs=concrete if for_smoke else None, donate=donate,
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
def _gnn_fwd_and_loss(arch_name: str, cfg):
    if arch_name.startswith("pna"):
        def loss(params, batch, labels):
            logits = gnn_mod.pna_forward(cfg, params, batch)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
            return jnp.sum(nll * batch.node_mask) / jnp.maximum(
                jnp.sum(batch.node_mask), 1.0
            )
        return gnn_mod.pna_init, loss, "node_labels"
    if arch_name.startswith("gin"):
        def loss(params, batch, labels):
            logits = gnn_mod.gin_forward(cfg, params, batch)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(
                jnp.take_along_axis(logp, labels[:, None], axis=-1)
            )
        return gnn_mod.gin_init, loss, "graph_labels"
    if arch_name.startswith("dimenet"):
        def loss(params, batch_and_tri, energies):
            batch, tkj, tji, tm = batch_and_tri
            e = gnn_mod.dimenet_forward(cfg, params, batch, tkj, tji, tm)
            return jnp.mean((e - energies) ** 2)
        return gnn_mod.dimenet_init, loss, "energies"
    if arch_name.startswith("nequip"):
        def loss(params, batch, energies):
            e = gnn_mod.nequip_energy(cfg, params, batch.positions, batch)
            return jnp.mean((e - energies) ** 2)
        return gnn_mod.nequip_init, loss, "energies"
    raise ValueError(arch_name)


def _pad512(x: int) -> int:
    return -(-x // 512) * 512


def _graph_shapes_for_cell(cell: ShapeCell) -> Tuple[int, int, int, int]:
    """(n_nodes, n_edges_directed, d_feat, n_graphs) for a GNN cell.
    Node/edge capacities are padded to multiples of 512 so every cell
    shards over the full 512-chip mesh (pads are masked)."""
    p = cell.params
    if cell.kind == "full_graph":
        return _pad512(p["n_nodes"]), _pad512(p["n_edges"]), p["d_feat"], 1
    if cell.kind == "minibatch":
        mult = 1
        for f in p["fanout"]:
            mult *= f + 1
        n_cap = _pad512(p["batch_nodes"] * mult)
        return n_cap, 2 * n_cap, p["d_feat"], 1
    if cell.kind == "molecule":
        return (
            _pad512(p["n_nodes"] * p["batch"]),
            _pad512(p["n_edges"] * p["batch"]),
            1,
            p["batch"],
        )
    raise ValueError(cell.kind)


def _abstract_graph_batch(n, e, f, g, molecular: bool):
    return GraphBatch(
        node_feat=SDS((n, f), jnp.float32),
        senders=SDS((e,), jnp.int32),
        receivers=SDS((e,), jnp.int32),
        edge_mask=SDS((e,), jnp.bool_),
        node_mask=SDS((n,), jnp.bool_),
        graph_id=SDS((n,), jnp.int32),
        n_graphs=g,
        positions=SDS((n, 3), jnp.float32) if molecular else None,
        species=SDS((n,), jnp.int32) if molecular else None,
    )


def _concrete_graph_batch(key, n, e, f, g, molecular: bool, connected=True):
    rng = np.random.default_rng(0)
    senders = rng.integers(0, n, size=e).astype(np.int32)
    receivers = rng.integers(0, n, size=e).astype(np.int32)
    return GraphBatch(
        node_feat=jnp.asarray(rng.normal(size=(n, f)), jnp.float32),
        senders=jnp.asarray(senders),
        receivers=jnp.asarray(receivers),
        edge_mask=jnp.asarray(senders != receivers),
        node_mask=jnp.ones((n,), bool),
        graph_id=jnp.asarray(
            np.minimum(np.arange(n) * g // max(n, 1), g - 1), jnp.int32
        ),
        n_graphs=g,
        positions=(
            jnp.asarray(rng.normal(size=(n, 3)) * 2, jnp.float32)
            if molecular else None
        ),
        species=(
            jnp.asarray(rng.integers(0, 8, size=n), jnp.int32)
            if molecular else None
        ),
    )


def _gnn_cell(arch_name: str, cfg, cell: ShapeCell, multi_pod: bool,
              for_smoke: bool) -> CellProgram:
    molecular = arch_name.startswith(("dimenet", "nequip"))
    n, e, f, g = _graph_shapes_for_cell(cell)
    if hasattr(cfg, "d_in") and cfg.d_in != f:
        cfg = dataclasses.replace(cfg, d_in=f)  # shape dictates input width
    flat_axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if (not for_smoke and not os.environ.get("REPRO_NO_PIN")
            and hasattr(cfg, "shard_axes")):
        upd = {"shard_axes": flat_axes}
        if hasattr(cfg, "msg_dtype"):
            upd["msg_dtype"] = jnp.bfloat16
        cfg = dataclasses.replace(cfg, **upd)
    init, loss, label_kind = _gnn_fwd_and_loss(arch_name, cfg)
    p_abs = _abstract_params(functools.partial(init, cfg))
    batch_abs = _abstract_graph_batch(n, e, f, g, molecular)
    flat = ("pod", "data", "model") if multi_pod else ("data", "model")
    gspec = GraphBatch(
        node_feat=P(flat, None), senders=P(flat), receivers=P(flat),
        edge_mask=P(flat), node_mask=P(flat), graph_id=P(flat),
        n_graphs=g,
        positions=P(flat, None) if molecular else None,
        species=P(flat) if molecular else None,
    )
    p_spec = jax.tree.map(lambda _: P(), p_abs)
    opt_spec = jax.tree.map(lambda _: P(), jax.eval_shape(adamw_init, p_abs))

    if label_kind == "node_labels":
        lab_abs, lab_spec = SDS((n,), jnp.int32), P(flat)
    elif label_kind == "graph_labels":
        lab_abs, lab_spec = SDS((g,), jnp.int32), P()
    else:
        lab_abs, lab_spec = SDS((g,), jnp.float32), P()

    is_dimenet = arch_name.startswith("dimenet")
    t_cap = 2 * e if is_dimenet else 0

    def step(params, opt_state, batch, labels, *tri):
        if is_dimenet:
            arg = (batch,) + tri
        else:
            arg = batch
        l, grads = jax.value_and_grad(loss)(params, arg, labels)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(
            params, grads, opt_state, jnp.float32(1e-3)
        )
        return params, opt_state, {"loss": l, "grad_norm": gn}

    abstract = [p_abs, jax.eval_shape(adamw_init, p_abs), batch_abs, lab_abs]
    in_specs = [p_spec, opt_spec, gspec, lab_spec]
    if is_dimenet:
        abstract += [
            SDS((t_cap,), jnp.int32), SDS((t_cap,), jnp.int32),
            SDS((t_cap,), jnp.bool_),
        ]
        in_specs += [P(flat), P(flat), P(flat)]
    out_specs = (p_spec, opt_spec, {"loss": P(), "grad_norm": P()})

    def concrete(key):
        params = init(cfg, key)
        batch = _concrete_graph_batch(key, n, e, f, g, molecular)
        if label_kind == "node_labels":
            labels = jnp.asarray(
                np.random.default_rng(1).integers(0, cfg.n_classes, size=n),
                jnp.int32,
            )
        elif label_kind == "graph_labels":
            labels = jnp.asarray(
                np.random.default_rng(1).integers(0, cfg.n_classes, size=g),
                jnp.int32,
            )
        else:
            labels = jnp.asarray(
                np.random.default_rng(1).normal(size=g), jnp.float32
            )
        out = [params, adamw_init(params), batch, labels]
        if is_dimenet:
            tkj, tji, tm = gnn_mod.build_triplets(
                batch.senders, batch.receivers, batch.edge_mask, t_cap
            )
            out += [jnp.asarray(tkj), jnp.asarray(tji), jnp.asarray(tm)]
        return tuple(out)

    return CellProgram(
        name=f"{arch_name}:{cell.name}", fn=step,
        abstract_inputs=tuple(abstract), in_specs=tuple(in_specs),
        out_specs=out_specs,
        concrete_inputs=concrete if for_smoke else None, donate=(0, 1),
    )


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------
def _recsys_cell(arch_name: str, cfg, cell: ShapeCell, multi_pod: bool,
                 for_smoke: bool) -> CellProgram:
    init = functools.partial(rec_mod.deepfm_init, cfg)
    p_abs = _abstract_params(init)
    flat = ("pod", "data", "model") if multi_pod else ("data", "model")
    p_spec = {
        "embed": P(flat, None),
        "w1": P(flat),
        "bias": P(),
        "mlp": jax.tree.map(lambda _: P(), p_abs["mlp"]),
    }
    b = cell.params["batch"]
    if cell.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, p_abs)
        opt_spec = {
            "m": p_spec, "v": p_spec, "count": P(),
        }

        def step(params, opt_state, sparse, labels):
            def loss(p):
                return rec_mod.deepfm_loss(cfg, p, sparse, labels)

            l, grads = jax.value_and_grad(loss)(params)
            grads, gn = clip_by_global_norm(grads, 1.0)
            params, opt_state = adamw_update(
                params, grads, opt_state, jnp.float32(1e-3)
            )
            return params, opt_state, {"loss": l, "grad_norm": gn}

        abstract = (
            p_abs, opt_abs,
            SDS((b, cfg.n_sparse), jnp.int32), SDS((b,), jnp.float32),
        )
        in_specs = (p_spec, opt_spec, P(flat, None), P(flat))
        out_specs = (p_spec, opt_spec, {"loss": P(), "grad_norm": P()})
        donate = (0, 1)

        def concrete(key):
            params = init(key)
            rng = np.random.default_rng(0)
            ids = jnp.asarray(
                rng.integers(0, cfg.rows_per_field, size=(b, cfg.n_sparse)),
                jnp.int32,
            )
            lab = jnp.asarray(rng.integers(0, 2, size=b), jnp.float32)
            return params, adamw_init(params), ids, lab

    elif cell.kind == "serve":
        def step(params, sparse):
            return rec_mod.deepfm_forward(cfg, params, sparse)

        abstract = (p_abs, SDS((b, cfg.n_sparse), jnp.int32))
        in_specs = (p_spec, P(flat, None))
        out_specs = P(flat)
        donate = ()

        def concrete(key):
            rng = np.random.default_rng(0)
            return init(key), jnp.asarray(
                rng.integers(0, cfg.rows_per_field, size=(b, cfg.n_sparse)),
                jnp.int32,
            )

    elif cell.kind == "retrieval":
        nc = _pad512(cell.params["n_candidates"])

        def step(params, sparse, cand):
            return rec_mod.retrieval_score(cfg, params, sparse, cand)

        abstract = (
            p_abs, SDS((b, cfg.n_sparse), jnp.int32),
            SDS((nc, cfg.embed_dim), jnp.float32),
        )
        in_specs = (p_spec, P(None, None), P(flat, None))
        out_specs = P(None, flat)
        donate = ()

        def concrete(key):
            rng = np.random.default_rng(0)
            return (
                init(key),
                jnp.asarray(
                    rng.integers(
                        0, cfg.rows_per_field, size=(b, cfg.n_sparse)
                    ), jnp.int32,
                ),
                jnp.asarray(
                    rng.normal(size=(nc, cfg.embed_dim)), jnp.float32
                ),
            )
    else:
        raise ValueError(cell.kind)
    return CellProgram(
        name=f"{arch_name}:{cell.name}", fn=step,
        abstract_inputs=abstract, in_specs=in_specs, out_specs=out_specs,
        concrete_inputs=concrete if for_smoke else None, donate=donate,
    )


# ---------------------------------------------------------------------------
# coremaint cells (the paper's own workload)
# ---------------------------------------------------------------------------
def _coremaint_cell(arch_name: str, cfg, cell: ShapeCell, multi_pod: bool,
                    for_smoke: bool) -> CellProgram:
    from ..core.insert import insert_batch
    from ..core.remove import remove_batch

    n = cfg.n_vertices
    cap = _pad512(cfg.edge_capacity)
    b = cell.params["batch_edges"]
    flat = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_levels = 512  # max core bound for label segments at this scale

    if cell.kind == "coremaint_remove":
        def step(src, dst, valid, core, label, slots):
            return remove_batch(src, dst, valid, core, label, slots, n,
                                n_levels)

        abstract = (
            SDS((cap,), jnp.int32), SDS((cap,), jnp.int32),
            SDS((cap,), jnp.bool_), SDS((n,), jnp.int32),
            SDS((n,), jnp.int64), SDS((b,), jnp.int32),
        )
        in_specs = (P(flat), P(flat), P(flat), P(), P(), P())
        out_specs = None
    else:
        def step(src, dst, valid, core, label, ns, nd, ok, ne):
            return insert_batch(src, dst, valid, core, label, ns, nd, ok,
                                ne, n, n_levels)

        abstract = (
            SDS((cap,), jnp.int32), SDS((cap,), jnp.int32),
            SDS((cap,), jnp.bool_), SDS((n,), jnp.int32),
            SDS((n,), jnp.int64), SDS((b,), jnp.int32),
            SDS((b,), jnp.int32), SDS((b,), jnp.bool_), SDS((), jnp.int32),
        )
        in_specs = (P(flat), P(flat), P(flat), P(), P(), P(), P(), P(), P())
        out_specs = None

    def concrete(key):
        from ..graph.generators import erdos_renyi
        from ..core.api import CoreMaintainer

        g = erdos_renyi(n, min(cap // 4, 3 * n), seed=0)
        m = CoreMaintainer.from_graph(g, capacity=cap)
        if cell.kind == "coremaint_remove":
            slots = np.full(b, -1, dtype=np.int32)
            keys = list(m.edge_slot.values())[:b]
            slots[: len(keys)] = keys
            return (m.src, m.dst, m.valid, m.core, m.label,
                    jnp.asarray(slots))
        rng = np.random.default_rng(1)
        ns = rng.integers(0, n, size=b).astype(np.int32)
        nd = (ns + 1 + rng.integers(0, n - 1, size=b)).astype(np.int32) % n
        ok = ns != nd
        return (m.src, m.dst, m.valid, m.core, m.label,
                jnp.asarray(ns), jnp.asarray(nd), jnp.asarray(ok),
                m.n_edges)

    return CellProgram(
        name=f"{arch_name}:{cell.name}", fn=step,
        abstract_inputs=abstract, in_specs=in_specs, out_specs=out_specs,
        concrete_inputs=concrete if for_smoke else None,
    )


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------
def build_cell(
    arch_name: str,
    shape_name: str,
    smoke: bool = False,
    multi_pod: bool = False,
    unroll: bool = False,
) -> CellProgram:
    mod = get_arch(arch_name)
    cfg = mod.smoke() if smoke else mod.full()
    if unroll and hasattr(cfg, "scan_unroll"):
        cfg = dataclasses.replace(cfg, scan_unroll=cfg.n_layers)
    shapes = mod.SHAPES_SMOKE if smoke else mod.SHAPES
    cell = next(c for c in shapes if c.name == shape_name)
    if mod.FAMILY == "lm":
        return _lm_cell(arch_name, cfg, cell, multi_pod, smoke)
    if mod.FAMILY == "gnn":
        return _gnn_cell(arch_name, cfg, cell, multi_pod, smoke)
    if mod.FAMILY == "recsys":
        return _recsys_cell(arch_name, cfg, cell, multi_pod, smoke)
    if mod.FAMILY == "coremaint":
        return _coremaint_cell(arch_name, cfg, cell, multi_pod, smoke)
    raise ValueError(mod.FAMILY)


def cell_names(arch_name: str, smoke: bool = False):
    mod = get_arch(arch_name)
    shapes = mod.SHAPES_SMOKE if smoke else mod.SHAPES
    return [c.name for c in shapes]
