"""Distributed training launcher.

On a real TPU pod slice this binary runs once per host (jax.distributed
initializes from the TPU environment); here it drives the same code path
on CPU with optional virtual devices.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt

Features exercised: sharded params (single-pod mesh when >1 device),
microbatching, cosine schedule, atomic checkpoints + auto-resume,
preemption guard, straggler monitor, optional cross-pod int8
error-feedback gradient compression (--compress-pod-grads, documented in
optim/compression.py; engaged when the mesh has a "pod" axis).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.lm import synthetic_lm_batches
from repro.models import transformer as tf_mod
from repro.train.loop import TrainConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit(
            f"launch.train drives LM archs; use examples/train_gnn.py or "
            f"benchmarks for {args.arch}"
        )
    cfg = mod.smoke() if args.smoke else mod.full()
    if args.smoke:
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")

    data = synthetic_lm_batches(cfg.vocab, args.batch, args.seq, seed=0)

    def batches():
        for toks, tgts in data:
            yield jnp.asarray(toks), jnp.asarray(tgts)

    def lf(p, tokens, targets):
        return tf_mod.loss_fn(cfg, p, tokens, targets)

    tc = TrainConfig(
        lr=args.lr, warmup=max(1, args.steps // 10),
        total_steps=args.steps, micro_batches=args.micro_batches,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    params, report = run_training(
        params, lf, batches(), tc,
        on_step=lambda s, m: print(
            f"[train] step {s:05d} loss={m['loss']:.4f} "
            f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}"
        ) if s % 10 == 0 else None,
    )
    hist = report["history"]
    print(f"[train] done @ step {report['final_step']}  "
          f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}  "
          f"stragglers={report['stragglers']}")


if __name__ == "__main__":
    main()
