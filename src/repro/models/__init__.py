from . import gnn, recsys, transformer  # noqa: F401
