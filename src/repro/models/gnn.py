"""GNN architectures: PNA, GIN, DimeNet, NequIP.

Message passing is built on ``jax.ops.segment_sum/max/min`` over an
edge-index list (senders/receivers) — the JAX-native scatter formulation
(no sparse formats needed). The ELL Pallas kernel (kernels/segment_ell)
is a drop-in backend for the aggregation when neighbor lists are padded.

* PNA     — 4 aggregators x 3 degree scalers [arXiv:2004.05718]
* GIN     — sum aggregation, learnable eps [arXiv:1810.00826]
* DimeNet — directional edge messages + triplet angular basis
            [arXiv:2003.03123]; spherical basis reduced to
            Legendre(cos angle) x radial Bessel (documented simplification)
* NequIP  — E(3)-equivariant l<=2 irrep features with explicit
            tensor-product paths [arXiv:2101.03164]; forces via jax.grad.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..compat import shard_map

Array = jax.Array


def _sharded_gather(vals, idx, axes):
    """Row gather from a sharded table via shard_map: forward all-gathers
    the table once (tiled); the TRANSPOSE therefore reduce-scatters the
    cotangents instead of all-reducing them (§Perf iteration B4)."""
    if axes is None:
        return vals[idx]
    from jax.sharding import PartitionSpec as P

    def f(v_shard, i_shard):
        full = jax.lax.all_gather(v_shard, axes, axis=0, tiled=True)
        return full[i_shard]

    in_specs = (P(axes, *([None] * (vals.ndim - 1))), P(axes))
    out_specs = P(axes, *([None] * (vals.ndim - 1)))
    return shard_map(f, in_specs=in_specs, out_specs=out_specs)(vals, idx)


def _pin(x, axes):
    """Pin the leading (edge/node/triplet) dim sharded over ``axes`` —
    keeps GNN aggregation tensors distributed instead of replicated
    (§Perf iteration B1). No-op when axes is None (single device)."""
    if axes is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# batch container
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Padded graph batch. senders/receivers index nodes; mask marks pads."""

    node_feat: Array       # [N, F] float
    senders: Array         # [E] int32
    receivers: Array       # [E] int32
    edge_mask: Array       # [E] bool
    node_mask: Array       # [N] bool
    graph_id: Array        # [N] int32 — node -> graph (batched small graphs)
    n_graphs: int
    positions: Optional[Array] = None   # [N, 3] for molecular models
    species: Optional[Array] = None     # [N] int32 atom types

    def tree_flatten(self):
        return (
            (self.node_feat, self.senders, self.receivers, self.edge_mask,
             self.node_mask, self.graph_id, self.positions, self.species),
            (self.n_graphs,),
        )

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(
            node_feat=ch[0], senders=ch[1], receivers=ch[2], edge_mask=ch[3],
            node_mask=ch[4], graph_id=ch[5], n_graphs=aux[0],
            positions=ch[6], species=ch[7],
        )


jax.tree_util.register_pytree_node(
    GraphBatch, GraphBatch.tree_flatten, GraphBatch.tree_unflatten
)


def _seg_sum(x, ids, n):
    return jax.ops.segment_sum(x, ids, num_segments=n)


def _sharded_seg_sum(x, ids, n, axes):
    """segment_sum with a SHARDED output: per-shard local scatter into a
    full-size buffer, then one psum_scatter (reduce-scatter wire cost
    instead of all-reduce — §Perf iteration B3). Requires n % mesh == 0
    (cells pad to 512). Falls back to plain segment_sum when axes is None
    or no mesh is active."""
    if axes is None:
        return _seg_sum(x, ids, n)
    from jax.sharding import PartitionSpec as P

    flat = tuple(a for ax in ((axes,) if isinstance(axes, str) else axes)
                 for a in ((ax,) if isinstance(ax, str) else ax))

    def f(xs, is_):
        buf = jax.ops.segment_sum(xs, is_, num_segments=n)
        return jax.lax.psum_scatter(buf, flat, scatter_dimension=0,
                                    tiled=True)

    in_specs = (P(axes, *([None] * (x.ndim - 1))), P(axes))
    out_specs = P(axes, *([None] * (x.ndim - 1)))
    return shard_map(f, in_specs=in_specs, out_specs=out_specs)(x, ids)


def _seg_max(x, ids, n):
    return jax.ops.segment_max(x, ids, num_segments=n)


def _seg_min(x, ids, n):
    return jax.ops.segment_min(x, ids, num_segments=n)


def _mlp_init(key, sizes, dtype=jnp.float32):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append(
            {
                "w": jax.random.normal(k1, (a, b), dtype) / math.sqrt(a),
                "b": jnp.zeros((b,), dtype),
            }
        )
    return params


def _mlp_apply(params, x, act=jax.nn.silu, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# PNA
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 1433
    n_classes: int = 7
    delta: float = 2.5  # mean log-degree normalizer (dataset statistic)
    shard_axes: Any = None


def pna_init(cfg: PNAConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else cfg.d_hidden
        layers.append(
            {
                "pre": _mlp_init(keys[i], [d_in, cfg.d_hidden]),
                # 4 aggregators x 3 scalers + self
                "post": _mlp_init(
                    keys[i],
                    [12 * cfg.d_hidden + d_in, cfg.d_hidden, cfg.d_hidden],
                ),
            }
        )
    return {
        "layers": layers,
        "readout": _mlp_init(keys[-1], [cfg.d_hidden, cfg.n_classes]),
    }


def pna_forward(cfg: PNAConfig, params, batch: GraphBatch) -> Array:
    n = batch.node_feat.shape[0]
    h = batch.node_feat
    deg = _seg_sum(
        batch.edge_mask.astype(jnp.float32), batch.receivers, n
    ) + 1e-6
    log_deg = jnp.log(deg + 1.0)
    amp = (log_deg / cfg.delta)[:, None]
    att = (cfg.delta / jnp.maximum(log_deg, 1e-6))[:, None]
    for lyr in params["layers"]:
        msg = _sharded_gather(
            _mlp_apply(lyr["pre"], h), batch.senders, cfg.shard_axes
        )
        msg = jnp.where(batch.edge_mask[:, None], msg, 0.0)
        s = _sharded_seg_sum(msg, batch.receivers, n, cfg.shard_axes)
        mean = s / deg[:, None]
        neg = jnp.where(batch.edge_mask[:, None], msg, -1e30)
        pos = jnp.where(batch.edge_mask[:, None], msg, 1e30)
        mx = jnp.maximum(_seg_max(neg, batch.receivers, n), -1e30)
        mn = jnp.minimum(_seg_min(pos, batch.receivers, n), 1e30)
        mx = jnp.where(deg[:, None] > 1e-5, mx, 0.0)
        mn = jnp.where(deg[:, None] > 1e-5, mn, 0.0)
        sq = _sharded_seg_sum(
            msg * msg, batch.receivers, n, cfg.shard_axes
        ) / deg[:, None]
        std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-6)
        aggs = jnp.concatenate([mean, mx, mn, std], axis=-1)  # [N, 4D]
        scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], axis=-1)
        h = _mlp_apply(lyr["post"], jnp.concatenate([h, scaled], axis=-1))
        h = h * batch.node_mask[:, None]
    return _mlp_apply(params["readout"], h)  # node logits


# ---------------------------------------------------------------------------
# GIN
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 8
    n_classes: int = 2
    shard_axes: Any = None


def gin_init(cfg: GINConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else cfg.d_hidden
        layers.append(
            {
                "mlp": _mlp_init(keys[i], [d_in, cfg.d_hidden, cfg.d_hidden]),
                "eps": jnp.zeros((), jnp.float32),
            }
        )
    return {
        "layers": layers,
        "readout": _mlp_init(
            keys[-1], [cfg.n_layers * cfg.d_hidden, cfg.d_hidden,
                       cfg.n_classes]
        ),
    }


def gin_forward(cfg: GINConfig, params, batch: GraphBatch) -> Array:
    n = batch.node_feat.shape[0]
    h = batch.node_feat
    pooled = []
    for lyr in params["layers"]:
        msg = jnp.where(
            batch.edge_mask[:, None],
            _sharded_gather(h, batch.senders, cfg.shard_axes), 0.0,
        )
        agg = _sharded_seg_sum(msg, batch.receivers, n, cfg.shard_axes)
        h = _mlp_apply(lyr["mlp"], (1.0 + lyr["eps"]) * h + agg,
                       final_act=True)
        h = h * batch.node_mask[:, None]
        pooled.append(
            _seg_sum(h, batch.graph_id, batch.n_graphs)
        )  # graph sum-pool per layer (GIN readout)
    z = jnp.concatenate(pooled, axis=-1)
    return _mlp_apply(params["readout"], z)  # [G, n_classes]


# ---------------------------------------------------------------------------
# DimeNet (directional message passing)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 16
    shard_axes: Any = None       # mesh axes for edge/triplet tensors (B1)
    msg_dtype: Any = jnp.float32  # bf16 halves collective bytes (B2)


def _bessel_basis(d: Array, n_radial: int, cutoff: float) -> Array:
    """Radial Bessel basis [*, n_radial]."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    return (
        jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d[..., None] / cutoff)
        / d[..., None]
    )


def _legendre_cos(cos_a: Array, n: int) -> Array:
    """First n Legendre polynomials of cos(angle) — the angular factor of
    the spherical basis (simplified from spherical Bessel x Y_l; see module
    docstring)."""
    outs = [jnp.ones_like(cos_a), cos_a]
    for l in range(2, n):
        outs.append(
            ((2 * l - 1) * cos_a * outs[-1] - (l - 1) * outs[-2]) / l
        )
    return jnp.stack(outs[:n], axis=-1)


def dimenet_init(cfg: DimeNetConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_blocks + 4)
    d = cfg.d_hidden
    params = {
        "species_embed": jax.random.normal(
            keys[0], (cfg.n_species, d), jnp.float32
        ) / math.sqrt(d),
        "rbf_embed": _mlp_init(keys[1], [cfg.n_radial, d]),
        "msg_embed": _mlp_init(keys[2], [3 * d, d]),
        "blocks": [],
        "out": _mlp_init(keys[-1], [d, d, 1]),
    }
    for i in range(cfg.n_blocks):
        k = keys[3 + i]
        sub = jax.random.split(k, 6)
        params["blocks"].append(
            {
                "w_rbf": _mlp_init(sub[0], [cfg.n_radial, d]),
                "w_sbf": _mlp_init(
                    sub[1], [cfg.n_spherical * cfg.n_radial, cfg.n_bilinear]
                ),
                "bilinear": jax.random.normal(
                    sub[2], (cfg.n_bilinear, d, d), jnp.float32
                ) / d,
                "msg_mlp": _mlp_init(sub[3], [d, d, d]),
                "upd_mlp": _mlp_init(sub[4], [2 * d, d, d]),
            }
        )
    return params


def dimenet_forward(
    cfg: DimeNetConfig,
    params,
    batch: GraphBatch,
    triplet_kj: Array,   # [T] edge ids (k->j)
    triplet_ji: Array,   # [T] edge ids (j->i)
    triplet_mask: Array, # [T] bool
) -> Array:
    """Returns per-graph energy [G]."""
    pos = batch.positions
    sp = params["species_embed"][batch.species]
    vec = pos[batch.senders] - pos[batch.receivers]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = _bessel_basis(dist, cfg.n_radial, cfg.cutoff)  # [E, R]
    # initial edge message from endpoint species + rbf
    m = _mlp_apply(
        params["msg_embed"],
        jnp.concatenate(
            [sp[batch.senders], sp[batch.receivers],
             _mlp_apply(params["rbf_embed"], rbf)],
            axis=-1,
        ),
        final_act=True,
    )
    m = (m * batch.edge_mask[:, None]).astype(cfg.msg_dtype)
    m = _pin(m, cfg.shard_axes)
    n_edges = m.shape[0]

    # triplet angles: edge kj = (k->j), edge ji = (j->i): angle at j
    v1 = -vec[triplet_kj]  # j->k
    v2 = vec[triplet_ji]   # j->i  (sender j, receiver i: vec = pos_j - pos_i)
    cos_a = jnp.sum(v1 * v2, axis=-1) / (
        jnp.linalg.norm(v1 + 1e-12, axis=-1)
        * jnp.linalg.norm(v2 + 1e-12, axis=-1)
        + 1e-9
    )
    ang = _legendre_cos(jnp.clip(cos_a, -1.0, 1.0), cfg.n_spherical)  # [T,S]
    sbf = (
        ang[:, :, None] * _bessel_basis(
            dist[triplet_kj], cfg.n_radial, cfg.cutoff
        )[:, None, :]
    ).reshape(ang.shape[0], -1).astype(cfg.msg_dtype)  # [T, S*R]
    sbf = _pin(sbf, cfg.shard_axes)

    for blk in params["blocks"]:
        if cfg.msg_dtype != jnp.float32:
            # compute the whole block in msg_dtype (backward scatters then
            # stay in msg_dtype too — §Perf B2)
            blk = jax.tree.map(lambda a: a.astype(cfg.msg_dtype), blk)
        g_rbf = _mlp_apply(blk["w_rbf"], rbf.astype(cfg.msg_dtype))  # [E, D]
        g_sbf = _pin(_mlp_apply(blk["w_sbf"], sbf), cfg.shard_axes)  # [T,B]
        m_kj = _sharded_gather(
            _mlp_apply(blk["msg_mlp"], m, final_act=True), triplet_kj,
            cfg.shard_axes,
        )
        # bilinear: combine angular basis with incoming messages
        inter = jnp.einsum("tb,bdf,td->tf", g_sbf, blk["bilinear"], m_kj)
        inter = _pin(inter * triplet_mask[:, None], cfg.shard_axes)
        agg = _sharded_seg_sum(
            inter.astype(cfg.msg_dtype), triplet_ji, n_edges,
            cfg.shard_axes,
        )
        upd = _mlp_apply(
            blk["upd_mlp"],
            jnp.concatenate([m * g_rbf, agg], axis=-1).astype(cfg.msg_dtype),
            final_act=True,
        )
        m = m + upd.astype(cfg.msg_dtype)
        m = _pin(m * batch.edge_mask[:, None], cfg.shard_axes)

    n = batch.node_feat.shape[0]
    atom = _sharded_seg_sum(
        m.astype(jnp.float32), batch.receivers, n, cfg.shard_axes
    )  # edge->atom
    e_atom = _mlp_apply(params["out"], atom)[:, 0] * batch.node_mask
    return _seg_sum(e_atom, batch.graph_id, batch.n_graphs)


def build_triplets(
    senders, receivers, edge_mask, max_triplets: int
) -> Tuple[Any, Any, Any]:
    """Host-side triplet construction: pairs (edge k->j, edge j->i), k != i."""
    import numpy as np

    senders = np.asarray(senders)
    receivers = np.asarray(receivers)
    mask = np.asarray(edge_mask)
    by_receiver: Dict[int, list] = {}
    for e, (s, r) in enumerate(zip(senders, receivers)):
        if mask[e]:
            by_receiver.setdefault(int(r), []).append(e)
    kj, ji = [], []
    for e_ji, (j, i) in enumerate(zip(senders, receivers)):
        if not mask[e_ji]:
            continue
        for e_kj in by_receiver.get(int(j), []):
            if senders[e_kj] != i:  # k != i
                kj.append(e_kj)
                ji.append(e_ji)
    t = len(kj)
    if t > max_triplets:
        kj, ji, t = kj[:max_triplets], ji[:max_triplets], max_triplets
    out_kj = np.zeros(max_triplets, dtype=np.int32)
    out_ji = np.zeros(max_triplets, dtype=np.int32)
    out_m = np.zeros(max_triplets, dtype=bool)
    out_kj[:t] = kj
    out_ji[:t] = ji
    out_m[:t] = True
    return out_kj, out_ji, out_m


# ---------------------------------------------------------------------------
# NequIP (E(3)-equivariant, l <= 2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    shard_axes: Any = None


def _sph_harmonics(unit: Array) -> Tuple[Array, Array, Array]:
    """Real spherical harmonics l=0,1,2 of unit vectors [*, 3]."""
    x, y, z = unit[..., 0], unit[..., 1], unit[..., 2]
    y0 = jnp.ones_like(x)[..., None]  # [*, 1]
    y1 = unit  # [*, 3]
    y2 = jnp.stack(
        [
            math.sqrt(3.0) * x * y,
            math.sqrt(3.0) * y * z,
            0.5 * (2 * z * z - x * x - y * y),
            math.sqrt(3.0) * x * z,
            math.sqrt(3.0) / 2.0 * (x * x - y * y),
        ],
        axis=-1,
    )  # [*, 5]
    return y0, y1, y2


def _vec5_to_mat(v5: Array) -> Array:
    """Inverse map of the l=2 component basis to symmetric traceless 3x3."""
    a = v5[..., 0] / math.sqrt(3.0)
    b = v5[..., 1] / math.sqrt(3.0)
    c = v5[..., 2]
    d = v5[..., 3] / math.sqrt(3.0)
    e = v5[..., 4] * 2.0 / math.sqrt(3.0)
    xx = (e - c / 1.5) / 2.0
    yy = (-e - c / 1.5) / 2.0
    # xx + yy + zz = 0; zz = 2c/3... solve: zz = c*2/3? use c = 0.5(2zz-xx-yy)
    # with xx+yy = -zz: c = 1.5 zz -> zz = c/1.5
    zz = c / 1.5
    m = jnp.stack(
        [
            jnp.stack([xx, a, d], axis=-1),
            jnp.stack([a, yy, b], axis=-1),
            jnp.stack([d, b, zz], axis=-1),
        ],
        axis=-2,
    )
    return m


def _mat_to_vec5(m: Array) -> Array:
    return jnp.stack(
        [
            math.sqrt(3.0) * m[..., 0, 1],
            math.sqrt(3.0) * m[..., 1, 2],
            1.5 * m[..., 2, 2],
            math.sqrt(3.0) * m[..., 0, 2],
            math.sqrt(3.0) / 2.0 * (m[..., 0, 0] - m[..., 1, 1]),
        ],
        axis=-1,
    )


def nequip_init(cfg: NequIPConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    c = cfg.d_hidden
    params = {
        "species_embed": jax.random.normal(
            keys[0], (cfg.n_species, c), jnp.float32
        ) / math.sqrt(c),
        "layers": [],
        "out": _mlp_init(keys[-1], [c, c, 1]),
    }
    n_paths = 11  # tensor-product paths below
    for i in range(cfg.n_layers):
        sub = jax.random.split(keys[1 + i], 4)
        params["layers"].append(
            {
                "radial": _mlp_init(sub[0], [cfg.n_rbf, c, n_paths * c]),
                "self0": jax.random.normal(sub[1], (c, c), jnp.float32) / math.sqrt(c),
                "self1": jax.random.normal(sub[2], (c, c), jnp.float32) / math.sqrt(c),
                "self2": jax.random.normal(sub[3], (c, c), jnp.float32) / math.sqrt(c),
                "gate": _mlp_init(sub[0], [c, 2 * c]),
            }
        )
    return params


def nequip_energy(
    cfg: NequIPConfig, params, positions: Array, batch: GraphBatch
) -> Array:
    """Per-graph energy. ``positions`` is separated out for jax.grad forces."""
    n = batch.node_feat.shape[0]
    c = cfg.d_hidden
    h0 = params["species_embed"][batch.species]  # [N, C] scalars
    h1 = jnp.zeros((n, c, 3), jnp.float32)
    h2 = jnp.zeros((n, c, 5), jnp.float32)

    vec = positions[batch.senders] - positions[batch.receivers]
    dist = jnp.linalg.norm(vec + 1e-9, axis=-1)
    unit = vec / (dist[..., None] + 1e-9)
    y0, y1, y2 = _sph_harmonics(unit)
    rbf = _bessel_basis(dist, cfg.n_rbf, cfg.cutoff)  # [E, R]
    # smooth cutoff envelope
    env = jnp.where(
        dist < cfg.cutoff,
        0.5 * (jnp.cos(jnp.pi * dist / cfg.cutoff) + 1.0),
        0.0,
    )
    emask = batch.edge_mask * env

    for lyr in params["layers"]:
        w = _mlp_apply(lyr["radial"], rbf, final_act=False)  # [E, 11C]
        w = (w * emask[:, None]).reshape(-1, 11, c)
        s0 = _sharded_gather(h0, batch.senders, cfg.shard_axes)
        s1 = _sharded_gather(h1, batch.senders, cfg.shard_axes)
        s2 = _sharded_gather(h2, batch.senders, cfg.shard_axes)
        # tensor-product paths (sender feature x edge harmonic -> receiver l)
        p = []
        p.append(w[:, 0] * s0)                                     # 0x0->0
        p.append(jnp.einsum("ec,ecd->ecd", w[:, 1] * s0,
                            jnp.broadcast_to(y1[:, None, :], s1.shape)))  # 0x1->1
        p.append(w[:, 2, :, None] * s0[..., None] * y2[:, None, :])  # 0x2->2
        p.append(w[:, 3, :, None] * s1)                             # 1x0->1
        p.append(w[:, 4] * jnp.einsum("ecd,ed->ec", s1, y1))        # 1x1->0
        p.append(
            w[:, 5, :, None] * jnp.cross(
                s1, jnp.broadcast_to(y1[:, None, :], s1.shape), axis=-1
            )
        )                                                           # 1x1->1
        outer = (
            s1[..., :, None] * y1[:, None, None, :]
            + s1[..., None, :] * y1[:, None, :, None]
        ) * 0.5
        tr = (outer[..., 0, 0] + outer[..., 1, 1] + outer[..., 2, 2]) / 3.0
        outer = outer - tr[..., None, None] * jnp.eye(3, dtype=outer.dtype)
        p.append(w[:, 6, :, None] * _mat_to_vec5(outer))            # 1x1->2
        m2 = _vec5_to_mat(s2)
        p.append(
            w[:, 7, :, None] * jnp.einsum("ecij,ej->eci", m2, y1)
        )                                                           # 2x1->1
        p.append(w[:, 8, :, None] * s2)                             # 2x0->2
        y2m = _vec5_to_mat(jnp.broadcast_to(y2[:, None, :], s2.shape))
        p.append(w[:, 9] * jnp.einsum("ecij,ecij->ec", m2, y2m))    # 2x2->0
        p.append(
            w[:, 10, :, None] * _mat_to_vec5(
                jnp.einsum("ecij,ecjk->ecik", m2, y2m)
                + jnp.einsum("ecij,ecjk->ecik", y2m, m2)
            ) * 0.5
        )                                                           # 2x2->2*
        msg0 = p[0] + p[4] + p[9]
        msg1 = p[1] + p[3] + p[5] + p[7]
        msg2 = p[2] + p[6] + p[8] + p[10]
        a0 = _sharded_seg_sum(msg0, batch.receivers, n, cfg.shard_axes)
        a1 = _sharded_seg_sum(msg1, batch.receivers, n, cfg.shard_axes)
        a2 = _sharded_seg_sum(msg2, batch.receivers, n, cfg.shard_axes)
        # self interaction + gated nonlinearity
        h0n = h0 @ lyr["self0"] + a0
        h1n = jnp.einsum("ncd,ce->ned", h1 + a1, lyr["self1"])
        h2n = jnp.einsum("ncd,ce->ned", h2 + a2, lyr["self2"])
        gates = _mlp_apply(lyr["gate"], h0n)
        g1 = jax.nn.sigmoid(gates[..., :c])[..., None]
        g2 = jax.nn.sigmoid(gates[..., c:])[..., None]
        h0 = jax.nn.silu(h0n)
        h1 = h1n * g1
        h2 = h2n * g2

    e_atom = _mlp_apply(params["out"], h0)[:, 0] * batch.node_mask
    return _seg_sum(e_atom, batch.graph_id, batch.n_graphs)


def nequip_energy_forces(cfg, params, batch: GraphBatch):
    def etot(pos):
        return jnp.sum(nequip_energy(cfg, params, pos, batch))

    energy = nequip_energy(cfg, params, batch.positions, batch)
    forces = -jax.grad(etot)(batch.positions)
    return energy, forces
