"""DeepFM [arXiv:1703.04247] with a hand-built EmbeddingBag.

JAX has no native EmbeddingBag or CSR sparse — the lookup is
``jnp.take`` + ``jax.ops.segment_sum`` (multi-hot bags), which IS part of
the system (prompt requirement). The embedding table is a single
row-sharded [vocab_total, dim] matrix with per-field offsets so the table
shards cleanly over the full mesh.

Branches: first-order (scalar weight per feature), second-order FM
interaction (Pallas kernel available in kernels/fm_interaction), deep MLP
on concatenated field embeddings. Retrieval scoring (1 query x 1M
candidates) is a batched dot against a candidate embedding matrix.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str = "deepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    rows_per_field: int = 1_000_000   # hashed vocab per field
    n_dense: int = 0
    dtype: Any = jnp.float32
    use_pallas_fm: bool = False

    @property
    def vocab_total(self) -> int:
        # padded to a multiple of 4096 so the row-sharded table divides any
        # mesh up to 4096 chips (standard vocab padding)
        raw = self.n_sparse * self.rows_per_field
        return -(-raw // 4096) * 4096

    @property
    def n_params(self) -> int:
        n = self.vocab_total * (self.embed_dim + 1)
        d_in = self.n_sparse * self.embed_dim + self.n_dense
        dims = (d_in,) + self.mlp_dims + (1,)
        for a, b in zip(dims[:-1], dims[1:]):
            n += a * b + b
        return n


def deepfm_init(cfg: DeepFMConfig, key) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    d_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    dims = (d_in,) + cfg.mlp_dims + (1,)
    mlp = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        kk, k3 = jax.random.split(k3)
        mlp.append(
            {
                "w": (jax.random.normal(kk, (a, b), jnp.float32)
                      / math.sqrt(a)).astype(cfg.dtype),
                "b": jnp.zeros((b,), cfg.dtype),
            }
        )
    return {
        "embed": (
            jax.random.normal(
                k1, (cfg.vocab_total, cfg.embed_dim), jnp.float32
            ) * 0.01
        ).astype(cfg.dtype),
        "w1": (
            jax.random.normal(k2, (cfg.vocab_total,), jnp.float32) * 0.01
        ).astype(cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
        "mlp": mlp,
    }


def embedding_bag(
    table: Array,
    ids: Array,
    bag_ids: Optional[Array] = None,
    n_bags: Optional[int] = None,
    weights: Optional[Array] = None,
    combine: str = "sum",
) -> Array:
    """EmbeddingBag: gather rows then segment-reduce into bags.

    ids: [K] row indices; bag_ids: [K] bag assignment (None = identity).
    """
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if bag_ids is None:
        return rows
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if combine == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(ids, dtype=rows.dtype), bag_ids, num_segments=n_bags
        )
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _field_ids(cfg: DeepFMConfig, sparse: Array) -> Array:
    """Per-field hashed ids -> global rows via field offsets."""
    offsets = (
        jnp.arange(cfg.n_sparse, dtype=sparse.dtype) * cfg.rows_per_field
    )
    return sparse + offsets[None, :]


def deepfm_forward(cfg: DeepFMConfig, params, sparse: Array,
                   dense: Optional[Array] = None) -> Array:
    """sparse [B, n_sparse] int32 -> logits [B]."""
    b = sparse.shape[0]
    rows = _field_ids(cfg, sparse)  # [B, F]
    emb = jnp.take(params["embed"], rows.reshape(-1), axis=0).reshape(
        b, cfg.n_sparse, cfg.embed_dim
    )
    first = jnp.sum(
        jnp.take(params["w1"], rows.reshape(-1), axis=0).reshape(b, -1),
        axis=-1,
    )
    if cfg.use_pallas_fm:
        from ..kernels.ops import fm_interaction_op

        second = fm_interaction_op(emb)
    else:
        s = jnp.sum(emb, axis=1)
        s2 = jnp.sum(emb * emb, axis=1)
        second = 0.5 * jnp.sum(s * s - s2, axis=-1)
    deep_in = emb.reshape(b, -1)
    if dense is not None and cfg.n_dense:
        deep_in = jnp.concatenate([deep_in, dense.astype(emb.dtype)], axis=-1)
    h = deep_in
    for i, lyr in enumerate(params["mlp"]):
        h = h @ lyr["w"] + lyr["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
    return (first + second + h[:, 0] + params["bias"]).astype(jnp.float32)


def deepfm_loss(cfg, params, sparse, labels, dense=None) -> Array:
    logits = deepfm_forward(cfg, params, sparse, dense)
    return jnp.mean(
        jnp.maximum(logits, 0.0)
        - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_score(cfg: DeepFMConfig, params, query_sparse: Array,
                    cand_emb: Array) -> Array:
    """Score 1 query against [n_cand, d] candidate embeddings — batched dot,
    not a loop (retrieval_cand shape)."""
    rows = _field_ids(cfg, query_sparse)
    emb = jnp.take(params["embed"], rows.reshape(-1), axis=0).reshape(
        query_sparse.shape[0], cfg.n_sparse, cfg.embed_dim
    )
    q = jnp.sum(emb, axis=1)  # [B, d] pooled query embedding
    return jnp.einsum("bd,nd->bn", q, cand_emb)
