"""Unified decoder-only LM covering every assigned transformer arch.

One parametric implementation:
  * attention: GQA (llama/yi/qwen) or MLA (DeepSeek-V2 latent KV compression)
  * optional qk-norm (qwen3), optional QKV bias (qwen2)
  * FFN: dense SwiGLU or DeepSeek-MoE (shared + routed experts, top-k,
    sort-based capacity dispatch)
  * layers stacked with lax.scan over a stacked param pytree (compile time
    stays O(1) in depth — required for 60-layer dry-runs)
  * KV-cache prefill/decode; MLA caches the 512+64-dim latent per token,
    which is what makes the 500k-context decode cell cheap.

Everything is explicit-dtype (bf16 activations/params, f32 logits+loss,
f32 rngless init) — the package-level x64 flag does not affect numerics.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 0          # 0 = dense q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    attention: str = "gqa"           # "gqa" | "mla"
    qk_norm: bool = False
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    remat: str = "none"              # "none" | "full"
    scan_unroll: int = 1             # dry-run sets n_layers for true HLO cost
    # activation-sharding constraints (mesh axis names); None = unconstrained.
    # Pinning activations batch-sharded forces GSPMD to gather FSDP weights
    # at use instead of resharding activations to full batch (§Perf A2).
    batch_axes: Any = None           # e.g. "data" or ("pod", "data")
    tp_axis: Any = None              # e.g. "model"
    attn_chunk: int = 0              # >0: streaming-softmax KV chunking (D2)

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + layers)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        if self.attention == "mla":
            m = self.mla or MLAConfig()
            qk_head = m.nope_head_dim + m.rope_head_dim
            q_in = m.q_lora if m.q_lora else d
            attn = (
                (d * m.q_lora if m.q_lora else 0)
                + q_in * self.n_heads * qk_head
                + d * (m.kv_lora + m.rope_head_dim)
                + m.kv_lora * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        else:
            attn = (
                d * self.n_heads * self.d_head
                + 2 * d * self.n_kv_heads * self.d_head
                + self.n_heads * self.d_head * d
            )
        if self.moe:
            ffn = (
                d * self.moe.n_routed  # router
                + (self.moe.n_routed + self.moe.n_shared)
                * 3 * d * self.moe.d_expert
            )
        else:
            ffn = 3 * d * ff
        per_layer = attn + ffn + 2 * d
        return v * d * 2 + self.n_layers * per_layer + d

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.n_params
        d = self.d_model
        inactive = (
            (self.moe.n_routed - self.moe.top_k)
            * 3 * d * self.moe.d_expert
        ) * self.n_layers
        return self.n_params - inactive


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def _pin(cfg, x, *rest):
    """with_sharding_constraint(batch_axes, *rest) when configured."""
    if cfg.batch_axes is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(cfg.batch_axes, *rest)
    )


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x [..., S, H, D]; positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )
    return out.astype(x.dtype)


def _init(key, shape, fan_in, dtype):
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(
        dtype
    )


# ---------------------------------------------------------------------------
# parameter init (stacked over layers)
# ---------------------------------------------------------------------------
def init_params(cfg: LMConfig, key: jax.Array) -> Dict[str, Any]:
    keys = jax.random.split(key, 16)
    d, dt = cfg.d_model, cfg.dtype
    L = cfg.n_layers
    p: Dict[str, Any] = {
        "embed": _init(keys[0], (cfg.vocab, d), d, dt),
        "unembed": _init(keys[1], (d, cfg.vocab), d, dt),
        "final_norm": jnp.ones((d,), dt),
    }
    layer: Dict[str, Any] = {
        "ln_attn": jnp.ones((L, d), dt),
        "ln_ffn": jnp.ones((L, d), dt),
    }
    if cfg.attention == "mla":
        m = cfg.mla or MLAConfig()
        qk_head = m.nope_head_dim + m.rope_head_dim
        q_in = m.q_lora if m.q_lora else d
        if m.q_lora:
            layer["w_dq"] = _init(keys[2], (L, d, m.q_lora), d, dt)
            layer["q_ln"] = jnp.ones((L, m.q_lora), dt)
        layer["w_uq"] = _init(keys[3], (L, q_in, cfg.n_heads, qk_head), q_in, dt)
        layer["w_dkv"] = _init(
            keys[4], (L, d, m.kv_lora + m.rope_head_dim), d, dt
        )
        layer["kv_ln"] = jnp.ones((L, m.kv_lora), dt)
        layer["w_uk"] = _init(
            keys[5], (L, m.kv_lora, cfg.n_heads, m.nope_head_dim), m.kv_lora, dt
        )
        layer["w_uv"] = _init(
            keys[6], (L, m.kv_lora, cfg.n_heads, m.v_head_dim), m.kv_lora, dt
        )
        layer["w_o"] = _init(
            keys[7], (L, cfg.n_heads, m.v_head_dim, d),
            cfg.n_heads * m.v_head_dim, dt,
        )
    else:
        layer["w_q"] = _init(
            keys[2], (L, d, cfg.n_heads, cfg.d_head), d, dt
        )
        layer["w_k"] = _init(
            keys[3], (L, d, cfg.n_kv_heads, cfg.d_head), d, dt
        )
        layer["w_v"] = _init(
            keys[4], (L, d, cfg.n_kv_heads, cfg.d_head), d, dt
        )
        layer["w_o"] = _init(
            keys[5], (L, cfg.n_heads, cfg.d_head, d),
            cfg.n_heads * cfg.d_head, dt,
        )
        if cfg.qkv_bias:
            layer["b_q"] = jnp.zeros((L, cfg.n_heads, cfg.d_head), dt)
            layer["b_k"] = jnp.zeros((L, cfg.n_kv_heads, cfg.d_head), dt)
            layer["b_v"] = jnp.zeros((L, cfg.n_kv_heads, cfg.d_head), dt)
        if cfg.qk_norm:
            layer["q_norm"] = jnp.ones((L, cfg.d_head), dt)
            layer["k_norm"] = jnp.ones((L, cfg.d_head), dt)
    if cfg.moe:
        mo = cfg.moe
        layer["router"] = _init(keys[8], (L, d, mo.n_routed), d, jnp.float32)
        layer["w_gate"] = _init(
            keys[9], (L, mo.n_routed, d, mo.d_expert), d, dt
        )
        layer["w_up"] = _init(
            keys[10], (L, mo.n_routed, d, mo.d_expert), d, dt
        )
        layer["w_down"] = _init(
            keys[11], (L, mo.n_routed, mo.d_expert, d), mo.d_expert, dt
        )
        if mo.n_shared:
            sh_ff = mo.d_expert * mo.n_shared
            layer["ws_gate"] = _init(keys[12], (L, d, sh_ff), d, dt)
            layer["ws_up"] = _init(keys[13], (L, d, sh_ff), d, dt)
            layer["ws_down"] = _init(keys[14], (L, sh_ff, d), sh_ff, dt)
    else:
        layer["w_gate"] = _init(keys[8], (L, d, cfg.d_ff), d, dt)
        layer["w_up"] = _init(keys[9], (L, d, cfg.d_ff), d, dt)
        layer["w_down"] = _init(keys[10], (L, cfg.d_ff, d), cfg.d_ff, dt)
    p["layers"] = layer
    return p


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _attend_chunked(q: Array, k: Array, v: Array, causal: bool,
                    chunk: int) -> Array:
    """Streaming-softmax attention: lax.scan over KV chunks with running
    max/normalizer — the flash-attention recurrence expressed at the XLA
    level, so the [S, T] score matrix never materializes (peak activation
    drops from O(S*T) to O(S*chunk); §Perf bonus iteration D2). The Pallas
    kernel (kernels/flash_attention.py) is the TPU-native form; this path
    keeps the dry-run/CPU graph structurally identical."""
    b, s, h, dq = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    n_chunks = t // chunk
    qg = (q.reshape(b, s, hkv, g, dq).astype(jnp.float32)
          / math.sqrt(dq))
    kc = k.reshape(b, n_chunks, chunk, hkv, -1).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, -1).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(s)

    def body(carry, xs):
        acc, m, l = carry
        k_i, v_i, idx = xs
        logits = jnp.einsum(
            "bshgd,bthd->bhgst", qg, k_i.astype(jnp.float32)
        )
        if causal:
            k_pos = idx * chunk + jnp.arange(chunk)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = alpha[..., None] * acc + jnp.einsum(
            "bhgst,bthd->bhgsd", p, v_i.astype(jnp.float32)
        )
        return (acc_new, m_new, l_new), None

    dv = v.shape[-1]
    init = (
        jnp.zeros((b, hkv, g, s, dv), jnp.float32),
        jnp.full((b, hkv, g, s), -1e30, jnp.float32),
        jnp.zeros((b, hkv, g, s), jnp.float32),
    )
    (acc, m, l), _ = jax.lax.scan(
        body, init, (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dv).astype(q.dtype)


def _attend(q: Array, k: Array, v: Array, causal: bool,
            q_offset: Array | int = 0) -> Array:
    """q [B,S,H,Dq], k/v [B,T,Hkv,D*]; returns [B,S,H,Dv]. fp32 softmax."""
    b, s, h, dq = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dq)
    logits = jnp.einsum(
        "bshgd,bthd->bhgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(dq)
    if causal:
        q_pos = q_offset + jnp.arange(s)[:, None]
        k_pos = jnp.arange(t)[None, :]
        mask = q_pos >= k_pos
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def _gqa_qkv(cfg, lp, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, lp["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["w_v"])
    if cfg.qkv_bias:
        q = q + lp["b_q"]
        k = k + lp["b_k"]
        v = v + lp["b_v"]
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mla_q(cfg, lp, x, positions):
    m = cfg.mla or MLAConfig()
    if m.q_lora:
        cq = rms_norm(
            jnp.einsum("bsd,dr->bsr", x, lp["w_dq"]), lp["q_ln"], cfg.norm_eps
        )
    else:
        cq = x
    q = jnp.einsum("bsr,rhk->bshk", cq, lp["w_uq"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = rope(q[..., m.nope_head_dim :], positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_latent(cfg, lp, x, positions):
    """Returns the per-token latent cache entry: c_kv [B,S,R], k_rope [B,S,1,Dr]."""
    m = cfg.mla or MLAConfig()
    dkv = jnp.einsum("bsd,dr->bsr", x, lp["w_dkv"])
    c_kv = rms_norm(dkv[..., : m.kv_lora], lp["kv_ln"], cfg.norm_eps)
    k_rope = rope(
        dkv[..., m.kv_lora :][:, :, None, :], positions, cfg.rope_theta
    )
    return c_kv, k_rope


def _mla_attend(cfg, lp, q, c_kv, k_rope, causal, q_offset=0):
    """MLA attention against the latent cache (absorbed form).

    q [B,S,H,nope+rope]; c_kv [B,T,R]; k_rope [B,T,1,Dr].
    k_nope[h] = c_kv @ w_uk[h]; score = q_nope.k_nope + q_rope.k_rope.
    The nope part is computed in the latent space by absorbing w_uk into q
    (q_lat = q_nope @ w_uk^T), so per-token decode work is O(R) not O(H*D).
    """
    m = cfg.mla or MLAConfig()
    q_nope = q[..., : m.nope_head_dim]
    q_rope = q[..., m.nope_head_dim :]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, lp["w_uk"])
    logits = jnp.einsum(
        "bshr,btr->bhst", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32)
    )
    logits = logits + jnp.einsum(
        "bshk,btk->bhst",
        q_rope.astype(jnp.float32),
        k_rope[:, :, 0].astype(jnp.float32),
    )
    logits = logits / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    s, t = q.shape[1], c_kv.shape[1]
    if causal:
        q_pos = q_offset + jnp.arange(s)[:, None]
        k_pos = jnp.arange(t)[None, :]
        logits = jnp.where((q_pos >= k_pos)[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # value in latent space, then up-project: o = (probs @ c_kv) @ w_uv
    o_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhk->bshk", o_lat.astype(q.dtype), lp["w_uv"])
    return o


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------
def _dense_ffn(lp, x):
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, lp["w_gate"]))
    up = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
    return jnp.einsum("bsf,fd->bsd", gate * up, lp["w_down"])


def _moe_ffn(cfg: LMConfig, lp, x):
    """Sort-based capacity MoE (shared experts always-on)."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), lp["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, mo.top_k)  # [t, k]
    topw = (topw / jnp.sum(topw, axis=-1, keepdims=True)).astype(x.dtype)

    # capacity dispatch: group assignments by expert
    cap = int(mo.capacity_factor * mo.top_k * t / mo.n_routed) + 1
    flat_e = topi.reshape(-1)  # [t*k]
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), mo.top_k)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each assignment within its expert group
    pos_in_e = jnp.arange(t * mo.top_k, dtype=jnp.int32) - jnp.searchsorted(
        se, se, side="left"
    ).astype(jnp.int32)
    keep = pos_in_e < cap
    # dropped assignments scatter out of bounds (mode="drop" discards them)
    slot = jnp.where(keep, se * cap + pos_in_e, mo.n_routed * cap)
    # gather tokens into [E, cap, d]
    buf_tok = jnp.zeros((mo.n_routed * cap,), jnp.int32).at[slot].set(
        st_, mode="drop"
    )
    buf_use = jnp.zeros((mo.n_routed * cap,), bool).at[slot].set(
        keep, mode="drop"
    )
    buf_w = jnp.zeros((mo.n_routed * cap,), x.dtype).at[slot].set(
        sw, mode="drop"
    )
    xe = xt[buf_tok].reshape(mo.n_routed, cap, d)
    xe = xe * buf_use.reshape(mo.n_routed, cap, 1).astype(x.dtype)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, lp["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", xe, lp["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", gate * up, lp["w_down"])
    ye = ye * buf_w.reshape(mo.n_routed, cap, 1)
    out = jnp.zeros((t, d), x.dtype).at[buf_tok].add(
        ye.reshape(mo.n_routed * cap, d)
    )
    # router aux loss (load balancing, GShard style)
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, mo.n_routed, dtype=jnp.float32), axis=1), axis=0
    )
    aux = jnp.sum(me * ce) * mo.n_routed
    if mo.n_shared:
        sh_gate = jax.nn.silu(jnp.einsum("td,df->tf", xt, lp["ws_gate"]))
        sh_up = jnp.einsum("td,df->tf", xt, lp["ws_up"])
        out = out + jnp.einsum("tf,fd->td", sh_gate * sh_up, lp["ws_down"])
    out = out.reshape(b, s, d)
    return _pin(cfg, out, None, None), aux


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _layer_fwd(cfg: LMConfig, lp, x, positions):
    x = _pin(cfg, x, None, None)
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    if cfg.attention == "mla":
        q = _pin(cfg, _mla_q(cfg, lp, h, positions), None, cfg.tp_axis, None)
        c_kv, k_rope = _mla_latent(cfg, lp, h, positions)
        attn = _mla_attend(cfg, lp, q, c_kv, k_rope, causal=True)
    else:
        q, k, v = _gqa_qkv(cfg, lp, h, positions)
        q = _pin(cfg, q, None, None, None)
        if cfg.attn_chunk and q.shape[1] % cfg.attn_chunk == 0:
            attn = _attend_chunked(q, k, v, causal=True,
                                   chunk=cfg.attn_chunk)
        else:
            attn = _attend(q, k, v, causal=True)
    attn = _pin(cfg, attn, None, None, None)
    x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["w_o"])
    x = _pin(cfg, x, None, None)
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    if cfg.moe:
        y, aux = _moe_ffn(cfg, lp, h)
    else:
        y, aux = _dense_ffn(lp, h), jnp.float32(0.0)
    y = _pin(cfg, y, None, None)
    return x + y, aux


def forward(cfg: LMConfig, params, tokens: Array) -> Tuple[Array, Array]:
    """tokens [B, S] -> (logits [B, S, vocab] f32, aux loss)."""
    x = _pin(cfg, params["embed"][tokens], None, None)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(carry, lp):
        x, aux = carry
        fn = _layer_fwd
        if cfg.remat == "full":
            fn = jax.checkpoint(
                _layer_fwd, static_argnums=(0,),
                policy=jax.checkpoint_policies.nothing_saveable,
            )
        x, a = fn(cfg, lp, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"],
                               unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(jnp.float32),
        params["unembed"].astype(jnp.float32),
    )
    logits = _pin(cfg, logits, None, cfg.tp_axis)
    return logits, aux / cfg.n_layers


def loss_fn(cfg: LMConfig, params, tokens, targets) -> Array:
    logits, aux = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------
def init_cache(cfg: LMConfig, batch: int, max_seq: int) -> Dict[str, Array]:
    dt = cfg.dtype
    if cfg.attention == "mla":
        m = cfg.mla or MLAConfig()
        return {
            "c_kv": jnp.zeros((cfg.n_layers, batch, max_seq, m.kv_lora), dt),
            "k_rope": jnp.zeros(
                (cfg.n_layers, batch, max_seq, 1, m.rope_head_dim), dt
            ),
            "length": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros(
            (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head), dt
        ),
        "v": jnp.zeros(
            (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head), dt
        ),
        "length": jnp.zeros((), jnp.int32),
    }


def _decode_layer(cfg, lp, x, layer_cache, pos):
    """x [B, 1, d]; layer_cache holds this layer's K/V (or latent) slices."""
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    positions = pos[None, None]  # [1,1]
    t = (
        layer_cache["c_kv"].shape[1]
        if cfg.attention == "mla"
        else layer_cache["k"].shape[1]
    )
    kv_mask = (jnp.arange(t) <= pos)[None, :]
    if cfg.attention == "mla":
        q = _mla_q(cfg, lp, h, positions)
        c_kv_new, k_rope_new = _mla_latent(cfg, lp, h, positions)
        zero = jnp.zeros((), pos.dtype)
        c_kv = jax.lax.dynamic_update_slice(
            layer_cache["c_kv"], c_kv_new, (zero, pos, zero)
        )
        k_rope = jax.lax.dynamic_update_slice(
            layer_cache["k_rope"], k_rope_new, (zero, pos, zero, zero)
        )
        m = cfg.mla or MLAConfig()
        q_nope = q[..., : m.nope_head_dim]
        q_rope = q[..., m.nope_head_dim :]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, lp["w_uk"])
        logits = jnp.einsum(
            "bshr,btr->bhst", q_lat.astype(jnp.float32),
            c_kv.astype(jnp.float32),
        ) + jnp.einsum(
            "bshk,btk->bhst", q_rope.astype(jnp.float32),
            k_rope[:, :, 0].astype(jnp.float32),
        )
        logits = logits / math.sqrt(m.nope_head_dim + m.rope_head_dim)
        logits = jnp.where(kv_mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
        attn = jnp.einsum(
            "bshr,rhk->bshk", o_lat.astype(x.dtype), lp["w_uv"]
        )
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        q, k_new, v_new = _gqa_qkv(cfg, lp, h, positions)
        zero = jnp.zeros((), pos.dtype)
        k = jax.lax.dynamic_update_slice(
            layer_cache["k"], k_new, (zero, pos, zero, zero)
        )
        v = jax.lax.dynamic_update_slice(
            layer_cache["v"], v_new, (zero, pos, zero, zero)
        )
        b, s, hh, dq = q.shape
        hkv = k.shape[2]
        g = hh // hkv
        qg = q.reshape(b, s, hkv, g, dq)
        logits = jnp.einsum(
            "bshgd,bthd->bhgst", qg.astype(jnp.float32), k.astype(jnp.float32)
        ) / math.sqrt(dq)
        logits = jnp.where(kv_mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum(
            "bhgst,bthd->bshgd", probs, v.astype(jnp.float32)
        ).reshape(b, s, hh, dq).astype(x.dtype)
        new_cache = {"k": k, "v": v}
    x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["w_o"])
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    if cfg.moe:
        y, _ = _moe_ffn(cfg, lp, h)
    else:
        y = _dense_ffn(lp, h)
    return x + y, new_cache


def decode_step(cfg: LMConfig, params, cache, token: Array):
    """token [B] -> (logits [B, vocab], new cache). One decode position."""
    x = params["embed"][token][:, None, :]  # [B,1,d]
    pos = cache["length"]

    def body(x, xs):
        lp, layer_cache = xs
        x, new_cache = _decode_layer(cfg, lp, x, layer_cache, pos)
        return x, new_cache

    cache_layers = {k: v for k, v in cache.items() if k != "length"}
    x, new_layers = jax.lax.scan(
        body, x, (params["layers"], cache_layers), unroll=cfg.scan_unroll
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(jnp.float32),
        params["unembed"].astype(jnp.float32),
    )[:, 0]
    new_cache = dict(new_layers)
    new_cache["length"] = pos + 1
    return logits, new_cache


def prefill(cfg: LMConfig, params, tokens: Array):
    """tokens [B, S] -> (last logits [B, vocab], cache filled to S)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)[None, :]

    def body(x, lp):
        h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        if cfg.attention == "mla":
            q = _mla_q(cfg, lp, h, positions)
            c_kv, k_rope = _mla_latent(cfg, lp, h, positions)
            attn = _mla_attend(cfg, lp, q, c_kv, k_rope, causal=True)
            lc = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            q, k, v = _gqa_qkv(cfg, lp, h, positions)
            if cfg.attn_chunk and q.shape[1] % cfg.attn_chunk == 0:
                attn = _attend_chunked(q, k, v, causal=True,
                                       chunk=cfg.attn_chunk)
            else:
                attn = _attend(q, k, v, causal=True)
            lc = {"k": k, "v": v}
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["w_o"])
        h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
        if cfg.moe:
            y, _ = _moe_ffn(cfg, lp, h)
        else:
            y = _dense_ffn(lp, h)
        return x + y, lc

    x, cache_layers = jax.lax.scan(body, x, params["layers"],
                                   unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x[:, -1:].astype(jnp.float32),
        params["unembed"].astype(jnp.float32),
    )[:, 0]
    cache = dict(cache_layers)
    cache["length"] = jnp.asarray(s, jnp.int32)
    return logits, cache
