from .adamw import adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from .schedule import cosine_with_warmup  # noqa: F401
from .compression import (  # noqa: F401
    compress_int8,
    decompress_int8,
    error_feedback_allreduce,
)
