"""AdamW with f32 master state over (possibly bf16) params."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), gn


def adamw_update(
    params,
    grads,
    state,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = tree.unflatten([o[0] for o in out])
    new_state = {
        "m": tree.unflatten([o[1] for o in out]),
        "v": tree.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_p, new_state
