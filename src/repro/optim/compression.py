"""Gradient compression for cross-pod links: int8 quantization with error
feedback (EF-SGD style). The pod axis all-reduce is the bandwidth-bound
collective at 1000+-node scale; int8 + EF cuts its bytes 4x with no
asymptotic convergence penalty.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def error_feedback_allreduce(grads, residuals, axis_name: str):
    """Quantize (grad + residual), psum the int8 payload over ``axis_name``,
    keep the quantization error as the next residual.

    Returns (averaged_grads, new_residuals)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        new_r = g32 - deq
        # int8 payload summed on the wire; scales are f32 scalars
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.pmean(scale, axis_name)
        avg = summed.astype(jnp.float32) * scale_sum / jax.lax.psum(
            1, axis_name
        )
        return avg.astype(g.dtype), new_r

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tree.unflatten([o[0] for o in outs]), tree.unflatten(
        [o[1] for o in outs]
    )
