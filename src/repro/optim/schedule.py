"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, base_lr: float, warmup: int, total: int,
                       min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(1, warmup)
    t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
