"""Sharding rules: param/activation PartitionSpecs per architecture family.

Logical layout (mesh axes: optional "pod", "data", "model"):
  * LM params: FSDP over "data" on the d_model/ff dimension that is NOT
    tensor-parallel; TP over "model" on heads/ff; embeddings sharded
    (vocab on "model", d on "data"); MoE experts sharded over "model"
    (expert parallelism).
  * LM activations: batch over ("pod","data") — per-shape overrides below.
  * GNN/recsys: see the per-family spec functions.

"pod" is pure data parallelism: every param spec leaves it unsharded; the
gradient all-reduce over pods is where optim/compression applies.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def lm_param_specs(cfg, mesh: Mesh = None, model_size: int = 16) -> Dict[str, Any]:
    """Returns a pytree of PartitionSpec matching models.transformer params.

    Head dimensions are tensor-parallel only when the head count divides the
    model axis (qwen2: 28 q / 4 kv heads, yi: 56 heads do not divide 16 —
    those fall back to FSDP-only attention; the §Perf iteration explores
    better layouts for them)."""
    fsdp = "data"
    tp = "model"

    def htp(n_heads):
        return tp if n_heads % model_size == 0 else None

    lay: Dict[str, Any] = {
        "ln_attn": P(None, None),
        "ln_ffn": P(None, None),
    }
    if cfg.attention == "mla":
        m = cfg.mla
        h = htp(cfg.n_heads)
        if m and m.q_lora:
            lay["w_dq"] = P(None, fsdp, tp)
            lay["q_ln"] = P(None, None)
        lay["w_uq"] = P(None, fsdp, h, None)
        lay["w_dkv"] = P(None, fsdp, None)
        lay["kv_ln"] = P(None, None)
        lay["w_uk"] = P(None, None, h, None)
        lay["w_uv"] = P(None, None, h, None)
        lay["w_o"] = P(None, h, None, fsdp)
    else:
        hq = htp(cfg.n_heads)
        hkv = htp(cfg.n_kv_heads)
        lay["w_q"] = P(None, fsdp, hq, None)
        lay["w_k"] = P(None, fsdp, hkv, None)
        lay["w_v"] = P(None, fsdp, hkv, None)
        lay["w_o"] = P(None, hq, None, fsdp)
        if cfg.qkv_bias:
            lay["b_q"] = P(None, hq, None)
            lay["b_k"] = P(None, hkv, None)
            lay["b_v"] = P(None, hkv, None)
        if cfg.qk_norm:
            lay["q_norm"] = P(None, None)
            lay["k_norm"] = P(None, None)
    if cfg.moe:
        lay["router"] = P(None, fsdp, None)
        lay["w_gate"] = P(None, tp, fsdp, None)   # experts over model axis
        lay["w_up"] = P(None, tp, fsdp, None)
        lay["w_down"] = P(None, tp, None, fsdp)
        if cfg.moe.n_shared:
            lay["ws_gate"] = P(None, fsdp, tp)
            lay["ws_up"] = P(None, fsdp, tp)
            lay["ws_down"] = P(None, tp, fsdp)
    else:
        lay["w_gate"] = P(None, fsdp, tp)
        lay["w_up"] = P(None, fsdp, tp)
        lay["w_down"] = P(None, tp, fsdp)
    return {
        "embed": P(tp, fsdp),
        "unembed": P(fsdp, tp),
        "final_norm": P(None),
        "layers": lay,
    }


def lm_batch_spec(multi_pod: bool) -> P:
    return P(("pod", "data") if multi_pod else "data", None)


def lm_cache_specs(
    cfg,
    multi_pod: bool,
    batch: int = 0,
    data_size: int = 16,
    model_size: int = 16,
) -> Dict[str, Any]:
    """KV cache layout. Two regimes:

    * batch >= data axis: batch-sharded cache (decode_32k), heads/latent
      over model where divisible.
    * batch < data axis (long_500k, batch=1): SEQUENCE-sharded cache —
      GSPMD lowers the masked softmax over the sharded length axis to
      cheap all-reduces of the running max/sum (flash-decoding layout).
    """
    pods = 2 if multi_pod else 1
    batch_ax = ("pod", "data") if multi_pod else "data"
    seq_shard = batch % (data_size * pods) != 0
    b_ax = None if seq_shard else batch_ax
    s_ax = batch_ax if seq_shard else None
    if cfg.attention == "mla":
        m = cfg.mla
        lat = "model" if (m and m.kv_lora % model_size == 0) else None
        return {
            "c_kv": P(None, b_ax, s_ax, lat),
            "k_rope": P(None, b_ax, s_ax, None, None),
            "length": P(),
        }
    if cfg.n_kv_heads % model_size == 0:
        hkv, s2_ax = "model", s_ax
    else:
        # too few KV heads for TP (yi: 8, qwen2: 4): shard the cache
        # LENGTH over "model" instead — the masked softmax over a sharded
        # length axis costs only tiny running-max/sum all-reduces
        # (flash-decoding layout; §Perf bonus iteration D1)
        hkv = None
        if s_ax:
            base = s_ax if isinstance(s_ax, tuple) else (s_ax,)
            s2_ax = base + ("model",)
        else:
            s2_ax = "model"
    return {
        "k": P(None, b_ax, s2_ax, hkv, None),
        "v": P(None, b_ax, s2_ax, hkv, None),
        "length": P(),
    }


def opt_state_specs(param_specs) -> Dict[str, Any]:
    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }


def gnn_specs(multi_pod: bool):
    """Full-graph GNN: nodes and edges 1D-sharded over the whole mesh."""
    flat = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {
        "node_feat": P(flat, None),
        "senders": P(flat),
        "receivers": P(flat),
        "edge_mask": P(flat),
        "node_mask": P(flat),
        "graph_id": P(flat),
    }


def recsys_specs(multi_pod: bool):
    flat = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {
        "embed": P(flat, None),    # rows over the whole mesh
        "w1": P(flat),
        "batch": P(flat),
    }


def shard_params(params, specs, mesh: Mesh):
    return jax.tree.map(
        lambda p, s: jax.device_put(p, NamedSharding(mesh, s)), params, specs
    )
