from .loop import TrainConfig, make_train_step, run_training  # noqa: F401
from . import checkpoint, fault  # noqa: F401
