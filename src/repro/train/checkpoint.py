"""Fault-tolerant checkpointing: atomic writes, content hashing, latest-valid
auto-resume, per-host shard files.

Write protocol: serialize to ``<dir>/tmp.<step>.<host>``, fsync, then
atomically rename to ``step_<step>/shard_<host>.npz`` and finally write the
``COMMIT`` marker with a payload hash — a crash at any point leaves either
a complete committed step or garbage that restore() skips.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(tree, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(p) for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(
    ckpt_dir: str, step: int, state: Any, host_id: int = 0
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    os.makedirs(step_dir, exist_ok=True)
    flat = _flatten(state)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{host_id}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    final = os.path.join(step_dir, f"shard_{host_id:05d}.npz")
    os.replace(tmp, final)  # atomic
    digest = hashlib.sha256(open(final, "rb").read()).hexdigest()
    marker = os.path.join(step_dir, f"COMMIT_{host_id:05d}")
    with open(marker + ".tmp", "w") as f:
        json.dump({"step": step, "sha256": digest}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(marker + ".tmp", marker)
    return final


def _is_committed(step_dir: str, host_id: int) -> bool:
    marker = os.path.join(step_dir, f"COMMIT_{host_id:05d}")
    shard = os.path.join(step_dir, f"shard_{host_id:05d}.npz")
    if not (os.path.exists(marker) and os.path.exists(shard)):
        return False
    try:
        meta = json.load(open(marker))
        digest = hashlib.sha256(open(shard, "rb").read()).hexdigest()
        return digest == meta["sha256"]
    except Exception:
        return False


def latest_step(ckpt_dir: str, host_id: int = 0) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            step = int(name.split("_")[1])
            if _is_committed(os.path.join(ckpt_dir, name), host_id):
                steps.append(step)
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str, like: Any, step: Optional[int] = None, host_id: int = 0
) -> Tuple[Optional[int], Any]:
    """Restore latest committed (or given) step; returns (step, state)."""
    step = latest_step(ckpt_dir, host_id) if step is None else step
    if step is None:
        return None, like
    shard = os.path.join(
        ckpt_dir, f"step_{step:010d}", f"shard_{host_id:05d}.npz"
    )
    flat = dict(np.load(shard))
    return step, _unflatten_like(like, flat)


def prune_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
