"""Fault tolerance runtime: preemption handling, straggler detection,
elastic re-meshing hooks.

On real pods, SIGTERM arrives ~30s before preemption; the handler flips a
flag the train loop checks each step so it checkpoints and exits cleanly.
Straggler mitigation is a per-step deadline: steps exceeding
``deadline_factor`` x the rolling median are logged (on TPU the collective
itself cannot be abandoned — mitigation is re-scheduling the slow host;
here we record and expose the decision hook).
"""
from __future__ import annotations

import signal
import time
from typing import Callable, List, Optional


class PreemptionGuard:
    def __init__(self) -> None:
        self.requested = False
        self._old = None

    def install(self) -> "PreemptionGuard":
        def handler(signum, frame):
            self.requested = True

        self._old = signal.signal(signal.SIGTERM, handler)
        return self

    def uninstall(self) -> None:
        if self._old is not None:
            signal.signal(signal.SIGTERM, self._old)


class StragglerMonitor:
    def __init__(self, deadline_factor: float = 3.0, window: int = 32):
        self.deadline_factor = deadline_factor
        self.window = window
        self.durations: List[float] = []
        self.straggler_steps: List[int] = []
        self.on_straggler: Optional[Callable[[int, float], None]] = None
        self._t0 = None
        self._step = 0

    def step_start(self, step: int) -> None:
        self._t0 = time.monotonic()
        self._step = step

    def step_end(self) -> float:
        dt = time.monotonic() - self._t0
        hist = self.durations[-self.window:]
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.deadline_factor * med:
                self.straggler_steps.append(self._step)
                if self.on_straggler:
                    self.on_straggler(self._step, dt)
        self.durations.append(dt)
        return dt


class ElasticMesh:
    """Tracks desired vs available device counts; on shrink/grow the driver
    re-creates the mesh and re-shards from the latest checkpoint. On a real
    cluster `available()` would query the coordinator; here it is injectable
    for tests."""

    def __init__(self, desired: int, available_fn: Callable[[], int]):
        self.desired = desired
        self.available_fn = available_fn

    def needs_remesh(self, current: int) -> bool:
        return self.available_fn() != current

    def next_shape(self) -> int:
        avail = self.available_fn()
        # largest power-of-two <= available (keeps mesh factorable)
        shape = 1
        while shape * 2 <= avail:
            shape *= 2
        return shape
