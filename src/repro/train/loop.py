"""Generic training loop: grad accumulation (microbatching), clipping,
schedule, AdamW, checkpoint/auto-resume, preemption + straggler hooks.

``make_train_step`` returns a pure jittable function
(params, opt_state, step, batch) -> (params, opt_state, metrics); the
driver in launch/train.py pjits it with the arch's sharding specs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from ..optim.schedule import cosine_with_warmup
from . import checkpoint as ckpt
from .fault import PreemptionGuard, StragglerMonitor


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    clip_norm: float = 1.0
    weight_decay: float = 0.1
    micro_batches: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep_ckpts: int = 3


def make_train_step(
    loss_fn: Callable[..., jax.Array],
    tc: TrainConfig,
):
    """loss_fn(params, *batch) -> scalar. Batch leaves' leading axis is split
    into ``micro_batches`` chunks for gradient accumulation."""

    def train_step(params, opt_state, step, *batch):
        def lf(p, *mb):
            return loss_fn(p, *mb)

        if tc.micro_batches == 1:
            loss, grads = jax.value_and_grad(lf)(params, *batch)
        else:
            def split(x):
                return x.reshape(
                    (tc.micro_batches, x.shape[0] // tc.micro_batches)
                    + x.shape[1:]
                )

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_loss, acc_grads = carry
                loss, grads = jax.value_and_grad(lf)(params, *mb)
                return (
                    acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads),
                ), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zero), micro
            )
            loss = loss / tc.micro_batches
            grads = jax.tree.map(lambda g: g / tc.micro_batches, grads)

        grads, gnorm = clip_by_global_norm(grads, tc.clip_norm)
        lr = cosine_with_warmup(step, tc.lr, tc.warmup, tc.total_steps)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr, weight_decay=tc.weight_decay
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def run_training(
    params,
    loss_fn,
    batches,
    tc: TrainConfig,
    jit_kwargs: Optional[Dict[str, Any]] = None,
    log_every: int = 10,
    on_step: Optional[Callable[[int, Dict[str, Any]], None]] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Host driver: auto-resume, checkpoint cadence, preemption-safe."""
    opt_state = adamw_init(params)
    step0 = 0
    if tc.ckpt_dir:
        restored_step, (params, opt_state) = ckpt.restore_checkpoint(
            tc.ckpt_dir, (params, opt_state)
        )
        if restored_step is not None:
            step0 = restored_step + 1
    train_step = jax.jit(
        make_train_step(loss_fn, tc), donate_argnums=(0, 1),
        **(jit_kwargs or {}),
    )
    guard = PreemptionGuard().install()
    monitor = StragglerMonitor()
    history = []
    step = step0
    try:
        for step, batch in enumerate(batches, start=step0):
            if step >= tc.total_steps:
                break
            monitor.step_start(step)
            params, opt_state, metrics = train_step(
                params, opt_state, jnp.asarray(step), *batch
            )
            metrics = {k: float(v) for k, v in metrics.items()}
            monitor.step_end()
            history.append(metrics)
            if on_step:
                on_step(step, metrics)
            if tc.ckpt_dir and (
                step % tc.ckpt_every == 0 or guard.requested
            ):
                ckpt.save_checkpoint(tc.ckpt_dir, step, (params, opt_state))
                ckpt.prune_checkpoints(tc.ckpt_dir, tc.keep_ckpts)
            if guard.requested:
                break
    finally:
        guard.uninstall()
    return params, {
        "history": history,
        "final_step": step,
        "stragglers": monitor.straggler_steps,
    }
