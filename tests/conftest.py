"""Shared test helpers (importable from any test module via
``from conftest import ...`` under pytest's prepend import mode)."""
import numpy as np


def sample_absent(cur, rng, k):
    """k distinct normalized edges absent from CSRGraph ``cur`` (no
    self-loops), by rejection sampling."""
    batch = []
    while len(batch) < k:
        u, v = rng.integers(0, cur.n, size=2)
        key = (int(min(u, v)), int(max(u, v)))
        if u == v or cur.has_edge(*key) or key in batch:
            continue
        batch.append(key)
    return np.asarray(batch, dtype=np.int64)
