"""Shared test helpers (importable from any test module via
``from conftest import ...`` under pytest's prepend import mode).

Also registers hypothesis profiles: the ``ci`` profile (selected with
``HYPOTHESIS_PROFILE=ci``, as .github/workflows/ci.yml does) derandomizes
example generation so CI failures are reproducible, disables the
per-example deadline (jit compiles inside examples would otherwise flake
as DeadlineExceeded), and prints the reproduction blob of any failing
example instead.
"""
import os

import numpy as np

if os.environ.get("REQUIRE_HYPOTHESIS"):
    # CI sets this so a missing hypothesis install fails collection
    # LOUDLY instead of silently dropping the fuzz variants of the
    # churn/property tests to their deterministic parametrizations
    # (tests define the @given tests only when hypothesis imports).
    import hypothesis  # noqa: F401  (ImportError here IS the signal)

try:
    from hypothesis import settings as _hsettings

    _hsettings.register_profile(
        "ci", derandomize=True, deadline=None, print_blob=True
    )
    _hsettings.register_profile("dev", deadline=None, print_blob=True)
    _hsettings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # hypothesis tests importorskip themselves
    pass


def sample_absent(cur, rng, k):
    """k distinct normalized edges absent from CSRGraph ``cur`` (no
    self-loops), by rejection sampling."""
    batch = []
    while len(batch) < k:
        u, v = rng.integers(0, cur.n, size=2)
        key = (int(min(u, v)), int(max(u, v)))
        if u == v or cur.has_edge(*key) or key in batch:
            continue
        batch.append(key)
    return np.asarray(batch, dtype=np.int64)
