"""Tests of the static-analysis subsystem (repro/analysis/).

Two layers:

* seeded violations — for EACH audit rule, a minimal program built to
  violate exactly that invariant, proving the rule actually fires and
  that its finding names the offending primitive / program / round
  (a rule that can't fail guards nothing);
* the real engines — the committed budget manifests must hold on the
  current device count, api.py must pass the host-sync lint clean, and
  the walker/formula plumbing must round-trip.
"""
import json
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import (
    AuditParams,
    EngineConfig,
    Finding,
    TracedEngine,
    audit_engines,
    check_bench,
    eval_formula,
    generate_budget,
    guess_formula,
    iter_sites,
    lint_file,
    make_check,
    make_report,
    primitive_names,
    run_rules,
    tainted_truncations,
    trace_engine,
)
from repro.analysis.programs import trace_removal_round
from repro.compat import shard_map


# -- fixtures ---------------------------------------------------------------

def _mini_traced(config=None, window=16, fcap=0, sizes=None, **fields):
    """A hand-built TracedEngine around seeded programs — small enough
    that each rule test states its whole world explicitly."""
    cfg = config or EngineConfig("seeded", "unified")
    params = AuditParams(n=8, capacity=32, lanes=4)
    base = dict(programs={}, lowered={}, donated={}, rounds={})
    base.update(fields)
    return TracedEngine(
        config=cfg, params=params, n_devices=1, window=window,
        frontier_cap=fcap,
        sizes=sizes or dict(n=8, d=1, cap=fcap, n_owned=8, n_pad=8,
                            lanes=4, window=window, local_cap=32),
        **base,
    )


def _budget(**over):
    b = {
        "program_collectives": {},
        "rounds": {},
        "forbid_round_vertex_psum": False,
        "donated_args": {},
        "max_callback_primitives": 0,
        "max_tainted_truncations": 0,
        "max_jit_variants": 99,
        "large_output_bytes": 1024,
        "require_large_outputs_donated": False,
    }
    b.update(over)
    return b


def _run(traced, budget, rule):
    return run_rules(traced, budget, names=[rule])[rule]


# -- seeded violations: each rule must fire, naming the offender ------------

def test_seeded_collective_budget_histogram_drift():
    """A program whose collective histogram doesn't match the manifest
    fires with both the budgeted and the observed counts."""
    mesh = jax.make_mesh((1,), ("data",))
    sm = shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                   in_specs=(P(),), out_specs=P(), check_vma=False)
    jx = jax.make_jaxpr(sm)(jnp.zeros(8, jnp.int32))
    traced = _mini_traced(programs={"apply_batch": jx})
    budget = _budget(program_collectives={"apply_batch": {"psum": 2}})
    [f] = _run(traced, budget, "collective_budget")
    assert f.program == "apply_batch"
    assert "psum" in f.message and "drifted" in f.message


def test_seeded_collective_budget_vertex_psum_in_round():
    """The forbid_round_vertex_psum guarantee: a vertex-sized psum
    inside a while-loop body is flagged, naming the primitive, its
    size, and where it sits."""
    mesh = jax.make_mesh((1,), ("data",))
    n = 8

    def kernel(x):
        def body(c):
            return jax.lax.psum(c, "data") + 1

        return jax.lax.while_loop(lambda c: c[0] < 10, body, x)

    sm = shard_map(kernel, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_vma=False)
    jx = jax.make_jaxpr(sm)(jnp.zeros(n, jnp.int32))
    traced = _mini_traced(programs={"apply_batch": jx})
    budget = _budget(
        program_collectives={"apply_batch": {"psum": 1}},
        forbid_round_vertex_psum=True,
    )
    finds = _run(traced, budget, "collective_budget")
    assert any("vertex-sized psum" in f.message
               and "while:body_jaxpr" in f.message for f in finds)


def test_seeded_collective_budget_round_op_mismatch():
    """A round whose budget lists the wrong collective fires naming BOTH
    ops and the round."""
    mesh = jax.make_mesh((1,), ("data",))
    log, jx = trace_removal_round("range", 8, 16, mesh)
    traced = _mini_traced(rounds={"removal_round": (log, jx)})
    budget = _budget(rounds={"removal_round": {
        "main": [{"op": "psum", "recv_bytes": "n * 3 * 4"},
                 {"op": "all_gather", "recv_bytes": "d * ceil_div(n_owned, 8)"}],
        "overflow": [],
    }})
    finds = _run(traced, budget, "collective_budget")
    assert any("removal_round" in f.message and "psum" in f.message
               and "reduce_scatter" in f.message for f in finds)


def test_seeded_traffic_cross_check_catches_a_lying_note():
    """If the trace-time accounting and the jaxpr disagree — here a
    tampered byte note — the cross-check inside collective_budget
    reports the exact collective."""
    import dataclasses as dc

    mesh = jax.make_mesh((1,), ("data",))
    log, jx = trace_removal_round("range", 8, 16, mesh)
    # tamper the setup entry regather (a reduce_scatter in the jaxpr)
    assert log[1].op == "regather"
    lied = [log[0], dc.replace(log[1], recv_bytes=log[1].recv_bytes + 4)]
    lied += log[2:]
    traced = _mini_traced(rounds={"removal_round": (lied, jx)})
    finds = _run(traced, _budget(), "collective_budget")
    assert any("cross-check" in f.message and "reduce_scatter" in f.message
               for f in finds)


def test_seeded_host_sync_callback_fires():
    def f(x):
        return jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )

    jx = jax.make_jaxpr(f)(jnp.zeros(4, jnp.float32))
    traced = _mini_traced(programs={"apply_batch": jx})
    finds = _run(traced, _budget(), "host_sync")
    assert finds and all("pure_callback" in f.message for f in finds)
    assert finds[0].program == "apply_batch"


def test_seeded_host_sync_undonated_large_output_fires():
    f = jax.jit(lambda x: x * 2)  # no donate_argnums
    x = jnp.zeros(256, jnp.int32)  # 1024B: at the threshold
    traced = _mini_traced(
        programs={"apply_batch": jax.make_jaxpr(lambda a: a * 2)(x)},
        lowered={"apply_batch": f.lower(x)},
    )
    budget = _budget(require_large_outputs_donated=True)
    [f_] = _run(traced, budget, "host_sync")
    assert "does not alias" in f_.message and "1024B" in f_.message


def test_seeded_donation_drift_fires():
    f = jax.jit(lambda x: x * 2)  # declares nothing donated
    x = jnp.zeros(256, jnp.int32)
    traced = _mini_traced(lowered={"apply_batch": f.lower(x)})
    budget = _budget(donated_args={"apply_batch": [0]})
    finds = _run(traced, budget, "donation")
    assert any("donated-arg set drifted" in f.message
               and "[0]" in f.message for f in finds)


def test_seeded_donation_passes_when_lowering_donates():
    f = jax.jit(lambda x: x * 2, donate_argnums=(0,))
    x = jnp.zeros(256, jnp.int32)
    traced = _mini_traced(lowered={"apply_batch": f.lower(x)})
    budget = _budget(donated_args={"apply_batch": [0]})
    assert _run(traced, budget, "donation") == []


def test_seeded_dtype_policy_sentinel_truncation_fires():
    """The exact corruption _require_x64 guards against: an int64
    sentinel pushed through an int32 convert."""
    def f(x):
        big = jnp.int64(1) << 62
        return (x + big).astype(jnp.int32)

    jx = jax.make_jaxpr(f)(jnp.zeros(4, jnp.int64))
    traced = _mini_traced(programs={"apply_batch": jx})
    finds = _run(traced, _budget(), "dtype_policy")
    assert finds and "convert_element_type" in finds[0].message
    assert "2**31" in finds[0].message


def test_taint_is_cut_at_booleans_and_sort_permutations():
    """The two precision cuts that keep the rule quiet on the real
    programs: comparing against a sentinel yields an untainted flag,
    and an argsort permutation never inherits its keys' taint — but
    the sorted KEYS themselves stay tainted."""
    big = jnp.int64(1) << 62

    def clean(x):
        flag = x == big                       # bool: taint dies here
        perm = jnp.argsort(x + big)           # keys tainted, perm not
        return (jnp.where(flag, 1, 0).astype(jnp.int32),
                perm.astype(jnp.int32))

    assert tainted_truncations(
        jax.make_jaxpr(clean)(jnp.zeros(4, jnp.int64))) == []

    def dirty(x):
        return jnp.sort(x + big).astype(jnp.int32)  # the keys column

    assert tainted_truncations(
        jax.make_jaxpr(dirty)(jnp.zeros(4, jnp.int64))) != []


def test_taint_propagates_through_while_carry():
    def f(x):
        big = jnp.int64(1) << 62

        def body(c):
            return c + big

        y = jax.lax.while_loop(lambda c: c[0] < 5, body, x)
        return y.astype(jnp.int32)

    assert tainted_truncations(
        jax.make_jaxpr(f)(jnp.zeros(4, jnp.int64))) != []


def test_seeded_recompile_surface_fires():
    """A manifest pinning fewer jit variants than the planner lattice
    reaches fires and prints the lattice."""
    traced = _mini_traced(
        config=EngineConfig("seeded", "sharded"),
        sizes=dict(n=64, d=1, cap=0, n_owned=64, n_pad=64, lanes=8,
                   window=16, local_cap=256),
    )
    finds = _run(traced, _budget(max_jit_variants=1), "recompile_surface")
    assert any("max_jit_variants=1" in f.message for f in finds)
    # a traced bucket outside the planner lattice is its own finding
    traced_off = _mini_traced(
        config=EngineConfig("seeded", "sharded"), window=7,
        sizes=dict(n=64, d=1, cap=0, n_owned=64, n_pad=64, lanes=8,
                   window=7, local_cap=256),
    )
    finds = _run(traced_off, _budget(max_jit_variants=99),
                 "recompile_surface")
    assert any("unplanned variant" in f.message for f in finds)


# -- walker / formula plumbing ---------------------------------------------

def test_walker_attributes_cond_branches():
    def f(p, x):
        return jax.lax.cond(p, lambda v: v + 1, lambda v: v - 1, x)

    jx = jax.make_jaxpr(f)(True, jnp.int32(1))
    branch_sites = [s for s in iter_sites(jx) if s.cond_branches]
    assert branch_sites, "no sites attributed to a cond branch"
    assert {s.cond_branches[0] for s in branch_sites} == {0, 1}
    assert "cond" in primitive_names(jx)


def test_eval_formula_restricted():
    env = dict(n=64, d=8, n_owned=8, cap=16)
    assert eval_formula("n_owned * 3 * 4", env) == 96
    assert eval_formula("d * (cap + 1) * 4", env) == 544
    assert eval_formula("d * ceil_div(n_owned, 8)", env) == 8
    assert eval_formula(42, env) == 42
    with pytest.raises(ValueError, match="unknown size name"):
        eval_formula("bogus + 1", env)
    with pytest.raises(ValueError):
        eval_formula("__import__('os')", env)


def test_guess_formula_prefers_structural_over_literal():
    env = dict(n=64, d=8, n_owned=8, n_pad=64, cap=16, lanes=8,
               window=16, local_cap=32)
    assert guess_formula(8 * 3 * 4, env) == "n_owned * 3 * 4"
    assert guess_formula(8 * 17 * 4, env) == "d * (cap + 1) * 4"
    assert guess_formula(1234567, env) == 1234567  # falls back literal


# -- hostlint ---------------------------------------------------------------

_LINT_FIXTURE = textwrap.dedent(
    """
    import numpy as np

    class M:
        def apply_batch(self):
            a = int(self.n_edges)
            b = self.core.block_until_ready()
            c = float(self.label[0])
            d = np.asarray(self.valid)
            e = self.n_edges.item()
            f = int(self.n_edges)  # sync: ok
            g = int(self.capacity)
            return a

        def _refresh_bounds(self):
            return int(self.n_edges)
    """
)


def test_hostlint_seeded_violations_fire(tmp_path):
    p = tmp_path / "fixture.py"
    p.write_text(_LINT_FIXTURE)
    finds = lint_file(str(p))
    msgs = [f.message for f in finds]
    assert len(finds) == 5, msgs
    assert all(f.func == "apply_batch" for f in finds)
    assert any("int(...)" in m for m in msgs)
    assert any("block_until_ready" in m for m in msgs)
    assert any("float(...)" in m for m in msgs)
    assert any("np.asarray" in m for m in msgs)
    assert any(".item()" in m for m in msgs)
    # the allowlisted line, the host-int call, and the amortized sync
    # point outside the sync-free set are all untouched
    allowed_lines = [i + 1 for i, line in
                     enumerate(_LINT_FIXTURE.splitlines())
                     if "# sync: ok" in line or "capacity" in line
                     or "_refresh_bounds" in line]
    assert not any(f.lineno in allowed_lines for f in finds)


def test_hostlint_real_api_is_clean():
    """The shipped planning path keeps its sync-free promise."""
    assert lint_file() == []


def test_hostlint_all_targets_clean():
    """The full lint surface — api.py plus the engine-level builders in
    core/engine.py, core/sharded.py, and the fixpoint builders in
    core/remove.py / core/insert.py — is sync-free."""
    from repro.analysis.hostlint import LINT_TARGETS

    for path in LINT_TARGETS:
        assert lint_file(path) == [], path


def test_hostlint_covers_fixpoint_builders():
    """Regression: the remove/insert fixpoint builders (including the
    weighted h-index passes and the halo twins) stay in the lint
    surface, and a host coercion of a weighted device parameter (the
    weight column, the halo working set) fires by bare name."""
    import os

    from repro.analysis.hostlint import (
        DEVICE_PARAMS,
        INSERT_PATH,
        LINT_TARGETS,
        REMOVE_PATH,
    )

    assert {"removal_fixpoint", "weighted_core_fixpoint_pass",
            "weighted_core_fixpoint_pass_halo"} \
        <= LINT_TARGETS[os.path.normpath(REMOVE_PATH)]
    assert {"promotion_fixpoint", "weighted_promotion_fixpoint",
            "weighted_promotion_fixpoint_halo", "freelist_alloc"} \
        <= LINT_TARGETS[os.path.normpath(INSERT_PATH)]
    assert {"w", "total_w", "src_h", "core_h"} <= DEVICE_PARAMS


def test_hostlint_weighted_param_coercion_fires(tmp_path):
    p = tmp_path / "remove_fixture.py"
    p.write_text(textwrap.dedent(
        """
        import numpy as np

        def weighted_core_fixpoint_pass(src, dst, valid, w, core, n):
            maxw = int(w)                 # device column: sync
            cap = int(w.shape[0])         # static aval metadata: fine
            tw = np.asarray(total_w)      # sync: ok  (reviewed)
            return core
        """
    ))
    finds = lint_file(
        str(p), funcs=frozenset({"weighted_core_fixpoint_pass"})
    )
    [f] = finds
    assert "int(...)" in f.message


def test_hostlint_bare_device_param_fires(tmp_path):
    """Engine-level helpers are free functions: device state is a bare
    parameter name, not self.<field> — the lint must still catch a host
    coercion of it (and leave static python ints alone)."""
    p = tmp_path / "engine_fixture.py"
    p.write_text(textwrap.dedent(
        """
        def batch_program(src, dst, valid, core, label, n_edges, n):
            rounds = int(n)           # static python int: fine
            width = bool(n_edges)     # device scalar: sync
            return core

        def helper_outside_set(core):
            return int(core)
        """
    ))
    finds = lint_file(str(p), funcs=frozenset({"batch_program"}))
    [f] = finds
    assert f.func == "batch_program"
    assert "bool(...)" in f.message


# -- benchcheck -------------------------------------------------------------

def test_benchcheck_flags_incoherent_artifact(tmp_path):
    from repro.analysis.benchcheck import BENCH_SCHEMA

    p = tmp_path / "bench.json"
    p.write_text(json.dumps({
        "schema": BENCH_SCHEMA,
        "engines_agree": False,
        "churn": {"engines_agree": True},
        "frontier_scaling": [{"frontier_exchange": "bitmask"}],
    }))
    check = check_bench(str(p))
    assert check["rule"] == "bench_coherence" and not check["ok"]
    msgs = [f["message"] for f in check["findings"]]
    assert any("engines diverged" in m for m in msgs)
    assert any("lacks 'vertex_sharded'" in m for m in msgs)
    assert any("n_devices" in m for m in msgs)
    assert any("not a sparse-frontier row" in m for m in msgs)


def test_benchcheck_launch_section(tmp_path):
    """The v3 launch checks: a pallas round with no pallas_call (silent
    fallback to the unfused path) and a pallas round that does not beat
    lax's launch count are both incoherent; a genuinely-fused strictly
    smaller section passes those checks."""
    from repro.analysis.benchcheck import BENCH_SCHEMA

    base = {
        "schema": BENCH_SCHEMA,
        "engines_agree": True,
        "churn": {"engines_agree": True},
        "pallas": {"batches_per_s": 3.0},
    }
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({**base, "launches_per_round": {
        "lax": {"removal": {"gather": 2}, "promotion": {"gather": 4}},
        "pallas": {"removal": {"gather": 2},          # no pallas_call
                   "promotion": {"pallas_call": 1, "gather": 4}},
    }}))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert any("traces no pallas_call" in m for m in msgs)
    assert any("not strictly fewer" in m for m in msgs)

    p.write_text(json.dumps({**base, "launches_per_round": {
        "lax": {"removal": {"gather": 9}, "promotion": {"gather": 9}},
        "pallas": {"removal": {"pallas_call": 1, "scatter": 2},
                   "promotion": {"pallas_call": 3, "scatter": 2}},
    }}))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert not any("pallas" in m and "launch" in m for m in msgs)

    p.write_text(json.dumps(base))  # section absent entirely
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert any("launches_per_round" in m for m in msgs)


def test_benchcheck_v4_sections(tmp_path):
    """The v4 coherence rules: interpret-mode pallas rows are excluded
    from speedup coherence (the launch-count claim stays), mesh_scaling
    rows must be halo rows whose [d_e, d_v] shape factorizes their
    device count, and the frontier autoplan must show the overflow
    fallback receding."""
    from repro.analysis.benchcheck import BENCH_SCHEMA

    base = {
        "schema": BENCH_SCHEMA,
        "engines_agree": True,
        "churn": {"engines_agree": True},
    }
    p = tmp_path / "bench.json"
    # interpret-mode pallas at a sub-1 speedup: NOT a finding; the same
    # row without the stamp demands a coherent speedup and flags both
    p.write_text(json.dumps({
        **base,
        "pallas": {"batches_per_s": 3.0, "interpret_mode": True},
        "speedup_pallas_vs_host": 0.4,
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert not any("speedup_pallas" in m for m in msgs)
    p.write_text(json.dumps({
        **base,
        "pallas": {"batches_per_s": 3.0},
        "speedup_pallas_vs_host": 0.4,
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert any("interpret_mode stamp" in m for m in msgs)
    assert any("speedup_pallas_vs_host is 0.40x" in m for m in msgs)
    # a timed non-interpret engine row below the host baseline
    p.write_text(json.dumps({
        **base,
        "vertex_halo": {"batches_per_s": 5.0},
        "speedup_vertex_halo_vs_host": 0.9,
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert any("speedup_vertex_halo_vs_host is 0.90x" in m for m in msgs)
    # mesh_scaling rows: the shape must factorize the device count, and
    # only halo rows belong in the sweep
    p.write_text(json.dumps({
        **base,
        "mesh_scaling": [
            {"n_devices": 8, "mesh_shape": [4, 2],
             "vertex_sharding": "halo"},
            {"n_devices": 8, "mesh_shape": [4, 4],
             "vertex_sharding": "halo"},
            {"n_devices": 8, "mesh_shape": [2, 4],
             "vertex_sharding": "range"},
        ],
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert not any("mesh_scaling[0]" in m for m in msgs)
    assert any("mesh_scaling[1]" in m and "factorizing" in m for m in msgs)
    assert any("mesh_scaling[2]" in m and "not a halo row" in m
               for m in msgs)
    # the autoplan section must show fewer overflow fallbacks after
    p.write_text(json.dumps({
        **base,
        "frontier_autoplan": {"overflow_rounds_before": 2,
                              "overflow_rounds_after": 5,
                              "blind_cap": 256, "tuned_cap": 512},
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert any("did not reduce overflow" in m for m in msgs)
    p.write_text(json.dumps({
        **base,
        "frontier_autoplan": {"overflow_rounds_before": 9,
                              "overflow_rounds_after": 0,
                              "blind_cap": 256, "tuned_cap": 512},
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert not any("overflow" in m for m in msgs)


def test_benchcheck_v5_sections(tmp_path):
    """The v5 coherence rules: the weighted row must have been timed,
    and the temporal sliding-window section must drain (insertions ==
    removals, all-zero final cores), agree across engines, carry a sane
    window/stride pair, and time every temporal engine."""
    from repro.analysis.benchcheck import BENCH_SCHEMA

    base = {
        "schema": BENCH_SCHEMA,
        "engines_agree": True,
        "churn": {"engines_agree": True},
    }
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({
        **base,
        "weighted": {"batches_per_s": 0.0},
        "temporal": {
            "window": 6, "stride": 9,  # stride > window: gap, flagged
            "engines_agree": False,
            "total_insertions": 500, "total_removals": 480,
            "final_cores_zero": False,
            "host": {"batches_per_s": 2.0},
            # unified row missing entirely; sharded present but untimed
            "sharded": {"batches_per_s": 0.0},
            "weighted": {"batches_per_s": 1.0},
        },
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert any("weighted.batches_per_s is not > 0" in m for m in msgs)
    assert any("temporal engines diverged" in m for m in msgs)
    assert any("did not drain" in m for m in msgs)
    assert any("final_cores_zero" in m for m in msgs)
    assert any("window/stride malformed" in m for m in msgs)
    assert any("lacks the 'unified' engine row" in m for m in msgs)
    assert any("temporal.sharded.batches_per_s is not > 0" in m
               for m in msgs)
    # a coherent v5 artifact raises none of the new findings
    p.write_text(json.dumps({
        **base,
        "weighted": {"batches_per_s": 4.0},
        "temporal": {
            "window": 6, "stride": 3,
            "engines_agree": True,
            "total_insertions": 500, "total_removals": 500,
            "final_cores_zero": True,
            "host": {"batches_per_s": 2.0},
            "unified": {"batches_per_s": 3.0},
            "sharded": {"batches_per_s": 1.0},
            "weighted": {"batches_per_s": 1.5},
        },
    }))
    msgs = [f["message"] for f in check_bench(str(p))["findings"]]
    assert not any("temporal" in m or "weighted" in m for m in msgs)


def test_benchcheck_missing_artifact_one_actionable_finding(tmp_path):
    """A missing BENCH_stream.json must produce ONE finding telling the
    user how to regenerate it — not a traceback, not a cascade of
    lacks-key noise."""
    check = check_bench(str(tmp_path / "nope.json"))
    assert not check["ok"]
    [f] = check["findings"]
    assert "no bench artifact" in f["message"]
    assert "benchmarks.run" in f["message"]


def test_benchcheck_stale_schema_one_actionable_finding(tmp_path):
    """An artifact predating the current schema stamp (e.g. recorded
    before max_frontier observability) is rejected with a single
    regenerate hint, even if its other fields look coherent."""
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({
        "schema": "repro.analysis/bench/v1",
        "engines_agree": True,
        "churn": {"engines_agree": True},
    }))
    check = check_bench(str(p))
    assert not check["ok"]
    [f] = check["findings"]
    assert "predates the current artifact schema" in f["message"]
    assert "repro.analysis/bench/v1" in f["message"]
    assert "benchmarks.run" in f["message"]


def test_benchcheck_accepts_committed_artifact():
    import os

    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_stream.json")
    check = check_bench(path)
    assert check["ok"], check["findings"]


# -- report schema ----------------------------------------------------------

def test_report_schema_roundtrip():
    bad = Finding("collective_budget", "unified", "boom", "apply_batch")
    checks = [make_check("collective_budget", "unified", [bad]),
              make_check("donation", "unified", [])]
    report = make_report(checks, n_devices=1)
    assert report["schema"] == "repro.analysis/report/v1"
    assert report["ok"] is False
    assert report["checks"][0]["findings"][0]["message"] == "boom"
    assert json.loads(json.dumps(report)) == report  # JSON-serializable


# -- the real engines against the committed manifests ----------------------

def test_audit_passes_on_committed_budgets_fast_engines():
    """host + unified on the current device count — the full five-config
    matrix (including the sharded traces at 1 AND 8 devices) is gated by
    the CI analysis job via the CLI."""
    report = audit_engines(["host", "unified"])
    failing = [c for c in report["checks"] if not c["ok"]]
    assert report["ok"], failing


@pytest.mark.slow
def test_audit_passes_on_committed_budgets_all_engines():
    report = audit_engines(sorted(
        __import__("repro.analysis.programs",
                   fromlist=["ENGINE_CONFIGS"]).ENGINE_CONFIGS))
    failing = [c for c in report["checks"] if not c["ok"]]
    assert report["ok"], failing


@pytest.mark.slow
def test_generated_budget_matches_committed_manifest():
    """--write-budgets is reproducible: regenerating the unified
    manifest on this device count reproduces the committed one
    byte-for-byte (guards against drift between the generator and the
    checked-in files)."""
    from repro.analysis import load_budget

    traced = trace_engine("unified")
    fresh = generate_budget(traced)
    committed = load_budget("unified")
    fresh["generated_with"].pop("devices")
    committed["generated_with"].pop("devices")
    assert fresh == committed
