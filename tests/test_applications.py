"""Core-number applications: k-core sparsification correctness under
dynamic edits, sampling priorities."""
import numpy as np

from repro.core.api import CoreMaintainer
from repro.core.applications import (
    core_sampling_weights,
    densest_region_vertices,
    kcore_subgraph,
)
from repro.core.oracle import bz_from_csr
from repro.graph.csr import build_csr
from repro.graph.generators import erdos_renyi


def test_kcore_subgraph_is_the_kcore_after_edits():
    g = erdos_renyi(300, 1500, seed=0)
    m = CoreMaintainer.from_graph(g, capacity=8192)
    rng = np.random.default_rng(0)
    batch = []
    while len(batch) < 40:
        u, v = rng.integers(0, g.n, size=2)
        key = (int(min(u, v)), int(max(u, v)))
        if u != v and not g.has_edge(*key) and key not in batch:
            batch.append(key)
    m.insert_edges(np.asarray(batch))

    for k in (2, 3, int(m.cores().max())):
        nodes, edges = kcore_subgraph(m, k)
        # every vertex of the extracted subgraph has degree >= k inside it
        if nodes.size == 0:
            continue
        sub = build_csr(m.n, edges)
        deg = sub.degrees()
        assert (deg[nodes] >= k).all(), (k, deg[nodes].min())
        # and the node set matches {v: core(v) >= k}
        np.testing.assert_array_equal(
            nodes, np.nonzero(m.cores() >= k)[0]
        )


def test_sampling_weights_bias_toward_dense_regions():
    g = erdos_renyi(200, 900, seed=1)
    m = CoreMaintainer.from_graph(g)
    w = core_sampling_weights(m, alpha=2.0)
    assert abs(w.sum() - 1.0) < 1e-5
    c = m.cores()
    assert w[c == c.max()].mean() > w[c == c.min()].mean()


def test_densest_region_nonempty():
    g = erdos_renyi(200, 900, seed=2)
    m = CoreMaintainer.from_graph(g)
    v = densest_region_vertices(m, top_frac=0.05)
    assert v.size >= 1
    assert (m.cores()[v] >= m.cores().max() - 1).any()
