"""Cross-engine churn harness: long balanced insert/remove/re-insert
streams through EVERY engine configuration — host / unified / sharded,
plus the sharded engine's range-sharded vertex layout, hierarchical
free-list, and sparse frontier-exchange variants, and the fused Pallas
stat-kernel backend on both device engines — pinned bit-identical
to each other and to the sequential oracle. This is the differential
lockdown of the in-program free-list slot recycler, the per-shard
high-water window, and the vertex-layout layer (sparse frontier
overflow fallback included — see the triangle boundary test).

The claims under test (docs/DESIGN.md §4.1–§4.2):

* heavy recycled-slot traffic (just-removed re-insertion, same-batch
  remove+re-insert, duplicate dirt) never desynchronizes cores OR
  k-order labels between any two engine configurations — including
  ``vertex_sharding="range"``, whose per-round exchanges are owned
  stat slices + bitmasks rather than full vertex arrays;
* the hierarchical free-list ranking (one scalar per shard instead of
  the windowed dead-mask all_gather) allocates the IDENTICAL LIVE EDGE
  SET — and, core numbers never depending on slot positions, identical
  cores and labels — as the interleaved ranking;
* with flat live edges, capacity never grows after warm-up and the slot
  high-water mark is bounded by the running max of the live count (the
  recycling invariant) — host-side defrag never fires on device engines;
* ``validate=False`` masked rows consume no slots and leave
  ``live_edges`` / ``BatchStats`` untouched;
* a save -> load round trip after recycling (tombstones + free-list +
  per-shard high-water marks, all carried by the ``valid`` mask)
  restores an equivalent maintainer on 1 and 8 forced host devices;
* a batch that must defrag AND grow places the sharded buffers exactly
  once (regression: the old compact-then-grow path placed them twice);
* the weighted h-index configs ride the same dirty stream: on the unit
  weights ``apply_batch`` defaults to they match every unweighted
  config's cores, and under RANDOM integer weights both weighted
  configs stay pinned to ``weighted_core_oracle`` (first-occurrence
  duplicate weights, live re-insert no-ops, same-batch remove+insert
  roundtrips committing the new weight) on 1 and 8 forced devices.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

try:  # the fuzz variant needs hypothesis; the deterministic harness not
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.api import CoreMaintainer
from repro.core.oracle import OrderCoreMaintainer, bz_from_csr
from repro.core.weighted import weighted_core_oracle
from repro.graph.csr import build_csr
from repro.graph.generators import erdos_renyi
from repro.graph.stream import churn_stream

ENGINES = ("host", "unified", "sharded")

# every engine CONFIGURATION the differential harness pins bit-identical:
# the three engines plus the sharded engine's vertex-layout / free-list /
# frontier-exchange variants (CoreMaintainer kwargs per name)
CONFIGS = {
    "host": dict(engine="host"),
    "unified": dict(engine="unified"),
    "sharded": dict(engine="sharded"),
    "vertex_range": dict(engine="sharded", vertex_sharding="range"),
    "freelist_hier": dict(engine="sharded", freelist="hierarchical"),
    "frontier_sparse": dict(engine="sharded", vertex_sharding="range",
                            frontier_exchange="sparse"),
    # the 2-axis halo layout (edge x vertex mesh; degenerate (1, 1) on a
    # single device) — the owner-range working set plus halo must stay
    # bit-identical to every flat layout
    "vertex_halo": dict(engine="sharded", vertex_sharding="halo"),
    # the fused Pallas stat kernels (kernels/coremaint.py) — interpret
    # mode off-TPU, so this runs (and must stay bit-identical) everywhere
    "pallas": dict(engine="unified", kernel_backend="pallas"),
    "pallas_sharded": dict(engine="sharded", kernel_backend="pallas"),
    # the weighted h-index engine: on the unit weights apply_batch
    # defaults to, weighted coreness degenerates to plain coreness, so
    # these rows ride the SAME dirty stream and must match every other
    # config's CORES. Labels are compared only among the weighted
    # configs: weighted maintenance freezes labels through the fixpoints
    # and renumbers once per batch, a deliberately different (equally
    # valid) k-order schedule than the order-based engines'.
    "weighted": dict(engine="unified", weighted=True),
    "weighted_sharded": dict(engine="sharded", weighted=True),
}

# configs whose labels follow the weighted renumber-once-per-batch
# schedule rather than the order-based one
WEIGHTED_CONFIGS = ("weighted", "weighted_sharded")


def _norm(edges) -> list:
    """Normalized (lo, hi) tuples of an [k, 2] edge array."""
    return [
        (int(min(a, b)), int(max(a, b))) for a, b in np.asarray(edges)
    ]


def _effective_delta(live, ins, rm):
    """Replay one dirty event with apply_batch semantics on a host-side
    live-set mirror: removals first, then first-occurrence-deduped
    insertions. Returns the clean (inserted, removed) lists the
    sequential oracle (which rejects duplicate edits) can consume."""
    removed = []
    for e in _norm(rm):
        if e in live:
            live.discard(e)
            removed.append(e)
    inserted = []
    for e in _norm(ins):
        if e[0] != e[1] and e not in live:
            live.add(e)
            inserted.append(e)
    return inserted, removed


def _run_churn_differential(m0, graph_seed, stream_seed, n_batches,
                            batch_size, p_reinsert):
    """Every engine sees the same dirty churn events; after every event
    all three agree bit-exactly (cores AND labels) with each other, with
    BZ from scratch, and with the sequential order-based oracle fed the
    clean effective delta."""
    n = 24
    g = erdos_renyi(n, m0, seed=graph_seed)
    cap = 4 * g.m + 64
    ms = {
        e: CoreMaintainer.from_graph(g, capacity=cap, **kw)
        for e, kw in CONFIGS.items()
    }
    caps0 = {e: m.capacity for e, m in ms.items()}
    oracle = OrderCoreMaintainer(n, g.edge_array())
    live = set(_norm(g.edge_array()))
    hwm_bound = len(live)  # running max of the live count
    for ev in churn_stream(g, n_batches, batch_size, seed=stream_seed,
                           p_reinsert=p_reinsert):
        stats = {
            e: m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
            for e, m in ms.items()
        }
        inserted, removed = _effective_delta(live, ev.edges, ev.removals)
        oracle.remove_batch(np.asarray(removed).reshape(-1, 2))
        oracle.insert_batch(np.asarray(inserted).reshape(-1, 2))
        hwm_bound = max(hwm_bound, len(live))
        expect = bz_from_csr(
            build_csr(n, np.asarray(sorted(live), dtype=np.int64))
        )
        u = ms["unified"]
        np.testing.assert_array_equal(u.cores(), expect)
        np.testing.assert_array_equal(u.cores(), oracle.core)
        for e in CONFIGS:
            if e == "unified":
                continue
            np.testing.assert_array_equal(u.cores(), ms[e].cores(), e)
            if e not in WEIGHTED_CONFIGS:
                np.testing.assert_array_equal(u.labels(), ms[e].labels(), e)
        # the weighted configs' labels follow their own (shared)
        # renumber-once-per-batch schedule — identical to each other
        np.testing.assert_array_equal(
            ms["weighted"].labels(), ms["weighted_sharded"].labels()
        )
        for e, st_ in stats.items():
            assert int(st_.n_inserted) == len(inserted), e
            assert int(st_.n_removed) == len(removed), e
        # the recycling invariant: the slot high-water mark never outruns
        # the running max of the live count (holes are filled first)
        assert int(stats["unified"].high_water) <= hwm_bound
        assert int(u.n_edges) == u.live_edges == len(live)
        # both free-list rankings allocate the identical live set (slot
        # POSITIONS may differ across shards; the keys may not)
        for e in ("sharded", "vertex_range", "freelist_hier",
                  "frontier_sparse", "vertex_halo", "pallas_sharded",
                  "weighted", "weighted_sharded"):
            assert ms[e].edge_slot.keys() == u.edge_slot.keys(), e
    # balanced stream + generous initial capacity: nothing may grow
    for e, m in ms.items():
        assert m.capacity == caps0[e], e


@pytest.mark.parametrize(
    "params",
    [
        # (m0, graph_seed, stream_seed, n_batches, batch_size, p_reinsert)
        (60, 0, 1, 4, 12, 0.6),   # mixed fresh/recycled traffic
        (45, 7, 3, 3, 8, 1.0),    # every insert re-inserts a removal
        (90, 2, 9, 3, 16, 0.3),   # denser graph, mostly fresh inserts
    ],
)
def test_churn_engines_bit_identical(params):
    _run_churn_differential(*params)


if HAVE_HYPOTHESIS:

    @st.composite
    def churn_params(draw):
        # n is held fixed so the whole hypothesis run shares one jit
        # cache per (batch-bucket, window-bucket) pair; the graph, the
        # stream shape, and the dirt all vary through the seeds
        m0 = draw(st.integers(min_value=40, max_value=90))
        graph_seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        stream_seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        n_batches = draw(st.integers(min_value=2, max_value=4))
        batch_size = draw(st.sampled_from([8, 12, 16]))
        p_reinsert = draw(st.sampled_from([0.3, 0.6, 1.0]))
        return m0, graph_seed, stream_seed, n_batches, batch_size, p_reinsert

    @given(churn_params())
    @settings(
        max_examples=8,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large],
    )
    def test_churn_engines_bit_identical_fuzz(params):
        _run_churn_differential(*params)


def _weighted_oracle_state(n, live):
    """Exact weighted cores of a (lo, hi) -> weight live-set mirror."""
    if not live:
        return np.zeros(n, dtype=np.int64)
    edges = np.asarray(sorted(live), dtype=np.int64)
    weights = np.asarray([live[tuple(e)] for e in edges], dtype=np.int64)
    return weighted_core_oracle(n, edges, weights)


def _run_weighted_churn_differential(m0, graph_seed, stream_seed,
                                     n_batches, batch_size, max_w):
    """The weighted twin of ``_run_churn_differential``: both weighted
    engine configs see the same dirty churn stream with RANDOM integer
    weights on every insert list; after every event their cores match
    the numpy peeling oracle on a host-side live-set mirror that pins
    the engine's weight semantics — removals first, first occurrence
    of an in-batch duplicate wins, re-inserting a live edge keeps the
    stored weight, and remove+re-insert in ONE batch lands the new
    weight (the same-batch roundtrip path)."""
    n = 24
    g = erdos_renyi(n, m0, seed=graph_seed)
    rng = np.random.default_rng(stream_seed + 1)
    w0 = rng.integers(1, max_w + 1, g.m)
    cap = 4 * g.m + 64
    ms = {
        e: CoreMaintainer.from_graph(g, capacity=cap, weights=w0,
                                     **CONFIGS[e])
        for e in WEIGHTED_CONFIGS
    }
    live = {e: int(w) for e, w in zip(_norm(g.edge_array()), w0)}
    np.testing.assert_array_equal(
        ms["weighted"].cores(), _weighted_oracle_state(n, live)
    )
    for ev in churn_stream(g, n_batches, batch_size, seed=stream_seed):
        iw = rng.integers(1, max_w + 1, len(ev.edges))
        stats = {
            e: m.apply_batch(insert_edges=ev.edges,
                             remove_edges=ev.removals,
                             insert_weights=iw)
            for e, m in ms.items()
        }
        # host mirror of the engine's batch semantics: removals first,
        # then insertions in order with duplicate/live rows skipped (so
        # the first occurrence's weight sticks and a same-batch
        # remove+insert roundtrip commits the new weight)
        removed = 0
        for e in _norm(ev.removals):
            if live.pop(e, None) is not None:
                removed += 1
        inserted = 0
        for e, w in zip(_norm(ev.edges), iw):
            if e[0] != e[1] and e not in live:
                live[e] = int(w)
                inserted += 1
        expect = _weighted_oracle_state(n, live)
        u = ms["weighted"]
        np.testing.assert_array_equal(u.cores(), expect)
        np.testing.assert_array_equal(
            u.cores(), ms["weighted_sharded"].cores()
        )
        np.testing.assert_array_equal(
            u.labels(), ms["weighted_sharded"].labels()
        )
        for e, st_ in stats.items():
            assert int(st_.n_inserted) == inserted, e
            assert int(st_.n_removed) == removed, e
        assert ms["weighted_sharded"].edge_slot.keys() == \
            u.edge_slot.keys()
        # the stored weight column mirrors the live map exactly
        wcol = np.asarray(u.w)
        for e, slot in u.edge_slot.items():
            assert int(wcol[slot]) == live[e], e


@pytest.mark.parametrize(
    "params",
    [
        # (m0, graph_seed, stream_seed, n_batches, batch_size, max_w)
        (60, 0, 1, 4, 12, 7),   # mixed traffic, spread weights
        (45, 7, 3, 3, 8, 1),    # all-unit weights == unweighted cores
        (90, 2, 9, 3, 16, 13),  # denser graph, heavier weights
    ],
)
def test_weighted_churn_engines_match_oracle(params):
    _run_weighted_churn_differential(*params)


def test_weighted_duplicate_and_same_batch_roundtrip():
    """Pin the weight-commit rules one at a time (against the oracle,
    on both weighted configs): in-batch duplicates keep the FIRST
    occurrence's weight, re-inserting a live edge is a no-op that keeps
    the stored weight, and remove + re-insert in the SAME batch (the
    slot-recycling roundtrip) commits the NEW weight."""
    n = 8
    e0 = np.asarray([[0, 1], [1, 2], [2, 0], [3, 4]], dtype=np.int64)
    w0 = np.asarray([2, 3, 4, 5], dtype=np.int64)
    for config in WEIGHTED_CONFIGS:
        g = build_csr(n, e0)
        m = CoreMaintainer.from_graph(
            g, capacity=64, weights=w0, **CONFIGS[config]
        )
        # weights align with g.edge_array() (build_csr normalizes and
        # sorts), so mirror from the canonical row order
        live = {e: int(w) for e, w in zip(_norm(g.edge_array()), w0)}
        # in-batch duplicate: first occurrence wins
        m.apply_batch(insert_edges=[[4, 5], [4, 5]], insert_weights=[6, 9])
        live[(4, 5)] = 6
        # re-insert of a live edge: no-op, stored weight kept
        m.apply_batch(insert_edges=[[0, 1]], insert_weights=[9])
        # same-batch remove + re-insert: the NEW weight lands
        m.apply_batch(insert_edges=[[1, 2]], remove_edges=[[1, 2]],
                      insert_weights=[7])
        live[(1, 2)] = 7
        wcol = np.asarray(m.w)
        for e, slot in m.edge_slot.items():
            assert int(wcol[slot]) == live[e], (config, e)
        np.testing.assert_array_equal(
            m.cores(), _weighted_oracle_state(n, live), config
        )


if HAVE_HYPOTHESIS:

    @st.composite
    def weighted_churn_params(draw):
        # same shape discipline as churn_params (fixed n, pow2 lane
        # buckets shared across examples); weights draw from three
        # regimes — unit (degenerates to plain coreness), narrow, wide
        m0 = draw(st.integers(min_value=40, max_value=90))
        graph_seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        stream_seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        n_batches = draw(st.integers(min_value=2, max_value=3))
        batch_size = draw(st.sampled_from([8, 12, 16]))
        max_w = draw(st.sampled_from([1, 5, 13]))
        return m0, graph_seed, stream_seed, n_batches, batch_size, max_w

    @given(weighted_churn_params())
    @settings(
        max_examples=6,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large],
    )
    def test_weighted_churn_engines_match_oracle_fuzz(params):
        _run_weighted_churn_differential(*params)


@pytest.mark.parametrize("config", tuple(CONFIGS))
def test_capacity_flat_under_balanced_churn(config):
    """Acceptance: >= 50 balanced 50/50 batches on a TIGHT table. After
    warm-up, capacity never grows on any engine configuration; on the
    device engines the in-program recycler absorbs every batch without a
    single host-side defrag, and the high-water mark stays pinned at the
    live count."""
    engine = CONFIGS[config]["engine"]
    g = erdos_renyi(60, 240, seed=2)
    cap = int(g.m * 1.4) + 32  # far less than the stream's gross inserts
    m = CoreMaintainer.from_graph(g, capacity=cap, **CONFIGS[config])
    cap_after_warmup = None
    defrags = 0
    orig = CoreMaintainer._defrag_to

    def counting(self, new_cap):
        nonlocal defrags
        defrags += 1
        return orig(self, new_cap)

    live = set(_norm(g.edge_array()))
    events = list(churn_stream(g, 52, 16, seed=7))
    try:
        CoreMaintainer._defrag_to = counting
        for i, ev in enumerate(events):
            m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
            _effective_delta(live, ev.edges, ev.removals)
            if i == 1:
                cap_after_warmup = m.capacity
                defrags = 0
            if cap_after_warmup is not None:
                assert m.capacity == cap_after_warmup, f"grew at batch {i}"
    finally:
        CoreMaintainer._defrag_to = orig
    if engine != "host":
        # flat live edges -> the free-list recycles every tombstone
        # in-program; the host reclaim path never runs
        assert defrags == 0
        assert int(m.last_batch_stats.high_water) <= len(live) + 1
        assert int(m.n_edges) == len(live)
    assert m.live_edges == len(live)
    expect = bz_from_csr(build_csr(m.n, np.asarray(sorted(live),
                                                   dtype=np.int64)))
    np.testing.assert_array_equal(m.cores(), expect)


@pytest.mark.parametrize(
    "config", ("unified", "sharded", "vertex_range", "frontier_sparse")
)
def test_masked_rows_consume_nothing(config):
    """validate=False drops out-of-range rows BEFORE they can touch the
    device: no slot is consumed, live_edges and n_edges are unchanged,
    and the batch stats count only the surviving rows."""
    g = erdos_renyi(40, 120, seed=5)
    m = CoreMaintainer.from_graph(g, capacity=512, **CONFIGS[config],
                                  validate=False)
    live0 = m.live_edges
    ne0 = int(m.n_edges)
    core0 = m.cores().copy()
    # all rows masked -> the batch degenerates to the empty-batch path
    st_ = m.apply_batch(insert_edges=[[5, 9999], [-1, 3]],
                        remove_edges=[[40, 0], [2, -7]])
    assert int(st_.n_inserted) == 0 and int(st_.n_removed) == 0
    assert int(st_.n_recycled) == 0
    assert m.live_edges == live0 and int(m.n_edges) == ne0
    np.testing.assert_array_equal(m.cores(), core0)
    # mixed batch: only the in-range row lands
    ins = [[0, 39], [0, 40], [-1, 1]]
    already = (0, 39) in m.edge_slot
    st_ = m.apply_batch(insert_edges=ins)
    assert int(st_.n_inserted) == (0 if already else 1)
    assert m.live_edges == live0 + int(st_.n_inserted)
    assert int(m.n_edges) == m.live_edges


@pytest.mark.parametrize("n_triangles", (3, 4, 5))
def test_frontier_sparse_across_overflow_boundary(n_triangles):
    """ACCEPTANCE: the sparse frontier exchange straddling its overflow
    fallback. Removing one edge from each of T disjoint triangles makes
    the FIRST removal round drop exactly 2T vertices (both endpoints of
    every removed edge; the third vertex follows in round 2, and the
    terminating rounds of both fixpoints have EMPTY frontiers). With the
    cap forced to 8, T = 3 / 4 / 5 puts that round's frontier below /
    exactly at / above the cap — the overflowing round takes the
    in-program bitmask fallback — and every regime must stay
    bit-identical (cores AND labels) to the unified engine and the BZ
    oracle, through the re-inserting promotion batch too."""
    T = n_triangles
    n = 3 * T
    edges = np.asarray(
        [e for t in range(T)
         for e in ((3 * t, 3 * t + 1), (3 * t, 3 * t + 2),
                   (3 * t + 1, 3 * t + 2))],
        dtype=np.int64,
    )
    g = build_csr(n, edges)
    mk = dict(capacity=4 * len(edges) + 16)
    mu = CoreMaintainer.from_graph(g, **mk)
    mf = CoreMaintainer.from_graph(
        g, engine="sharded", vertex_sharding="range",
        frontier_exchange="sparse", frontier_cap=8, **mk,
    )
    rm = np.asarray([(3 * t, 3 * t + 1) for t in range(T)], dtype=np.int64)
    for m in (mu, mf):
        m.apply_batch(remove_edges=rm)
    np.testing.assert_array_equal(mu.cores(), mf.cores())
    np.testing.assert_array_equal(mu.labels(), mf.labels())
    gone = set(map(tuple, rm.tolist()))
    live = np.asarray(
        [e for e in map(tuple, edges.tolist()) if e not in gone],
        dtype=np.int64,
    )
    np.testing.assert_array_equal(mu.cores(), bz_from_csr(build_csr(n, live)))
    # re-insert: T whole triangles promote back 1 -> 2 (3T candidates —
    # above the cap again at T=3 already), same bit-identity demands
    for m in (mu, mf):
        m.apply_batch(insert_edges=rm)
    np.testing.assert_array_equal(mu.cores(), mf.cores())
    np.testing.assert_array_equal(mu.labels(), mf.labels())
    np.testing.assert_array_equal(mu.cores(), bz_from_csr(build_csr(n, edges)))


def test_save_load_after_recycling_roundtrip(tmp_path):
    """Tombstones, the implicit free-list, and the high-water bookkeeping
    all ride in the ``valid`` mask: a reload mid-churn (holes present)
    restores an equivalent maintainer under every engine configuration
    and continues bit-identically. The second leg saves FROM the
    range-sharded reader — its padded, vertex-sharded core/label must
    checkpoint unpadded and reload under any layout."""
    g = erdos_renyi(50, 180, seed=1)
    m = CoreMaintainer.from_graph(g, capacity=1024)
    live = set(_norm(g.edge_array()))
    events = list(churn_stream(g, 4, 12, seed=4))
    for ev in events[:3]:
        m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        _effective_delta(live, ev.edges, ev.removals)
    # punch extra unrecycled holes so the saved state is fragmented
    holes = np.asarray(sorted(live), dtype=np.int64)[:7]
    m.apply_batch(remove_edges=holes)
    _effective_delta(live, np.zeros((0, 2), np.int64), holes)
    p = str(tmp_path / "churned.npz")
    m.save(p)
    loaded = {e: CoreMaintainer.load(p, **kw) for e, kw in CONFIGS.items()}
    val = np.asarray(m.valid)
    hwm = int(np.nonzero(val)[0].max()) + 1
    for e, m2 in loaded.items():
        assert m2.live_ub == len(live), e
        assert m2.hwm_ub == hwm, e  # recomputed exactly from the mask
        assert m2.edge_slot == m.edge_slot, e
    # fragmented save FROM range-sharded vertex state, reload replicated
    p2 = str(tmp_path / "churned_vs.npz")
    loaded["vertex_range"].save(p2)
    loaded["reload_of_vs"] = CoreMaintainer.load(p2)
    assert loaded["reload_of_vs"].core.shape == (g.n,)  # pad stripped
    # everyone (original + reloads) continues identically
    ev = events[3]
    for m2 in (m, *loaded.values()):
        m2.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
    _effective_delta(live, ev.edges, ev.removals)
    expect = bz_from_csr(build_csr(m.n, np.asarray(sorted(live),
                                                   dtype=np.int64)))
    np.testing.assert_array_equal(m.cores(), expect)
    for e, m2 in loaded.items():
        np.testing.assert_array_equal(m.cores(), m2.cores(), e)
        if e not in WEIGHTED_CONFIGS:
            np.testing.assert_array_equal(m.labels(), m2.labels(), e)
        assert m2.live_edges == len(live), e
    # the weighted reloads (unit weights recovered from the unweighted
    # checkpoint) share the renumber-once-per-batch label schedule
    np.testing.assert_array_equal(
        loaded["weighted"].labels(), loaded["weighted_sharded"].labels()
    )


def test_compact_then_grow_places_sharded_buffers_once():
    """Regression: one apply_batch that must BOTH defrag and grow used to
    place the sharded buffers twice (_compact placed, then _grow placed
    again). _ensure_capacity now fuses them into a single re-layout."""
    g = erdos_renyi(40, 150, seed=9)
    m = CoreMaintainer.from_graph(g, capacity=g.m + 12, engine="sharded")
    placements = 0
    orig = CoreMaintainer._place_sharded

    def counting(self):
        nonlocal placements
        placements += 1
        return orig(self)

    cap0 = m.capacity
    big = np.asarray(
        [[u, v] for u in range(6) for v in range(u + 1, 40)
         if (u, v) not in m.edge_slot][:40],
        dtype=np.int64,
    )
    try:
        CoreMaintainer._place_sharded = counting
        m.apply_batch(insert_edges=big)  # cannot fit: defrag + grow
    finally:
        CoreMaintainer._place_sharded = orig
    assert m.capacity > cap0
    assert placements == 1, f"sharded buffers placed {placements}x"
    live = set(_norm(g.edge_array())) | set(_norm(big))
    expect = bz_from_csr(build_csr(m.n, np.asarray(sorted(live),
                                                   dtype=np.int64)))
    np.testing.assert_array_equal(m.cores(), expect)
    assert m.live_edges == len(live)


def test_pure_defrag_keeps_capacity():
    """When live edges shrink but the high-water mark stays pinned high
    (a live edge stuck in a top slot above a sea of holes), the
    escalation path defrags WITHOUT growing — _compact demoted to a rare
    defrag, not the reclaim path."""
    g = erdos_renyi(40, 150, seed=3)
    m = CoreMaintainer.from_graph(g, capacity=g.m + 24)
    edges = g.edge_array()
    # remove most edges: live collapses but the top slots stay occupied,
    # so high_water stays ~m while the table is mostly holes
    m.apply_batch(remove_edges=edges[: g.m - 10])
    hw = int(m.last_batch_stats.high_water)
    assert hw == g.m  # top slot still live above the holes
    live = set(_norm(edges[g.m - 10:]))
    # a batch too big for the window above the pinned high-water mark:
    # the exact-bound refresh still crosses the threshold, so the
    # escalation must defrag — but a packed table leaves plenty of room,
    # so capacity must NOT grow
    fresh = []
    for u in range(40):
        for v in range(u + 1, 40):
            if (u, v) not in live and len(fresh) < 30:
                fresh.append((u, v))
    fresh = np.asarray(fresh, dtype=np.int64)
    defrags = 0
    orig = CoreMaintainer._defrag_to

    def counting(self, new_cap):
        nonlocal defrags
        defrags += 1
        return orig(self, new_cap)

    cap0 = m.capacity
    try:
        CoreMaintainer._defrag_to = counting
        m.apply_batch(insert_edges=fresh)
    finally:
        CoreMaintainer._defrag_to = orig
    live |= set(_norm(fresh))
    assert defrags == 1
    assert m.capacity == cap0
    assert int(m.last_batch_stats.high_water) <= len(live)
    expect = bz_from_csr(build_csr(m.n, np.asarray(sorted(live),
                                                   dtype=np.int64)))
    np.testing.assert_array_equal(m.cores(), expect)
    assert m.live_edges == len(live)


_ROUNDTRIP_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    import repro  # enables x64
    from repro.core.api import CoreMaintainer
    from repro.core.oracle import bz_from_csr
    from repro.graph.csr import build_csr
    from repro.graph.generators import erdos_renyi
    from repro.graph.stream import churn_stream

    assert len(jax.devices()) == 8, jax.devices()
    g = erdos_renyi(83, 320, seed=1)  # n % 8 != 0: vertex pad in play
    ms = CoreMaintainer.from_graph(g, capacity=645, engine="sharded")
    mu = CoreMaintainer.from_graph(g, capacity=645, engine="unified")
    mv = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                   vertex_sharding="range")
    mh = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                   freelist="hierarchical")
    # sparse frontier exchange with a deliberately TINY forced cap: the
    # per-round frontiers of a 24-edit churn batch straddle it, so the
    # stream exercises both cond arms on a real 8-shard mesh
    mf = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                   vertex_sharding="range",
                                   frontier_exchange="sparse",
                                   frontier_cap=4)
    # the fused Pallas stat kernels under a REAL 8-shard mesh (interpret
    # mode off-TPU): local partials swap in, the collective schedule does
    # not change, so cores AND labels must track the lax engines exactly
    mp = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                   kernel_backend="pallas")
    # 2-axis halo meshes: both proper edge x vertex factorizations of the
    # same 8 devices (one on each kernel backend) plus BOTH degenerate
    # shapes — (1, 8) is pure vertex sharding, (8, 1) pure edge sharding
    # — all of which must track the flat engines bit-exactly
    mh42 = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                     vertex_sharding="halo",
                                     mesh_shape=(4, 2))
    mh24 = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                     vertex_sharding="halo",
                                     mesh_shape=(2, 4),
                                     kernel_backend="pallas")
    mh18 = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                     vertex_sharding="halo",
                                     mesh_shape=(1, 8))
    mh81 = CoreMaintainer.from_graph(g, capacity=645, engine="sharded",
                                     vertex_sharding="halo",
                                     mesh_shape=(8, 1))
    halos = (mh42, mh24, mh18, mh81)
    assert ms.capacity % 8 == 0, ms.capacity
    assert mv.core.shape == (88,)  # padded to the shard multiple

    def norm(edges):
        return [(int(min(a, b)), int(max(a, b))) for a, b in edges]

    live = set(norm(g.edge_array()))
    events = list(churn_stream(g, 8, 24, seed=5))
    for ev in events[:6]:
        for m in (ms, mu, mv, mh, mf, mp, *halos):
            m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        for e in norm(ev.removals):
            live.discard(e)
        for e in norm(ev.edges):
            if e[0] != e[1]:
                live.add(e)
        # range-sharded vertex state, the hierarchical free-list, and
        # the overflow-straddling sparse frontier exchange stay
        # bit-identical to the replicated interleaved engine mid-stream
        np.testing.assert_array_equal(mu.cores(), mv.cores())
        np.testing.assert_array_equal(mu.labels(), mv.labels())
        np.testing.assert_array_equal(mu.cores(), mh.cores())
        np.testing.assert_array_equal(mu.labels(), mh.labels())
        np.testing.assert_array_equal(mu.cores(), mf.cores())
        np.testing.assert_array_equal(mu.labels(), mf.labels())
        np.testing.assert_array_equal(mu.cores(), mp.cores())
        np.testing.assert_array_equal(mu.labels(), mp.labels())
        for hm in halos:
            np.testing.assert_array_equal(mu.cores(), hm.cores())
            np.testing.assert_array_equal(mu.labels(), hm.labels())
        # hierarchical ranks (shard, slot): slot POSITIONS may differ
        # from the interleaved engines, the LIVE SET may not
        assert mh.edge_slot.keys() == mu.edge_slot.keys()
    # flat live edges on a tight table: nobody grew, slots recycled
    assert ms.capacity == 648 and mu.capacity == 645
    assert int(ms.last_batch_stats.n_recycled) > 0
    assert int(mh.last_batch_stats.n_recycled) > 0
    # per-shard window bound: densest shard stays far under local cap
    assert int(ms.last_batch_stats.high_water) <= -(-len(live) // 8) + 24

    p = "/tmp/churn_8dev_roundtrip.npz"
    ms.save(p)
    pv = "/tmp/churn_8dev_roundtrip_vs.npz"
    mv.save(pv)  # fragmented save FROM range-sharded (padded) state
    m2 = CoreMaintainer.load(p, engine="sharded")   # re-strided over 8
    m3 = CoreMaintainer.load(p, engine="unified")
    m4 = CoreMaintainer.load(pv, engine="sharded", vertex_sharding="range")
    m5 = CoreMaintainer.load(pv, engine="unified")
    assert m5.core.shape == (g.n,)  # the phantom pad never leaks out
    assert m2.edge_slot.keys() == m3.edge_slot.keys() == {
        tuple(e) for e in live
    }
    assert m4.edge_slot.keys() == m5.edge_slot.keys() == {
        tuple(e) for e in live
    }
    for ev in events[6:]:
        for m in (ms, mu, mv, mh, mf, mp, *halos, m2, m3, m4, m5):
            m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        for e in norm(ev.removals):
            live.discard(e)
        for e in norm(ev.edges):
            if e[0] != e[1]:
                live.add(e)
    expect = bz_from_csr(build_csr(g.n, np.asarray(sorted(live),
                                                   dtype=np.int64)))
    for name, m in (("sharded", ms), ("unified", mu),
                    ("vertex-range", mv), ("freelist-hier", mh),
                    ("frontier-sparse", mf), ("pallas-sharded", mp),
                    ("halo-4x2", mh42), ("halo-2x4-pallas", mh24),
                    ("halo-1x8", mh18), ("halo-8x1", mh81),
                    ("reload-sharded", m2), ("reload-unified", m3),
                    ("reload-vertex-range", m4), ("reload-vs-unified", m5)):
        np.testing.assert_array_equal(m.cores(), expect, err_msg=name)
        np.testing.assert_array_equal(m.labels(), ms.labels(), err_msg=name)
        assert m.live_edges == len(live), name
    print("churn-roundtrip-8dev OK")
    """
)


_WEIGHTED_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    import repro  # enables x64
    from repro.core.api import CoreMaintainer
    from repro.core.weighted import weighted_core_oracle
    from repro.graph.csr import build_csr
    from repro.graph.generators import erdos_renyi
    from repro.graph.stream import churn_stream

    assert len(jax.devices()) == 8, jax.devices()
    n = 40
    g = erdos_renyi(n, 150, seed=4)
    rng = np.random.default_rng(11)
    w0 = rng.integers(1, 9, g.m)
    cap = 4 * g.m + 64
    mk = dict(capacity=cap, weighted=True, weights=w0)
    engines = {
        "unified": CoreMaintainer.from_graph(g, **mk),
        "pallas": CoreMaintainer.from_graph(g, kernel_backend="pallas",
                                            **mk),
        "sharded": CoreMaintainer.from_graph(g, engine="sharded", **mk),
        "range_sparse": CoreMaintainer.from_graph(
            g, engine="sharded", vertex_sharding="range",
            frontier_exchange="sparse", frontier_cap=8, **mk),
        "halo_2x4": CoreMaintainer.from_graph(
            g, engine="sharded", vertex_sharding="halo",
            mesh_shape=(2, 4), **mk),
    }

    def norm(edges):
        return [(int(min(a, b)), int(max(a, b))) for a, b in edges]

    def oracle_state(live):
        if not live:
            return np.zeros(n, dtype=np.int64)
        e = np.asarray(sorted(live), dtype=np.int64)
        w = np.asarray([live[tuple(r)] for r in e], dtype=np.int64)
        return weighted_core_oracle(n, e, w)

    live = {e: int(w) for e, w in zip(norm(g.edge_array()), w0)}
    events = list(churn_stream(g, 6, 16, seed=8))
    for ev in events[:4]:
        iw = rng.integers(1, 9, len(ev.edges))
        for m in engines.values():
            m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals,
                          insert_weights=iw)
        for e in norm(ev.removals):
            live.pop(e, None)
        for e, w in zip(norm(ev.edges), iw):
            if e[0] != e[1] and e not in live:
                live[e] = int(w)
        expect = oracle_state(live)
        ref = engines["unified"]
        np.testing.assert_array_equal(ref.cores(), expect)
        for name, m in engines.items():
            np.testing.assert_array_equal(ref.cores(), m.cores(),
                                          err_msg=name)
            np.testing.assert_array_equal(ref.labels(), m.labels(),
                                          err_msg=name)
    # save FROM the sharded weighted table mid-churn (holes present),
    # reload under both engines: the weight column rides the checkpoint
    p = "/tmp/weighted_churn_8dev.npz"
    engines["sharded"].save(p)
    engines["reload_unified"] = CoreMaintainer.load(p, weighted=True)
    engines["reload_sharded"] = CoreMaintainer.load(p, weighted=True,
                                                    engine="sharded")
    for ev in events[4:]:
        iw = rng.integers(1, 9, len(ev.edges))
        for m in engines.values():
            m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals,
                          insert_weights=iw)
        for e in norm(ev.removals):
            live.pop(e, None)
        for e, w in zip(norm(ev.edges), iw):
            if e[0] != e[1] and e not in live:
                live[e] = int(w)
    expect = oracle_state(live)
    ref = engines["unified"]
    np.testing.assert_array_equal(ref.cores(), expect)
    for name, m in engines.items():
        np.testing.assert_array_equal(ref.cores(), m.cores(), err_msg=name)
        np.testing.assert_array_equal(ref.labels(), m.labels(),
                                      err_msg=name)
        wcol = np.asarray(m.w)
        for e, slot in m.edge_slot.items():
            assert int(wcol[slot]) == live[e], (name, e)
    print("weighted-churn-8dev OK")
    """
)


@pytest.mark.slow
def test_weighted_churn_oracle_8dev(tmp_path):
    """8 forced host devices: the weighted engine matrix (unified lax +
    pallas, sharded replicated, range+sparse, 2x4 halo) under random
    integer weights stays pinned to the peeling oracle — cores AND
    labels — through dirty churn and a mid-churn save/load whose
    checkpoint carries the weight column."""
    script = tmp_path / "weighted8.py"
    script.write_text(_WEIGHTED_8DEV)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "weighted-churn-8dev OK" in out.stdout


@pytest.mark.slow
def test_churn_save_load_roundtrip_8dev(tmp_path):
    """8 forced host devices: recycled-slot churn on a genuinely sharded
    table, then a save -> load round trip (sharded AND unified readers)
    that must keep tracking BZ and the original engines bit-exactly."""
    script = tmp_path / "roundtrip8.py"
    script.write_text(_ROUNDTRIP_8DEV)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "churn-roundtrip-8dev OK" in out.stdout
