"""Dry-run machinery on a small 8-device mesh (subprocess): lower+compile
representative cells with their PartitionSpecs — the same code path the
512-device production dry-run uses (launch/dryrun.py)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from jax.sharding import NamedSharding

    from repro.compat import set_mesh
    from repro.launch.steps import build_cell

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    set_mesh(mesh)
    cells = [
        ("qwen2-7b", "train_4k"),
        ("deepseek-v2-lite-16b", "decode_32k"),
        ("pna", "full_graph_sm"),
        ("deepfm", "retrieval_cand"),
    ]
    for arch, shape in cells:
        prog = build_cell(arch, shape, smoke=True, multi_pod=False)
        in_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), prog.in_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
        with mesh:
            compiled = jax.jit(prog.fn, in_shardings=in_sh).lower(
                *prog.abstract_inputs
            ).compile()
        assert compiled.cost_analysis() is not None
        print("OK", arch, shape)
    """
)


@pytest.mark.slow
def test_dryrun_cells_compile_on_8dev(tmp_path):
    script = tmp_path / "dryrun_small.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert out.stdout.count("OK") == 4
