"""Edit-path hardening: out-of-range endpoints must never reach the slot
table (where per-edge stat scatters would clamp them onto vertex n-1 and
``live_edges`` would count a phantom slot), and the engines must refuse
to run with x64 disabled (int64 labels / 1<<62 key sentinels corrupt
silently under x32)."""
import numpy as np
import pytest

import jax

from repro.core.api import CoreMaintainer
from repro.core.oracle import bz_from_csr
from repro.graph.generators import erdos_renyi

ENGINES = ("unified", "host", "sharded")


@pytest.fixture(scope="module")
def small_graph():
    return erdos_renyi(8, 12, seed=0)


@pytest.mark.parametrize("engine", ENGINES)
def test_out_of_range_insert_raises(small_graph, engine):
    m = CoreMaintainer.from_graph(small_graph, capacity=64, engine=engine)
    before = m.cores().copy()
    live0 = m.live_edges
    with pytest.raises(ValueError, match="out of range"):
        m.apply_batch(insert_edges=[[5, 999]])
    # no phantom slot, no state corruption
    assert m.live_edges == live0
    np.testing.assert_array_equal(m.cores(), before)
    np.testing.assert_array_equal(m.cores(), bz_from_csr(small_graph))


@pytest.mark.parametrize("engine", ENGINES)
def test_negative_remove_raises(small_graph, engine):
    m = CoreMaintainer.from_graph(small_graph, capacity=64, engine=engine)
    live0 = m.live_edges
    with pytest.raises(ValueError, match="out of range"):
        m.apply_batch(remove_edges=[[-3, 2]])
    with pytest.raises(ValueError, match="out of range"):
        m.remove_edges([[0, 8]])  # n == 8: first out-of-range vertex id
    assert m.live_edges == live0


@pytest.mark.parametrize("engine", ENGINES)
def test_validate_false_masks_instead(small_graph, engine):
    """validate=False drops the offending rows; valid rows in the same
    batch still apply."""
    m = CoreMaintainer.from_graph(
        small_graph, capacity=64, engine=engine, validate=False
    )
    live0 = m.live_edges
    st = m.apply_batch(insert_edges=[[5, 999]], remove_edges=[[-3, 2]])
    assert int(st.n_inserted) == 0
    assert int(st.n_removed) == 0
    assert m.live_edges == live0
    np.testing.assert_array_equal(m.cores(), bz_from_csr(small_graph))
    # mixed good/bad batch: only the good row lands
    g = small_graph
    absent = None
    for u in range(g.n):
        for v in range(u + 1, g.n):
            if not g.has_edge(u, v):
                absent = (u, v)
                break
        if absent:
            break
    st = m.apply_batch(insert_edges=[[7, 100], list(absent)])
    assert int(st.n_inserted) == 1
    assert m.live_edges == live0 + 1
    assert absent in m.edge_slot


@pytest.mark.parametrize("engine", ENGINES)
def test_rejected_mixed_batch_is_atomic(small_graph, engine):
    """A batch with an invalid insert and a VALID removal must be rejected
    whole: the host path applies removals first, so validation has to run
    for both halves before any state changes."""
    m = CoreMaintainer.from_graph(small_graph, capacity=64, engine=engine)
    before = m.cores().copy()
    live0 = m.live_edges
    rm = small_graph.edge_array()[:1]
    with pytest.raises(ValueError, match="out of range"):
        m.apply_batch(insert_edges=[[0, 999]], remove_edges=rm)
    assert m.live_edges == live0  # the valid removal was NOT committed
    assert (int(rm[0, 0]), int(rm[0, 1])) in m.edge_slot
    np.testing.assert_array_equal(m.cores(), before)


def test_host_insert_path_validates(small_graph):
    m = CoreMaintainer.from_graph(small_graph, capacity=64, engine="host")
    with pytest.raises(ValueError, match="out of range"):
        m.insert_edges([[5, 999]])
    assert (5, 999) not in m.edge_slot


def test_x64_guard_fires_loudly(small_graph):
    """Disabling x64 after import must raise with a clear message, not
    silently corrupt the int64 label space."""
    m = CoreMaintainer.from_graph(small_graph, capacity=64)
    try:
        jax.config.update("jax_enable_x64", False)
        with pytest.raises(RuntimeError, match="x64"):
            m.apply_batch(insert_edges=[[0, 1]])
        with pytest.raises(RuntimeError, match="x64"):
            CoreMaintainer.from_graph(small_graph, capacity=64)
        mh = m
        mh.engine = "host"
        with pytest.raises(RuntimeError, match="x64"):
            mh.insert_edges([[0, 1]])
        with pytest.raises(RuntimeError, match="x64"):
            mh.remove_edges(small_graph.edge_array()[:1])
    finally:
        jax.config.update("jax_enable_x64", True)
