"""JAX parallel core maintenance vs the sequential oracle.

The defining property: after any sequence of batched insertions/removals,
the JAX maintainer's core numbers equal BZ-from-scratch (and hence the
Simplified-Order oracle's)."""
import numpy as np
import pytest

from repro.core.api import CoreMaintainer
from repro.core.decomposition import (
    h_index_decomposition,
    peel_decomposition,
)
from repro.core.oracle import bz_from_csr
from repro.graph.csr import add_edges_csr, build_csr, remove_edges_csr
from repro.graph.generators import barabasi_albert, erdos_renyi, rmat

import jax.numpy as jnp


def _bz(n, edges):
    return bz_from_csr(build_csr(n, edges))


# ---------------------------------------------------------------------------
# decomposition
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(4))
def test_peel_decomposition_matches_bz(seed):
    g = erdos_renyi(120, 460, seed=seed)
    m = CoreMaintainer.from_graph(g, init="jax-peel")
    np.testing.assert_array_equal(m.cores(), bz_from_csr(g))


@pytest.mark.parametrize("seed", range(4))
def test_h_index_matches_bz(seed):
    g = rmat(7, 400, seed=seed)
    m = CoreMaintainer.from_graph(g)
    core = h_index_decomposition(m.src, m.dst, m.valid, m.n)
    np.testing.assert_array_equal(np.asarray(core), bz_from_csr(g))


def test_peel_rank_is_valid_korder():
    """Certificate: dout(v) = |{w in N(v): (core,rank) greater}| <= core(v)."""
    g = erdos_renyi(100, 420, seed=1)
    m = CoreMaintainer.from_graph(g, init="jax-peel")
    core, label = m.cores(), m.labels()
    for v in range(g.n):
        succ = sum(
            1
            for w in g.neighbors(v)
            if (core[w], label[w]) > (core[v], label[v])
        )
        assert succ <= core[v], (v, succ, core[v])


# ---------------------------------------------------------------------------
# insertion
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(6))
def test_insert_batches_match_bz(seed):
    rng = np.random.default_rng(seed)
    n = 90
    g = erdos_renyi(n, 200, seed=seed)
    m = CoreMaintainer.from_graph(g, capacity=4096)
    cur = g
    for bi in range(4):
        batch = []
        while len(batch) < 8:
            u, v = rng.integers(0, n, size=2)
            if u == v:
                continue
            key = (int(min(u, v)), int(max(u, v)))
            if cur.has_edge(*key) or key in batch:
                continue
            batch.append(key)
        arr = np.asarray(batch, dtype=np.int64)
        m.insert_edges(arr)
        cur = add_edges_csr(cur, arr)
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))


def test_insert_dense_hotspot():
    """Many edges incident to the same vertices in one batch — core numbers
    can rise by more than one; exercises multi-round promotion."""
    n = 30
    base = [(i, (i + 1) % n) for i in range(n)]  # ring, core 1
    g = build_csr(n, np.asarray(base))
    m = CoreMaintainer.from_graph(g, capacity=4096)
    # densify vertices 0..7 into a clique
    batch = [
        (i, j) for i in range(8) for j in range(i + 1, 8) if not g.has_edge(i, j)
    ]
    m.insert_edges(np.asarray(batch))
    cur = add_edges_csr(g, np.asarray(batch))
    np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
    assert int(m.last_insert_stats.rounds) >= 2  # multi-round cascade


def test_insert_uniform_core_graph():
    """BA graphs: all vertices share one core number — the case where prior
    parallel methods serialize but ours keeps full parallelism (paper §1)."""
    g = barabasi_albert(120, deg=6, seed=0)
    m = CoreMaintainer.from_graph(g, capacity=8192)
    rng = np.random.default_rng(3)
    batch = []
    while len(batch) < 16:
        u, v = rng.integers(0, g.n, size=2)
        key = (int(min(u, v)), int(max(u, v)))
        if u == v or g.has_edge(*key) or key in batch:
            continue
        batch.append(key)
    arr = np.asarray(batch)
    m.insert_edges(arr)
    cur = add_edges_csr(g, arr)
    np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))


# ---------------------------------------------------------------------------
# removal
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(6))
def test_remove_batches_match_bz(seed):
    rng = np.random.default_rng(seed + 50)
    n = 90
    g = erdos_renyi(n, 340, seed=seed)
    m = CoreMaintainer.from_graph(g)
    cur = g
    for bi in range(4):
        edges = cur.edge_array()
        take = rng.choice(edges.shape[0], size=10, replace=False)
        batch = edges[take]
        m.remove_edges(batch)
        cur = remove_edges_csr(cur, batch)
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))


def test_remove_whole_clique_cascade():
    """Removing a clique edge triggers a multi-level cascade."""
    n = 12
    clique = [(i, j) for i in range(8) for j in range(i + 1, 8)]
    tail = [(7 + i, 8 + i) for i in range(n - 8)]
    g = build_csr(n, np.asarray(clique + tail))
    m = CoreMaintainer.from_graph(g)
    batch = np.asarray([(0, 1), (0, 2), (1, 2)])
    m.remove_edges(batch)
    cur = remove_edges_csr(g, batch)
    np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))


# ---------------------------------------------------------------------------
# mixed workloads + order certificate
# ---------------------------------------------------------------------------
def _order_certificate(m: CoreMaintainer):
    """dout(v) <= core(v) for all v (valid k-order witness)."""
    core, label = m.cores(), m.labels()
    src = np.asarray(m.src)
    dst = np.asarray(m.dst)
    val = np.asarray(m.valid)
    dout = np.zeros(m.n, dtype=np.int64)
    for s, d, ok in zip(src, dst, val):
        if not ok:
            continue
        if (core[d], label[d]) > (core[s], label[s]):
            dout[s] += 1
        else:
            dout[d] += 1
    bad = np.nonzero(dout > core)[0]
    return bad


@pytest.mark.parametrize("seed", range(4))
def test_mixed_workload_and_certificate(seed):
    rng = np.random.default_rng(seed + 9)
    n = 70
    g = erdos_renyi(n, 260, seed=seed)
    m = CoreMaintainer.from_graph(g, capacity=8192)
    cur = g
    for step in range(8):
        if rng.random() < 0.5:
            batch = []
            while len(batch) < 6:
                u, v = rng.integers(0, n, size=2)
                key = (int(min(u, v)), int(max(u, v)))
                if u == v or cur.has_edge(*key) or key in batch:
                    continue
                batch.append(key)
            arr = np.asarray(batch)
            m.insert_edges(arr)
            cur = add_edges_csr(cur, arr)
        else:
            edges = cur.edge_array()
            take = rng.choice(
                edges.shape[0], size=min(6, edges.shape[0]), replace=False
            )
            batch = edges[take]
            m.remove_edges(batch)
            cur = remove_edges_csr(cur, batch)
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
        bad = _order_certificate(m)
        assert bad.size == 0, f"k-order certificate violated at {bad}"


def test_save_load_roundtrip(tmp_path):
    g = erdos_renyi(50, 150, seed=0)
    m = CoreMaintainer.from_graph(g)
    p = str(tmp_path / "state.npz")
    m.save(p)
    m2 = CoreMaintainer.load(p)
    np.testing.assert_array_equal(m.cores(), m2.cores())
    m.insert_edges(np.asarray([[0, 49]]))
    m2.insert_edges(np.asarray([[0, 49]]))
    np.testing.assert_array_equal(m.cores(), m2.cores())
