"""Pallas kernels vs pure-jnp oracles, interpret=True, shape/dtype sweeps."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.segment_ell import ell_aggregate, ell_stat
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fm_interaction import fm_interaction
from repro.graph.csr import ell_from_csr
from repro.graph.generators import erdos_renyi


def _random_ell(n, max_deg, seed):
    rng = np.random.default_rng(seed)
    deg = rng.integers(0, max_deg + 1, size=n)
    nbrs = np.full((n, max_deg), n, dtype=np.int32)
    for v in range(n):
        nbrs[v, : deg[v]] = rng.integers(0, n, size=deg[v])
    return jnp.asarray(nbrs)


@pytest.mark.parametrize("n,max_deg", [(64, 8), (300, 17), (1024, 33), (7, 3)])
@pytest.mark.parametrize("op", ["count_ge", "count_gt", "sum", "max"])
def test_ell_stat_sweep(n, max_deg, op):
    nbrs = _random_ell(n, max_deg, seed=n + max_deg)
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(0, 50, size=n), dtype=jnp.int32)
    got = ell_stat(nbrs, vals, vals, op=op, interpret=True)
    want = ref.ell_stat_ref(nbrs, vals, vals, op=op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("op", ["sum", "max"])
def test_ell_aggregate_sweep(dtype, op):
    n, max_deg, f = 200, 12, 16
    nbrs = _random_ell(n, max_deg, seed=5)
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(n, f)), dtype=dtype)
    got = ell_aggregate(nbrs, feats, op=op, interpret=True)
    want = ref.ell_aggregate_ref(nbrs, feats, op=op)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


def test_ell_stat_mcd_matches_real_graph():
    """mcd via the kernel == mcd by definition on a real graph."""
    g = erdos_renyi(150, 600, seed=3)
    ell = ell_from_csr(g)
    rng = np.random.default_rng(2)
    core = rng.integers(0, 10, size=g.n).astype(np.int32)
    got = ell_stat(
        jnp.asarray(ell.nbrs), jnp.asarray(core), jnp.asarray(core),
        op="count_ge", interpret=True,
    )
    want = np.array(
        [
            sum(1 for w in g.neighbors(v) if core[w] >= core[v])
            for v in range(g.n)
        ],
        dtype=np.int32,
    )
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize(
    "b,h,hkv,s,d", [(2, 4, 4, 256, 64), (1, 8, 2, 512, 64), (2, 4, 1, 128, 128)]
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, s, d, causal):
    rng = np.random.default_rng(b * 100 + h)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype=jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=dtype)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 2e-3,
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-3,
    )


@pytest.mark.parametrize("b,f,d", [(64, 39, 10), (1000, 26, 16), (3, 5, 4)])
def test_fm_interaction_sweep(b, f, d):
    rng = np.random.default_rng(b)
    emb = jnp.asarray(rng.normal(size=(b, f, d)), dtype=jnp.float32)
    got = fm_interaction(emb, block_b=256, interpret=True)
    want = ref.fm_interaction_ref(emb)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )
