"""Pallas kernels vs pure-jnp oracles, interpret=True, shape/dtype sweeps."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import graph_ops as G
from repro.kernels import coremaint, ref
from repro.kernels.segment_ell import ell_aggregate, ell_stat
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fm_interaction import fm_interaction
from repro.graph.csr import ell_from_csr
from repro.graph.generators import erdos_renyi


def _random_ell(n, max_deg, seed):
    rng = np.random.default_rng(seed)
    deg = rng.integers(0, max_deg + 1, size=n)
    nbrs = np.full((n, max_deg), n, dtype=np.int32)
    for v in range(n):
        nbrs[v, : deg[v]] = rng.integers(0, n, size=deg[v])
    return jnp.asarray(nbrs)


@pytest.mark.parametrize("n,max_deg", [(64, 8), (300, 17), (1024, 33), (7, 3)])
@pytest.mark.parametrize("op", ["count_ge", "count_gt", "sum", "max"])
def test_ell_stat_sweep(n, max_deg, op):
    nbrs = _random_ell(n, max_deg, seed=n + max_deg)
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(0, 50, size=n), dtype=jnp.int32)
    got = ell_stat(nbrs, vals, vals, op=op, interpret=True)
    want = ref.ell_stat_ref(nbrs, vals, vals, op=op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("op", ["sum", "max"])
def test_ell_aggregate_sweep(dtype, op):
    n, max_deg, f = 200, 12, 16
    nbrs = _random_ell(n, max_deg, seed=5)
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(n, f)), dtype=dtype)
    got = ell_aggregate(nbrs, feats, op=op, interpret=True)
    want = ref.ell_aggregate_ref(nbrs, feats, op=op)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


def test_ell_stat_mcd_matches_real_graph():
    """mcd via the kernel == mcd by definition on a real graph."""
    g = erdos_renyi(150, 600, seed=3)
    ell = ell_from_csr(g)
    rng = np.random.default_rng(2)
    core = rng.integers(0, 10, size=g.n).astype(np.int32)
    got = ell_stat(
        jnp.asarray(ell.nbrs), jnp.asarray(core), jnp.asarray(core),
        op="count_ge", interpret=True,
    )
    want = np.array(
        [
            sum(1 for w in g.neighbors(v) if core[w] >= core[v])
            for v in range(g.n)
        ],
        dtype=np.int32,
    )
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize(
    "b,h,hkv,s,d", [(2, 4, 4, 256, 64), (1, 8, 2, 512, 64), (2, 4, 1, 128, 128)]
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, s, d, causal):
    rng = np.random.default_rng(b * 100 + h)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype=jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=dtype)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 2e-3,
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-3,
    )


@pytest.mark.parametrize("b,f,d", [(64, 39, 10), (1000, 26, 16), (3, 5, 4)])
def test_fm_interaction_sweep(b, f, d):
    rng = np.random.default_rng(b)
    emb = jnp.asarray(rng.normal(size=(b, f, d)), dtype=jnp.float32)
    got = fm_interaction(emb, block_b=256, interpret=True)
    want = ref.fm_interaction_ref(emb)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# -- segment_ell regressions ------------------------------------------------

@pytest.mark.parametrize("n,max_deg", [(0, 8), (64, 0), (0, 0)])
@pytest.mark.parametrize("op", ["count_ge", "sum", "max"])
def test_ell_stat_zero_grid(n, max_deg, op):
    """Regression: n == 0 or max_deg == 0 used to launch a zero-sized
    grid, returning an UNINITIALIZED output buffer. Both entry points
    must short-circuit to explicit zeros."""
    nbrs = jnp.full((n, max_deg), n, dtype=jnp.int32)
    vals = jnp.zeros((n,), dtype=jnp.int32)
    got = ell_stat(nbrs, vals, vals, op=op, interpret=True)
    assert got.shape == (n,)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(n, np.int32))


@pytest.mark.parametrize("n,max_deg", [(0, 8), (64, 0), (0, 0)])
@pytest.mark.parametrize("op", ["sum", "max"])
def test_ell_aggregate_zero_grid(n, max_deg, op):
    nbrs = jnp.full((n, max_deg), n, dtype=jnp.int32)
    feats = jnp.zeros((n, 16), dtype=jnp.float32)
    got = ell_aggregate(nbrs, feats, op=op, interpret=True)
    assert got.shape == (n, 16)
    np.testing.assert_array_equal(
        np.asarray(got), np.zeros((n, 16), np.float32)
    )


def test_ell_stat_max_isolated_vertex_is_zero():
    """Regression: op="max" rows with NO live neighbor slots used to leak
    the running-max sentinel (INT32_MIN) instead of the documented
    identity 0. Negative values make any leak (sentinel OR a stale
    accumulator) visible."""
    n, max_deg = 96, 8
    nbrs = np.full((n, max_deg), n, dtype=np.int32)  # all padding
    nbrs[0, :3] = [1, 2, 3]  # one connected row as a control
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(-50, -1, size=n), dtype=jnp.int32)
    got = np.asarray(ell_stat(jnp.asarray(nbrs), vals, vals, op="max",
                              interpret=True))
    want = np.asarray(ref.ell_stat_ref(jnp.asarray(nbrs), vals, vals,
                                       op="max"))
    np.testing.assert_array_equal(got, want)
    assert got[0] == max(int(vals[i]) for i in (1, 2, 3))
    np.testing.assert_array_equal(got[1:], np.zeros(n - 1, np.int32))


def test_ell_aggregate_max_isolated_vertex_is_zero():
    n, max_deg, f = 80, 6, 8
    nbrs = np.full((n, max_deg), n, dtype=np.int32)
    nbrs[0, :2] = [1, 2]
    rng = np.random.default_rng(1)
    feats = jnp.asarray(-1.0 - rng.random((n, f)), dtype=jnp.float32)
    got = np.asarray(ell_aggregate(jnp.asarray(nbrs), feats, op="max",
                                   interpret=True))
    want = np.asarray(ref.ell_aggregate_ref(jnp.asarray(nbrs), feats,
                                            op="max"))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got[1:], np.zeros((n - 1, f), np.float32))
    np.testing.assert_array_equal(
        got[0], np.maximum(np.asarray(feats)[1], np.asarray(feats)[2])
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("op", ["count_ge", "count_gt"])
def test_ell_stat_matches_graph_ops_on_random_graphs(op, seed):
    """Differential: the ELL kernel's count stats == the COO
    segment-sum path (core/graph_ops.py) on random graphs — the two
    traversal layouts must agree on every vertex."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 180))
    m = int(rng.integers(n, 4 * n))
    g = erdos_renyi(n, m, seed=seed + 10)
    ell = ell_from_csr(g)
    edges = g.edge_array()
    src = jnp.asarray(edges[:, 0].astype(np.int32))
    dst = jnp.asarray(edges[:, 1].astype(np.int32))
    valid = jnp.ones((edges.shape[0],), dtype=bool)
    vals = jnp.asarray(rng.integers(0, 12, size=n), dtype=jnp.int32)
    got = ell_stat(jnp.asarray(ell.nbrs), vals, vals, op=op, interpret=True)
    fn = G.count_ge if op == "count_ge" else G.count_gt
    want = fn(src, dst, valid, vals, g.n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- fused COO maintenance kernels (kernels/coremaint.py) -------------------

def _random_slot_table(seed, n=150, cap=512):
    """A random COO slot table shaped like the engines': dead slots,
    self-edge-free random endpoints, maintenance-like core/label state."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=cap).astype(np.int32)
    dst = rng.integers(0, n, size=cap).astype(np.int32)
    dst = np.where(dst == src, (dst + 1) % n, dst).astype(np.int32)
    valid = rng.random(cap) < 0.7
    core = rng.integers(0, 6, size=n).astype(np.int32)
    label = rng.integers(0, 1 << 40, size=n).astype(np.int64)
    aux = rng.random(n) < 0.4
    return (jnp.asarray(src), jnp.asarray(dst), jnp.asarray(valid),
            jnp.asarray(core), jnp.asarray(label), jnp.asarray(aux))


@pytest.mark.parametrize("seed", [0, 3, 7])
def test_coo_stat_matches_graph_ops(seed):
    """Every packed stat of the fused kernel is BIT-identical to the lax
    segment-sum implementations it replaces (integer adds only — order
    cannot matter)."""
    n = 150
    src, dst, valid, core, label, aux = _random_slot_table(seed, n=n)
    k = lambda stat, a=None: np.asarray(coremaint.coo_stat(
        src, dst, valid, core, label, n, stat=stat, aux=a, interpret=True))

    mcd, hi, dout = G.mcd_hi_dout(src, dst, valid, core, label, n)
    np.testing.assert_array_equal(
        k("mcd_hi_dout"),
        np.stack([np.asarray(mcd), np.asarray(hi), np.asarray(dout)], -1),
    )
    np.testing.assert_array_equal(
        k("hi_dout"), np.stack([np.asarray(hi), np.asarray(dout)], -1)
    )
    np.testing.assert_array_equal(
        k("mcd")[:, 0], np.asarray(G.count_ge(src, dst, valid, core, n))
    )
    np.testing.assert_array_equal(
        k("same_in", aux)[:, 0],
        np.asarray(G.count_same_level_in(src, dst, valid, core, aux, n)),
    )
    din, expand = G.din_and_expand(src, dst, valid, core, label, aux, n)
    np.testing.assert_array_equal(k("din", aux)[:, 0], np.asarray(din))
    np.testing.assert_array_equal(k("din", aux)[:, 0] > 0,
                                  np.asarray(expand))


@pytest.mark.parametrize("seed", [0, 5])
def test_fused_removal_round_matches_unfused(seed):
    """The single-launch removal round == stats pass + host-side
    threshold + commit, including the decision outputs."""
    n = 150
    src, dst, valid, core, label, _ = _random_slot_table(seed, n=n)
    mcd, hi, dout, new_core, drop = coremaint.fused_removal_round(
        src, dst, valid, core, label, n, interpret=True
    )
    wm, wh, wd = G.mcd_hi_dout(src, dst, valid, core, label, n)
    wdrop = (wm < core) & (core > 0)
    np.testing.assert_array_equal(np.asarray(mcd), np.asarray(wm))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(wh))
    np.testing.assert_array_equal(np.asarray(dout), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(drop), np.asarray(wdrop))
    np.testing.assert_array_equal(
        np.asarray(new_core),
        np.asarray(core - wdrop.astype(jnp.int32)),
    )
    assert np.asarray(drop).any(), "degenerate case: no drops exercised"


@pytest.mark.parametrize("seed", [0, 5])
def test_fused_promotion_stats_matches_unfused(seed):
    n = 150
    src, dst, valid, core, label, _ = _random_slot_table(seed, n=n)
    hi, dout, viol = coremaint.fused_promotion_stats(
        src, dst, valid, core, label, n, interpret=True
    )
    wh, wd = G.hi_and_dout_same(src, dst, valid, core, label, n)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(wh))
    np.testing.assert_array_equal(np.asarray(dout), np.asarray(wd))
    np.testing.assert_array_equal(
        np.asarray(viol), np.asarray((wh + wd) > core)
    )
    assert np.asarray(viol).any(), "degenerate case: no violators exercised"


def test_coo_stat_empty_table_short_circuits():
    """cap == 0 and n == 0 must return explicit zeros (the same class of
    zero-grid bug fixed in segment_ell)."""
    core = jnp.zeros((9,), jnp.int32)
    label = jnp.zeros((9,), jnp.int64)
    e = jnp.zeros((0,), jnp.int32)
    out = coremaint.coo_stat(e, e, jnp.zeros((0,), bool), core, label, 9,
                             stat="mcd_hi_dout", interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((9, 3), np.int32))
    out = coremaint.coo_stat(e, e, jnp.zeros((0,), bool),
                             jnp.zeros((0,), jnp.int32),
                             jnp.zeros((0,), jnp.int64), 0,
                             stat="hi_dout", interpret=True)
    assert out.shape == (0, 2)
    mcd, hi, dout, new_core, drop = coremaint.fused_removal_round(
        e, e, jnp.zeros((0,), bool), core, label, 9, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(new_core), np.zeros(9, np.int32))
    assert not np.asarray(drop).any()


def test_coo_stat_rejects_non_int64_labels():
    """x32 labels would silently truncate the k-order comparisons —
    refuse loudly (the same guard the engines enforce via _require_x64)."""
    n = 8
    e = jnp.zeros((4,), jnp.int32)
    with pytest.raises(TypeError, match="int64"):
        coremaint.coo_stat(e, e + 1, jnp.ones((4,), bool),
                           jnp.zeros((n,), jnp.int32),
                           jnp.zeros((n,), jnp.int32), n,
                           stat="hi_dout", interpret=True)


def test_coo_stat_non_divisible_blocks():
    """n and cap straddling block boundaries: padding slots/vertices must
    contribute nothing and the unpadded prefix must round-trip."""
    n = 77  # not a multiple of any pow2 block
    src, dst, valid, core, label, _ = _random_slot_table(11, n=n, cap=300)
    out = coremaint.coo_stat(src, dst, valid, core, label, n,
                             stat="mcd_hi_dout", block_n=64, block_e=128,
                             interpret=True)
    mcd, hi, dout = G.mcd_hi_dout(src, dst, valid, core, label, n)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.stack([np.asarray(mcd), np.asarray(hi), np.asarray(dout)], -1),
    )
