"""Edge-case coverage for maintenance machinery: OM label renumbering,
capacity compaction and growth, decomposition init parity."""
import numpy as np

import jax.numpy as jnp

from repro.core.api import CoreMaintainer
from repro.core.oracle import bz_from_csr
from repro.core.order import LABEL_GAP, needs_renumber, renumber
from repro.graph.csr import add_edges_csr, build_csr, remove_edges_csr
from repro.graph.generators import erdos_renyi


def test_global_renumber_preserves_order():
    g = erdos_renyi(120, 500, seed=0)
    m = CoreMaintainer.from_graph(g)
    core, label = m.core, m.label
    # order pairs before
    order = np.lexsort((np.asarray(label), np.asarray(core)))
    new_label = renumber(core, label)
    order2 = np.lexsort((np.asarray(new_label), np.asarray(core)))
    np.testing.assert_array_equal(order, order2)
    # labels respaced to the standard gap
    diffs = np.diff(np.sort(np.asarray(new_label)))
    assert (diffs == int(LABEL_GAP)).all()


def test_forced_renumber_keeps_maintenance_exact():
    g = erdos_renyi(80, 300, seed=1)
    m = CoreMaintainer.from_graph(g, capacity=4096)
    # push labels to the renumber threshold artificially
    m.label = m.label - (jnp.int64(1) << 61) - 1
    assert bool(needs_renumber(m.label))
    m._maybe_renumber()
    assert not bool(needs_renumber(m.label))
    # maintenance still exact afterwards
    rng = np.random.default_rng(0)
    batch = []
    while len(batch) < 12:
        u, v = rng.integers(0, g.n, size=2)
        key = (int(min(u, v)), int(max(u, v)))
        if u != v and not g.has_edge(*key) and key not in batch:
            batch.append(key)
    m.insert_edges(np.asarray(batch))
    expect = bz_from_csr(add_edges_csr(g, np.asarray(batch)))
    np.testing.assert_array_equal(m.cores(), expect)


def test_capacity_compaction_and_growth():
    g = erdos_renyi(50, 120, seed=2)
    m = CoreMaintainer.from_graph(g, capacity=int(g.m * 1.4) + 8)
    cur = g
    rng = np.random.default_rng(3)
    # churn: repeatedly remove and insert to exhaust slots -> forces
    # _compact (tombstone reuse) and possibly _grow
    for round_ in range(10):
        edges = cur.edge_array()
        take = rng.choice(edges.shape[0], size=10, replace=False)
        rm = edges[take]
        m.remove_edges(rm)
        cur = remove_edges_csr(cur, rm)
        ins = []
        while len(ins) < 10:
            u, v = rng.integers(0, cur.n, size=2)
            key = (int(min(u, v)), int(max(u, v)))
            if u != v and not cur.has_edge(*key) and key not in ins:
                ins.append(key)
        m.insert_edges(np.asarray(ins))
        cur = add_edges_csr(cur, np.asarray(ins))
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
    assert m.live_edges == cur.m


def test_jax_peel_init_equals_host_bz_init_behaviour():
    g = erdos_renyi(90, 360, seed=4)
    m1 = CoreMaintainer.from_graph(g, init="host-bz", capacity=2048)
    m2 = CoreMaintainer.from_graph(g, init="jax-peel", capacity=2048)
    np.testing.assert_array_equal(m1.cores(), m2.cores())
    # same batch gives same cores through either init's k-order
    rng = np.random.default_rng(5)
    batch = []
    while len(batch) < 10:
        u, v = rng.integers(0, g.n, size=2)
        key = (int(min(u, v)), int(max(u, v)))
        if u != v and not g.has_edge(*key) and key not in batch:
            batch.append(key)
    m1.insert_edges(np.asarray(batch))
    m2.insert_edges(np.asarray(batch))
    np.testing.assert_array_equal(m1.cores(), m2.cores())


def test_empty_and_duplicate_batches_are_noops():
    g = erdos_renyi(40, 100, seed=6)
    m = CoreMaintainer.from_graph(g)
    before = m.cores().copy()
    m.insert_edges(np.zeros((0, 2), dtype=np.int64))
    # inserting existing edges / self loops is filtered
    e = g.edge_array()[:5]
    m.insert_edges(e)
    m.insert_edges(np.asarray([[3, 3]]))
    m.remove_edges(np.asarray([[0, 39]]) if not g.has_edge(0, 39)
                   else np.zeros((0, 2), np.int64))
    np.testing.assert_array_equal(m.cores(), before)
