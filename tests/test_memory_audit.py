"""Memory auditor (repro.analysis.memory): seeded violations + runtime
cross-checks.

Each rule the memory auditor adds is proven to FIRE on a hand-seeded
violation (naming the offending primitive/path), and the d=1 symbolic
formulas are validated against real buffer sizes and the compiled
program's ``memory_analysis()`` — the liveness model is static, so this
is the one place its numbers meet actual allocations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import (
    AuditParams,
    EngineConfig,
    TracedEngine,
    generate_memory_section,
    load_budget,
    profile_program,
    replicated_vertex_sites,
    trace_engine,
)
from repro.analysis.memory import STATE_ARGS, body_arg_map
from repro.analysis.rules import eval_formula, run_rules
from repro.compat import shard_map


def _mini_traced(config=None, programs=None, donated=None, sizes=None):
    cfg = config or EngineConfig("seeded", "unified")
    return TracedEngine(
        config=cfg, params=AuditParams(n=8, capacity=32, lanes=4),
        n_devices=1, window=16, frontier_cap=0,
        programs=programs or {}, lowered={}, donated=donated or {},
        rounds={},
        sizes=sizes or dict(n=8, d=1, cap=0, n_owned=8, n_pad=8,
                            lanes=4, window=16, local_cap=32),
    )


def _memory_findings(traced, section):
    return run_rules(traced, {"memory": section},
                     names=["memory_budget"])["memory_budget"]


# -- the liveness pass itself ----------------------------------------------

def test_profile_donation_frees_inputs_early():
    """A donated input dies at its last use; a retained one is pinned to
    the end — the difference is exactly the input's bytes."""
    x = jnp.zeros(1024, jnp.float32)
    jx = jax.make_jaxpr(lambda x: (x + 1.0) * 2.0)(x)
    pinned = profile_program(jx, donated=())
    freed = profile_program(jx, donated=(0,))
    assert pinned.point_bytes[-1] - freed.point_bytes[-1] == x.nbytes
    assert freed.peak < pinned.peak or freed.peak == pinned.peak


def test_profile_while_round_points_tagged():
    """Points inside a lax.while_loop body are the per-round working
    set; round_peak must come from them and only them."""
    def f(x):
        return jax.lax.while_loop(
            lambda c: c[1] < 4,
            lambda c: (c[0] * 2, c[1] + 1),
            (x, jnp.int32(0)),
        )

    jx = jax.make_jaxpr(f)(jnp.zeros(256, jnp.float32))
    prof = profile_program(jx)
    assert any(prof.in_round)
    assert not all(prof.in_round)
    assert prof.round_peak <= prof.peak
    assert prof.round_peak == max(
        b for b, r in zip(prof.point_bytes, prof.in_round) if r
    )


def test_while_carry_not_double_counted():
    """The while body's returned carry aliases the loop's output — a
    body that only rescales a big carry must not cost two copies of it
    at the loop boundary."""
    big = 1 << 20

    def f(x):
        return jax.lax.while_loop(
            lambda c: c[1] < 4, lambda c: (c[0] * 2, c[1] + 1),
            (x, jnp.int32(0)),
        )

    jx = jax.make_jaxpr(f)(jnp.zeros(big, jnp.float32))
    prof = profile_program(jx, donated=(0,))
    # x + one temp inside the body = 2 copies; 3 would mean the carry
    # out-alias was dropped
    assert prof.peak < 3 * big * 4


# -- seeded violations: each rule must fire, naming the offender ------------

def test_seeded_undonated_vertex_sized_output_fires():
    """require_state_donated: a vertex-sized output that aliases no
    donated input is a hidden per-batch copy — the rule names it."""
    jx = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros(8, jnp.int32))
    traced = _mini_traced(programs={"apply_batch": jx},
                          donated={"apply_batch": ()})
    section = generate_memory_section(traced)
    assert section["require_state_donated"] is True
    finds = _memory_findings(traced, section)
    [f] = [f for f in finds if "aliases no donated input" in f.message]
    assert f.program == "apply_batch"
    assert "int32[8]" in f.message
    # donating the input clears it
    traced_ok = _mini_traced(programs={"apply_batch": jx},
                             donated={"apply_batch": (0,)})
    section_ok = generate_memory_section(traced_ok)
    assert not [f for f in _memory_findings(traced_ok, section_ok)
                if "aliases no donated" in f.message]


def test_seeded_replicated_vertex_buffer_fires():
    """forbid_replicated_vertex_buffers: a 1-D all_gather that
    materializes >= n elements inside the shard_map body is refused at
    generation time (the halo refactor deleted the waiver mechanism —
    there is nothing left to excuse it) and flagged by the check rule
    when such a program is audited against a clean committed section."""
    mesh = jax.make_mesh((1,), ("data",))
    sm = shard_map(lambda x: jax.lax.all_gather(x, "data", tiled=True),
                   mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                   check_vma=False)
    jx = jax.make_jaxpr(sm)(jnp.zeros(8, jnp.int32))
    cfg = EngineConfig("seeded_range", "sharded", vertex_sharding="range")
    traced = _mini_traced(config=cfg, programs={"apply_batch": jx},
                          donated={"apply_batch": (0,)})
    assert [elems for _, elems in
            replicated_vertex_sites(jx, 8)] == [8]
    with pytest.raises(RuntimeError, match="replicated"):
        generate_memory_section(traced)
    # the check rule fires too: audit the offending trace against the
    # section a CLEAN program commits (same shapes, no gather)
    clean_sm = shard_map(lambda x: x + 1, mesh=mesh,
                         in_specs=(P("data"),), out_specs=P("data"),
                         check_vma=False)
    clean = jax.make_jaxpr(clean_sm)(jnp.zeros(8, jnp.int32))
    clean_traced = _mini_traced(config=cfg,
                                programs={"apply_batch": clean},
                                donated={"apply_batch": (0,)})
    section = generate_memory_section(clean_traced)
    assert section["forbid_replicated_vertex_buffers"] is True
    assert section["waivers"] == []
    finds = _memory_findings(traced, section)
    [f] = [f for f in finds if "O(n)-replicated" in f.message]
    assert "all_gather" in f.message and "no committed waiver" in f.message


def test_seeded_stale_waiver_fires():
    """A waiver whose site no longer traces is stale — silently keeping
    it would let a future regression hide behind a dead exemption."""
    jx = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros(8, jnp.int32))
    cfg = EngineConfig("seeded_range", "sharded", vertex_sharding="range")
    traced = _mini_traced(config=cfg, programs={"apply_batch": jx},
                          donated={"apply_batch": (0,)})
    section = generate_memory_section(traced)
    section["waivers"] = [{"program": "apply_batch", "op": "all_gather",
                           "in_round": False, "count": 2,
                           "reason": "gone"}]
    finds = _memory_findings(traced, section)
    assert any("stale waiver" in f.message for f in finds)


def test_seeded_wrong_peak_formula_fires():
    jx = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros(8, jnp.int32))
    traced = _mini_traced(programs={"apply_batch": jx},
                          donated={"apply_batch": (0,)})
    section = generate_memory_section(traced)
    section["programs"]["apply_batch"]["peak"] = "1"
    finds = _memory_findings(traced, section)
    assert any("peak live bytes drifted" in f.message for f in finds)


def test_missing_memory_section_fires_with_regenerate_hint():
    traced = _mini_traced(programs={}, donated={})
    finds = run_rules(traced, {}, names=["memory_budget"])["memory_budget"]
    [f] = finds
    assert "no memory section" in f.message
    assert "--write-budgets" in f.message


# -- the committed manifests ------------------------------------------------

@pytest.mark.parametrize(
    "engine", ["vertex_range", "frontier_sparse", "vertex_halo"])
def test_committed_range_engines_pass_unwaived(engine):
    """The halo refactor deleted the entry core/label gather — every
    range/halo manifest now enforces the replicated-buffer rule with an
    EMPTY waiver list (a reappearing gather fails generation outright,
    so no silent re-waiving is possible)."""
    mem = load_budget(engine)["memory"]
    assert mem["forbid_replicated_vertex_buffers"] is True
    assert mem["waivers"] == []


def test_committed_replicated_engines_have_no_waivers():
    for engine in ("host", "unified", "sharded"):
        mem = load_budget(engine)["memory"]
        assert mem["forbid_replicated_vertex_buffers"] is False
        assert mem["waivers"] == []


# -- d=1 formulas vs actual buffers -----------------------------------------

def test_at_rest_formulas_match_real_buffer_sizes_exactly():
    """Every at_rest formula in the committed unified manifest equals —
    to the byte — the nbytes of the concrete state array the engine
    actually carries at that argument position."""
    traced = trace_engine("unified")
    mem = load_budget("unified")["memory"]["programs"]["apply_batch"]
    env = traced.sizes
    state = {
        "src": jnp.zeros(traced.params.capacity, jnp.int32),
        "dst": jnp.zeros(traced.params.capacity, jnp.int32),
        "valid": jnp.zeros(traced.params.capacity, bool),
        "core": jnp.zeros(traced.params.n, jnp.int32),
        "label": jnp.zeros(traced.params.n, jnp.int64),
        "n_edges": jnp.int32(0),
    }
    at_rest = dict(mem["at_rest"])
    assert set(at_rest) == set(state)
    for name, arr in state.items():
        assert eval_formula(at_rest[name], env) == arr.nbytes, name


def test_donated_formula_matches_compiled_alias_bytes_exactly():
    """XLA's own donation accounting agrees with the symbolic credit:
    the compiled unified batch program aliases exactly the bytes the
    manifest's ``donated`` formula predicts."""
    traced = trace_engine("unified")
    mem = load_budget("unified")["memory"]["programs"]["apply_batch"]
    ma = traced.lowered["apply_batch"].compile().memory_analysis()
    assert (eval_formula(mem["donated"], traced.sizes)
            == ma.alias_size_in_bytes)


def test_peak_formula_bounds_compiled_memory_analysis():
    """The symbolic peak is an UN-FUSED upper bound: it must cover the
    compiled program's actual residency (args + outputs + temps -
    aliased), and stay within 8x of it — XLA's fusion collapses
    elementwise chains the jaxpr-level model counts individually, and a
    looser ratio would mean the model stopped tracking real buffers."""
    traced = trace_engine("unified")
    mem = load_budget("unified")["memory"]["programs"]["apply_batch"]
    model = eval_formula(mem["peak"], traced.sizes)
    ma = traced.lowered["apply_batch"].compile().memory_analysis()
    measured = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    assert measured <= model <= 8 * measured


def test_sharded_state_args_resolve_through_body_arg_map():
    """shard_map prepends hoisted constants to its body invars; the
    outer->body argument map must still land every STATE_ARGS position
    on the owned per-device shard of the right array."""
    traced = trace_engine("vertex_range")
    closed = traced.programs["apply_batch"]
    amap = body_arg_map(closed)
    from repro.analysis.memory import program_body

    body = program_body(closed)
    env = traced.sizes
    expect = {
        "src": ("int32", env["local_cap"]),
        "dst": ("int32", env["local_cap"]),
        "valid": ("bool", env["local_cap"]),
        "core": ("int32", env["n_owned"]),
        "label": ("int64", env["n_owned"]),
        "n_edges": ("int32", None),
    }
    for name, pos in STATE_ARGS["apply_batch"]:
        aval = body.invars[amap[pos]].aval
        dtype, dim = expect[name]
        assert str(aval.dtype) == dtype, name
        assert (aval.shape == () if dim is None
                else aval.shape == (dim,)), name


@pytest.mark.slow
def test_memory_audit_passes_for_all_committed_engines():
    from repro.analysis import audit_engines
    from repro.analysis.programs import ENGINE_CONFIGS

    report = audit_engines(sorted(ENGINE_CONFIGS),
                           rules=["memory_budget"])
    failing = [c for c in report["checks"] if not c["ok"]]
    assert report["ok"], failing
