"""Model-level unit tests: attention path equivalences, MLA cache math,
MoE dispatch mass conservation, NequIP equivariance."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.transformer import (
    LMConfig, MLAConfig, MoEConfig, _attend, _attend_chunked, decode_step,
    forward, init_cache, init_params, prefill,
)
from repro.models import gnn as G


def test_chunked_attention_matches_dense():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
    dense = _attend(q, k, v, causal=True)
    chunked = _attend_chunked(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(
        np.asarray(dense), np.asarray(chunked), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("attention", ["gqa", "mla"])
def test_prefill_then_decode_matches_forward(attention):
    """Teacher-forced decode after prefill must reproduce forward logits."""
    cfg = LMConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=128, dtype=jnp.float32,
        attention=attention,
        mla=MLAConfig(kv_lora=16, q_lora=0, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16)
        if attention == "mla" else None,
    )
    if attention == "mla":
        cfg = LMConfig(**{**cfg.__dict__, "n_kv_heads": 4})
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    full_logits, _ = forward(cfg, params, toks)

    # prefill on the first 8 tokens, decode the next 4 teacher-forced
    cache = init_cache(cfg, 2, 16)
    logits_p, cache = prefill(cfg, params, toks[:, :8])
    # pad prefill cache into the decode cache capacity
    for k_ in cache:
        if k_ == "length":
            continue
        pad = 16 - cache[k_].shape[2]
        widths = [(0, 0)] * cache[k_].ndim
        widths[2] = (0, pad)
        cache[k_] = jnp.pad(cache[k_], widths)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, 7]),
        rtol=2e-4, atol=2e-4,
    )
    for i in range(8, 12):
        logits_d, cache = decode_step(cfg, params, cache, toks[:, i])
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, i]),
            rtol=2e-4, atol=2e-4,
        )


def test_moe_shared_plus_routed_runs_and_is_finite():
    cfg = LMConfig(
        name="moe", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_head=16, d_ff=64, vocab=64, dtype=jnp.float32,
        moe=MoEConfig(n_routed=8, n_shared=1, top_k=2, d_expert=16),
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, aux = forward(cfg, params, toks)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert float(aux) > 0.0  # load-balance loss present


def _random_rotation(seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


def test_nequip_rotation_equivariance():
    """Energy invariant and forces equivariant under a random rotation."""
    from repro.data.graphs import random_molecule_batch
    from repro.models.gnn import NequIPConfig, nequip_energy_forces

    cfg = NequIPConfig(n_layers=2, d_hidden=8, n_rbf=4)
    params = G.nequip_init(cfg, jax.random.PRNGKey(0))
    batch = random_molecule_batch(n_mols=2, n_atoms=6, n_edges=16, seed=1)
    e0, f0 = nequip_energy_forces(cfg, params, batch)
    R = _random_rotation(3)
    import dataclasses
    batch_rot = G.GraphBatch(
        node_feat=batch.node_feat, senders=batch.senders,
        receivers=batch.receivers, edge_mask=batch.edge_mask,
        node_mask=batch.node_mask, graph_id=batch.graph_id,
        n_graphs=batch.n_graphs,
        positions=jnp.asarray(np.asarray(batch.positions) @ R.T,
                              jnp.float32),
        species=batch.species,
    )
    e1, f1 = nequip_energy_forces(cfg, params, batch_rot)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(f0) @ R.T, np.asarray(f1), rtol=1e-3, atol=1e-4
    )
