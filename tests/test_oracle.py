"""Oracle correctness: Simplified-Order and Traversal maintainers must agree
with BZ-from-scratch after arbitrary random edit sequences."""
import numpy as np
import pytest

from repro.core.oracle import (
    OrderCoreMaintainer,
    TraversalCoreMaintainer,
    bz_core_decomposition,
)
from repro.graph.csr import build_csr
from repro.graph.generators import erdos_renyi, barabasi_albert, rmat


def _recompute(n, adj):
    core, _ = bz_core_decomposition(n, adj)
    return core


def _check_against_bz(maintainer):
    expect = _recompute(maintainer.n, maintainer.adj)
    np.testing.assert_array_equal(maintainer.core, expect)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("cls", [OrderCoreMaintainer, TraversalCoreMaintainer])
def test_random_inserts_match_bz(cls, seed):
    rng = np.random.default_rng(seed)
    n = 60
    g = erdos_renyi(n, 120, seed=seed)
    m = cls(n, g.edge_array())
    for _ in range(40):
        while True:
            u, v = rng.integers(0, n, size=2)
            if u != v and int(v) not in m.adj[int(u)]:
                break
        m.insert_edge(int(u), int(v))
        _check_against_bz(m)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("cls", [OrderCoreMaintainer, TraversalCoreMaintainer])
def test_random_removes_match_bz(cls, seed):
    rng = np.random.default_rng(seed + 100)
    n = 60
    g = erdos_renyi(n, 220, seed=seed)
    m = cls(n, g.edge_array())
    edges = g.edge_array()
    idx = rng.permutation(edges.shape[0])[:40]
    for i in idx:
        u, v = edges[i]
        m.remove_edge(int(u), int(v))
        _check_against_bz(m)


@pytest.mark.parametrize("cls", [OrderCoreMaintainer, TraversalCoreMaintainer])
def test_mixed_workload(cls):
    rng = np.random.default_rng(7)
    n = 80
    g = barabasi_albert(n, deg=6, seed=3)
    m = cls(n, g.edge_array())
    for step in range(60):
        if rng.random() < 0.5:
            while True:
                u, v = rng.integers(0, n, size=2)
                if u != v and int(v) not in m.adj[int(u)]:
                    break
            m.insert_edge(int(u), int(v))
        else:
            # remove a random existing edge
            cands = [(a, b) for a in range(n) for b in m.adj[a] if a < b]
            if not cands:
                continue
            u, v = cands[rng.integers(0, len(cands))]
            m.remove_edge(int(u), int(v))
        _check_against_bz(m)
    if isinstance(m, OrderCoreMaintainer):
        m.check_invariants()


def test_same_core_graph_has_parallel_work():
    """BA graphs give all vertices the same core — the case where prior
    parallel methods reduce to sequential but ours does not (paper §1)."""
    g = barabasi_albert(200, deg=6, seed=0)
    m = OrderCoreMaintainer(g.n, g.edge_array())
    assert len(set(m.core.tolist())) <= 4  # near-uniform cores


def test_example_figure1():
    """The paper's Figure 1 worked example: inserting e1, e2, e3 raises
    every vertex's core number by one."""
    # vertices: v=0, u1..u5 = 1..5
    edges = np.array(
        [[0, 2], [1, 2], [1, 3], [2, 3], [3, 4], [3, 5], [4, 5]]
    )
    m = OrderCoreMaintainer(6, edges)
    assert int(m.core[0]) == 1
    assert all(int(m.core[i]) == 2 for i in range(1, 6))
    m.insert_edge(0, 3)   # e1: v-u3
    m.insert_edge(2, 4)   # e2: u2-u4  (paper inserts u2->u3's配... e2=(u2,u4))
    m.insert_edge(1, 4)   # e3: u1-u4
    _check_against_bz(m)


def test_example_figure2_removal():
    """Figure 2: removing e1, e2, e3 lowers every vertex's core by one."""
    # v=0 core 2; u1..u5 = 1..5 core 3
    edges = np.array(
        [
            [0, 2], [0, 3],
            [1, 2], [1, 3], [1, 4],
            [2, 3], [2, 4], [2, 5],
            [3, 4], [3, 5],
            [4, 5],
        ]
    )
    m = OrderCoreMaintainer(6, edges)
    assert int(m.core[0]) == 2
    assert all(int(m.core[i]) == 3 for i in range(1, 6))
    m.remove_edge(0, 2)  # e1
    m.remove_edge(2, 3)  # e2
    m.remove_edge(1, 4)  # e3
    _check_against_bz(m)


def test_rmat_generator_power_law():
    g = rmat(10, 4000, seed=1)
    deg = g.degrees()
    assert deg.max() > 4 * max(1, int(np.median(deg[deg > 0])))


def test_order_visits_fewer_than_traversal():
    """The paper's core efficiency claim: the Order algorithm's searched set
    V+ is (much) smaller than Traversal's over the same edge stream."""
    g = erdos_renyi(500, 2000, seed=2)
    mo = OrderCoreMaintainer(g.n, g.edge_array())
    mt = TraversalCoreMaintainer(g.n, g.edge_array())
    rng = np.random.default_rng(0)
    v_plus_order, v_plus_trav = [], []
    for _ in range(50):
        while True:
            u, v = rng.integers(0, g.n, size=2)
            if u != v and int(v) not in mo.adj[int(u)]:
                break
        mo.insert_edge(int(u), int(v))
        mt.insert_edge(int(u), int(v))
        v_plus_order.append(mo.last_v_plus)
        v_plus_trav.append(mt.last_v_plus)
        np.testing.assert_array_equal(mo.core, mt.core)
    assert sum(v_plus_order) < sum(v_plus_trav)
    # Fig. 5: the searched set stays small for most edges
    assert np.median(v_plus_order) <= 32
