"""Property-based tests (hypothesis): the system invariants.

Invariant 1: after ANY batch edit sequence, the JAX maintainer's core
numbers equal BZ recomputation from scratch.
Invariant 2: the k-order certificate dout(v) <= core(v) holds after every
batch (validity of the maintained order for future edits).
Invariant 3: the sequential Simplified-Order oracle agrees edge-by-edge.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.api import CoreMaintainer
from repro.core.oracle import OrderCoreMaintainer, bz_from_csr
from repro.graph.csr import add_edges_csr, build_csr, remove_edges_csr


@st.composite
def graph_and_edits(draw):
    n = draw(st.integers(min_value=6, max_value=40))
    max_edges = n * (n - 1) // 2
    m0 = draw(st.integers(min_value=0, max_value=min(3 * n, max_edges)))
    rng_seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(rng_seed)
    # initial edges
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    rng.shuffle(pairs)
    init = pairs[:m0]
    # edit script: list of ("ins"|"rem", batch_size)
    n_steps = draw(st.integers(min_value=1, max_value=4))
    steps = [
        (draw(st.sampled_from(["ins", "rem"])),
         draw(st.integers(min_value=1, max_value=6)))
        for _ in range(n_steps)
    ]
    return n, init, steps, rng_seed


@given(graph_and_edits())
@settings(
    max_examples=40,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
def test_core_numbers_and_certificate(data):
    n, init, steps, rng_seed = data
    rng = np.random.default_rng(rng_seed + 1)
    g = build_csr(n, np.asarray(init, dtype=np.int64).reshape(-1, 2))
    m = CoreMaintainer.from_graph(g, capacity=4 * n * n + 64)
    cur = g
    for kind, size in steps:
        existing = {tuple(e) for e in cur.edge_array().tolist()}
        if kind == "ins":
            absent = [
                (i, j)
                for i in range(n)
                for j in range(i + 1, n)
                if (i, j) not in existing
            ]
            if not absent:
                continue
            take = rng.choice(len(absent), size=min(size, len(absent)),
                              replace=False)
            batch = np.asarray([absent[t] for t in take])
            m.insert_edges(batch)
            cur = add_edges_csr(cur, batch)
        else:
            if not existing:
                continue
            lst = sorted(existing)
            take = rng.choice(len(lst), size=min(size, len(lst)),
                              replace=False)
            batch = np.asarray([lst[t] for t in take])
            m.remove_edges(batch)
            cur = remove_edges_csr(cur, batch)
        # Invariant 1
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
        # Invariant 2: k-order certificate
        core, label = m.cores(), m.labels()
        src = np.asarray(m.src)
        dst = np.asarray(m.dst)
        val = np.asarray(m.valid)
        dout = np.zeros(n, dtype=np.int64)
        for s, d, ok in zip(src, dst, val):
            if not ok:
                continue
            if (core[d], label[d]) > (core[s], label[s]):
                dout[s] += 1
            else:
                dout[d] += 1
        assert (dout <= core).all(), np.nonzero(dout > core)


@given(graph_and_edits())
@settings(
    max_examples=15,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
def test_oracle_agrees_with_jax(data):
    n, init, steps, rng_seed = data
    rng = np.random.default_rng(rng_seed + 2)
    g = build_csr(n, np.asarray(init, dtype=np.int64).reshape(-1, 2))
    m = CoreMaintainer.from_graph(g, capacity=4 * n * n + 64)
    oracle = OrderCoreMaintainer(n, g.edge_array())
    cur = g
    for kind, size in steps:
        existing = {tuple(e) for e in cur.edge_array().tolist()}
        if kind == "ins":
            absent = [
                (i, j)
                for i in range(n)
                for j in range(i + 1, n)
                if (i, j) not in existing
            ]
            if not absent:
                continue
            take = rng.choice(len(absent), size=min(size, len(absent)),
                              replace=False)
            batch = np.asarray([absent[t] for t in take])
            m.insert_edges(batch)
            oracle.insert_batch(batch)
            cur = add_edges_csr(cur, batch)
        else:
            if not existing:
                continue
            lst = sorted(existing)
            take = rng.choice(len(lst), size=min(size, len(lst)),
                              replace=False)
            batch = np.asarray([lst[t] for t in take])
            m.remove_edges(batch)
            oracle.remove_batch(batch)
            cur = remove_edges_csr(cur, batch)
        np.testing.assert_array_equal(m.cores(), oracle.core)
