"""RecSys substrate end-to-end: DeepFM trains on planted CTR data; the
embedding-bag primitive; retrieval scoring."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.data.recsys import synthetic_ctr_batches
from repro.models.recsys import (
    DeepFMConfig,
    deepfm_forward,
    deepfm_init,
    deepfm_loss,
    embedding_bag,
    retrieval_score,
)
from repro.train.loop import TrainConfig, run_training


def test_deepfm_learns_planted_ctr():
    cfg = DeepFMConfig(n_sparse=8, embed_dim=8, mlp_dims=(32, 32),
                       rows_per_field=1024)
    params = deepfm_init(cfg, jax.random.PRNGKey(0))
    data = synthetic_ctr_batches(cfg.n_sparse, cfg.rows_per_field,
                                 batch=256, seed=0)

    def batches():
        for ids, labels in data:
            yield jnp.asarray(ids), jnp.asarray(labels)

    def lf(p, ids, labels):
        return deepfm_loss(cfg, p, ids, labels)

    tc = TrainConfig(lr=1e-2, warmup=5, total_steps=300, weight_decay=0.0)
    params, report = run_training(params, lf, batches(), tc)
    hist = report["history"]
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    assert last < first - 0.005, (first, last)
    # AUC sanity on a held-out batch from the SAME planted distribution
    ids, labels = next(data)
    scores = np.asarray(deepfm_forward(cfg, params, jnp.asarray(ids)))
    pos = scores[labels > 0.5]
    neg = scores[labels < 0.5]
    auc = (pos[:, None] > neg[None, :]).mean()
    assert auc > 0.6, auc


def test_embedding_bag_multihot():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([0, 1, 5, 5], jnp.int32)
    bags = jnp.asarray([0, 0, 1, 1], jnp.int32)
    out = embedding_bag(table, ids, bags, n_bags=2)
    np.testing.assert_allclose(np.asarray(out[0]), [2.0, 4.0])
    np.testing.assert_allclose(np.asarray(out[1]), [20.0, 22.0])
    mean = embedding_bag(table, ids, bags, n_bags=2, combine="mean")
    np.testing.assert_allclose(np.asarray(mean[0]), [1.0, 2.0])


def test_retrieval_scores_batched_dot():
    cfg = DeepFMConfig(n_sparse=4, embed_dim=8, mlp_dims=(16,),
                       rows_per_field=128)
    params = deepfm_init(cfg, jax.random.PRNGKey(0))
    q = jnp.asarray(np.random.default_rng(0).integers(
        0, 128, size=(1, 4)), jnp.int32)
    cand = jnp.asarray(np.random.default_rng(1).normal(
        size=(1000, 8)), jnp.float32)
    s = retrieval_score(cfg, params, q, cand)
    assert s.shape == (1, 1000)
    assert bool(jnp.all(jnp.isfinite(s)))
