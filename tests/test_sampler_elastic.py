"""Neighbor sampler (fanout + core-priority), elastic mesh hooks."""
import numpy as np

from repro.core.api import CoreMaintainer
from repro.core.applications import core_sampling_weights
from repro.graph.generators import erdos_renyi
from repro.graph.sampler import NeighborSampler
from repro.train.fault import ElasticMesh


def test_fanout_sampler_block_validity():
    g = erdos_renyi(500, 3000, seed=0)
    s = NeighborSampler(g, fanouts=(5, 3), seed=1)
    batch = np.asarray([1, 2, 3, 10, 20])
    blk = s.sample(batch)
    n_live = int(blk.node_mask.sum())
    assert n_live >= len(batch)
    assert blk.seed_mask.sum() == len(batch)
    # every live edge points between live local nodes
    for snd, rcv, ok in zip(blk.senders, blk.receivers, blk.edge_mask):
        if ok:
            assert blk.node_mask[snd] and blk.node_mask[rcv]
            # and corresponds to a real edge in the base graph
            gs = blk.node_ids[snd]
            gr = blk.node_ids[rcv]
            assert g.has_edge(int(gs), int(gr))


def test_core_priority_weights_integrate_with_sampler():
    g = erdos_renyi(400, 2400, seed=1)
    m = CoreMaintainer.from_graph(g)
    w = core_sampling_weights(m, alpha=1.5)
    rng = np.random.default_rng(0)
    seeds = rng.choice(g.n, size=64, replace=False, p=w)
    core = m.cores()
    assert core[seeds].mean() >= core.mean()  # biased toward dense regions


def test_elastic_mesh_shrink_grow():
    avail = {"n": 16}
    em = ElasticMesh(desired=16, available_fn=lambda: avail["n"])
    assert not em.needs_remesh(16)
    avail["n"] = 9  # lost 7 hosts
    assert em.needs_remesh(16)
    assert em.next_shape() == 8  # largest power of two that fits
    avail["n"] = 33
    assert em.next_shape() == 32
