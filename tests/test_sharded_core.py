"""Sharded (multi-device) core maintenance — run in a subprocess with 8
virtual CPU devices so the main test session keeps a single device."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp

    import repro  # enables x64
    from repro.core.api import CoreMaintainer
    from repro.core.oracle import bz_from_csr
    from repro.core.sharded import (
        make_sharded_insert_round,
        make_sharded_remove,
        shard_edges,
    )
    from repro.graph.csr import add_edges_csr, build_csr, remove_edges_csr
    from repro.graph.generators import erdos_renyi

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("data",))

    # ---- removal ----------------------------------------------------------
    g = erdos_renyi(64, 256, seed=0)
    m = CoreMaintainer.from_graph(g, capacity=512)
    edges = g.edge_array()
    rng = np.random.default_rng(0)
    rm = edges[rng.choice(edges.shape[0], size=12, replace=False)]
    # apply tombstones on host
    slots = [m.edge_slot[(int(a), int(b))] for a, b in rm]
    valid = np.asarray(m.valid).copy()
    valid[slots] = False
    src, dst, valid_s = shard_edges(
        mesh, "data", np.asarray(m.src), np.asarray(m.dst), valid
    )
    fn = make_sharded_remove(mesh, m.n)
    core = fn(src, dst, valid_s, m.core)
    expect = bz_from_csr(remove_edges_csr(g, rm))
    np.testing.assert_array_equal(np.asarray(core), expect)
    print("sharded-remove OK")

    # ---- insertion (single round graph: fresh edges not raising twice) ----
    g2 = erdos_renyi(64, 200, seed=1)
    m2 = CoreMaintainer.from_graph(g2, capacity=1024)
    batch = []
    rng = np.random.default_rng(1)
    while len(batch) < 10:
        u, v = rng.integers(0, 64, size=2)
        key = (int(min(u, v)), int(max(u, v)))
        if u == v or g2.has_edge(*key) or key in batch:
            continue
        batch.append(key)
    arr = np.asarray(batch, dtype=np.int32)
    src = np.asarray(m2.src).copy()
    dst = np.asarray(m2.dst).copy()
    val = np.asarray(m2.valid).copy()
    ne = int(m2.n_edges)
    src[ne : ne + len(arr)] = arr[:, 0]
    dst[ne : ne + len(arr)] = arr[:, 1]
    val[ne : ne + len(arr)] = True
    ssrc, sdst, sval = shard_edges(mesh, "data", src, dst, val)
    round_fn = make_sharded_insert_round(mesh, m2.n)
    core = m2.core
    label = m2.label
    for _ in range(8):  # host round loop
        ecore = np.asarray(core)
        root = np.where(
            (ecore[arr[:, 0]] < ecore[arr[:, 1]])
            | (
                (ecore[arr[:, 0]] == ecore[arr[:, 1]])
                & (np.asarray(label)[arr[:, 0]] < np.asarray(label)[arr[:, 1]])
            ),
            arr[:, 0],
            arr[:, 1],
        )
        seed = np.zeros(m2.n, dtype=bool)
        seed[root] = True
        new_core, promoted = round_fn(
            ssrc, sdst, sval, core, label, jnp.asarray(seed)
        )
        if int(jnp.sum(promoted)) == 0:
            break
        core = new_core
        # labels: promoted to head of new level (host-side, small batch)
        lab = np.asarray(label).copy()
        prom = np.asarray(promoted)
        nc = np.asarray(new_core)
        for lvl in np.unique(nc[prom]):
            movers = np.nonzero(prom & (nc == lvl))[0]
            others = np.nonzero((~prom) & (nc == lvl))[0]
            base = lab[others].min() if others.size else 0
            order = movers[np.argsort(lab[movers])]
            for i, v in enumerate(order):
                lab[v] = base - (len(order) - i) * (1 << 20)
        label = jnp.asarray(lab)
    expect = bz_from_csr(add_edges_csr(g2, arr))
    np.testing.assert_array_equal(np.asarray(core), expect)
    print("sharded-insert OK")
    """
)


@pytest.mark.slow
def test_sharded_core_8dev(tmp_path):
    script = tmp_path / "sharded.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "sharded-remove OK" in out.stdout
    assert "sharded-insert OK" in out.stdout
