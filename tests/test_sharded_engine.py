"""engine="sharded": the full mixed-batch program with the edge-slot
table sharded over the mesh's data axis. On the single-device test
session the mesh has one shard — the same code path as multi-device, with
the psums degenerate; the slow subprocess test below re-runs parity on 8
forced host devices where the slot table genuinely spans shards."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from conftest import sample_absent as _sample_absent

from repro.core.api import CoreMaintainer
from repro.core.oracle import bz_from_csr
from repro.graph.csr import add_edges_csr, build_csr, remove_edges_csr
from repro.graph.generators import erdos_renyi
from repro.graph.stream import mixed_stream


@pytest.mark.parametrize("seed", range(3))
def test_sharded_mixed_batches_match_bz(seed):
    """Oracle-checked fuzz: one sharded apply_batch per mixed event == BZ
    from scratch, including dup/self-loop batches and tight-capacity
    churn through _compact/_grow."""
    rng = np.random.default_rng(seed + 40)
    n = 70
    g = erdos_renyi(n, 260, seed=seed)
    m = CoreMaintainer.from_graph(
        g, capacity=int(g.m * 1.5) + 8, engine="sharded"
    )
    cur = g
    for step in range(5):
        ins = _sample_absent(cur, rng, 6)
        edges = cur.edge_array()
        take = rng.choice(edges.shape[0], size=6, replace=False)
        rm = edges[take]
        # adversarial garnish: self-loop + in-batch duplicate + dup of a
        # live edge, all of which must be masked on device
        garnish = np.asarray([[3, 3], list(ins[0]), list(edges[0])])
        m.apply_batch(
            insert_edges=np.concatenate([ins, garnish]), remove_edges=rm
        )
        cur = add_edges_csr(remove_edges_csr(cur, rm), ins)
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
    assert m.live_edges == cur.m


def test_sharded_agrees_with_unified_on_stream():
    """Cores AND k-order labels identical to the unified engine on the
    same mixed stream (all statistics are exact integers, so the two
    engines are bit-identical, not just equivalent)."""
    g = erdos_renyi(70, 280, seed=8)
    mu = CoreMaintainer.from_graph(g, capacity=2048, engine="unified")
    ms = CoreMaintainer.from_graph(g, capacity=2048, engine="sharded")
    for ev in mixed_stream(g, 6, 12, seed=4):
        su = mu.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        ss = ms.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        np.testing.assert_array_equal(mu.cores(), ms.cores())
        np.testing.assert_array_equal(mu.labels(), ms.labels())
        assert int(su.n_inserted) == int(ss.n_inserted)
        assert int(su.n_removed) == int(ss.n_removed)
    assert mu.live_edges == ms.live_edges
    assert mu.edge_slot == ms.edge_slot


def test_sharded_remove_and_reinsert_same_batch():
    g = erdos_renyi(50, 180, seed=3)
    m = CoreMaintainer.from_graph(g, capacity=1024, engine="sharded")
    before = m.cores().copy()
    e = g.edge_array()[:4]
    st = m.apply_batch(insert_edges=e, remove_edges=e)
    assert int(st.n_removed) == 4
    assert int(st.n_inserted) == 4
    np.testing.assert_array_equal(m.cores(), before)
    for a, b in e:
        assert (int(a), int(b)) in m.edge_slot


def test_sharded_save_load_roundtrip(tmp_path):
    """save() on sharded reloads under any engine (and back) with the
    same state and identical continuation."""
    g = erdos_renyi(50, 150, seed=0)
    m = CoreMaintainer.from_graph(g, capacity=1024, engine="sharded")
    ev = next(mixed_stream(g, 1, 20, seed=2))
    m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
    p = str(tmp_path / "state.npz")
    m.save(p)
    m2 = CoreMaintainer.load(p, engine="sharded")
    m3 = CoreMaintainer.load(p, engine="unified")
    assert m2.edge_slot == m.edge_slot == m3.edge_slot
    ins = _sample_absent(
        build_csr(m.n, np.asarray(sorted(m.edge_slot))),
        np.random.default_rng(1), 5,
    )
    for mm in (m, m2, m3):
        mm.apply_batch(insert_edges=ins)
    np.testing.assert_array_equal(m.cores(), m2.cores())
    np.testing.assert_array_equal(m.cores(), m3.cores())
    np.testing.assert_array_equal(m.labels(), m2.labels())


_PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax

    import repro  # enables x64
    from repro.core.api import CoreMaintainer
    from repro.core.oracle import bz_from_csr
    from repro.graph.csr import build_csr
    from repro.graph.generators import erdos_renyi
    from repro.graph.stream import mixed_stream

    assert len(jax.devices()) == 8, jax.devices()
    g = erdos_renyi(80, 320, seed=1)
    # tight capacity: live slots span every shard and churn crosses
    # shard boundaries; odd capacity also exercises the divisibility pad
    mu = CoreMaintainer.from_graph(g, capacity=645, engine="unified")
    ms = CoreMaintainer.from_graph(g, capacity=645, engine="sharded")
    assert ms.capacity % 8 == 0, ms.capacity
    live = {tuple(e) for e in g.edge_array().tolist()}
    for ev in mixed_stream(g, 8, 24, seed=3):
        mu.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        ms.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        live.difference_update(map(tuple, ev.removals.tolist()))
        live.update(map(tuple, ev.edges.tolist()))
        cur = build_csr(g.n, np.asarray(sorted(live), dtype=np.int64))
        np.testing.assert_array_equal(ms.cores(), bz_from_csr(cur))
        np.testing.assert_array_equal(ms.cores(), mu.cores())
        np.testing.assert_array_equal(ms.labels(), mu.labels())
    assert ms.live_edges == mu.live_edges == len(live)
    # masked invalid edges are dropped identically under sharding
    ms.validate = False
    st = ms.apply_batch(insert_edges=[[5, 9999], [-1, 3]])
    assert int(st.n_inserted) == 0
    print("sharded-parity-8dev OK")
    """
)


@pytest.mark.slow
def test_sharded_engine_parity_8dev(tmp_path):
    """Multi-process parity: the sharded engine on 8 forced host devices
    tracks BZ and the unified engine exactly (cores and labels)."""
    script = tmp_path / "parity.py"
    script.write_text(_PARITY_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "sharded-parity-8dev OK" in out.stdout
