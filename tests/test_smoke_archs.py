"""Per-arch smoke tests: reduced config, one real step on CPU, output
shapes + finiteness. Covers all 10 assigned architectures x all shapes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import arch_names
from repro.launch.steps import build_cell, cell_names

ALL = []
for a in arch_names():
    for s in cell_names(a, smoke=True):
        ALL.append((a, s))


def _finite(tree):
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype,
                                                     jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), (
                "non-finite output"
            )


@pytest.mark.parametrize("arch,shape", ALL)
def test_smoke(arch, shape):
    prog = build_cell(arch, shape, smoke=True)
    inputs = prog.concrete_inputs(jax.random.PRNGKey(0))
    # abstract specs must match the concrete inputs
    abs_flat = jax.tree.leaves(prog.abstract_inputs)
    conc_flat = jax.tree.leaves(inputs)
    assert len(abs_flat) == len(conc_flat)
    for a, c in zip(abs_flat, conc_flat):
        assert tuple(a.shape) == tuple(c.shape), (prog.name, a.shape, c.shape)
        assert a.dtype == c.dtype, (prog.name, a.dtype, c.dtype)
    out = jax.jit(prog.fn)(*inputs)
    _finite(out)
