"""Regression tests for the temporal stream layer (graph/stream.py):
``temporal_replay`` input validation + the equal-timestamp tie-crossing
refusal, and ``sliding_window_stream`` expiry semantics (refresh,
same-step roundtrip, drain invariant, tie-order independence) — plus an
end-to-end replay through ``CoreMaintainer.apply_batch`` pinned to the
BZ oracle on the live set after every step.
"""
import numpy as np
import pytest

from repro.core.api import CoreMaintainer
from repro.core.oracle import bz_from_csr
from repro.graph.csr import build_csr
from repro.graph.stream import sliding_window_stream, temporal_replay


# -- temporal_replay: validation --------------------------------------------

def test_temporal_replay_rejects_mx2_shape():
    """A [m, 2] edge list used to slip through with vertex ids replayed
    as timestamps; it must be refused up front."""
    edges = np.asarray([[0, 1], [1, 2]], dtype=np.int64)
    with pytest.raises(ValueError, match=r"shape \[m, 3\]"):
        list(temporal_replay(edges, batch_size=2))


def test_temporal_replay_rejects_float_timestamps():
    ewt = np.asarray([[0, 1, 0.5], [1, 2, 1.5]])
    with pytest.raises(ValueError, match="integer dtype"):
        list(temporal_replay(ewt, batch_size=2))


def test_temporal_replay_rejects_bad_batch_size():
    ewt = np.asarray([[0, 1, 0]], dtype=np.int64)
    with pytest.raises(ValueError, match="batch_size"):
        list(temporal_replay(ewt, batch_size=0))


def test_sliding_window_rejects_malformed_input():
    with pytest.raises(ValueError, match=r"shape \[m, 3\]"):
        list(sliding_window_stream(np.zeros((3, 2), np.int64), window=2))
    with pytest.raises(ValueError, match="integer dtype"):
        list(sliding_window_stream(np.zeros((3, 3)), window=2))
    ewt = np.asarray([[0, 1, 0]], dtype=np.int64)
    with pytest.raises(ValueError, match="window"):
        list(sliding_window_stream(ewt, window=0))
    with pytest.raises(ValueError, match="stride"):
        list(sliding_window_stream(ewt, window=2, stride=0))


# -- temporal_replay: stable sort + tie-crossing refusal --------------------

_TIED = np.asarray(
    [[0, 1, 5], [2, 3, 1], [4, 5, 1], [6, 7, 1]], dtype=np.int64
)  # unsorted; three rows tied at t=1


def test_temporal_replay_refuses_tie_crossing_batch_boundary():
    """Unsorted input + a t=1 tie straddling the batch_size=2 boundary:
    which tied edge lands in the earlier batch would be an artifact of
    file order, so the replay refuses and names the timestamp."""
    with pytest.raises(ValueError, match="equal-timestamp"):
        list(temporal_replay(_TIED, batch_size=2))
    with pytest.raises(ValueError, match="t=1"):
        list(temporal_replay(_TIED, batch_size=2))


def test_temporal_replay_allows_ties_kept_in_one_batch():
    """The same unsorted input is fine when the batch size keeps the
    tied run together — and the stable sort replays the tied rows in
    input order."""
    events = list(temporal_replay(_TIED, batch_size=3))
    assert [ev.t for ev in events] == [1, 5]
    np.testing.assert_array_equal(
        events[0].edges, [[2, 3], [4, 5], [6, 7]]  # input order kept
    )
    np.testing.assert_array_equal(events[1].edges, [[0, 1]])
    assert all(ev.kind == "insert" for ev in events)


def test_temporal_replay_presorted_ties_may_cross():
    """Pre-sorted input is the caller's OWN deterministic tie order, so
    a tie crossing a batch boundary is allowed — and the stable sort
    guarantees the batches reproduce the input order exactly."""
    presorted = _TIED[np.argsort(_TIED[:, 2], kind="stable")]
    events = list(temporal_replay(presorted, batch_size=2))
    assert [len(ev.edges) for ev in events] == [2, 2]
    np.testing.assert_array_equal(events[0].edges, [[2, 3], [4, 5]])
    np.testing.assert_array_equal(events[1].edges, [[6, 7], [0, 1]])


# -- sliding_window_stream: expiry semantics --------------------------------

def _drain_totals(events):
    ins = sum(len(ev.edges) for ev in events)
    rm = sum(len(ev.removals) for ev in events)
    return ins, rm


def test_sliding_window_same_step_roundtrip():
    """An edge expiring in the same step its re-arrival lands round-trips
    through ONE mixed event (removal + insertion — the engine's
    same-batch slot-recycling path), and the stream drains."""
    ewt = np.asarray([[0, 1, 0], [0, 1, 3]], dtype=np.int64)
    events = list(sliding_window_stream(ewt, window=2, stride=2))
    assert [ev.t for ev in events] == [2, 4, 6]
    assert all(ev.kind == "mixed" for ev in events)
    # t=4: the t=0 arrival expired AND the t=3 arrival re-inserts
    assert len(events[1].edges) == len(events[1].removals) == 1
    ins, rm = _drain_totals(events)
    assert ins == rm == 2


def test_sliding_window_rearrival_refreshes_age():
    """A re-arrival of a LIVE edge does not re-insert it — it refreshes
    the age, pushing expiry out to the latest arrival + window."""
    ewt = np.asarray([[0, 1, 0], [0, 1, 1]], dtype=np.int64)
    events = list(sliding_window_stream(ewt, window=3, stride=1))
    ins, rm = _drain_totals(events)
    assert ins == rm == 1  # one logical edge: one insert, one expiry
    assert events[-1].t == 4  # expiry keyed off the t=1 refresh, not t=0


def test_sliding_window_drops_self_loops_and_dedups_in_step():
    ewt = np.asarray(
        [[2, 2, 0], [0, 1, 0], [1, 0, 1], [3, 4, 1]], dtype=np.int64
    )
    events = list(sliding_window_stream(ewt, window=4, stride=4))
    # one step of arrivals: (0,1) once (the t=1 duplicate refreshes it),
    # (3,4) once, the self-loop never
    assert sorted(map(tuple, events[0].edges)) == [(0, 1), (3, 4)]
    ins, rm = _drain_totals(events)
    assert ins == rm == 2


def test_sliding_window_tie_order_independent():
    """Timestamps only gate which step an edge joins, so shuffling the
    input rows (including equal-timestamp ties) cannot change the event
    sequence — unlike temporal_replay there is nothing to refuse."""
    rng = np.random.default_rng(3)
    ewt = np.stack(
        [rng.integers(0, 20, 120), rng.integers(0, 20, 120),
         rng.integers(0, 12, 120)], axis=1,
    ).astype(np.int64)
    ref = list(sliding_window_stream(ewt, window=4, stride=2))
    shuffled = ewt[rng.permutation(len(ewt))]
    got = list(sliding_window_stream(shuffled, window=4, stride=2))
    assert [ev.t for ev in got] == [ev.t for ev in ref]
    for a, b in zip(got, ref):
        assert sorted(map(tuple, a.edges)) == sorted(map(tuple, b.edges))
        assert sorted(map(tuple, a.removals)) == \
            sorted(map(tuple, b.removals))


def test_sliding_window_empty_input_yields_nothing():
    assert list(sliding_window_stream(np.zeros((0, 3), np.int64),
                                      window=2)) == []


def test_sliding_window_drains_through_engine():
    """End-to-end: replay a random temporal stream through the unified
    engine (removals first — apply_batch's order), checking cores
    against BZ on a live-set mirror after every event; after the last
    event the graph is empty and every core is zero."""
    n = 16
    rng = np.random.default_rng(7)
    ewt = np.stack(
        [rng.integers(0, n, 150), rng.integers(0, n, 150),
         rng.integers(0, 10, 150)], axis=1,
    ).astype(np.int64)
    events = list(sliding_window_stream(ewt, window=3, stride=1))
    m = CoreMaintainer.from_graph(
        build_csr(n, np.zeros((0, 2), np.int64)), capacity=512
    )
    live = set()
    for ev in events:
        m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        for e in map(tuple, ev.removals):
            live.discard(e)
        for e in map(tuple, ev.edges):
            live.add(e)
        expect = bz_from_csr(
            build_csr(n, np.asarray(sorted(live), dtype=np.int64))
        )
        np.testing.assert_array_equal(m.cores(), expect)
    assert not live
    assert m.live_edges == 0
    assert not m.cores().any()
