"""Training-infrastructure integration tests: checkpoint/kill/resume
equivalence, gradient compression, schedules, straggler monitor."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.compression import compress_int8, decompress_int8
from repro.optim.schedule import cosine_with_warmup
from repro.train import checkpoint as ckpt
from repro.train.fault import StragglerMonitor
from repro.train.loop import TrainConfig, run_training


def _toy_setup():
    def loss_fn(params, x, y):
        pred = x @ params["w"] + params["b"]
        return jnp.mean((pred - y) ** 2)

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)

    def batches(n, seed=0):
        r = np.random.default_rng(seed)
        for _ in range(n):
            x = r.normal(size=(16, 4)).astype(np.float32)
            y = x @ w_true + 0.01 * r.normal(size=(16, 1)).astype(np.float32)
            yield jnp.asarray(x), jnp.asarray(y)

    params = {
        "w": jnp.zeros((4, 1), jnp.float32),
        "b": jnp.zeros((1,), jnp.float32),
    }
    return loss_fn, batches, params


def test_training_converges_and_checkpoints(tmp_path):
    loss_fn, batches, params = _toy_setup()
    tc = TrainConfig(lr=1e-1, warmup=2, total_steps=30,
                     ckpt_dir=str(tmp_path), ckpt_every=10)
    params, report = run_training(params, loss_fn, batches(40), tc)
    hist = report["history"]
    assert hist[-1]["loss"] < 0.05 * hist[0]["loss"]
    assert ckpt.latest_step(str(tmp_path)) is not None


def test_resume_reproduces_uninterrupted_run(tmp_path):
    loss_fn, batches, params0 = _toy_setup()

    def fresh():  # donation in the train loop consumes the buffers
        return jax.tree.map(jnp.copy, params0)

    # uninterrupted 20 steps
    tc = TrainConfig(lr=1e-1, warmup=2, total_steps=20)
    p_full, _ = run_training(fresh(), loss_fn, batches(30), tc)

    # interrupted: 10 steps w/ checkpoint, then resume to 20.
    # data stream is restarted identically at the right offset (the host
    # restarts the deterministic pipeline at step k on resume).
    dir1 = str(tmp_path / "ck")
    # same schedule (total_steps=20); the interruption is the stream
    # ending after 10 batches (preemption equivalent)
    tc1 = TrainConfig(lr=1e-1, warmup=2, total_steps=20, ckpt_dir=dir1,
                      ckpt_every=9)
    p_half, rep = run_training(fresh(), loss_fn, batches(10), tc1)
    last = ckpt.latest_step(dir1)
    assert last == 9
    tc2 = TrainConfig(lr=1e-1, warmup=2, total_steps=20, ckpt_dir=dir1,
                      ckpt_every=100)
    stream = batches(30)
    for _ in range(last + 1):  # skip consumed batches
        next(stream)
    p_res, _ = run_training(fresh(), loss_fn, stream, tc2)
    np.testing.assert_allclose(
        np.asarray(p_res["w"]), np.asarray(p_full["w"]), rtol=1e-5,
        atol=1e-6,
    )


def test_checkpoint_commit_markers_reject_corruption(tmp_path):
    state = {"a": jnp.arange(8, dtype=jnp.float32)}
    ckpt.save_checkpoint(str(tmp_path), 5, state)
    assert ckpt.latest_step(str(tmp_path)) == 5
    # corrupt the shard: the sha256 check must reject it
    shard = os.path.join(str(tmp_path), "step_0000000005",
                         "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(30)
        f.write(b"\x00\x01\x02")
    assert ckpt.latest_step(str(tmp_path)) is None


def test_microbatch_accumulation_matches_full_batch():
    loss_fn, batches, params = _toy_setup()
    from repro.train.loop import make_train_step

    tc1 = TrainConfig(lr=1e-2, warmup=1, total_steps=10, micro_batches=1)
    tc4 = TrainConfig(lr=1e-2, warmup=1, total_steps=10, micro_batches=4)
    step1 = jax.jit(make_train_step(loss_fn, tc1))
    step4 = jax.jit(make_train_step(loss_fn, tc4))
    x, y = next(batches(1))
    fresh = lambda: jax.tree.map(jnp.copy, params)
    p1, _, m1 = step1(fresh(), adamw_init(params), jnp.int32(0), x, y)
    p4, _, m4 = step4(fresh(), adamw_init(params), jnp.int32(0), x, y)
    # same total batch; accumulated grads equal the full-batch mean
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-4, atol=1e-6)


def test_int8_compression_error_feedback_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = compress_int8(g)
    deq = decompress_int8(q, s)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.01  # int8 with per-tensor scale
    # residual accumulation: repeated compression of g + residual loses
    # no mass over rounds (EF property)
    residual = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(10):
        q, s = compress_int8(g + residual)
        deq = decompress_int8(q, s)
        residual = g + residual - deq
        total = total + deq
    np.testing.assert_allclose(
        np.asarray(total / 10), np.asarray(g), rtol=0.02, atol=2e-3
    )


def test_schedule_shapes():
    lr0 = cosine_with_warmup(jnp.int32(0), 1e-3, 10, 100)
    lr_w = cosine_with_warmup(jnp.int32(10), 1e-3, 10, 100)
    lr_end = cosine_with_warmup(jnp.int32(100), 1e-3, 10, 100)
    assert float(lr0) == 0.0
    assert abs(float(lr_w) - 1e-3) < 1e-9
    assert float(lr_end) <= 0.11e-3 + 1e-9


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(deadline_factor=2.0, window=16)
    import time as _t
    for i in range(12):
        mon.step_start(i)
        mon.durations.append(0.01)  # synthetic fast steps
    mon.step_start(99)
    mon._t0 -= 1.0  # pretend the step took 1s
    mon.step_end()
    assert 99 in mon.straggler_steps
