"""Unified mixed-batch engine: exactness against the oracle, device-side
dedup semantics, same-batch remove+re-insert, slot-table mirror, and the
in-program renumber gate."""
import numpy as np
import pytest
from conftest import sample_absent as _sample_absent

import jax.numpy as jnp

from repro.core.api import CoreMaintainer
from repro.core.oracle import bz_from_csr
from repro.core.order import LABEL_GAP, needs_renumber
from repro.graph.csr import add_edges_csr, build_csr, remove_edges_csr
from repro.graph.generators import erdos_renyi
from repro.graph.stream import mixed_stream


def _certificate_violations(m: CoreMaintainer) -> np.ndarray:
    core, label = m.cores(), m.labels()
    src = np.asarray(m.src)
    dst = np.asarray(m.dst)
    val = np.asarray(m.valid)
    dout = np.zeros(m.n, dtype=np.int64)
    for s, d, ok in zip(src, dst, val):
        if not ok:
            continue
        if (core[d], label[d]) > (core[s], label[s]):
            dout[s] += 1
        else:
            dout[d] += 1
    return np.nonzero(dout > core)[0]


@pytest.mark.parametrize("seed", range(4))
def test_mixed_batches_match_bz(seed):
    """One apply_batch call per mixed insert+remove event == BZ from
    scratch, with the k-order certificate intact after every batch."""
    rng = np.random.default_rng(seed + 21)
    n = 80
    g = erdos_renyi(n, 300, seed=seed)
    m = CoreMaintainer.from_graph(g, capacity=4096)
    cur = g
    for step in range(6):
        ins = _sample_absent(cur, rng, 6)
        edges = cur.edge_array()
        take = rng.choice(edges.shape[0], size=6, replace=False)
        rm = edges[take]
        m.apply_batch(insert_edges=ins, remove_edges=rm)
        cur = add_edges_csr(remove_edges_csr(cur, rm), ins)
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
        bad = _certificate_violations(m)
        assert bad.size == 0, f"k-order certificate violated at {bad}"


def test_remove_and_reinsert_same_batch():
    """An edge listed in BOTH halves of one batch round-trips: removals
    apply first, so it ends up present and cores are unchanged."""
    g = erdos_renyi(50, 180, seed=3)
    m = CoreMaintainer.from_graph(g, capacity=1024)
    before = m.cores().copy()
    e = g.edge_array()[:4]
    st = m.apply_batch(insert_edges=e, remove_edges=e)
    assert int(st.n_removed) == 4
    assert int(st.n_inserted) == 4
    np.testing.assert_array_equal(m.cores(), before)
    for a, b in e:
        assert (int(a), int(b)) in m.edge_slot


def test_remove_then_reinsert_across_stream():
    """mixed_stream recycles removed edges into the candidate pool; the
    maintainer tracks BZ exactly across the whole stream."""
    n = 60
    g = erdos_renyi(n, 240, seed=5)
    m = CoreMaintainer.from_graph(g, capacity=4096)
    live = {tuple(e) for e in g.edge_array().tolist()}
    removed_once = set()
    reinserted = 0
    for ev in mixed_stream(g, 10, 16, seed=9):
        assert ev.kind == "mixed"
        reinserted += sum(
            1 for e in map(tuple, ev.edges.tolist()) if e in removed_once
        )
        removed_once.update(map(tuple, ev.removals.tolist()))
        m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        live.difference_update(map(tuple, ev.removals.tolist()))
        live.update(map(tuple, ev.edges.tolist()))
        cur = build_csr(n, np.asarray(sorted(live), dtype=np.int64))
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
    assert m.live_edges == len(live)


def test_device_dedup_and_membership():
    """Self-loops, in-batch duplicates, and already-present edges are all
    filtered on device; the batch is a no-op."""
    g = erdos_renyi(40, 120, seed=6)
    m = CoreMaintainer.from_graph(g, capacity=1024)
    before = m.cores().copy()
    live_before = m.live_edges
    e = g.edge_array()[:5]
    batch = np.concatenate(
        [e, np.asarray([[3, 3], [7, 9], [9, 7], [7, 9]])]
    )
    extra = 0 if g.has_edge(7, 9) else 1
    st = m.apply_batch(insert_edges=batch)
    assert int(st.n_inserted) == extra  # (7, 9) once, everything else dropped
    np.testing.assert_array_equal(
        m.cores(), bz_from_csr(add_edges_csr(g, np.asarray([[7, 9]])))
        if extra else before,
    )
    assert m.live_edges == live_before + extra
    # removing a non-existent edge is a no-op too
    st = m.apply_batch(remove_edges=np.asarray([[0, 39], [39, 0]])
                       if not g.has_edge(0, 39) else None)
    assert int(st.n_removed) == 0


def test_engines_agree_on_stream():
    """The unified one-call engine and the seed two-call path produce
    identical cores on the same mixed stream."""
    g = erdos_renyi(70, 280, seed=8)
    mu = CoreMaintainer.from_graph(g, capacity=2048, engine="unified")
    mh = CoreMaintainer.from_graph(g, capacity=2048, engine="host")
    for ev in mixed_stream(g, 6, 12, seed=4):
        su = mu.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        # apply_batch dispatches to the seed two-call path on engine="host"
        sh = mh.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
        np.testing.assert_array_equal(mu.cores(), mh.cores())
        assert int(su.n_inserted) == int(sh.n_inserted)
        assert int(su.n_removed) == int(sh.n_removed)
    assert mu.live_edges == mh.live_edges
    with pytest.raises(ValueError):
        CoreMaintainer.from_graph(g, engine="hosts")


def test_save_load_rebuilds_slot_table(tmp_path):
    """load() leaves the host mirror lazy; on first access it must match
    the live edge set exactly, slot by slot."""
    g = erdos_renyi(50, 150, seed=0)
    m = CoreMaintainer.from_graph(g, capacity=1024)
    ev = next(mixed_stream(g, 1, 20, seed=2))
    m.apply_batch(insert_edges=ev.edges, remove_edges=ev.removals)
    p = str(tmp_path / "state.npz")
    m.save(p)
    m2 = CoreMaintainer.load(p)
    assert m2.slot_cache is None  # mirror not built yet
    assert m2.edge_slot == m.edge_slot
    src = np.asarray(m2.src)
    dst = np.asarray(m2.dst)
    val = np.asarray(m2.valid)
    for (a, b), slot in m2.edge_slot.items():
        assert val[slot]
        assert {int(src[slot]), int(dst[slot])} == {a, b}
    # both continue identically after the round trip
    ins = _sample_absent(build_csr(m.n, np.asarray(sorted(m.edge_slot))),
                         np.random.default_rng(1), 5)
    m.apply_batch(insert_edges=ins)
    m2.apply_batch(insert_edges=ins)
    np.testing.assert_array_equal(m.cores(), m2.cores())


def test_in_program_renumber_gate():
    """The label renumber runs inside the compiled program when headroom
    is exhausted, and reports via stats.renumbered."""
    g = erdos_renyi(40, 160, seed=7)
    m = CoreMaintainer.from_graph(g, capacity=1024)
    st = m.apply_batch(insert_edges=_sample_absent(
        g, np.random.default_rng(3), 4))
    assert not bool(st.renumbered)
    m.label = m.label - (jnp.int64(1) << 61) - 1
    assert bool(needs_renumber(m.label))
    st = m.apply_batch(insert_edges=_sample_absent(
        build_csr(m.n, np.asarray(sorted(m.edge_slot))),
        np.random.default_rng(4), 4))
    assert bool(st.renumbered)
    assert not bool(needs_renumber(m.label))
    diffs = np.diff(np.sort(m.labels()))
    assert (diffs == int(LABEL_GAP)).all()


def test_host_engine_slot_table_survives_midbatch_compaction():
    """Regression: when _compact fires inside _insert_edges_host, the new
    edges must land in the POST-compaction slot mirror (a stale pre-compact
    dict would make the batch invisible to later removals/dedup)."""
    g = erdos_renyi(40, 100, seed=13)
    m = CoreMaintainer.from_graph(g, capacity=g.m + 10, engine="host")
    rng = np.random.default_rng(7)
    edges = g.edge_array()
    rm = edges[rng.choice(edges.shape[0], size=15, replace=False)]
    m.remove_edges(rm)  # tombstones eat the headroom
    cur = remove_edges_csr(g, rm)
    ins = _sample_absent(cur, rng, 18)  # forces _compact mid-insert
    m.insert_edges(ins)
    cur = add_edges_csr(cur, ins)
    for a, b in ins:
        assert (int(a), int(b)) in m.edge_slot
    # removal of a just-inserted edge must actually remove it
    st = m.remove_edges(ins[:3])
    assert int(st.rounds) > 0
    cur = remove_edges_csr(cur, ins[:3])
    np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
    assert m.live_edges == cur.m


def test_capacity_growth_under_unified_stream():
    """Churn through compaction/growth with the sync-free capacity bound."""
    g = erdos_renyi(40, 100, seed=2)
    m = CoreMaintainer.from_graph(g, capacity=int(g.m * 1.4) + 8)
    live = {tuple(e) for e in g.edge_array().tolist()}
    rng = np.random.default_rng(11)
    for _ in range(8):
        lst = sorted(live)
        take = rng.choice(len(lst), size=8, replace=False)
        rm = np.asarray([lst[i] for i in take], dtype=np.int64)
        cur = build_csr(m.n, np.asarray(lst, dtype=np.int64))
        ins = _sample_absent(cur, rng, 8)
        m.apply_batch(insert_edges=ins, remove_edges=rm)
        live.difference_update(map(tuple, rm.tolist()))
        live.update(map(tuple, ins.tolist()))
        cur = build_csr(m.n, np.asarray(sorted(live), dtype=np.int64))
        np.testing.assert_array_equal(m.cores(), bz_from_csr(cur))
    assert m.live_edges == len(live)
