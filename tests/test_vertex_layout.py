"""Unit + traffic tests of the vertex-layout layer (core/vertex_layout.py).

Two kinds of claims:

* algebraic — ``RangeShardedVertices`` round-trips state/masks exactly
  (padding, bit-packing, owner slicing), and ``ReplicatedVertices`` off
  a mesh is the identity, so layout-generic fixpoint code degenerates to
  the original single-device program verbatim;

* traffic — per FIXPOINT ROUND the range layout's collectives are one
  reduce_scatter of the packed stats (each device receives
  O(n / n_shards) words — O(n) mesh-wide) plus bit-packed changed-vertex
  masks (ceil(n_owned / 8) bytes per shard per device), where the
  replicated layout psums the full [n]-sized stats to every device
  (O(n * n_shards) mesh-wide). Asserted from the trace-time accounting
  (``record_traffic``): a ``lax.while_loop`` body traces exactly once,
  so the records ARE the per-round collective budget — this is the
  acceptance check of the O(n + frontier-bits * d) traffic model
  (docs/DESIGN.md §4.2), and it runs without executing a single batch.
  The 8-shard numbers are pinned by the slow subprocess test below.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import cross_check_round, primitive_names
from repro.analysis.programs import trace_removal_round
from repro.compat import shard_map
from repro.core.vertex_layout import (
    RangeShardedVertices,
    ReplicatedVertices,
    make_layout,
    record_traffic,
)


def test_replicated_layout_is_identity_off_mesh():
    lay = ReplicatedVertices(7)
    x = jnp.arange(7, dtype=jnp.int32)
    m = x > 3
    assert lay.complete(x) is x
    assert lay.own(x) is x
    assert lay.gather_mask(m) is m
    assert lay.gather_state(x) is x
    assert bool(lay.any_owned(m))
    np.testing.assert_array_equal(
        np.asarray(lay.add_at(lay.zeros(), jnp.array([1, 1, 6]),
                              jnp.array([2, 3, 4], jnp.int32))),
        np.array([0, 5, 0, 0, 0, 0, 4], np.int32),
    )


def test_make_layout_factory():
    assert make_layout("replicated", 5, None).kind == "replicated"
    lay = make_layout("range", 10, "data", 4)
    assert lay.kind == "range" and lay.n_owned == 3 and lay.n_pad == 12
    assert lay.frontier_cap is None
    assert make_layout("range", 10, "data", 4, 8).frontier_cap == 8
    with pytest.raises(ValueError):
        make_layout("range", 5, None)
    with pytest.raises(ValueError):
        make_layout("diagonal", 5, "data")


def test_make_layout_rejects_misconfiguration_at_construction():
    """The replicated layout has no shard ranges and no frontier: a
    silently ignored n_shards/frontier_cap would hide a caller that
    believes it built a sharded or sparse layout — both raise HERE, not
    three layers down at trace time."""
    with pytest.raises(ValueError, match="n_shards"):
        make_layout("replicated", 10, "data", 8)
    with pytest.raises(ValueError, match="frontier_cap"):
        make_layout("replicated", 10, "data", 1, 16)
    # the sparse bucket must be able to hold at least one index
    with pytest.raises(ValueError, match="frontier_cap"):
        make_layout("range", 10, "data", 2, 0)
    with pytest.raises(ValueError, match="frontier_cap"):
        make_layout("range", 10, "data", 2, -4)


def test_record_traffic_nesting_raises_and_outer_survives():
    """Nested record_traffic() used to silently steal the outer
    context's records; now the inner entry raises and the outer log
    keeps accumulating afterwards, intact."""
    lay = RangeShardedVertices(16, "data", 1)
    mesh = jax.make_mesh((1,), ("data",))

    def kernel(stats):
        return lay.complete(stats)

    sm = shard_map(kernel, mesh=mesh, in_specs=(P(),),
                   out_specs=P("data"), check_vma=False)
    with record_traffic() as outer:
        jax.make_jaxpr(sm)(jnp.zeros(16, jnp.int32))
        n_before = len(outer)
        assert n_before == 1
        with pytest.raises(RuntimeError, match="nest"):
            with record_traffic():
                pass  # pragma: no cover — entry must raise
        # the outer context still owns the log: more records land in it
        # (a different dtype forces a genuinely fresh trace — an
        # identical call could be served from the trace cache)
        jax.make_jaxpr(sm)(jnp.zeros(16, jnp.int64))
        assert len(outer) == n_before + 1
        assert all(t.op == "reduce_scatter" for t in outer)
    # fully unwound: a fresh context starts empty and records again
    # (again a fresh dtype, to dodge the trace cache)
    with record_traffic() as log2:
        jax.make_jaxpr(sm)(jnp.zeros(16, jnp.float32))
    assert [t.op for t in log2] == ["reduce_scatter"]


def test_range_layout_roundtrips_one_shard():
    """Pad/pack/slice bookkeeping on a 1-shard mesh with n not a byte
    multiple: complete == plain sum, gather(own(x)) == x, and the
    bit-packed mask round-trips exactly."""
    mesh = jax.make_mesh((1,), ("data",))
    n = 13
    lay = RangeShardedVertices(n, "data", 1)
    assert lay.n_owned == 13 and lay.n_pad == 13

    def kernel(stats, full, mask_bits):
        owned = lay.complete(stats)
        state = lay.gather_state(lay.own(full))
        mask = lay.gather_mask(lay.own(mask_bits))
        delta = lay.add_at(lay.zeros(), jnp.array([0, 12, 12]),
                           jnp.array([5, 1, 1], jnp.int32))
        return owned, state, mask, delta, lay.any_owned(lay.own(mask_bits))

    f = shard_map(
        kernel, mesh=mesh, in_specs=(P(), P(), P()),
        out_specs=(P("data"), P(), P(), P("data"), P()), check_vma=False,
    )
    stats = jnp.arange(n, dtype=jnp.int32)
    full = jnp.arange(n, dtype=jnp.int64) * 7 - 3
    mask = (jnp.arange(n) % 3) == 0
    owned, state, got_mask, delta, some = jax.jit(f)(stats, full, mask)
    np.testing.assert_array_equal(np.asarray(owned), np.asarray(stats))
    np.testing.assert_array_equal(np.asarray(state), np.asarray(full))
    np.testing.assert_array_equal(np.asarray(got_mask), np.asarray(mask))
    assert int(delta[0]) == 5 and int(delta[12]) == 2
    assert bool(some)


def test_per_round_traffic_replicated_vs_range():
    """The acceptance traffic model on a 1-shard mesh: the replicated
    layout psums the full [n, 3] stats each round; the range layout
    replaces that with ONE reduce_scatter (owned words) + ONE bit-packed
    mask gather — no [n]-sized integer array crosses the mesh inside a
    round. (The 8-shard byte counts are pinned by the subprocess test.)
    """
    n, cap = 24, 32
    mesh = jax.make_mesh((1,), ("data",))

    rep_log, rep_jx = trace_removal_round("replicated", n, cap, mesh)
    rng_log, rng_jx = trace_removal_round("range", n, cap, mesh)
    rep_prims = primitive_names(rep_jx)
    rng_prims = primitive_names(rng_jx)

    # replicated: exactly one vertex collective per round — the [n, 3]
    # int32 psum, every device receiving the full completed stats
    assert [t.op for t in rep_log] == ["psum"]
    assert rep_log[0].recv_bytes == n * 3 * 4
    assert "reduce_scatter" not in rep_prims

    # range: the stats arrive by reduce_scatter (owned slice only), the
    # decision comes back as a bit-packed mask, and nothing else moves
    assert [t.op for t in rng_log] == ["reduce_scatter", "gather_mask"]
    rs, gm = rng_log
    lay = RangeShardedVertices(n, "data", 1)
    assert rs.recv_bytes == lay.n_owned * 3 * 4
    assert gm.recv_bytes == 1 * -(-lay.n_owned // 8)  # n_shards * bytes
    # the collective-count cross-check straight off the jaxpr: the range
    # program really lowers to reduce_scatter + all_gather, and contains
    # no full-stat psum
    assert {"reduce_scatter", "all_gather"} <= rng_prims
    assert "psum" not in rng_prims
    # and the trace-time accounting above describes the REAL program,
    # collective by collective (op mapping + payload bytes)
    assert cross_check_round(rng_log, rng_jx) == []


def test_sparse_mask_roundtrip_across_overflow_boundary():
    """The compacted-index exchange reproduces the mask EXACTLY at every
    frontier size — empty, below, exactly at, and above the cap (where
    the in-program lax.cond falls back to the bitmask)."""
    mesh = jax.make_mesh((1,), ("data",))
    n, cap = 13, 4
    lay = RangeShardedVertices(n, "data", 1, frontier_cap=cap)

    f = jax.jit(shard_map(
        lambda m: lay.gather_mask(lay.own(m)), mesh=mesh,
        in_specs=(P(),), out_specs=P(), check_vma=False,
    ))
    rng = np.random.default_rng(3)
    for k in (0, cap - 1, cap, cap + 1, n):  # straddle the fallback
        mask = np.zeros(n, dtype=bool)
        mask[rng.choice(n, size=k, replace=False)] = True
        got = np.asarray(f(jnp.asarray(mask)))
        np.testing.assert_array_equal(got, mask, err_msg=f"frontier={k}")


def test_per_round_traffic_sparse_frontier():
    """ACCEPTANCE (docs/DESIGN.md §4.3): a sparse range-sharded removal
    round moves ONE reduce_scatter (owned stat words) + ONE
    O(cap * n_shards)-word index gather, and NO vertex-sized collective
    on the non-overflow branch — the bitmask gather exists only inside
    the overflow arm of the per-round lax.cond (branch="overflow").
    (The 8-shard byte counts are pinned by the subprocess test.)"""
    n, cap, fcap = 24, 32, 8
    mesh = jax.make_mesh((1,), ("data",))
    log, jaxpr = trace_removal_round("range", n, cap, mesh,
                                     frontier_cap=fcap)
    prims = primitive_names(jaxpr)

    lay = RangeShardedVertices(n, "data", 1, frontier_cap=fcap)
    main = [t for t in log if t.branch != "overflow"]
    fallback = [t for t in log if t.branch == "overflow"]
    # non-overflow round budget: stats in by reduce_scatter, frontier
    # out as count-prefixed indices — O(cap * d) words, n-independent
    assert [t.op for t in main] == ["reduce_scatter", "gather_frontier"]
    rs, gf = main
    assert rs.recv_bytes == lay.n_owned * 3 * 4
    assert gf.recv_bytes == 1 * (fcap + 1) * 4  # n_shards * (cap+1) words
    # nothing on the main branch scales with n beyond the owned stats:
    # the frontier payload must be strictly smaller than even ONE
    # vertex-sized int column would be at scale (here: it is cap-sized)
    assert all(t.recv_bytes <= max(rs.recv_bytes, gf.recv_bytes)
               for t in main)
    # the ONLY bitmask gather lives on the overflow branch
    assert [t.op for t in fallback] == ["gather_mask"]
    assert fallback[0].recv_bytes == 1 * -(-lay.n_owned // 8)
    # jaxpr cross-check: still reduce_scatter + all_gathers, no psum,
    # and the traffic notes match the program collective-by-collective
    # (branch attribution included — the overflow gather must sit on the
    # cond's overflow arm in the jaxpr too)
    assert {"reduce_scatter", "all_gather"} <= prims
    assert "psum" not in prims
    assert cross_check_round(log, jaxpr) == []


_TRAFFIC_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax

    import repro  # enables x64
    from repro.analysis import cross_check_round
    from repro.analysis.programs import trace_removal_round

    n, cap, d, fcap = 240, 512, 8, 8
    mesh = jax.make_mesh((8,), ("data",))
    rep_log, rep_jx = trace_removal_round("replicated", n, cap, mesh)
    rng_log, rng_jx = trace_removal_round("range", n, cap, mesh)
    sp_log, sp_jx = trace_removal_round("range", n, cap, mesh,
                                        frontier_cap=fcap)

    [psum] = rep_log
    rs, gm = rng_log
    # replicated: O(n) received per device, O(n * d) mesh-wide
    assert psum.recv_bytes == n * 3 * 4, psum
    # range: O(n / d) stat words per device -> O(n) mesh-wide ...
    assert rs.recv_bytes == (n // d) * 3 * 4, rs
    assert rs.recv_bytes * d == n * 3 * 4
    # ... plus the frontier bitmask: ceil(n/d/8) bytes per shard per
    # device — n bits per device, d * n BITS mesh-wide
    assert gm.recv_bytes == d * (-(-(n // d) // 8)), gm
    # the whole-mesh round budget: 8x fewer integer bytes, and the mask
    # adds only bits
    mesh_rep = psum.recv_bytes * d
    mesh_rng = rs.recv_bytes * d + gm.recv_bytes * d
    assert mesh_rng * 4 < mesh_rep, (mesh_rng, mesh_rep)

    # sparse frontier exchange (docs/DESIGN.md S4.3): the non-overflow
    # round is ONE reduce_scatter + ONE O(cap * d)-word index gather —
    # NO vertex-sized collective; the bitmask gather exists only on the
    # overflow arm of the per-round lax.cond. The gather payload is
    # d * (cap + 1) words, INDEPENDENT of n — on this toy n=240 the
    # bitmask is still cheaper (crossover at frontier < n/256), which
    # is exactly why the cap is a knob and the bitmask the fallback.
    main = [t for t in sp_log if t.branch != "overflow"]
    over = [t for t in sp_log if t.branch == "overflow"]
    assert [t.op for t in main] == ["reduce_scatter", "gather_frontier"], main
    assert main[0].recv_bytes == (n // d) * 3 * 4, main
    assert main[1].recv_bytes == d * (fcap + 1) * 4, main
    assert [t.op for t in over] == ["gather_mask"], over
    assert over[0].recv_bytes == gm.recv_bytes, over
    # the accounting above must describe the traced programs exactly
    # (op mapping, payload bytes, overflow-branch attribution) at 8
    # shards too, not just on the 1-shard mesh of the fast tests
    for log, jx in ((rep_log, rep_jx), (rng_log, rng_jx),
                    (sp_log, sp_jx)):
        mismatches = cross_check_round(log, jx)
        assert mismatches == [], mismatches
    print("traffic-8dev OK", mesh_rep, mesh_rng,
          main[1].recv_bytes * d)
    """
)


@pytest.mark.slow
def test_per_round_traffic_8_shards(tmp_path):
    """8 forced host devices: the per-round byte counts of both layouts,
    asserted from trace-time accounting (no batch is executed)."""
    script = tmp_path / "traffic8.py"
    script.write_text(_TRAFFIC_8DEV)
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(os.path.join(here, "..", "src")),
         os.path.abspath(here)]
    )
    out = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "traffic-8dev OK" in out.stdout


def test_vertex_sharding_needs_sharded_engine():
    from repro.core.api import CoreMaintainer
    from repro.graph.generators import erdos_renyi

    g = erdos_renyi(20, 40, seed=0)
    with pytest.raises(ValueError, match="vertex_sharding"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  vertex_sharding="range")
    with pytest.raises(ValueError, match="freelist"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  freelist="magic")
    # hierarchical ranking only differs across shards: accepting it on
    # the other engines would silently do nothing, so it must raise too
    with pytest.raises(ValueError, match="hierarchical"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  freelist="hierarchical")


def test_engine_config_matrix_rejected_at_construction():
    """Every invalid engine-configuration combination raises a
    construction-time ValueError NAMING the offending field — none may
    survive to a deep trace-time error or be silently ignored."""
    from repro.core.api import CoreMaintainer
    from repro.graph.generators import erdos_renyi

    g = erdos_renyi(20, 40, seed=0)
    bad = [
        (dict(engine="warp"), "engine"),
        (dict(vertex_sharding="diagonal"), "vertex_sharding"),
        (dict(freelist="magic"), "freelist"),
        (dict(frontier_exchange="rle"), "frontier_exchange"),
        # a mesh passed to an engine that never reads it
        (dict(engine="unified", mesh=jax.make_mesh((1,), ("data",))),
         "mesh"),
        (dict(engine="host", mesh=jax.make_mesh((1,), ("data",))),
         "mesh"),
        # combinations whose silent acceptance would do nothing
        (dict(engine="unified", vertex_sharding="range"),
         "vertex_sharding"),
        (dict(engine="host", freelist="hierarchical"), "hierarchical"),
        (dict(engine="sharded", frontier_exchange="sparse"),
         "frontier_exchange"),  # sparse without range vertex state
        (dict(engine="unified", frontier_exchange="sparse"),
         "frontier_exchange"),
        (dict(engine="sharded", vertex_sharding="range",
              frontier_cap=64), "frontier_cap"),  # cap without sparse
        (dict(engine="sharded", vertex_sharding="range",
              frontier_exchange="sparse", frontier_cap=-2),
         "frontier_cap"),
    ]
    for kw, field in bad:
        with pytest.raises(ValueError, match=field):
            CoreMaintainer.from_graph(g, capacity=128, **kw)
    # the valid corners of the matrix still construct
    CoreMaintainer.from_graph(g, capacity=128, engine="sharded",
                              vertex_sharding="range",
                              frontier_exchange="sparse")
    CoreMaintainer.from_graph(g, capacity=128, engine="sharded",
                              vertex_sharding="range",
                              frontier_exchange="sparse", frontier_cap=16)


def test_make_sharded_apply_rejects_bad_frontier_config():
    from repro.core.sharded import make_sharded_apply

    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="frontier_exchange"):
        make_sharded_apply(mesh, 16, 18, frontier_exchange="rle")
    with pytest.raises(ValueError, match="frontier_exchange"):
        make_sharded_apply(mesh, 16, 18, frontier_exchange="sparse")
    with pytest.raises(ValueError, match="frontier_cap"):
        make_sharded_apply(mesh, 16, 18, vertex_sharding="range",
                           frontier_exchange="sparse", frontier_cap=0)
    # a cap the bitmask exchange would silently ignore must raise too
    with pytest.raises(ValueError, match="frontier_cap"):
        make_sharded_apply(mesh, 16, 18, vertex_sharding="range",
                           frontier_cap=64)


def test_local_active_window_cannot_outrun_the_shard():
    """An oversized per-shard window (e.g. sized from the GLOBAL
    high-water mark) used to slice past the local shard and silently
    splice a SHORT slot table back together; it must raise loudly at
    the window boundary instead. The exact-boundary window still runs."""
    from repro.core.sharded import make_sharded_apply

    mesh = jax.make_mesh((1,), ("data",))
    n, cap = 8, 16

    def fresh_args():  # the engine donates its buffers — one set per call
        b = jnp.zeros(4, jnp.int32)
        ok = jnp.zeros(4, bool)
        return (jnp.zeros(cap, jnp.int32), jnp.zeros(cap, jnp.int32),
                jnp.zeros(cap, bool), jnp.zeros(n, jnp.int32),
                jnp.zeros(n, jnp.int64), jnp.int32(0),
                b, b, ok, b, b, ok)

    # window == per-shard capacity: legal, runs
    fn = make_sharded_apply(mesh, n, n + 2, local_active=cap)
    out = fn(*fresh_args())
    assert out[0].shape == (cap,)

    # one past the shard: loud ValueError naming the misconfiguration
    fn = make_sharded_apply(mesh, n, n + 2, local_active=cap + 1)
    with pytest.raises(ValueError, match="local_active"):
        fn(*fresh_args())
