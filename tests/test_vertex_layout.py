"""Unit + traffic tests of the vertex-layout layer (core/vertex_layout.py).

Two kinds of claims:

* algebraic — ``HaloShardedVertices`` round-trips owned state through
  the halo working set exactly (bind, regather, stat completion, sparse
  refresh with its overflow fallback — bit-identical at every frontier
  size), and ``ReplicatedVertices`` off a mesh is the identity, so
  layout-generic fixpoint code degenerates to the original
  single-device program verbatim;

* traffic — per FIXPOINT ROUND the halo layout's collectives are one
  bounded all_gather of halo-domain partial stats (O(d_v * halo_cap)
  words), the O(n_owned) ring placement ppermutes, and halo refreshes
  that are either sparse compacted-index gathers (O(cap * d_v) words)
  or a dense reduce_scatter regather (O(halo_cap)); the replicated
  layout psums the full [n]-sized stats to every device. Asserted from
  the trace-time accounting (``record_traffic``): a ``lax.while_loop``
  body traces exactly once, so the records ARE the per-round collective
  budget — the acceptance check of the §4.3/§4.4 traffic model, run
  without executing a single batch. The 8-shard and 2-axis numbers are
  pinned by the slow subprocess test below.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import cross_check_round, primitive_names
from repro.analysis.programs import trace_removal_round
from repro.compat import shard_map
from repro.core.vertex_layout import (
    HaloShardedVertices,
    ReplicatedVertices,
    make_layout,
    record_traffic,
)


def test_replicated_layout_is_identity_off_mesh():
    lay = ReplicatedVertices(7)
    x = jnp.arange(7, dtype=jnp.int32)
    m = x > 3
    assert lay.complete(x) is x
    assert lay.own(x) is x
    assert lay.gather_mask(m) is m
    assert lay.gather_state(x) is x
    assert bool(lay.any_owned(m))
    np.testing.assert_array_equal(
        np.asarray(lay.add_at(lay.zeros(), jnp.array([1, 1, 6]),
                              jnp.array([2, 3, 4], jnp.int32))),
        np.array([0, 5, 0, 0, 0, 0, 4], np.int32),
    )


def test_make_layout_factory():
    assert make_layout("replicated", 5, None).kind == "replicated"
    lay = make_layout("range", 10, "data", 4)
    assert isinstance(lay, HaloShardedVertices)
    assert lay.kind == "halo" and lay.n_owned == 3 and lay.n_pad == 12
    assert lay.frontier_cap is None and lay.edge_axes == ()
    assert make_layout("range", 10, "data", 4, 8).frontier_cap == 8
    two = make_layout("halo", 10, "data", 2, None, ("edge",))
    assert two.edge_axes == ("edge",) and two.n_owned == 5
    with pytest.raises(ValueError):
        make_layout("range", 5, None)
    with pytest.raises(ValueError):
        make_layout("diagonal", 5, "data")


def test_make_layout_rejects_misconfiguration_at_construction():
    """The replicated layout has no shard ranges and no frontier: a
    silently ignored n_shards/frontier_cap would hide a caller that
    believes it built a sharded or sparse layout — both raise HERE, not
    three layers down at trace time. Same for the range/halo split: the
    1-axis range layout must refuse pure-edge axes, and the 2-axis halo
    layout must refuse to run without them."""
    with pytest.raises(ValueError, match="n_shards"):
        make_layout("replicated", 10, "data", 8)
    with pytest.raises(ValueError, match="frontier_cap"):
        make_layout("replicated", 10, "data", 1, 16)
    with pytest.raises(ValueError, match="edge_axes"):
        make_layout("replicated", 10, "data", 1, None, ("edge",))
    # the sparse bucket must be able to hold at least one index
    with pytest.raises(ValueError, match="frontier_cap"):
        make_layout("range", 10, "data", 2, 0)
    with pytest.raises(ValueError, match="frontier_cap"):
        make_layout("range", 10, "data", 2, -4)
    # range <-> halo are the edge_axes=()/edge_axes=(...) halves
    with pytest.raises(ValueError, match="halo"):
        make_layout("range", 10, "data", 2, None, ("edge",))
    with pytest.raises(ValueError, match="edge axes"):
        make_layout("halo", 10, "data", 2)


def _full_halo_ids(n: int, n_pad: int, hcap: int) -> jnp.ndarray:
    """A 1-shard halo covering every vertex, sentinel-padded to hcap."""
    return jnp.concatenate([
        jnp.arange(n, dtype=jnp.int32),
        jnp.full((hcap - n,), n_pad, dtype=jnp.int32),
    ])


def test_record_traffic_nesting_raises_and_outer_survives():
    """Nested record_traffic() used to silently steal the outer
    context's records; now the inner entry raises and the outer log
    keeps accumulating afterwards, intact."""
    lay = make_layout("range", 16, "data", 1)
    mesh = jax.make_mesh((1,), ("data",))

    def kernel(ids, owned):
        return lay.bind(ids).gather_values(owned)

    sm = shard_map(kernel, mesh=mesh, in_specs=(P(), P("data")),
                   out_specs=P(), check_vma=False)
    ids = _full_halo_ids(16, 16, 16)
    with record_traffic() as outer:
        jax.make_jaxpr(sm)(ids, jnp.zeros(16, jnp.int32))
        n_before = len(outer)
        assert [t.op for t in outer] == ["gather_halo", "regather"]
        with pytest.raises(RuntimeError, match="nest"):
            with record_traffic():
                pass  # pragma: no cover — entry must raise
        # the outer context still owns the log: more records land in it
        # (a different dtype forces a genuinely fresh trace — an
        # identical call could be served from the trace cache)
        jax.make_jaxpr(sm)(ids, jnp.zeros(16, jnp.int64))
        assert len(outer) == n_before + 2
    # fully unwound: a fresh context starts empty and records again
    # (again a fresh dtype, to dodge the trace cache)
    with record_traffic() as log2:
        jax.make_jaxpr(sm)(ids, jnp.zeros(16, jnp.float32))
    assert [t.op for t in log2] == ["gather_halo", "regather"]


def test_halo_session_roundtrips_one_shard():
    """Bind/regather/complete bookkeeping on a 1-shard mesh with n not
    a pow2: halo values are exact images of the owned state, halo-domain
    partial stats complete back to the exact owned sums, and the
    owner-drop scatter-add lands replicated contributions correctly."""
    mesh = jax.make_mesh((1,), ("data",))
    n, hcap = 13, 16
    lay = make_layout("range", n, "data", 1)
    assert lay.n_owned == 13 and lay.n_pad == 13
    all_ids = jnp.arange(n, dtype=jnp.int32)

    def kernel(ids, core, mask):
        sess = lay.bind(ids)
        core_h = sess.gather_values(core)
        pos = sess.locate(all_ids)
        # halo-domain partials: vertex i contributes i at its halo slot
        stats = jnp.zeros(hcap, jnp.int32).at[pos].add(all_ids)
        owned_stats = sess.complete(stats)
        halo_mask, ovf = sess.refresh_mask(mask)
        delta = sess.add_at(sess.zeros(), jnp.array([0, 12, 12]),
                            jnp.array([5, 1, 1], jnp.int32))
        return (core_h, pos, owned_stats, halo_mask, ovf, delta,
                sess.any_owned(mask))

    f = shard_map(
        kernel, mesh=mesh, in_specs=(P(), P("data"), P("data")),
        out_specs=(P(), P(), P("data"), P(), P(), P("data"), P()),
        check_vma=False,
    )
    ids = _full_halo_ids(n, lay.n_pad, hcap)
    core = jnp.arange(n, dtype=jnp.int32) * 7 - 3
    mask = (jnp.arange(n) % 3) == 0
    core_h, pos, owned_stats, halo_mask, ovf, delta, some = (
        jax.jit(f)(ids, core, mask))
    np.testing.assert_array_equal(
        np.asarray(core_h)[np.asarray(pos)], np.asarray(core))
    np.testing.assert_array_equal(np.asarray(owned_stats),
                                  np.arange(n, dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(halo_mask)[np.asarray(pos)], np.asarray(mask))
    assert not bool(ovf)  # dense refresh never overflows
    assert int(delta[0]) == 5 and int(delta[12]) == 2
    assert bool(some)


def test_per_round_traffic_replicated_vs_range():
    """The acceptance traffic model on a 1-shard mesh: the replicated
    layout psums the full [n, 3] stats each round; the halo layout pays
    a one-time per-batch setup (halo-membership gather + entry
    regathers) and then, per round, ONE bounded halo-stat gather, the
    O(n_owned) ring placement, and a dense O(halo_cap) value regather —
    no [n]-replicated buffer anywhere. (The 8-shard and 2-axis byte
    counts are pinned by the subprocess test.)"""
    n, cap = 24, 32
    mesh = jax.make_mesh((1,), ("data",))

    rep_log, rep_jx = trace_removal_round("replicated", n, cap, mesh)
    rng_log, rng_jx = trace_removal_round("range", n, cap, mesh)
    rep_prims = primitive_names(rep_jx)
    rng_prims = primitive_names(rng_jx)

    # replicated: exactly one vertex collective per round — the [n, 3]
    # int32 psum, every device receiving the full completed stats
    assert [t.op for t in rep_log] == ["psum"]
    assert rep_log[0].recv_bytes == n * 3 * 4
    assert "reduce_scatter" not in rep_prims

    # halo (hcap = n_pad = 24 on this toy: the pow2 bucket clamps to n):
    # setup = membership gather + core/label entry regathers, then the
    # round: stat gather, 5 ring ppermutes, dense core/label refresh,
    # scalar continue-vote
    lay = make_layout("range", n, "data", 1)
    hcap = 24
    assert [t.op for t in rng_log] == (
        ["gather_halo", "regather", "regather"]          # per-batch setup
        + ["gather_stats"] + ["ppermute"] * 5            # round: stats+ring
        + ["regather", "regather", "psum_scalar"]        # round: refresh
    )
    setup, main = rng_log[:3], rng_log[3:]
    assert setup[0].recv_bytes == 1 * hcap * 4           # d_v * hcap ids
    assert (setup[1].recv_bytes, setup[2].recv_bytes) == (
        hcap * 4, hcap * 8)                              # core, label
    assert main[0].recv_bytes == 1 * hcap * 3 * 4        # d_v * hcap * 3
    assert (main[6].recv_bytes, main[7].recv_bytes) == (
        hcap * 4, hcap * 8)                              # dense refresh
    assert all(t.recv_bytes <= lay.n_owned * 2 * 4
               for t in main if t.op == "ppermute")
    # the collective-count cross-check straight off the jaxpr: the halo
    # program really lowers to all_gather + reduce_scatter + ppermute,
    # and contains no full-stat [n]-psum (the only psum is the scalar
    # continue-vote)
    assert {"reduce_scatter", "all_gather", "ppermute"} <= rng_prims
    # and the trace-time accounting above describes the REAL program,
    # collective by collective (op mapping + payload bytes)
    assert cross_check_round(rng_log, rng_jx) == []


@pytest.mark.parametrize("k_mode", ["empty", "cap-1", "cap", "cap+1", "all"])
def test_sparse_refresh_roundtrip_across_overflow_boundary(k_mode):
    """The sparse halo refresh reproduces the dense result EXACTLY at
    every frontier size — empty, below, exactly at, and above the cap
    (where the in-program lax.cond falls back to the dense regather):
    masks AND (core, label) value refreshes, bit for bit."""
    mesh = jax.make_mesh((1,), ("data",))
    n, cap, hcap = 13, 4, 16
    k = {"empty": 0, "cap-1": cap - 1, "cap": cap,
         "cap+1": cap + 1, "all": n}[k_mode]
    lay = make_layout("range", n, "data", 1, frontier_cap=cap)
    all_ids = jnp.arange(n, dtype=jnp.int32)

    def kernel(ids, old_core, old_label, new_core, new_label, changed):
        sess = lay.bind(ids)
        pos = sess.locate(all_ids)
        # stale halo = exact image of the pre-commit state
        core_h = sess.gather_values(old_core)
        label_h = sess.gather_values(old_label)
        halo_mask, m_ovf = sess.refresh_mask(changed)
        core_h, label_h, v_ovf = sess.refresh_values(
            new_core, new_label, changed, core_h, label_h)
        return pos, halo_mask, core_h, label_h, m_ovf, v_ovf

    f = jax.jit(shard_map(
        kernel, mesh=mesh,
        in_specs=(P(),) + (P("data"),) * 5,
        out_specs=(P(), P(), P(), P(), P(), P()), check_vma=False,
    ))
    rng = np.random.default_rng(3 + k)
    changed = np.zeros(n, dtype=bool)
    changed[rng.choice(n, size=k, replace=False)] = True
    old_core = rng.integers(0, 50, n).astype(np.int32)
    old_label = rng.integers(0, 1 << 40, n).astype(np.int64)
    new_core = np.where(changed, old_core + 1, old_core).astype(np.int32)
    new_label = np.where(changed, old_label + 7, old_label).astype(np.int64)

    ids = _full_halo_ids(n, lay.n_pad, hcap)
    pos, halo_mask, core_h, label_h, m_ovf, v_ovf = f(
        ids, jnp.asarray(old_core), jnp.asarray(old_label),
        jnp.asarray(new_core), jnp.asarray(new_label),
        jnp.asarray(changed))
    pos = np.asarray(pos)
    np.testing.assert_array_equal(np.asarray(halo_mask)[pos], changed,
                                  err_msg=f"frontier={k}")
    # the refreshed halo is an exact image of the committed state —
    # sparse path and overflow fallback alike
    np.testing.assert_array_equal(np.asarray(core_h)[pos], new_core,
                                  err_msg=f"frontier={k}")
    np.testing.assert_array_equal(np.asarray(label_h)[pos], new_label,
                                  err_msg=f"frontier={k}")
    assert bool(m_ovf) == (k > cap)
    assert bool(v_ovf) == (k > cap)


def test_per_round_traffic_sparse_frontier():
    """ACCEPTANCE (docs/DESIGN.md §4.3): a sparse halo removal round
    refreshes with THREE O(cap * d_v)-word compacted-index gathers —
    count-prefixed ids, cores, labels — and the dense O(halo_cap)
    regather exists only inside the overflow arm of the per-round
    lax.cond (branch="overflow"); nothing [n]-sized ever moves.
    (The 8-shard byte counts are pinned by the subprocess test.)"""
    n, cap, fcap = 24, 32, 8
    mesh = jax.make_mesh((1,), ("data",))
    log, jaxpr = trace_removal_round("range", n, cap, mesh,
                                     frontier_cap=fcap)
    prims = primitive_names(jaxpr)

    hcap = 24
    main = [t for t in log if t.branch != "overflow"]
    fallback = [t for t in log if t.branch == "overflow"]
    # setup + non-overflow round budget: stats by bounded gather, the
    # refresh as count-prefixed indices — O(cap * d_v) words,
    # n-independent
    assert [t.op for t in main] == (
        ["gather_halo", "regather", "regather"]
        + ["gather_stats"] + ["ppermute"] * 5
        + ["gather_frontier"] * 3 + ["psum_scalar"]
    )
    gi, gc, gl = [t for t in main if t.op == "gather_frontier"]
    assert gi.recv_bytes == 1 * (fcap + 1) * 4  # d_v * (cap+1) words
    assert gc.recv_bytes == 1 * fcap * 4        # d_v * cap int32 cores
    assert gl.recv_bytes == 1 * fcap * 8        # d_v * cap int64 labels
    # the ONLY dense halo regather lives on the overflow branch
    assert [t.op for t in fallback] == ["regather", "regather"]
    assert (fallback[0].recv_bytes, fallback[1].recv_bytes) == (
        hcap * 4, hcap * 8)
    # jaxpr cross-check: all_gathers + reduce_scatters, and the traffic
    # notes match the program collective-by-collective (branch
    # attribution included — the dense regather must sit on the cond's
    # overflow arm in the jaxpr too)
    assert {"reduce_scatter", "all_gather"} <= prims
    assert cross_check_round(log, jaxpr) == []


_TRAFFIC_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax

    import repro  # enables x64
    from repro.analysis import cross_check_round
    from repro.analysis.programs import trace_removal_round
    from repro.launch.mesh import make_edge_vertex_mesh

    n, cap, d, fcap, w = 2048, 4096, 8, 8, 16
    hcap = 64  # pow2(2*w + 2*lanes_total) = pow2(64), lanes=8
    mesh = jax.make_mesh((8,), ("data",))
    rep_log, rep_jx = trace_removal_round("replicated", n, cap, mesh,
                                          window=w)
    rng_log, rng_jx = trace_removal_round("range", n, cap, mesh,
                                          window=w)
    sp_log, sp_jx = trace_removal_round("range", n, cap, mesh,
                                        frontier_cap=fcap, window=w)
    # the SAME 8 devices factored as 4 edge shards x 2 vertex ranges
    mesh42 = make_edge_vertex_mesh(8, (4, 2), axis="data",
                                   edge_axis="edge")
    h_log, h_jx = trace_removal_round("halo", n, cap, mesh42, window=w)

    [psum] = rep_log
    # replicated: O(n) received per device, O(n * d) mesh-wide
    assert psum.recv_bytes == n * 3 * 4, psum

    def split(log):
        setup, main, over = log[:3], [], []
        for t in log[3:]:
            (over if t.branch == "overflow" else main).append(t)
        return setup, main, over

    # range on the shared axis: d_v = 8 vertex ranges
    setup, main, over = split(rng_log)
    assert [t.op for t in setup] == ["gather_halo", "regather",
                                     "regather"], setup
    assert setup[0].recv_bytes == d * hcap * 4, setup
    assert [t.op for t in main] == (
        ["gather_stats"] + ["ppermute"] * 5
        + ["regather", "regather", "psum_scalar"]), main
    assert main[0].recv_bytes == d * hcap * 3 * 4, main
    assert over == [], over
    # the whole per-round working set is O(n/d + hcap * d): every round
    # collective undercuts the replicated [n]-psum per device ...
    assert all(t.recv_bytes < psum.recv_bytes for t in main), main
    # ... and so does the round total, mesh-wide
    assert sum(t.recv_bytes for t in main) * d < psum.recv_bytes * d

    # 2-axis halo (d_e, d_v) = (4, 2): the halo-stat gather spans the
    # OWNER axis only — its payload shrinks from d*hcap to d_v*hcap
    # words — and the edge partials complete with one psum over the
    # pure-edge axis of the OWNED slice (n/d_v, never n)
    hsetup, hmain, hover = split(h_log)
    d_v = 2
    assert hsetup[0].recv_bytes == d_v * hcap * 4, hsetup
    assert [t.op for t in hmain[:2]] == ["gather_stats", "psum_edge"], hmain
    assert hmain[0].recv_bytes == d_v * hcap * 3 * 4, hmain
    assert hmain[1].recv_bytes == (n // d_v) * 3 * 4, hmain
    assert hover == [], hover

    # sparse frontier exchange (docs/DESIGN.md S4.3): the non-overflow
    # refresh is THREE O(cap * d)-word compacted gathers, INDEPENDENT
    # of n; the dense O(hcap) regather only moves on the overflow arm
    ssetup, smain, sover = split(sp_log)
    gf = [t for t in smain if t.op == "gather_frontier"]
    assert [t.recv_bytes for t in gf] == [
        d * (fcap + 1) * 4, d * fcap * 4, d * fcap * 8], gf
    assert [t.op for t in sover] == ["regather", "regather"], sover
    assert [t.recv_bytes for t in sover] == [hcap * 4, hcap * 8], sover

    # the accounting above must describe the traced programs exactly
    # (op mapping, payload bytes, overflow-branch attribution) at 8
    # shards and on the 2-axis mesh too, not just the 1-shard fast path
    for log, jx in ((rep_log, rep_jx), (rng_log, rng_jx),
                    (sp_log, sp_jx), (h_log, h_jx)):
        mismatches = cross_check_round(log, jx)
        assert mismatches == [], mismatches
    print("traffic-8dev OK",
          psum.recv_bytes, sum(t.recv_bytes for t in main),
          sum(t.recv_bytes for t in hmain))
    """
)


@pytest.mark.slow
def test_per_round_traffic_8_shards(tmp_path):
    """8 forced host devices: the per-round byte counts of the
    replicated, range, sparse, and 2-axis halo layouts, asserted from
    trace-time accounting (no batch is executed)."""
    script = tmp_path / "traffic8.py"
    script.write_text(_TRAFFIC_8DEV)
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(os.path.join(here, "..", "src")),
         os.path.abspath(here)]
    )
    out = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "traffic-8dev OK" in out.stdout


def test_vertex_sharding_needs_sharded_engine():
    from repro.core.api import CoreMaintainer
    from repro.graph.generators import erdos_renyi

    g = erdos_renyi(20, 40, seed=0)
    with pytest.raises(ValueError, match="vertex_sharding"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  vertex_sharding="range")
    with pytest.raises(ValueError, match="freelist"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  freelist="magic")
    # hierarchical ranking only differs across shards: accepting it on
    # the other engines would silently do nothing, so it must raise too
    with pytest.raises(ValueError, match="hierarchical"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  freelist="hierarchical")


def test_engine_config_matrix_rejected_at_construction():
    """Every invalid engine-configuration combination raises a
    construction-time ValueError NAMING the offending field — none may
    survive to a deep trace-time error or be silently ignored."""
    from repro.core.api import CoreMaintainer
    from repro.graph.generators import erdos_renyi

    g = erdos_renyi(20, 40, seed=0)
    bad = [
        (dict(engine="warp"), "engine"),
        (dict(vertex_sharding="diagonal"), "vertex_sharding"),
        (dict(freelist="magic"), "freelist"),
        (dict(frontier_exchange="rle"), "frontier_exchange"),
        # a mesh passed to an engine that never reads it
        (dict(engine="unified", mesh=jax.make_mesh((1,), ("data",))),
         "mesh"),
        (dict(engine="host", mesh=jax.make_mesh((1,), ("data",))),
         "mesh"),
        # combinations whose silent acceptance would do nothing
        (dict(engine="unified", vertex_sharding="range"),
         "vertex_sharding"),
        (dict(engine="host", freelist="hierarchical"), "hierarchical"),
        (dict(engine="sharded", frontier_exchange="sparse"),
         "frontier_exchange"),  # sparse without range vertex state
        (dict(engine="unified", frontier_exchange="sparse"),
         "frontier_exchange"),
        (dict(engine="sharded", vertex_sharding="range",
              frontier_cap=64), "frontier_cap"),  # cap without sparse
        (dict(engine="sharded", vertex_sharding="range",
              frontier_exchange="sparse", frontier_cap=-2),
         "frontier_cap"),
    ]
    for kw, field in bad:
        with pytest.raises(ValueError, match=field):
            CoreMaintainer.from_graph(g, capacity=128, **kw)
    # the valid corners of the matrix still construct
    CoreMaintainer.from_graph(g, capacity=128, engine="sharded",
                              vertex_sharding="range",
                              frontier_exchange="sparse")
    CoreMaintainer.from_graph(g, capacity=128, engine="sharded",
                              vertex_sharding="range",
                              frontier_exchange="sparse", frontier_cap=16)


def test_make_sharded_apply_rejects_bad_frontier_config():
    from repro.core.sharded import make_sharded_apply

    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="frontier_exchange"):
        make_sharded_apply(mesh, 16, 18, frontier_exchange="rle")
    with pytest.raises(ValueError, match="frontier_exchange"):
        make_sharded_apply(mesh, 16, 18, frontier_exchange="sparse")
    with pytest.raises(ValueError, match="frontier_cap"):
        make_sharded_apply(mesh, 16, 18, vertex_sharding="range",
                           frontier_exchange="sparse", frontier_cap=0)
    # a cap the bitmask exchange would silently ignore must raise too
    with pytest.raises(ValueError, match="frontier_cap"):
        make_sharded_apply(mesh, 16, 18, vertex_sharding="range",
                           frontier_cap=64)


def test_local_active_window_cannot_outrun_the_shard():
    """An oversized per-shard window (e.g. sized from the GLOBAL
    high-water mark) used to slice past the local shard and silently
    splice a SHORT slot table back together; it must raise loudly at
    the window boundary instead. The exact-boundary window still runs."""
    from repro.core.sharded import make_sharded_apply

    mesh = jax.make_mesh((1,), ("data",))
    n, cap = 8, 16

    def fresh_args():  # the engine donates its buffers — one set per call
        b = jnp.zeros(4, jnp.int32)
        ok = jnp.zeros(4, bool)
        return (jnp.zeros(cap, jnp.int32), jnp.zeros(cap, jnp.int32),
                jnp.zeros(cap, bool), jnp.zeros(n, jnp.int32),
                jnp.zeros(n, jnp.int64), jnp.int32(0),
                b, b, ok, b, b, ok)

    # window == per-shard capacity: legal, runs
    fn = make_sharded_apply(mesh, n, n + 2, local_active=cap)
    out = fn(*fresh_args())
    assert out[0].shape == (cap,)

    # one past the shard: loud ValueError naming the misconfiguration
    fn = make_sharded_apply(mesh, n, n + 2, local_active=cap + 1)
    with pytest.raises(ValueError, match="local_active"):
        fn(*fresh_args())
