"""Unit + traffic tests of the vertex-layout layer (core/vertex_layout.py).

Two kinds of claims:

* algebraic — ``RangeShardedVertices`` round-trips state/masks exactly
  (padding, bit-packing, owner slicing), and ``ReplicatedVertices`` off
  a mesh is the identity, so layout-generic fixpoint code degenerates to
  the original single-device program verbatim;

* traffic — per FIXPOINT ROUND the range layout's collectives are one
  reduce_scatter of the packed stats (each device receives
  O(n / n_shards) words — O(n) mesh-wide) plus bit-packed changed-vertex
  masks (ceil(n_owned / 8) bytes per shard per device), where the
  replicated layout psums the full [n]-sized stats to every device
  (O(n * n_shards) mesh-wide). Asserted from the trace-time accounting
  (``record_traffic``): a ``lax.while_loop`` body traces exactly once,
  so the records ARE the per-round collective budget — this is the
  acceptance check of the O(n + frontier-bits * d) traffic model
  (docs/DESIGN.md §4.2), and it runs without executing a single batch.
  The 8-shard numbers are pinned by the slow subprocess test below.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.remove import removal_fixpoint
from repro.core.vertex_layout import (
    RangeShardedVertices,
    ReplicatedVertices,
    make_layout,
    record_traffic,
)


def test_replicated_layout_is_identity_off_mesh():
    lay = ReplicatedVertices(7)
    x = jnp.arange(7, dtype=jnp.int32)
    m = x > 3
    assert lay.complete(x) is x
    assert lay.own(x) is x
    assert lay.gather_mask(m) is m
    assert lay.gather_state(x) is x
    assert bool(lay.any_owned(m))
    np.testing.assert_array_equal(
        np.asarray(lay.add_at(lay.zeros(), jnp.array([1, 1, 6]),
                              jnp.array([2, 3, 4], jnp.int32))),
        np.array([0, 5, 0, 0, 0, 0, 4], np.int32),
    )


def test_make_layout_factory():
    assert make_layout("replicated", 5, None).kind == "replicated"
    lay = make_layout("range", 10, "data", 4)
    assert lay.kind == "range" and lay.n_owned == 3 and lay.n_pad == 12
    with pytest.raises(ValueError):
        make_layout("range", 5, None)
    with pytest.raises(ValueError):
        make_layout("diagonal", 5, "data")


def test_range_layout_roundtrips_one_shard():
    """Pad/pack/slice bookkeeping on a 1-shard mesh with n not a byte
    multiple: complete == plain sum, gather(own(x)) == x, and the
    bit-packed mask round-trips exactly."""
    mesh = jax.make_mesh((1,), ("data",))
    n = 13
    lay = RangeShardedVertices(n, "data", 1)
    assert lay.n_owned == 13 and lay.n_pad == 13

    def kernel(stats, full, mask_bits):
        owned = lay.complete(stats)
        state = lay.gather_state(lay.own(full))
        mask = lay.gather_mask(lay.own(mask_bits))
        delta = lay.add_at(lay.zeros(), jnp.array([0, 12, 12]),
                           jnp.array([5, 1, 1], jnp.int32))
        return owned, state, mask, delta, lay.any_owned(lay.own(mask_bits))

    f = shard_map(
        kernel, mesh=mesh, in_specs=(P(), P(), P()),
        out_specs=(P("data"), P(), P(), P("data"), P()), check_vma=False,
    )
    stats = jnp.arange(n, dtype=jnp.int32)
    full = jnp.arange(n, dtype=jnp.int64) * 7 - 3
    mask = (jnp.arange(n) % 3) == 0
    owned, state, got_mask, delta, some = jax.jit(f)(stats, full, mask)
    np.testing.assert_array_equal(np.asarray(owned), np.asarray(stats))
    np.testing.assert_array_equal(np.asarray(state), np.asarray(full))
    np.testing.assert_array_equal(np.asarray(got_mask), np.asarray(mask))
    assert int(delta[0]) == 5 and int(delta[12]) == 2
    assert bool(some)


def _primitive_names(closed) -> set:
    """All primitive names in a (closed) jaxpr, nested jaxprs included."""
    names = set()

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            names.add(eqn.primitive.name)
            for val in eqn.params.values():
                vals = val if isinstance(val, (list, tuple)) else [val]
                for v in vals:
                    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                        walk(v.jaxpr)
                    elif hasattr(v, "eqns"):
                        walk(v)

    walk(closed.jaxpr)
    return names


def _trace_removal_round(vertex_sharding: str, n: int, cap: int,
                         mesh) -> list:
    """Trace (not run) the removal fixpoint under shard_map and return
    the layout collectives recorded for ONE loop round."""
    axis = "data"
    n_shards = dict(mesh.shape)[axis]
    layout = make_layout(
        "range" if vertex_sharding == "range" else "replicated",
        n, axis, n_shards,
    )
    stat_spec = P(axis) if vertex_sharding == "range" else P()

    def kernel(src, dst, valid, core, label):
        return removal_fixpoint(src, dst, valid, core, label, n, n + 2,
                                layout=layout)

    sm = shard_map(
        kernel, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(), P(), P(), stat_spec, stat_spec),
        check_vma=False,
    )
    src = jnp.zeros(cap, jnp.int32)
    dst = jnp.ones(cap, jnp.int32)
    valid = jnp.zeros(cap, bool)
    core = jnp.zeros(n, jnp.int32)
    label = jnp.zeros(n, jnp.int64)
    with record_traffic() as log:
        jaxpr = jax.make_jaxpr(sm)(src, dst, valid, core, label)
    return log, _primitive_names(jaxpr)


def test_per_round_traffic_replicated_vs_range():
    """The acceptance traffic model on a 1-shard mesh: the replicated
    layout psums the full [n, 3] stats each round; the range layout
    replaces that with ONE reduce_scatter (owned words) + ONE bit-packed
    mask gather — no [n]-sized integer array crosses the mesh inside a
    round. (The 8-shard byte counts are pinned by the subprocess test.)
    """
    n, cap = 24, 32
    mesh = jax.make_mesh((1,), ("data",))

    rep_log, rep_prims = _trace_removal_round("replicated", n, cap, mesh)
    rng_log, rng_prims = _trace_removal_round("range", n, cap, mesh)

    # replicated: exactly one vertex collective per round — the [n, 3]
    # int32 psum, every device receiving the full completed stats
    assert [t.op for t in rep_log] == ["psum"]
    assert rep_log[0].recv_bytes == n * 3 * 4
    assert "reduce_scatter" not in rep_prims

    # range: the stats arrive by reduce_scatter (owned slice only), the
    # decision comes back as a bit-packed mask, and nothing else moves
    assert [t.op for t in rng_log] == ["reduce_scatter", "gather_mask"]
    rs, gm = rng_log
    lay = RangeShardedVertices(n, "data", 1)
    assert rs.recv_bytes == lay.n_owned * 3 * 4
    assert gm.recv_bytes == 1 * -(-lay.n_owned // 8)  # n_shards * bytes
    # the collective-count cross-check straight off the jaxpr: the range
    # program really lowers to reduce_scatter + all_gather, and contains
    # no full-stat psum
    assert {"reduce_scatter", "all_gather"} <= rng_prims
    assert "psum" not in rng_prims


_TRAFFIC_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax

    import repro  # enables x64
    from test_vertex_layout import _trace_removal_round

    n, cap, d = 240, 512, 8
    mesh = jax.make_mesh((8,), ("data",))
    rep_log, _ = _trace_removal_round("replicated", n, cap, mesh)
    rng_log, _ = _trace_removal_round("range", n, cap, mesh)

    [psum] = rep_log
    rs, gm = rng_log
    # replicated: O(n) received per device, O(n * d) mesh-wide
    assert psum.recv_bytes == n * 3 * 4, psum
    # range: O(n / d) stat words per device -> O(n) mesh-wide ...
    assert rs.recv_bytes == (n // d) * 3 * 4, rs
    assert rs.recv_bytes * d == n * 3 * 4
    # ... plus the frontier bitmask: ceil(n/d/8) bytes per shard per
    # device — n bits per device, d * n BITS mesh-wide
    assert gm.recv_bytes == d * (-(-(n // d) // 8)), gm
    # the whole-mesh round budget: 8x fewer integer bytes, and the mask
    # adds only bits
    mesh_rep = psum.recv_bytes * d
    mesh_rng = rs.recv_bytes * d + gm.recv_bytes * d
    assert mesh_rng * 4 < mesh_rep, (mesh_rng, mesh_rep)
    print("traffic-8dev OK", mesh_rep, mesh_rng)
    """
)


@pytest.mark.slow
def test_per_round_traffic_8_shards(tmp_path):
    """8 forced host devices: the per-round byte counts of both layouts,
    asserted from trace-time accounting (no batch is executed)."""
    script = tmp_path / "traffic8.py"
    script.write_text(_TRAFFIC_8DEV)
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(os.path.join(here, "..", "src")),
         os.path.abspath(here)]
    )
    out = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "traffic-8dev OK" in out.stdout


def test_vertex_sharding_needs_sharded_engine():
    from repro.core.api import CoreMaintainer
    from repro.graph.generators import erdos_renyi

    g = erdos_renyi(20, 40, seed=0)
    with pytest.raises(ValueError, match="vertex_sharding"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  vertex_sharding="range")
    with pytest.raises(ValueError, match="freelist"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  freelist="magic")
    # hierarchical ranking only differs across shards: accepting it on
    # the other engines would silently do nothing, so it must raise too
    with pytest.raises(ValueError, match="hierarchical"):
        CoreMaintainer.from_graph(g, capacity=128, engine="unified",
                                  freelist="hierarchical")
